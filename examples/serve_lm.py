"""Serve a small LM with batched requests through the continuous-batching
engine (the paper is an inference macro, so serving is the end-to-end driver
for the LM stack; the SNN driver is train_sentiment_snn.py).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b --requests 6
"""
import sys

from repro.launch.serve import main

if __name__ == "__main__":
    main(sys.argv[1:])
