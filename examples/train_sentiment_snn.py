"""End-to-end driver: train the paper's IMDB sentiment SNN (Fig. 9b/10).

Architecture: GloVe-100d words -> encoder(100) -> FC128 -> FC128 -> 1 readout,
RMP neurons, 6-bit QAT weights, 11-bit V_MEM, 10 timesteps/word, membrane
state persists across words (the paper's sequential-memory mechanism).
29,312 trainable weights (paper: 29.3K).

Uses the real IMDB+GloVe if present on disk (data/imdb.py), else the
structure-matched synthetic task. A few hundred steps trains to >85% on the
synthetic task in a few minutes on CPU.

    PYTHONPATH=src python examples/train_sentiment_snn.py --steps 300
    PYTHONPATH=src python examples/train_sentiment_snn.py --trace   # Fig. 10
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.impulse_snn import IMDB
from repro.core import energy, pipeline, snn
from repro.data import imdb, make_sentiment_vocab, sentiment_batch
from repro.optim import adamw, apply_updates


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--words", type=int, default=12)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--trace", action="store_true", help="print Fig.10-style V trace")
    ap.add_argument("--backend", default="int_ref",
                    choices=["int_ref", "pallas"],
                    help="integer backend for the deployed-program eval")
    ap.add_argument("--interpret", action="store_true",
                    help="Pallas interpret mode (CPU containers)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    use_real = imdb.available()
    print(f"data: {'real IMDB+GloVe' if use_real else 'synthetic (structure-matched)'}")
    ds = None if use_real else make_sentiment_vocab(args.seed)
    if use_real:
        glove = imdb.load_glove()
        xs_all, ys_all = imdb.vectorize(imdb.load_reviews("train", 2000), glove,
                                        args.words)

    params = snn.init_fc_snn(jax.random.PRNGKey(args.seed), IMDB)
    print(f"trainable params: {snn.param_count(params)} (paper: 29.3K); "
          f"LSTM baseline: 247.8K (8.5x)")
    opt = adamw(lambda s: args.lr, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, aux), g = jax.value_and_grad(snn.sentiment_loss, has_aux=True)(
            params, x, y, IMDB)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss, aux["accuracy"]

    t0 = time.time()
    for s in range(args.steps):
        if use_real:
            idx = np.random.default_rng(s).integers(0, len(xs_all), args.batch)
            x, y = jnp.asarray(xs_all[idx]), jnp.asarray(ys_all[idx])
        else:
            xb, yb = sentiment_batch(ds, args.batch, args.words, seed=s)
            x, y = jnp.asarray(xb), jnp.asarray(yb)
        params, opt_state, loss, acc = step(params, opt_state, x, y)
        if (s + 1) % 25 == 0 or s == 0:
            print(f"step {s+1:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}"
                  f"  ({time.time()-t0:.0f}s)")

    # ---- eval: float QAT path vs deployed integer (macro) path ----
    xb, yb = sentiment_batch(ds, 512, args.words, seed=10_001) if not use_real \
        else (xs_all[:512], ys_all[:512])
    x, y = jnp.asarray(xb), jnp.asarray(yb)
    logits, _ = snn.sentiment_apply(params, x, IMDB)
    acc_f = float(jnp.mean((logits > 0) == (y > 0.5)))
    # deployed program: compile once, run on the chosen integer backend
    program = pipeline.compile_network(IMDB, params, domain="int")
    xs = pipeline.present_words(x, IMDB.timesteps)
    bkw = {"interpret": True} if (args.backend == "pallas" and
                                  (args.interpret or
                                   jax.default_backend() != "tpu")) else {}
    res = pipeline.run_network(program, xs, args.backend, **bkw)
    logits_i, rasters = res.logits[:, 0], res.rasters
    counts = pipeline.count_network_instructions(program, rasters)
    acc_i = float(jnp.mean((logits_i > 0) == (y > 0.5)))
    agree = float(jnp.mean((logits_i > 0) == (logits > 0)))
    print(f"\neval accuracy: float/QAT={acc_f:.4f}  "
          f"int-macro[{args.backend}]={acc_i:.4f} (agreement {agree:.3f})")

    sparsities = [1.0 - float(np.asarray(r).mean()) for r in rasters]
    print("per-layer spike sparsity (Fig.11a):",
          [f"{s:.3f}" for s in sparsities])
    e = energy.snn_energy_j(counts)
    n_inf = x.shape[0]
    print(f"macro energy for {n_inf} inferences: {e*1e9:.2f} nJ "
          f"({e/n_inf*1e12:.1f} pJ/inference) at point D")

    if args.trace:
        logits, aux = snn.sentiment_apply(params, x[:2], IMDB, return_trace=True)
        tr = np.asarray(aux["v_trace"])                      # (T_total, 2)
        print("\nFig.10 membrane trace (output neuron V per timestep):")
        for b in range(2):
            lab = "positive" if float(y[b]) > 0.5 else "negative"
            line = " ".join(f"{v:+.1f}" for v in tr[:: IMDB.timesteps, b])
            print(f"  true={lab:8s} V/word: {line}")
    return acc_f, acc_i


if __name__ == "__main__":
    main()
