"""Beyond-paper bridge: IMPULSE's spiking layer as a transformer FFN.

Trains a reduced llama3.2-style LM whose FFNs are rate-coded IF/RMP
populations with 6-bit QAT weights (models/spiking_ffn.py), then converts the
measured FFN spike sparsity into macro instruction counts and energy with the
paper-calibrated model — i.e. what the LM's FFN energy would be if its hidden
layers executed on (a grid of) IMPULSE macros.

    PYTHONPATH=src python examples/spiking_ffn_lm.py --steps 40
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ParallelConfig, RunConfig, ShapeConfig,
                                SpikingConfig, get_config, reduced_config)
from repro.core import energy, mapping
from repro.core.isa import InstrCount
from repro.data import lm_batch_fn
from repro.models import lm
from repro.train import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args(argv)

    base = reduced_config(get_config("llama3.2-1b"))
    cfg = dataclasses.replace(
        base, arch_id=base.arch_id + "-spikeffn",
        spiking=SpikingConfig(neuron="rmp", timesteps=8, threshold=0.5))
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    run = RunConfig(model=cfg, shape=shape,
                    parallel=ParallelConfig(remat="none", fsdp=False,
                                            seq_parallel=False),
                    optimizer="adamw", learning_rate=2e-3, warmup_steps=4)

    state, opt = init_train_state(jax.random.PRNGKey(0), run,
                                  total_steps=args.steps)
    step_fn = jax.jit(make_train_step(run, opt))
    fn = lm_batch_fn(cfg.vocab_size, args.batch, args.seq, seed=0)
    losses, t0 = [], time.time()
    for s in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in fn(s, 0, 1).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
        if (s + 1) % 10 == 0:
            print(f"step {s+1:3d} loss {losses[-1]:.4f} ({time.time()-t0:.0f}s)")
    print(f"loss: {losses[0]:.3f} -> {np.mean(losses[-5:]):.3f} "
          f"(spiking FFN trains: {np.mean(losses[-5:]) < losses[0]})")

    # measure FFN spike rate -> macro energy accounting
    batch = {k: jnp.asarray(v) for k, v in fn(999, 0, 1).items()}
    _, aux = lm.loss_fn(state.params, batch, cfg, run.parallel)
    rate = float(aux["aux"]) / cfg.n_layers           # mean spike rate/FFN
    sparsity = 1.0 - rate
    tokens = args.batch * args.seq
    tiles = mapping.fc_tiling(cfg.d_model, cfg.d_ff)
    T = cfg.spiking.timesteps
    events = rate * cfg.d_model * T * tokens * cfg.n_layers
    counts = InstrCount(acc_w2v=int(2 * events * tiles.col_tiles),
                        spike_check=2 * T * tokens * cfg.n_layers * tiles.col_tiles,
                        acc_v2v=2 * T * tokens * cfg.n_layers * tiles.col_tiles)
    e = energy.sequence_energy_j(counts)
    print(f"FFN spike sparsity: {sparsity:.3f} (paper's SNNs: ~0.85)")
    print(f"macro-mapped FFN energy: {e*1e9:.1f} nJ for {tokens} tokens "
          f"({e/tokens*1e12:.1f} pJ/token) at point D — "
          f"EDP reduction vs dense firing: {energy.edp_reduction(sparsity)*100:.1f}%")


if __name__ == "__main__":
    main()
