"""Long-context decode with a constant-size recurrent state (reduced RWKV6).

Demonstrates the IMPULSE principle at LM scale: the wkv state is a membrane
potential — O(1) memory per token regardless of context length, vs a KV cache
that grows linearly. Decodes far beyond any cache budget and reports state
sizes + tokens/s.

    PYTHONPATH=src python examples/long_context_rwkv.py --tokens 512
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig, get_config, reduced_config
from repro.models import lm


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tokens", type=int, default=512)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config("rwkv6-7b"))
    par = ParallelConfig(remat="none")
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)

    prompt = jnp.asarray(np.random.default_rng(0).integers(0, 64, (1, 16)),
                         jnp.int32)
    # max_len is irrelevant for rwkv (no KV cache) — state is O(1)
    logits, cache = lm.prefill(params, {"tokens": prompt}, cfg, 16, par)
    state_bytes = sum(x.size * x.dtype.itemsize
                      for x in jax.tree_util.tree_leaves(cache))
    print(f"recurrent state: {state_bytes/1e6:.2f} MB, CONSTANT in context length")
    full_cfg = get_config("rwkv6-7b")
    H, K = full_cfg.n_heads, full_cfg.rwkv.head_size
    full_state = full_cfg.n_layers * (H * K * K * 4 + 2 * full_cfg.d_model * 2)
    kv_at_500k = full_cfg.n_layers * 524288 * 8 * 64 * 2 * 2  # hypothetical GQA cache
    print(f"full rwkv6-7b state/stream: {full_state/1e6:.1f} MB vs GQA KV cache "
          f"@500k: {kv_at_500k/1e9:.1f} GB -> {kv_at_500k/full_state:.0f}x")

    step = jax.jit(lambda p, t, c: lm.decode_step(p, t, c, cfg, par))
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    t0 = time.perf_counter()
    for i in range(args.tokens):
        logits, cache = step(params, tok, cache)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    print(f"decoded {args.tokens} tokens in {dt:.2f}s "
          f"({args.tokens/dt:.1f} tok/s on CPU, reduced config)")
    print(f"context length now {int(cache['len'][0])}; state still "
          f"{state_bytes/1e6:.2f} MB")


if __name__ == "__main__":
    main()
