"""Quickstart: the IMPULSE macro end to end in 80 lines.

Maps a tiny spiking layer onto the bit-accurate macro model, runs the
in-memory instruction sequence for a few timesteps, cross-checks the
word-level ISA and the Pallas fused kernel, and prints the calibrated
energy/EDP numbers from the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import energy, isa, macro
from repro.kernels.fused_snn_step.ops import fused_snn_layer

rng = np.random.default_rng(0)

# --- 1. a 128-input x 12-neuron layer, 6-bit signed weights ----------------
wq = rng.integers(-31, 32, size=(isa.MACRO_IN, isa.MACRO_OUT)).astype(np.int8)
threshold, leak = 60, 2

bit_macro = macro.BitMacro.from_weights(wq, threshold=threshold, leak=leak)
state = isa.make_state(wq, threshold=threshold, leak=leak, clamp_mode="wrap")

# --- 2. run 5 timesteps of RMP neurons at ~85% input sparsity ---------------
print("timestep | spikes (bit-accurate macro) | ISA match | V match")
total = isa.InstrCount()
spike_raster = []
for t in range(5):
    in_spikes = rng.random(isa.MACRO_IN) < 0.15
    spike_raster.append(in_spikes)
    out_bits = bit_macro.timestep(0, in_spikes, "rmp")
    state, out_isa, cnt = isa.timestep(state, 0, in_spikes, "rmp")
    total += cnt
    ok_s = bool(np.array_equal(out_bits, np.asarray(out_isa)))
    ok_v = bool(np.array_equal(bit_macro.read_v(0), np.asarray(state.vmem[0])))
    print(f"   {t}     | {out_bits.astype(int)} | {ok_s} | {ok_v}")

# --- 3. same program through the Pallas fused kernel (TPU target) ----------
spikes = jnp.asarray(np.stack(spike_raster)[:, None, :].astype(np.int8))
out_k, v_k = fused_snn_layer(spikes, jnp.asarray(wq), threshold=threshold,
                             leak=leak, neuron="rmp", clamp_mode="wrap",
                             interpret=True)
print("\nPallas fused kernel matches bit-accurate macro:",
      bool(np.array_equal(np.asarray(v_k[0]), bit_macro.read_v(0))))

# --- 4. energy accounting (calibrated to the paper's silicon) ---------------
print(f"\ninstruction counts: {total}")
e = energy.sequence_energy_j(total)
d = energy.sequence_delay_s(total)
print(f"energy @0.85V/200MHz: {e*1e12:.1f} pJ | delay: {d*1e9:.1f} ns | "
      f"EDP: {e*d:.3e} J*s")
print(f"Fig.6  energy/update  IF={energy.neuron_update_energy_pj('if'):.2f} "
      f"LIF={energy.neuron_update_energy_pj('lif'):.2f} "
      f"RMP={energy.neuron_update_energy_pj('rmp'):.2f} pJ "
      "(paper: 1.81 / 2.67 / 1.68)")
print(f"Fig.11b EDP reduction @85% sparsity: "
      f"{energy.edp_reduction(0.85)*100:.1f}% (paper: ~97.4%)")
