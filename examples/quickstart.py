"""Quickstart: the IMPULSE macro end to end in ~100 lines.

Maps a tiny spiking layer onto the bit-accurate macro model, runs the
in-memory instruction sequence for a few timesteps, cross-checks the
word-level ISA — then compiles a whole NETWORK to an SNNProgram and runs it
on every execution backend (float / int_ref / fused-net Pallas / bitmacro),
verifying bit-identical spike rasters, and prints the calibrated energy/EDP
numbers from the paper.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import energy, isa, macro, pipeline, snn

rng = np.random.default_rng(0)

# --- 1. a 128-input x 12-neuron layer, 6-bit signed weights ----------------
wq = rng.integers(-31, 32, size=(isa.MACRO_IN, isa.MACRO_OUT)).astype(np.int8)
threshold, leak = 60, 2

bit_macro = macro.BitMacro.from_weights(wq, threshold=threshold, leak=leak)
state = isa.make_state(wq, threshold=threshold, leak=leak, clamp_mode="wrap")

# --- 2. run 5 timesteps of RMP neurons at ~85% input sparsity ---------------
print("timestep | spikes (bit-accurate macro) | ISA match | V match")
total = isa.InstrCount()
for t in range(5):
    in_spikes = rng.random(isa.MACRO_IN) < 0.15
    out_bits = bit_macro.timestep(0, in_spikes, "rmp")
    state, out_isa, cnt = isa.timestep(state, 0, in_spikes, "rmp")
    total += cnt
    ok_s = bool(np.array_equal(out_bits, np.asarray(out_isa)))
    ok_v = bool(np.array_equal(bit_macro.read_v(0), np.asarray(state.vmem[0])))
    print(f"   {t}     | {out_bits.astype(int)} | {ok_s} | {ok_v}")

# --- 3. a whole network as one compiled program, on every backend -----------
# encoder(24) -> FC 24x24 -> FC 24x12 -> readout 12x1, RMP neurons
cfg = SNNModelConfig(
    arch_id="quickstart", layer_sizes=(24, 24, 12, 1),
    spiking=SpikingConfig(neuron="rmp", timesteps=4, threshold=1.0,
                          leak=0.0625, w_bits=6, v_bits=11),
    timesteps=4)
params = snn.init_fc_snn(jax.random.PRNGKey(0), cfg)
x_words = jnp.asarray(rng.standard_normal((2, 3, 24)).astype(np.float32))
xs = pipeline.present_words(x_words, cfg.timesteps)

# wrap = raw silicon two's-complement arithmetic, the mode the bit-level
# macro implements (saturation is a word-level deployment policy)
program = pipeline.compile_network(cfg, params, domain="int", clamp_mode="wrap")
runs = {
    "float":    pipeline.run_network(program, xs, "float", collect_rasters=True),
    "int_ref":  pipeline.run_network(program, xs, "int_ref"),
    "pallas":   pipeline.run_network(program, xs, "pallas", interpret=True),
    "bitmacro": pipeline.run_network(program, xs, "bitmacro"),
}
ref = runs["int_ref"]
print("\nnetwork program on all backends (vs int_ref):")
for name, res in runs.items():
    ok = all(np.array_equal(np.asarray(a, np.int8), np.asarray(b))
             for a, b in zip(res.rasters, ref.rasters))
    ok &= bool(np.allclose(np.asarray(res.logits), np.asarray(ref.logits)))
    print(f"  {name:8s} rasters+logits match: {ok}")
counts = pipeline.count_network_instructions(program, ref.rasters)

# --- 4. energy accounting (calibrated to the paper's silicon) ---------------
print(f"\nsingle-macro instruction counts: {total}")
e = energy.sequence_energy_j(total)
d = energy.sequence_delay_s(total)
print(f"energy @0.85V/200MHz: {e*1e12:.1f} pJ | delay: {d*1e9:.1f} ns | "
      f"EDP: {e*d:.3e} J*s")
print(f"network program counts (energy-model input): {counts}")
print(f"Fig.6  energy/update  IF={energy.neuron_update_energy_pj('if'):.2f} "
      f"LIF={energy.neuron_update_energy_pj('lif'):.2f} "
      f"RMP={energy.neuron_update_energy_pj('rmp'):.2f} pJ "
      "(paper: 1.81 / 2.67 / 1.68)")
print(f"Fig.11b EDP reduction @85% sparsity: "
      f"{energy.edp_reduction(0.85)*100:.1f}% (paper: ~97.4%)")
