"""MNIST image classification with the paper's modified LeNet-5 SNN.

Conv1 (14ch, 3x3) is the spike encoder; Conv2,3 + FC1,2 map onto IMPULSE
(fan-in 3*3*14 = 126 <= 128, FC widths < 128). RMP neurons, 10 timesteps.
Real MNIST if on disk, else the synthetic stroke dataset.

    PYTHONPATH=src python examples/mnist_snn.py --steps 150
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.impulse_snn import MNIST
from repro.core import snn, mapping
from repro.data import mnist, mnist_like_batch
from repro.optim import adamw, apply_updates


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--lr", type=float, default=2e-3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    use_real = mnist.available()
    print(f"data: {'real MNIST' if use_real else 'synthetic strokes'}")
    if use_real:
        xs_all, ys_all = mnist.load("train")

    # macro mapping report (Fig. 3b)
    for name, t in (("conv2", mapping.conv_tiling(3, 14, 14, (14, 14))),
                    ("conv3", mapping.conv_tiling(3, 14, 14, (7, 7)))):
        print(f"{name}: fan-in {t.fan_in} <= 128, macros per position: {t.fc.n_macros}")
    for name, (i, o) in (("fc1", (686, 120)), ("fc2", (120, 84)), ("out", (84, 10))):
        t = mapping.fc_tiling(i, o)
        print(f"{name}: {i}->{o}, {t.row_tiles}x{t.col_tiles} = {t.n_macros} macros")

    params = snn.init_lenet_snn(jax.random.PRNGKey(args.seed), MNIST)
    opt = adamw(lambda s: args.lr, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, aux), g = jax.value_and_grad(snn.lenet_loss, has_aux=True)(
            params, x, y, MNIST)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss, aux["accuracy"]

    t0 = time.time()
    for s in range(args.steps):
        if use_real:
            idx = np.random.default_rng(s).integers(0, len(xs_all), args.batch)
            x, y = jnp.asarray(xs_all[idx]), jnp.asarray(ys_all[idx])
        else:
            xb, yb = mnist_like_batch(args.batch, seed=s)
            x, y = jnp.asarray(xb), jnp.asarray(yb)
        params, opt_state, loss, acc = step(params, opt_state, x, y)
        if (s + 1) % 25 == 0 or s == 0:
            print(f"step {s+1:4d}  loss {float(loss):.4f}  acc {float(acc):.3f}"
                  f"  ({time.time()-t0:.0f}s)")

    xb, yb = (xs_all[:512], ys_all[:512]) if use_real else mnist_like_batch(512, 9999)
    logits = snn.lenet_apply(params, jnp.asarray(xb), MNIST)
    acc = float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(yb)))
    print(f"\neval accuracy: {acc:.4f} (paper on real MNIST: 98.96%)")
    return acc


if __name__ == "__main__":
    main()
