"""Generate the §Dry-run and §Roofline tables of EXPERIMENTS.md from
artifacts/dryrun/*.json. Run after `python -m repro.launch.dryrun --all
--mesh both`. Output to stdout (paste/refresh into EXPERIMENTS.md)."""
from __future__ import annotations

import json
import sys
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load(mesh: str, tag: str = "") -> list[dict]:
    out = []
    d = ART / mesh
    for fp in sorted(d.glob("*.json")):
        if tag and not fp.stem.endswith(f"__{tag}"):
            continue
        if not tag and fp.stem.count("__") > 1:
            continue
        out.append(json.loads(fp.read_text()))
    return out


def fmt_bytes(b: float) -> str:
    return f"{b/2**30:.2f}"


def dryrun_table(mesh: str) -> str:
    rows = ["| arch | shape | chips | HLO GFLOPs/dev | GiB accessed/dev | "
            "coll GiB/dev (ag/ar/rs/a2a/cp) | peak GiB/dev | fits 16GiB |",
            "|---|---|---|---|---|---|---|---|"]
    for c in load(mesh):
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | - | - | - | - | - | "
                        f"SKIP: {c['skipped'].split(':')[0]} |")
            continue
        co = c["collectives"]
        coll = "/".join(f"{co[k]/2**30:.2f}" for k in
                        ("all-gather", "all-reduce", "reduce-scatter",
                         "all-to-all", "collective-permute"))
        rows.append(
            f"| {c['arch']} | {c['shape']} | {c['chips']} | "
            f"{c['flops_per_device']/1e9:.0f} | "
            f"{fmt_bytes(c['bytes_per_device'])} | {coll} | "
            f"{fmt_bytes(c['peak_bytes_per_device'])} | "
            f"{'yes' if c['fits_16GiB'] else 'NO'} |")
    return "\n".join(rows)


def roofline_table(mesh: str = "single", tag: str = "") -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant | "
            "roofline frac | MODEL/HLO flops | one-line lever |",
            "|---|---|---|---|---|---|---|---|---|"]
    for c in load(mesh, tag):
        if c.get("skipped"):
            rows.append(f"| {c['arch']} | {c['shape']} | - | - | - | SKIP | - | - | "
                        f"{c['skipped'].split(':')[0]} |")
            continue
        t = c["roofline_terms_s"]
        bound = max(t.values())
        frac = t["compute_s"] / bound if bound else 0
        dom = c["dominant"].replace("_s", "")
        lever = {
            "compute": "already compute-bound: reduce remat recompute / fuse",
            "memory": "raise arithmetic intensity: fuse elementwise chains, "
                      "bf16 stores, bigger tiles",
            "collective": "re-shard to cut cross-device bytes "
                          "(reduce-scatter grads, EP locality, SP boundaries)",
        }[dom]
        rows.append(
            f"| {c['arch']} | {c['shape']} | {t['compute_s']:.2e} | "
            f"{t['memory_s']:.2e} | {t['collective_s']:.2e} | {dom} | "
            f"{frac:.3f} | {c['useful_ratio']:.2f} | {lever} |")
    return "\n".join(rows)


def perf_table() -> str:
    """Hillclimb variants (tagged artifacts) vs their baselines."""
    rows = ["| arch | shape | tag | compute s | memory s | collective s | "
            "peak GiB | fits |", "|---|---|---|---|---|---|---|---|"]
    d = ART / "single"
    for fp in sorted(d.glob("*.json")):
        if fp.stem.count("__") != 2:            # tagged variants only
            continue
        c = json.loads(fp.read_text())
        if c.get("skipped"):
            continue
        tag = fp.stem.split("__")[-1]
        t = c["roofline_terms_s"]
        rows.append(f"| {c['arch']} | {c['shape']} | {tag} | "
                    f"{t['compute_s']:.2e} | {t['memory_s']:.2e} | "
                    f"{t['collective_s']:.2e} | "
                    f"{c['peak_bytes_per_device']/2**30:.1f} | "
                    f"{'yes' if c['fits_16GiB'] else 'no'} |")
    return "\n".join(rows)


if __name__ == "__main__":
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    if which in ("all", "dryrun"):
        print("### Dry-run: single-pod (16x16 = 256 chips)\n")
        print(dryrun_table("single"))
        print("\n### Dry-run: multi-pod (2x16x16 = 512 chips)\n")
        print(dryrun_table("multi"))
    if which in ("all", "roofline"):
        print("\n### Roofline (single-pod)\n")
        print(roofline_table("single"))
    if which in ("all", "perf"):
        print("\n### Perf variants (tagged)\n")
        print(perf_table())
