#!/usr/bin/env python
"""Repo invariant gate: AST lint of the library + static analysis of every
committed config (DESIGN.md §"Static verification").

    python tools/check_invariants.py               # the full CI gate
    python tools/check_invariants.py --lint-only   # AST lint, no jax import
    python tools/check_invariants.py --analyze-only
    python tools/check_invariants.py --mesh        # + mesh-contract rows
    python tools/check_invariants.py --trace       # + jaxpr trace matrix

Three parts, all blocking in CI:

  * lint — `repro.analysis.lint` over src/repro: no bare `assert` in
    library code (ANA001: `-O` strips them), no ad-hoc clamping to the
    11-bit V word outside core/quant.py (ANA002), no unseeded randomness
    in library paths (ANA003), no float casts in int-domain modules
    (ANA005). Pure stdlib; runs without jax.
  * analyze — compile every committed config (the two paper configs plus
    the benchmark/example geometries) and run the range pass + the
    kernel-contract pass for the backends each config is dispatched on.
    A config that cannot be *proven* overflow-free and contract-clean
    does not merge. ``--mesh`` additionally validates the mesh-execution
    contract rows (chain-preserving row split, per-shard VMEM) of the
    IMDB and LeNet5-mod geometries on the committed mesh shapes —
    statically, via dict-form meshes, so no forced host devices are
    needed.
  * trace (``--trace``) — `analysis.check_trace` over every committed
    config x every registered int backend: trace the real dispatch
    (batch/step/megastep and the mesh tick under an abstract mesh) to a
    jaxpr and prove dtype discipline, clamp count/placement/dominance,
    index bounds, and determinism; then close the static cost model's
    dense instruction counts exactly against the executed pipeline
    counter for the IMDB and LeNet5-mod geometries.

Exit status 0 iff every check passes; violations/errors are printed one
per line.
"""
from __future__ import annotations

import argparse
import pathlib
import sys

REPO = pathlib.Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "src"))

LINT_ROOT = REPO / "src" / "repro"


def run_lint() -> int:
    from repro.analysis import lint_paths
    violations = lint_paths([LINT_ROOT])
    for v in violations:
        print(v)
    print(f"lint: {len(violations)} violation(s) in {LINT_ROOT}")
    return len(violations)


def _committed_programs():
    """(name, program, {backend: contract_kw}) for every config this repo
    commits to executing — the paper configs plus the geometries the
    benchmarks and the quickstart build. Each backend carries the dispatch
    knobs it is actually run with (gating is a pallas_sparse knob, the
    dense-fallback crossover a pallas_events one)."""
    import jax

    from repro.configs.base import SpikingConfig
    from repro.configs.impulse_snn import IMDB, MNIST, SNNModelConfig
    from repro.core import pipeline, snn

    key = jax.random.PRNGKey(0)

    def _compile(cfg, init, **kw):
        # validate=False: this tool IS the validator; let it report the
        # failure with the config's name instead of dying inside compile
        return pipeline.compile_network(cfg, init(key, cfg), domain="int",
                                        validate=False, **kw)

    every_pallas = {"pallas": {}, "pallas_sparse": {}, "pallas_events": {}}
    yield ("imdb", _compile(IMDB, snn.init_fc_snn), every_pallas)
    yield ("mnist", _compile(MNIST, snn.init_lenet_snn), every_pallas)

    # benchmarks/sparsity_gating.py _conv_rows: event-gated LeNet slice
    gate = SNNModelConfig(
        arch_id="lenet-gate", conv_spec=((6, 3, 1), (8, 3, 2), (8, 3, 1)),
        in_shape=(10, 10, 1), layer_sizes=(5 * 5 * 8, 32, 4),
        spiking=SpikingConfig(neuron="if", timesteps=4, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=4, task="multiclass")
    yield ("lenet-gate", _compile(gate, snn.init_lenet_snn),
           {"pallas_sparse": {"gate_granularity": 2},
            "pallas_events": {"event_crossover": 0.25}})

    # benchmarks/fig9_efficiency.py: the LeNet5-mod energy-model program
    bench = SNNModelConfig(
        arch_id="lenet-bench", conv_spec=((8, 3, 1), (12, 3, 2)),
        in_shape=(12, 12, 1), layer_sizes=(6 * 6 * 12, 64, 10),
        spiking=SpikingConfig(neuron="rmp", timesteps=4, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=4, task="multiclass")
    yield ("lenet-bench", _compile(bench, snn.init_lenet_snn),
           {"pallas": {}})

    # examples/quickstart.py: the wrap-mode (raw silicon) program
    quick = SNNModelConfig(
        arch_id="quickstart", layer_sizes=(24, 24, 12, 1),
        spiking=SpikingConfig(neuron="rmp", timesteps=4, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=4)
    yield ("quickstart",
           _compile(quick, snn.init_fc_snn, clamp_mode="wrap"),
           {"pallas": {}, "bitmacro": {}})


#: mesh shapes the mesh suite / serving benchmark exercise on forced-host
#: devices — validated statically here as {axis: extent} dicts
MESH_SHAPES = ({"data": 4, "model": 1}, {"data": 1, "model": 4},
               {"data": 2, "model": 2})
#: geometries the mesh-execution contract rows are committed for: the IMDB
#: paper config and the LeNet5-mod benchmark program
MESH_PROGRAMS = ("imdb", "lenet-bench")


def run_analysis(mesh: bool = False) -> int:
    """Static analysis of every committed config; with ``mesh`` also the
    mesh-contract rows of `MESH_PROGRAMS` on each `MESH_SHAPES` entry."""
    from repro.analysis import (AnalysisError, check_kernel_contracts,
                                check_program)
    failures = 0
    for name, program, backends in _committed_programs():
        try:
            ranges = check_program(program)
            contracts = {b: check_kernel_contracts(program, b, **kw)
                         for b, kw in backends.items()}
        except AnalysisError as e:
            failures += 1
            print(f"analyze {name}: FAIL {type(e).__name__}: {e}")
            continue
        safe = ranges.max_safe_frames
        vmem = max(r.vmem_bytes for r in contracts.values())
        print(f"analyze {name}: ok — {len(ranges.layers)} layers in range "
              f"({program.clamp_mode}), max_safe_frames="
              f"{'unbounded' if safe is None else safe}, "
              f"vmem<={vmem}B across {sorted(contracts)}")
        if mesh and name in MESH_PROGRAMS:
            for shape in MESH_SHAPES:
                try:
                    rep = check_kernel_contracts(program, "pallas",
                                                 mesh=shape)
                except AnalysisError as e:
                    failures += 1
                    print(f"analyze {name} mesh {shape}: FAIL "
                          f"{type(e).__name__}: {e}")
                    continue
                rows = [c for c in rep.checks
                        if c.contract in ("mesh_axes", "mesh_split")]
                want = 1 + len(rep.calls)     # one axes row + one per call
                if len(rows) != want:
                    failures += 1
                    print(f"analyze {name} mesh {shape}: FAIL expected "
                          f"{want} mesh rows, got {len(rows)}")
                    continue
                print(f"analyze {name} mesh {shape}: ok — "
                      f"{len(rows)} mesh-contract row(s)")
    return failures


#: geometries whose static cost model must close exactly against the
#: executed pipeline instruction counter
CLOSURE_PROGRAMS = ("imdb", "lenet-bench")
#: the abstract mesh the trace matrix verifies the mesh tick under
TRACE_MESH = {"data": 2, "model": 2}


def run_trace() -> int:
    """Jaxpr trace matrix: every committed config x every registered int
    backend, all four surfaces under `TRACE_MESH`; plus exact cost-model
    closure for `CLOSURE_PROGRAMS`."""
    from repro.analysis import (TRACE_BACKENDS, AnalysisError,
                                check_cost_closure, check_trace)
    failures = 0
    for name, program, backends in _committed_programs():
        for b in TRACE_BACKENDS:
            try:
                rep = check_trace(program, b, mesh=TRACE_MESH,
                                  **backends.get(b, {}))
            except AnalysisError as e:
                failures += 1
                print(f"trace {name} x {b}: FAIL {type(e).__name__}: {e}")
                continue
            surfs = ",".join(s.surface for s in rep.surfaces)
            cost = rep.cost
            print(f"trace {name} x {b}: ok — [{surfs}] "
                  f"{len(rep.checks)} checks, macs={cost.macs}, "
                  f"hbm_bytes={cost.hbm_bytes}")
        if name in CLOSURE_PROGRAMS:
            try:
                check_cost_closure(program)
            except AnalysisError as e:
                failures += 1
                print(f"trace {name} closure: FAIL {type(e).__name__}: {e}")
                continue
            print(f"trace {name} closure: ok — dense instruction counts "
                  "close exactly against the executed pipeline")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--lint-only", action="store_true")
    ap.add_argument("--analyze-only", action="store_true")
    ap.add_argument("--mesh", action="store_true",
                    help="also validate mesh-execution contract rows for "
                         "the IMDB and LeNet5-mod geometries")
    ap.add_argument("--trace", action="store_true",
                    help="also trace every committed config on every int "
                         "backend and close the static cost model")
    args = ap.parse_args(argv)
    n = 0
    if not args.analyze_only:
        n += run_lint()
    if not args.lint_only:
        n += run_analysis(mesh=args.mesh)
        if args.trace:
            n += run_trace()
    if n:
        sys.exit(1)
    print("check_invariants: all clear")


if __name__ == "__main__":
    main()
