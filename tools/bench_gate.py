"""Benchmark regression gate: compare a fresh BENCH_quick.json against the
committed baseline and fail CI when the numbers that must not regress do.

    python tools/bench_gate.py BENCH_quick.json benchmarks/baseline_quick.json
    python tools/bench_gate.py BENCH_quick.json benchmarks/baseline_quick.json \
        --write-baseline     # intentional change: adopt current as baseline

Policy (what fails vs what only reports):

  * FAIL — a row present in the baseline is missing from the current run
    (benchmark coverage regressed), or any ``*_FAILED`` row is present.
  * FAIL — a skipped-work fraction dropped more than ``--abs-tol`` below
    its baseline: the event-gating keys (``skipped_tiles``,
    ``fc_skipped_tiles``, ``conv_skipped_tiles``, ``tile``, ``block<G>``,
    ``events``, ``skipped_rows``, ``pallas_events``) are the executed
    sparsity win this repo exists to keep;
    on the python/jax pin that generated the baseline they are
    deterministic (seeded rasters, seeded training), so a drop means
    gating got coarser or stopped firing. Gains are fine. Rows derived
    from float training are NOT bit-stable across jax versions — CI runs
    the hard gate only on the baseline leg of its matrix and keeps the
    other legs report-only.
  * FAIL — an instruction count (``instr``) drifted more than
    ``--rel-tol-instr`` in either direction, or a calibrated energy-model
    number (``energy``, ``E/op``, ``E/inference``, ``TOPS/W``,
    ``GOPS/mm2``, ``ours/theirs``, ``err``) drifted more than
    ``--rel-tol``: both are exact functions of the executed program and
    the paper's calibration, not of machine load.
  * FAIL — a traced cost-model number (``macs``, ``hbm_bytes``,
    the benchmarks/analysis_check.py rows) changed AT ALL: these are
    counted off the compiled jaxpr, so any drift is a real change to the
    dispatched computation — zero tolerance, no knob.
  * REPORT-ONLY — wall-clock (``us_per_call``, ``dense_us``, ``speedup``):
    CI CPUs are noisy and interpret-mode timing is not the target signal.
    Workload statistics (sparsities, frequencies, frame counts) and rows
    new in the current run are also report-only; regenerating the baseline
    adopts them.

Values parse from ``key=value`` tokens in the derived column; units
(``pJ``, ``nJ``, ``%``, ``x``, ``MHz``...) are stripped, ``a/b``
slash-lists compare elementwise.
"""
from __future__ import annotations

import argparse
import json
import re
import shutil
import sys

# keys whose drop below baseline - abs_tol fails the gate (prefix match for
# block2/block4/block8). skipped_rows is the serving engines' pooled
# per-slot row-skip fraction (benchmarks/serve_snn.py) — deterministic on
# the pin for the same reason the gating rows are (seeded rasters).
# pallas_events is the device event-list kernel's EXECUTED skip fraction
# (its own per-row counters, sparsity_gating granularity rows + the
# serve_snn device ledger) — a drop means the compaction path stopped
# skipping work it used to skip.
SKIP_FRACTION_KEYS = ("skipped_tiles", "fc_skipped_tiles",
                      "conv_skipped_tiles", "tile", "events",
                      "skipped_rows", "pallas_events")
SKIP_FRACTION_PREFIXES = ("block",)
# keys gated two-sided at rel_tol_instr / rel_tol. The measured_* /
# *_vs_dense spellings are the fig11 row keys — exact names, because
# compare() matches keys exactly
INSTR_KEYS = ("instr",)
CALIBRATED_KEYS = ("energy", "E/op", "E/inference", "EDP", "measured_EDP",
                   "TOPS/W", "GOPS/mm2", "ours/theirs", "err", "reduction",
                   "measured_reduction", "reduction_vs_dense")
# keys gated EXACTLY (zero tolerance): the trace cost model counts these
# off the compiled jaxpr (analysis.check_trace), so any change is a real
# change to the dispatched computation, never noise
TRACE_KEYS = ("macs", "hbm_bytes")

_NUM = re.compile(r"^[-+]?\d+(\.\d*)?([eE][-+]?\d+)?")


def _parse_value(tok: str):
    """'1.80pJ' -> 1.80, '0.040/0.020' -> [0.04, 0.02], else None."""
    if "/" in tok and not tok.replace(".", "").replace("/", "").isalpha():
        parts = [_parse_value(p) for p in tok.split("/")]
        if all(isinstance(p, float) for p in parts):
            return parts
    m = _NUM.match(tok)
    if m and m.group(0) not in ("", "-", "+"):
        rest = tok[m.end():]
        if rest == "" or rest.isalpha() or rest in ("%",):
            return float(m.group(0))
    return None


def parse_row(derived: str) -> dict:
    """key=value tokens of one derived column -> {key: float | [float]}."""
    out = {}
    for tok in derived.split():
        if "=" not in tok:
            continue
        key, _, val = tok.partition("=")
        parsed = _parse_value(val)
        if parsed is not None:
            out[key] = parsed
    return out


def _is_skip_key(key: str) -> bool:
    return key in SKIP_FRACTION_KEYS or any(
        key.startswith(p) and key[len(p):].isdigit()
        for p in SKIP_FRACTION_PREFIXES)


def _pairs(cur, base):
    """Element pairs of two parsed values; None when their shapes disagree
    (a slash-list losing elements is itself a regression, not a pass)."""
    cur = cur if isinstance(cur, list) else [cur]
    base = base if isinstance(base, list) else [base]
    if len(cur) != len(base):
        return None
    return zip(cur, base)


def compare(current: dict, baseline: dict, *, abs_tol: float = 0.05,
            rel_tol_instr: float = 0.02, rel_tol: float = 0.05
            ) -> tuple[list, list]:
    """Gate the current payload against the baseline. Returns
    (failures, notes) — both lists of human-readable strings; a non-empty
    failures list means the gate rejects the run."""
    failures, notes = [], []
    cur_rows = {r["name"]: r for r in current["rows"]}
    base_rows = {r["name"]: r for r in baseline["rows"]}
    for name in cur_rows:
        if name.endswith("_FAILED"):
            failures.append(f"{name}: benchmark crashed: "
                            f"{cur_rows[name]['derived']}")
    for name, brow in base_rows.items():
        if name.endswith("_FAILED"):
            continue                   # a broken baseline row gates nothing
        if name not in cur_rows:
            failures.append(f"{name}: row missing from current run "
                            "(benchmark coverage regressed)")
            continue
        cvals = parse_row(cur_rows[name]["derived"])
        bvals = parse_row(brow["derived"])
        for key, bval in bvals.items():
            if key not in cvals:
                failures.append(f"{name}: key {key!r} missing from current "
                                "derived column")
                continue
            cval = cvals[key]
            pairs = _pairs(cval, bval)
            if pairs is None:
                failures.append(
                    f"{name}: {key} value count changed vs baseline "
                    f"({cval} vs {bval}) — a benchmark stopped reporting "
                    "part of its sweep")
                continue
            for ci, bi in pairs:
                if _is_skip_key(key):
                    if ci < bi - abs_tol:
                        failures.append(
                            f"{name}: skipped-work fraction {key}={ci:.3f} "
                            f"dropped below baseline {bi:.3f} - {abs_tol}")
                    elif ci > bi + abs_tol:
                        notes.append(f"{name}: {key} improved "
                                     f"{bi:.3f} -> {ci:.3f}")
                elif key in TRACE_KEYS:
                    if ci != bi:
                        failures.append(
                            f"{name}: traced {key}={ci:g} != baseline "
                            f"{bi:g} — the compiled dispatch changed "
                            "(zero-tolerance key)")
                elif key in INSTR_KEYS or key in CALIBRATED_KEYS:
                    tol = rel_tol_instr if key in INSTR_KEYS else rel_tol
                    # true relative drift — no absolute floor, EDP rows
                    # live at 1e-20 J*s and would swamp any epsilon
                    drift = (abs(ci - bi) / abs(bi) if bi != 0
                             else float(ci != 0))
                    if drift > tol:
                        failures.append(
                            f"{name}: {key}={ci:g} drifted from baseline "
                            f"{bi:g} (> {tol:.0%} rel)")
                # anything else (wall-clock, workload stats): report-only
    for name in cur_rows:
        if name not in base_rows and not name.endswith("_FAILED"):
            notes.append(f"{name}: new row (not in baseline; regenerate "
                         "the baseline to gate it)")
    return failures, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("current", help="fresh BENCH_quick.json")
    ap.add_argument("baseline", help="committed benchmarks/baseline_quick.json")
    ap.add_argument("--abs-tol", type=float, default=0.05,
                    help="allowed drop of a skipped-work fraction")
    ap.add_argument("--rel-tol-instr", type=float, default=0.02,
                    help="allowed relative drift of instruction counts")
    ap.add_argument("--rel-tol", type=float, default=0.05,
                    help="allowed relative drift of calibrated energy rows")
    ap.add_argument("--write-baseline", action="store_true",
                    help="adopt the current run as the new baseline")
    args = ap.parse_args(argv)

    with open(args.current) as f:
        current = json.load(f)
    if args.write_baseline:
        # a payload with crashed benchmarks must never become the baseline:
        # compare() skips *_FAILED baseline rows, so adopting one would
        # silently and permanently drop those rows from gate coverage
        broken = [r["name"] for r in current["rows"]
                  if r["name"].endswith("_FAILED")]
        if current.get("failures", 0) or broken:
            print(f"bench_gate: refusing --write-baseline: current run has "
                  f"failures={current.get('failures', 0)} "
                  f"crashed rows={broken}")
            return 1
        shutil.copyfile(args.current, args.baseline)
        print(f"bench_gate: wrote {args.baseline} from {args.current}")
        return 0
    with open(args.baseline) as f:
        baseline = json.load(f)
    failures, notes = compare(current, baseline, abs_tol=args.abs_tol,
                              rel_tol_instr=args.rel_tol_instr,
                              rel_tol=args.rel_tol)
    for n in notes:
        print(f"bench_gate note: {n}")
    for f_ in failures:
        print(f"bench_gate FAIL: {f_}")
    if failures:
        print(f"bench_gate: {len(failures)} regression(s) vs {args.baseline}")
        return 1
    print(f"bench_gate: OK ({len(baseline['rows'])} baseline rows held)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
