"""Pallas TPU kernel: chunked RWKV6 recurrence with VMEM-resident state.

The (K, V) wkv state -- the "membrane potential" of the linear-attention
family -- stays in a VMEM scratch across the whole sequence (grid steps along
T revisit the same core sequentially), exactly the IMPULSE fused-array
structure: HBM traffic for the state is O(K*V) per head instead of
O(T*K*V). Each chunk does three MXU matmuls: (C,K)x(K,V) inter-chunk,
(C,K)x(K,C) intra-chunk decay attention, (C,C)x(C,V) value gather; K=V=64
pairs two heads per 128-lane tile when C is a multiple of 8.

Grid: (B*H, T // C). dimension_semantics = ("parallel", "arbitrary"): the T
axis must run sequentially (state carry), head-batch may be parallel.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, y_ref, sout_ref,
                 s_scratch, *, chunk: int):
    c = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(c == 0)
    def _init():
        s_scratch[...] = s0_ref[0].astype(jnp.float32)

    rr = r_ref[0].astype(jnp.float32)          # (C, K)
    kk = k_ref[0].astype(jnp.float32)
    vv = v_ref[0].astype(jnp.float32)          # (C, V)
    ww = w_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)           # (K,)

    C = chunk
    lw = jnp.log(ww)
    L = jnp.cumsum(lw, axis=0)
    Lx = L - lw
    r_d = rr * jnp.exp(Lx)
    k_d = kk * jnp.exp(-L)

    s = s_scratch[...]
    y_inter = jax.lax.dot_general(r_d, s, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)
    a = jax.lax.dot_general(r_d, k_d, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    bonus = jnp.sum(rr * u[None, :] * kk, axis=-1)           # (C,)
    a = jnp.where(ii > jj, a, 0.0) + jnp.where(ii == jj, bonus[:, None], 0.0)
    y = y_inter + jax.lax.dot_general(a, vv, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    y_ref[0] = y.astype(y_ref.dtype)

    ltot = L[C - 1]                                          # (K,)
    k2 = kk * jnp.exp(ltot[None, :] - L)
    s_new = jnp.exp(ltot)[:, None] * s + jax.lax.dot_general(
        k2, vv, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    s_scratch[...] = s_new

    @pl.when(c == n_chunks - 1)
    def _fin():
        sout_ref[0] = s_new.astype(sout_ref.dtype)


def wkv6_pallas(r, k, v, w, u, s0, *, chunk: int = 64,
                interpret: bool = False):
    """r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K); s0: (BH, K, V).
    T % chunk == 0. Returns (y (BH, T, V) f32, s_out (BH, K, V) f32)."""
    BH, T, K = r.shape
    V = v.shape[-1]
    grid = (BH, T // chunk)
    kern = functools.partial(_wkv6_kernel, chunk=chunk)
    y, s_out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, K), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K), lambda b, c: (b, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, V), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, K, V), lambda b, c: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((BH, T, V), jnp.float32),
            jax.ShapeDtypeStruct((BH, K, V), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary")) if not interpret else None,
        interpret=interpret,
    )(r, k, v, w, u, s0)
    return y, s_out
