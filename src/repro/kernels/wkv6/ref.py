"""Pure-jnp oracles for the RWKV6 (wkv) recurrence.

Sequential semantics per head (state S: (K, V), decay w_t in (0,1), bonus u):
    y_t = r_t @ (S + diag(u) k_t v_t^T)        # read with bonus on current token
    S   = diag(w_t) S + k_t v_t^T              # decay-then-accumulate update

This is IMPULSE's membrane update with a learned, data-dependent leak: S is
the membrane potential, w_t the leak, k v^T the synaptic accumulate.

Two references:
  * wkv6_sequential -- lax.scan over T, the ground-truth oracle;
  * wkv6_chunked    -- MXU-friendly chunked-parallel form (the algorithm the
    Pallas kernel implements); mathematically identical, float-reordered.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp


def wkv6_sequential(r, k, v, w, u, s0=None):
    """All of r,k,w: (BH, T, K); v: (BH, T, V); u: (BH, K).
    Returns (y (BH, T, V), s_final (BH, K, V))."""
    BH, T, K = r.shape
    V = v.shape[-1]
    s = jnp.zeros((BH, K, V), jnp.float32) if s0 is None else s0.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[..., :, None] * v_t[..., None, :]           # (BH, K, V)
        y = jnp.einsum("bk,bkv->bv", r_t, s + u[..., :, None] * kv)
        s = w_t[..., :, None] * s + kv
        return s, y

    xs = (jnp.moveaxis(r, 1, 0), jnp.moveaxis(k, 1, 0),
          jnp.moveaxis(v, 1, 0), jnp.moveaxis(w, 1, 0))
    s, ys = jax.lax.scan(step, s, xs)
    return jnp.moveaxis(ys, 0, 1), s


@partial(jax.jit, static_argnames=("chunk", "unroll"))
def wkv6_chunked(r, k, v, w, u, s0=None, chunk: int = 64, unroll: bool = False):
    """Chunked-parallel form. Same signature/returns as wkv6_sequential.
    T must be a multiple of ``chunk`` (ops.py pads). ``unroll`` unrolls the
    chunk loop (dry-run cost accounting — XLA cost analysis counts while-loop
    bodies once, so rolled loops understate FLOPs)."""
    BH, T, K = r.shape
    V = v.shape[-1]
    if T % chunk != 0:
        raise ValueError(f"wkv6 chunked form needs T % chunk == 0, got "
                         f"T={T}, chunk={chunk} (ops.py pads)")
    C = chunk
    f32 = jnp.float32
    r, k, v, w = (x.astype(f32) for x in (r, k, v, w))
    u = u.astype(f32)
    s = jnp.zeros((BH, K, V), f32) if s0 is None else s0.astype(f32)

    rc = r.reshape(BH, T // C, C, K)
    kc = k.reshape(BH, T // C, C, K)
    vc = v.reshape(BH, T // C, C, V)
    wc = w.reshape(BH, T // C, C, K)

    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    lower = ii > jj                                          # strictly causal
    diag = ii == jj

    def per_chunk(s, inp):
        rr, kk, vv, ww = inp                                  # (BH, C, *)
        lw = jnp.log(ww)                                      # (BH, C, K), <= 0
        L = jnp.cumsum(lw, axis=1)                            # inclusive
        Lx = L - lw                                           # exclusive
        r_d = rr * jnp.exp(Lx)                                # decayed receptance
        k_d = kk * jnp.exp(-L)                                # growth-compensated key
        y_inter = jnp.einsum("bck,bkv->bcv", r_d, s)
        A = jnp.einsum("bik,bjk->bij", r_d, k_d)
        bonus = jnp.einsum("bck,bck->bc", rr * u[:, None, :], kk)
        A = jnp.where(lower[None], A, 0.0) + jnp.where(diag[None], bonus[:, :, None], 0.0)
        y = y_inter + jnp.einsum("bij,bjv->biv", A, vv)
        Ltot = L[:, -1, :]                                    # (BH, K)
        k2 = kk * jnp.exp(Ltot[:, None, :] - L)
        s = jnp.exp(Ltot)[..., None] * s + jnp.einsum("bck,bcv->bkv", k2, vv)
        return s, y

    xs = (jnp.moveaxis(rc, 1, 0), jnp.moveaxis(kc, 1, 0),
          jnp.moveaxis(vc, 1, 0), jnp.moveaxis(wc, 1, 0))
    s, ys = jax.lax.scan(per_chunk, s, xs, unroll=(T // C) if unroll else 1)
    y = jnp.moveaxis(ys, 0, 1).reshape(BH, T, V)
    return y, s
