"""jit'd public wrapper for the wkv6 fused-state kernel.

Accepts model-layout tensors (B, T, H, K/V), handles T padding (padding steps
use w=1, k=r=0 so the state is untouched and outputs are dropped), and routes
to the Pallas kernel or the chunked pure-jnp path (identical math; used when
lowering for non-TPU backends and in the multi-pod dry-run)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.wkv6.kernel import wkv6_pallas
from repro.kernels.wkv6.ref import wkv6_chunked


@partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret", "unroll"))
def wkv6(r, k, v, w, u, s0=None, *, chunk: int = 64, use_pallas: bool = False,
         interpret: bool = False, unroll: bool = False):
    """r,k,w: (B, T, H, K); v: (B, T, H, V); u: (H, K);
    s0: optional (B, H, K, V) initial state (serving continuation).
    Returns (y (B, T, H, V) f32, s_out (B, H, K, V) f32)."""
    B, T, H, K = r.shape
    V = v.shape[-1]
    pad = (-T) % chunk

    def to_bh(x):
        return jnp.moveaxis(x, 2, 1).reshape(B * H, T, x.shape[-1])

    rb, kb, vb, wb = map(to_bh, (r, k, v, w))
    if pad:
        def zeros(x, d):
            return jnp.zeros((B * H, pad, d), x.dtype)
        rb = jnp.concatenate([rb, zeros(rb, K)], axis=1)
        kb = jnp.concatenate([kb, zeros(kb, K)], axis=1)
        vb = jnp.concatenate([vb, zeros(vb, V)], axis=1)
        wb = jnp.concatenate([wb, jnp.ones((B * H, pad, K), wb.dtype)], axis=1)
    ub = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    sb = (jnp.zeros((B * H, K, V), jnp.float32) if s0 is None
          else s0.reshape(B * H, K, V).astype(jnp.float32))

    if use_pallas:
        y, s_out = wkv6_pallas(rb.astype(jnp.float32), kb.astype(jnp.float32),
                               vb.astype(jnp.float32), wb.astype(jnp.float32),
                               ub.astype(jnp.float32), sb, chunk=chunk,
                               interpret=interpret)
    else:
        y, s_out = wkv6_chunked(rb, kb, vb, wb, ub, sb, chunk=chunk,
                                unroll=unroll)

    y = y[:, :T].reshape(B, H, T, V)
    y = jnp.moveaxis(y, 1, 2)                                # (B, T, H, V)
    return y, s_out.reshape(B, H, K, V)


def wkv6_decode_step(r, k, v, w, u, s):
    """Single-token decode: r,k,w (B, H, K); v (B, H, V); s (B, H, K, V).
    Returns (y (B, H, V), s'). This is the serving-path state update — one
    'AccW2V + leak' on the wkv membrane."""
    kv = k[..., :, None] * v[..., None, :]
    y = jnp.einsum("bhk,bhkv->bhv", r, s + u[None, :, :, None] * kv)
    s = w[..., :, None] * s + kv
    return y, s
