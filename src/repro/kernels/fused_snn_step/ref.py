"""Pure-jnp oracle for the fused SNN timestep kernel.

Semantics (integer domain, == isa.layer_timestep_int scanned over T):
  for t in range(T):
      v      = clamp11(v + spikes[t] @ W)
      if lif: v = clamp11(v - leak)
      fired  = v >= threshold
      if rmp: v = clamp11(where(fired, v - threshold, v))
      else:   v = where(fired, reset, v)
      out[t] = fired
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.isa import layer_timestep_int


def fused_snn_layer_ref(spikes: jax.Array, wq: jax.Array, *, neuron: str,
                        threshold: int, leak: int = 0, reset: int = 0,
                        clamp_mode: str = "saturate"
                        ) -> tuple[jax.Array, jax.Array]:
    """spikes: (T, B, N_in) int8/bool; wq: (N_in, N_out) int8.
    Returns (out_spikes (T, B, N_out) int8, v_final (B, N_out) int32)."""
    T, B, _ = spikes.shape
    v0 = jnp.zeros((B, wq.shape[1]), jnp.int32)

    def step(v, s_t):
        v, fired = layer_timestep_int(
            v, wq, s_t.astype(jnp.int32), neuron=neuron,
            threshold=jnp.int32(threshold), leak=jnp.int32(leak),
            reset=jnp.int32(reset), clamp_mode=clamp_mode)
        return v, fired.astype(jnp.int8)

    v_final, out = jax.lax.scan(step, v0, spikes)
    return out, v_final
