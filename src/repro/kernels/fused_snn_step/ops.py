"""jit'd public wrapper for the fused SNN timestep kernel (padding, dispatch,
and the pure-JAX fallback used on non-TPU backends / inside dry-runs)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_snn_step.kernel import fused_snn_pallas
from repro.kernels.fused_snn_step.ref import fused_snn_layer_ref

LANE = 128


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("neuron", "clamp_mode", "block_b", "block_n",
                                   "use_pallas", "interpret"))
def fused_snn_layer(spikes: jax.Array, wq: jax.Array, *, threshold: int,
                    leak: int = 0, reset: int = 0, neuron: str = "rmp",
                    clamp_mode: str = "saturate", block_b: int = 8,
                    block_n: int = 128, use_pallas: bool = True,
                    interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Run a full (T, B, N_in) spike raster through one spiking FC layer.

    Returns (out_spikes (T, B, N_out) int8, v_final (B, N_out) int32).
    ``use_pallas=False`` selects the pure-jnp reference path (identical
    semantics; used when lowering for meshes/backends without Pallas).
    """
    if not use_pallas:
        return fused_snn_layer_ref(
            spikes.astype(jnp.int8), wq, neuron=neuron, threshold=threshold,
            leak=leak, reset=reset, clamp_mode=clamp_mode)

    T, B, N_in = spikes.shape
    N_out = wq.shape[1]
    s = _pad_to(spikes.astype(jnp.int8), 2, LANE)
    s = _pad_to(s, 1, block_b)
    w = _pad_to(_pad_to(wq, 0, LANE), 1, block_n)
    params = jnp.array([threshold, leak, reset], jnp.int32)
    out, v = fused_snn_pallas(s, w, params, neuron=neuron,
                              clamp_mode=clamp_mode, block_b=block_b,
                              block_n=block_n, interpret=interpret)
    return out[:, :B, :N_out], v[:B, :N_out]
