"""Pallas TPU kernel: fused SNN timestep loop with VMEM-resident V_MEM.

This is the TPU-native realization of IMPULSE's fused W_MEM/V_MEM array:
the membrane-potential tile lives in VMEM (registers of the array, in macro
terms) across the ENTIRE timestep loop; weights are loaded HBM->VMEM once per
(batch, neuron) tile; the accumulate (AccW2V), leak (AccV2V), threshold
compare (SpikeCheck) and reset (ResetV) all execute in-kernel with no HBM
round-trip for V. HBM traffic for V: O(B*N) total instead of O(T*B*N).

Tiling: the macro's 128-row fan-in aligns with the MXU's 128-lane contraction;
spike activations are int8 {0,1} so the accumulate is an int8 x int8 -> int32
MXU matmul (the whole-row parallelism of the bitline adders).

Grid: (B // block_b, N_out // block_n); T is an in-kernel fori_loop so V never
leaves VMEM (grid dims would evict it).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.quant import clamp_v, spike_compare

NEURON_IDS = {"if": 0, "lif": 1, "rmp": 2}


def _snn_kernel(spikes_ref, w_ref, params_ref, out_ref, v_ref, *,
                neuron: str, clamp_mode: str, timesteps: int):
    """spikes_ref: (T, Bt, Nin) int8; w_ref: (Nin, Nt) int8;
    params_ref: (3,) int32 [threshold, leak, reset] (SMEM-like small operand);
    out_ref: (T, Bt, Nt) int8; v_ref: (Bt, Nt) int32 (final V, also the
    VMEM-resident accumulator via the carry)."""
    w = w_ref[...]
    threshold = params_ref[0]
    leak = params_ref[1]
    reset = params_ref[2]

    def body(t, v):
        s_in = spikes_ref[t]                                  # (Bt, Nin) int8
        # AccW2V: event-gated row accumulate == binary matmul on the MXU
        acc = jax.lax.dot_general(
            s_in, w, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        v = clamp_v(v + acc, clamp_mode)
        if neuron == "lif":                                   # AccV2V(-leak)
            v = clamp_v(v - leak, clamp_mode)
        fired = spike_compare(v, threshold, clamp_mode)       # SpikeCheck
        if neuron == "rmp":                                   # AccV2V(-th), gated
            v = clamp_v(jnp.where(fired, v - threshold, v), clamp_mode)
        else:                                                 # ResetV
            v = jnp.where(fired, reset, v)
        pl.store(out_ref, (pl.dslice(t, 1), slice(None), slice(None)),
                 fired.astype(jnp.int8)[None])
        return v

    v0 = jnp.zeros(v_ref.shape, jnp.int32)
    v_ref[...] = jax.lax.fori_loop(0, timesteps, body, v0)


def fused_snn_pallas(spikes: jax.Array, wq: jax.Array, params: jax.Array, *,
                     neuron: str, clamp_mode: str, block_b: int, block_n: int,
                     interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Dispatch the Pallas kernel. Shapes must be pre-padded:
    spikes (T, B, N_in) int8 with N_in % 128 == 0, B % block_b == 0;
    wq (N_in, N_out) int8 with N_out % block_n == 0; params (3,) int32."""
    T, B, N_in = spikes.shape
    N_out = wq.shape[1]
    grid = (B // block_b, N_out // block_n)
    kernel = functools.partial(_snn_kernel, neuron=neuron,
                               clamp_mode=clamp_mode, timesteps=T)
    out_spikes, v_final = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((T, block_b, N_in), lambda i, j: (0, i, 0)),
            pl.BlockSpec((N_in, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((3,), lambda i, j: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((T, block_b, block_n), lambda i, j: (0, i, j)),
            pl.BlockSpec((block_b, block_n), lambda i, j: (i, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((T, B, N_out), jnp.int8),
            jax.ShapeDtypeStruct((B, N_out), jnp.int32),
        ],
        interpret=interpret,
    )(spikes, wq, params)
    return out_spikes, v_final
