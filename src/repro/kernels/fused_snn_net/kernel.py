"""Pallas TPU kernel: the ENTIRE SNN stack fused into one kernel.

`fused_snn_step` realizes IMPULSE's W/V fusion within one layer; this kernel
is the network-level analogue of the paper's fused array. One `pallas_call`
executes encoder-spikes -> every spiking FC -> accumulate readout for the
whole `T_total` presentation:

  * every layer's V tile is a VMEM *scratch* buffer that persists across the
    in-kernel timestep loop — membrane potentials never visit HBM at all
    (not even once per layer as in per-layer dispatch);
  * inter-layer spike activations are kernel-local values: layer i's fired
    vector feeds layer i+1's MXU matmul in the same loop iteration, so the
    T*B*N spike traffic between layers also never touches HBM;
  * weights for ALL layers are loaded HBM->VMEM once per batch tile and
    stay resident (the IMDB stack is ~33 KB of int8 — V_MEM-sized).

HBM traffic: per-layer dispatch moves O(L*T*B*N) spike bytes + O(L*B*N) V
bytes; fused-net moves O(T*B*N_in) input + O(B*N) final V. The optional
raster outputs (`emit_rasters`, needed for event/energy accounting) add the
output spike stores back — serving uses emit_rasters=False.

Event-gated mode (``sparse=True``) is the execution-side realization of the
paper's sparsity claim (Fig. 11): per (timestep, layer, batch-tile) the
kernel reduces the in-VMEM int8 spike tile to an occupancy count and wraps
the MXU matmul + V accumulate in `@pl.when(count > 0)` — an all-silent tile
issues zero AccW2V work, exactly like silent input rows issue no AccW2V
cycles on silicon. The *neuron update* (leak / SpikeCheck / reset) still
runs every timestep: LIF leaks and RMP can re-fire with zero input, and the
macro's update sequence is unconditional too (the `u` term in the Fig. 11b
EDP model) — which is why gating stays bit-identical to the dense kernel.
Padded lanes/rows are zero-masked before occupancy is taken (their junk
spikes multiply zero weight rows, so masking changes no visible output but
keeps silence detection on logical lanes). Skipped-matmul counts per
(batch-tile, layer) come back as an extra output for the accounting layer.

Grid: (B // block_b,). The network dimension is NOT gridded: layer widths
are padded to the 128-lane MXU tile and the whole stack fits VMEM (the
macro's 128x12 geometry guarantees layer tiles are tiny). The timestep loop
is an in-kernel fori_loop — a grid dimension over T would evict V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import clamp_v, spike_compare

SKIP_LANES = 128    # skip-count output lane width (layer i in column i)


def _net_kernel(*refs, n_spiking: int, has_readout: bool, neuron: str,
                clamp_mode: str, timesteps: int, emit_rasters: bool,
                sparse: bool, logical_widths: tuple, batch_logical: int,
                block_b: int):
    """Ref layout (inputs, outputs, scratch):
      inputs : spikes_ref (T, Bt, N0p) int8; w_refs[i] (Nip, Nop) int8 for
               the n_spiking FCs (+ readout when has_readout); params_ref
               (n_spiking, 2) int32 rows of [threshold, leak];
      outputs: raster_refs[i] (T, Bt, Nop) int8 per spiking FC (only when
               emit_rasters); v_out_refs[i] (Bt, Nop) int32 per layer
               (readout last); skip_ref (1, SKIP_LANES) int32 (only when
               sparse) — skipped-matmul count of layer i in column i;
      scratch: v_refs[i] (Bt, Nop) int32 per layer — the fused V_MEM tiles.

    ``has_readout=False`` runs an all-spiking stack (no accumulate-only
    tail) — the shape conv layers lowered onto im2col patch rasters take.
    """
    n_w = n_spiking + (1 if has_readout else 0)
    spikes_ref = refs[0]
    w_refs = refs[1:1 + n_w]
    params_ref = refs[1 + n_w]
    pos = 2 + n_w
    raster_refs = refs[pos:pos + n_spiking] if emit_rasters else ()
    pos += n_spiking if emit_rasters else 0
    v_out_refs = refs[pos:pos + n_w]
    pos += n_w
    skip_ref = refs[pos] if sparse else None
    pos += 1 if sparse else 0
    v_refs = refs[pos:]

    ws = [w_refs[i][...] for i in range(n_w)]     # VMEM-resident weights
    for vref in v_refs:
        vref[...] = jnp.zeros_like(vref)
    if sparse:
        skip_ref[...] = jnp.zeros_like(skip_ref)
        b0 = pl.program_id(0) * block_b

    def mask_pad(x, n_logical):
        """Zero padded lanes (>= n_logical) and padded batch rows. Padded
        positions carry junk spikes whose downstream weight rows are zero —
        masking changes no visible output, but keeps the occupancy test on
        logical events only."""
        bt, n = x.shape
        lane_ok = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1) < n_logical
        row_ok = (jax.lax.broadcasted_iota(jnp.int32, (bt, n), 0) + b0
                  ) < batch_logical
        return jnp.where(lane_ok & row_ok, x, 0)

    def accumulate(i, cur):
        """AccW2V for a whole layer: binary matmul on the MXU. Returns the
        accumulated (clamped; readout unclamped) V value. Dense mode is
        pure compute — the caller stores V once after the neuron update.
        Sparse mode must go through the ref (only ref writes can be
        predicated): silent tiles skip the matmul + write entirely and the
        skip counter for layer i bumps instead."""
        if not sparse:
            acc = jax.lax.dot_general(cur, ws[i], (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.int32)
            v = v_refs[i][...] + acc
            return clamp_v(v, clamp_mode) if i < n_spiking else v
        occupied = jnp.sum(cur.astype(jnp.int32)) > 0

        @pl.when(occupied)
        def _do(i=i, cur=cur):
            acc = jax.lax.dot_general(cur, ws[i], (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.int32)
            v_refs[i][...] = clamp_v(v_refs[i][...] + acc, clamp_mode) \
                if i < n_spiking else v_refs[i][...] + acc

        @pl.when(jnp.logical_not(occupied))
        def _skip(i=i):
            col = jax.lax.broadcasted_iota(
                jnp.int32, (1, SKIP_LANES), 1) == i
            skip_ref[...] = skip_ref[...] + col.astype(jnp.int32)

        return v_refs[i][...]

    def body(t, carry):
        cur = spikes_ref[t]                                    # (Bt, N0p) int8
        if sparse:
            cur = mask_pad(cur, logical_widths[0])
        for i in range(n_spiking):
            v = accumulate(i, cur)
            if neuron == "lif":                                # AccV2V(-leak)
                v = clamp_v(v - params_ref[i, 1], clamp_mode)
            fired = spike_compare(v, params_ref[i, 0], clamp_mode)  # SpikeCheck
            if neuron == "rmp":                                # AccV2V(-th), gated
                v = clamp_v(jnp.where(fired, v - params_ref[i, 0], v),
                            clamp_mode)
            else:                                              # ResetV
                v = jnp.where(fired, 0, v)
            v_refs[i][...] = v
            cur = fired.astype(jnp.int8)                       # stays in VMEM
            if sparse:
                cur = mask_pad(cur, logical_widths[i + 1])
            if emit_rasters:
                pl.store(raster_refs[i],
                         (pl.dslice(t, 1), slice(None), slice(None)),
                         cur[None])
        if has_readout:
            # readout: wide int32 accumulate, no 11b clamp
            v_out = accumulate(n_spiking, cur)
            if not sparse:              # sparse mode already wrote the ref
                v_refs[n_spiking][...] = v_out
        return carry

    jax.lax.fori_loop(0, timesteps, body, 0)
    for i in range(n_w):
        v_out_refs[i][...] = v_refs[i][...]


def fused_snn_net_pallas(spikes: jax.Array, ws: list, params: jax.Array, *,
                         neuron: str, clamp_mode: str, block_b: int,
                         emit_rasters: bool, interpret: bool = False,
                         sparse: bool = False, logical_widths: tuple = (),
                         batch_logical: int = 0, has_readout: bool = True):
    """Dispatch the network kernel. Shapes must be pre-padded: spikes
    (T, B, N0p) int8 with B % block_b == 0; ws[i] (Nip, Nop) int8 with every
    dim a 128 multiple and Nip == previous Nop; params (n_spiking, 2) int32.
    ``has_readout=False`` treats every layer in ws as spiking (conv stacks
    lowered to patch rasters run this way — no accumulate-only tail).

    ``sparse`` selects the event-gated kernel; it needs ``logical_widths``
    (the pre-padding width of the input raster and of every layer's output,
    len(ws)+1 entries) and ``batch_logical`` (pre-padding B) to mask padding
    junk out of the occupancy test.

    Returns (rasters, v_finals, skips): rasters — list of (T, B, Nop) int8
    per spiking layer ([] when emit_rasters=False); v_finals — list of
    (B, Nop) int32 per layer, readout last; skips — (B // block_b, len(ws))
    int32 skipped-matmul counts per (batch tile, layer) in sparse mode,
    None otherwise.
    """
    T, B, _ = spikes.shape
    n_spiking = len(ws) - 1 if has_readout else len(ws)
    grid = (B // block_b,)
    if sparse and len(logical_widths) != len(ws) + 1:
        raise ValueError("sparse mode needs len(ws)+1 logical widths, got "
                         f"{len(logical_widths)} for {len(ws)} layers")
    kernel = functools.partial(
        _net_kernel, n_spiking=n_spiking, has_readout=has_readout,
        neuron=neuron, clamp_mode=clamp_mode, timesteps=T,
        emit_rasters=emit_rasters, sparse=sparse,
        logical_widths=tuple(logical_widths),
        batch_logical=batch_logical, block_b=block_b)

    in_specs = [pl.BlockSpec((T, block_b, spikes.shape[2]),
                             lambda b: (0, b, 0))]
    in_specs += [pl.BlockSpec(w.shape, lambda b: (0, 0)) for w in ws]
    in_specs += [pl.BlockSpec(params.shape, lambda b: (0, 0))]

    out_specs, out_shape = [], []
    if emit_rasters:
        for w in ws[:n_spiking]:
            out_specs.append(pl.BlockSpec((T, block_b, w.shape[1]),
                                          lambda b: (0, b, 0)))
            out_shape.append(jax.ShapeDtypeStruct((T, B, w.shape[1]), jnp.int8))
    for w in ws:
        out_specs.append(pl.BlockSpec((block_b, w.shape[1]), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, w.shape[1]), jnp.int32))
    if sparse:
        out_specs.append(pl.BlockSpec((1, SKIP_LANES), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B // block_b, SKIP_LANES),
                                              jnp.int32))

    scratch = [pltpu.VMEM((block_b, w.shape[1]), jnp.int32) for w in ws]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(spikes, *ws, params)
    outs = list(outs)
    skips = outs.pop()[:, :len(ws)] if sparse else None
    rasters = outs[:n_spiking] if emit_rasters else []
    v_finals = outs[n_spiking:] if emit_rasters else outs
    return rasters, v_finals, skips
