"""Pallas TPU kernel: the ENTIRE SNN stack fused into one kernel.

`fused_snn_step` realizes IMPULSE's W/V fusion within one layer; this kernel
is the network-level analogue of the paper's fused array. One `pallas_call`
executes encoder-spikes -> every spiking FC -> accumulate readout for the
whole `T_total` presentation:

  * every layer's V tile is a VMEM *scratch* buffer that persists across the
    in-kernel timestep loop — membrane potentials never visit HBM at all
    (not even once per layer as in per-layer dispatch);
  * inter-layer spike activations are kernel-local values: layer i's fired
    vector feeds layer i+1's MXU matmul in the same loop iteration, so the
    T*B*N spike traffic between layers also never touches HBM;
  * weights for ALL layers are loaded HBM->VMEM once per batch tile and
    stay resident (the IMDB stack is ~33 KB of int8 — V_MEM-sized).

HBM traffic: per-layer dispatch moves O(L*T*B*N) spike bytes + O(L*B*N) V
bytes; fused-net moves O(T*B*N_in) input + O(B*N) final V. The optional
raster outputs (`emit_rasters`, needed for event/energy accounting) add the
output spike stores back — serving uses emit_rasters=False.

Grid: (B // block_b,). The network dimension is NOT gridded: layer widths
are padded to the 128-lane MXU tile and the whole stack fits VMEM (the
macro's 128x12 geometry guarantees layer tiles are tiny). The timestep loop
is an in-kernel fori_loop — a grid dimension over T would evict V.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import clamp_v, spike_compare


def _net_kernel(*refs, n_spiking: int, neuron: str, clamp_mode: str,
                timesteps: int, emit_rasters: bool):
    """Ref layout (inputs, outputs, scratch):
      inputs : spikes_ref (T, Bt, N0p) int8; w_refs[i] (Nip, Nop) int8 for
               the n_spiking FCs + readout; params_ref (n_spiking, 2) int32
               rows of [threshold, leak];
      outputs: raster_refs[i] (T, Bt, Nop) int8 per spiking FC (only when
               emit_rasters); v_out_refs[i] (Bt, Nop) int32 per layer
               (readout last);
      scratch: v_refs[i] (Bt, Nop) int32 per layer — the fused V_MEM tiles.
    """
    n_w = n_spiking + 1
    spikes_ref = refs[0]
    w_refs = refs[1:1 + n_w]
    params_ref = refs[1 + n_w]
    pos = 2 + n_w
    raster_refs = refs[pos:pos + n_spiking] if emit_rasters else ()
    pos += n_spiking if emit_rasters else 0
    v_out_refs = refs[pos:pos + n_w]
    v_refs = refs[pos + n_w:]

    ws = [w_refs[i][...] for i in range(n_w)]     # VMEM-resident weights
    for vref in v_refs:
        vref[...] = jnp.zeros_like(vref)

    def body(t, carry):
        cur = spikes_ref[t]                                    # (Bt, N0p) int8
        for i in range(n_spiking):
            # AccW2V for the whole layer: binary matmul on the MXU
            acc = jax.lax.dot_general(
                cur, ws[i], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
            v = clamp_v(v_refs[i][...] + acc, clamp_mode)
            if neuron == "lif":                                # AccV2V(-leak)
                v = clamp_v(v - params_ref[i, 1], clamp_mode)
            fired = spike_compare(v, params_ref[i, 0], clamp_mode)  # SpikeCheck
            if neuron == "rmp":                                # AccV2V(-th), gated
                v = clamp_v(jnp.where(fired, v - params_ref[i, 0], v),
                            clamp_mode)
            else:                                              # ResetV
                v = jnp.where(fired, 0, v)
            v_refs[i][...] = v
            cur = fired.astype(jnp.int8)                       # stays in VMEM
            if emit_rasters:
                pl.store(raster_refs[i],
                         (pl.dslice(t, 1), slice(None), slice(None)),
                         cur[None])
        # readout: wide int32 accumulate, no 11b clamp
        v_refs[n_spiking][...] = v_refs[n_spiking][...] + jax.lax.dot_general(
            cur, ws[n_spiking], (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return carry

    jax.lax.fori_loop(0, timesteps, body, 0)
    for i in range(n_w):
        v_out_refs[i][...] = v_refs[i][...]


def fused_snn_net_pallas(spikes: jax.Array, ws: list, params: jax.Array, *,
                         neuron: str, clamp_mode: str, block_b: int,
                         emit_rasters: bool, interpret: bool = False):
    """Dispatch the network kernel. Shapes must be pre-padded: spikes
    (T, B, N0p) int8 with B % block_b == 0; ws[i] (Nip, Nop) int8 with every
    dim a 128 multiple and Nip == previous Nop; params (n_spiking, 2) int32.

    Returns (rasters, v_finals): rasters — list of (T, B, Nop) int8 per
    spiking layer ([] when emit_rasters=False); v_finals — list of
    (B, Nop) int32 per layer, readout last.
    """
    T, B, _ = spikes.shape
    n_spiking = len(ws) - 1
    grid = (B // block_b,)
    kernel = functools.partial(
        _net_kernel, n_spiking=n_spiking, neuron=neuron,
        clamp_mode=clamp_mode, timesteps=T, emit_rasters=emit_rasters)

    in_specs = [pl.BlockSpec((T, block_b, spikes.shape[2]),
                             lambda b: (0, b, 0))]
    in_specs += [pl.BlockSpec(w.shape, lambda b: (0, 0)) for w in ws]
    in_specs += [pl.BlockSpec(params.shape, lambda b: (0, 0))]

    out_specs, out_shape = [], []
    if emit_rasters:
        for w in ws[:-1]:
            out_specs.append(pl.BlockSpec((T, block_b, w.shape[1]),
                                          lambda b: (0, b, 0)))
            out_shape.append(jax.ShapeDtypeStruct((T, B, w.shape[1]), jnp.int8))
    for w in ws:
        out_specs.append(pl.BlockSpec((block_b, w.shape[1]), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, w.shape[1]), jnp.int32))

    scratch = [pltpu.VMEM((block_b, w.shape[1]), jnp.int32) for w in ws]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(spikes, *ws, params)
    rasters = list(outs[:n_spiking]) if emit_rasters else []
    v_finals = list(outs[n_spiking:] if emit_rasters else outs)
    return rasters, v_finals
