"""Pallas TPU kernel: the ENTIRE SNN stack fused into one kernel.

`fused_snn_step` realizes IMPULSE's W/V fusion within one layer; this kernel
is the network-level analogue of the paper's fused array. One `pallas_call`
executes encoder-spikes -> every spiking FC -> accumulate readout for the
whole `T_total` presentation:

  * every layer's V tile is a VMEM *scratch* buffer that persists across the
    in-kernel timestep loop — membrane potentials never visit HBM at all
    (not even once per layer as in per-layer dispatch);
  * inter-layer spike activations are kernel-local values: layer i's fired
    vector feeds layer i+1's MXU matmul in the same loop iteration, so the
    T*B*N spike traffic between layers also never touches HBM;
  * weights for ALL layers are loaded HBM->VMEM once per batch tile and
    stay resident (the IMDB stack is ~33 KB of int8 — V_MEM-sized).

HBM traffic: per-layer dispatch moves O(L*T*B*N) spike bytes + O(L*B*N) V
bytes; fused-net moves O(T*B*N_in) input + O(B*N) final V. The optional
raster outputs (`emit_rasters`, needed for event/energy accounting) add the
output spike stores back — serving uses emit_rasters=False.

Event-gated mode (``sparse=True``) is the execution-side realization of the
paper's sparsity claim (Fig. 11): per (timestep, layer, batch-tile) the
kernel reduces the in-VMEM int8 spike tile to occupancy counts and wraps
the MXU matmul + V accumulate in `@pl.when(count > 0)` — an all-silent tile
issues zero AccW2V work, exactly like silent input rows issue no AccW2V
cycles on silicon. ``granularity`` selects the gate's sub-tile resolution:
at 1 a layer's whole input tile is one gate (the original tile gate); at
G in {2, 4, 8} each 128-lane macro-row tile splits into G row blocks of
128/G lanes and every block's *partial* matmul is predicated independently.
Partial sums accumulate unclamped into the same V scratch and the 11-bit
clamp is applied once after the last block — exactly the dense kernel's
single clamp-after-accumulate, so row-block gating stays bit-identical in
both clamp modes (intermediate saturation would not commute). The *neuron
update* (leak / SpikeCheck / reset) still runs every timestep: LIF leaks
and RMP can re-fire with zero input, and the macro's update sequence is
unconditional too (the `u` term in the Fig. 11b EDP model). Padded
lanes/rows are zero-masked before occupancy is taken (their junk spikes
multiply zero weight rows, so masking changes no visible output but keeps
silence detection on logical lanes); row blocks made entirely of padding
are not emitted at all (a masked block contributes zero) and are excluded
from the skip count. Skipped-matmul counts per (batch-tile, gate site)
come back as an extra output — `skip_layout` defines the column map.

Grid: (B // block_b,). The network dimension is NOT gridded: layer widths
are padded to the 128-lane MXU tile and the whole stack fits VMEM (the
macro's 128x12 geometry guarantees layer tiles are tiny). The timestep loop
is an in-kernel fori_loop — a grid dimension over T would evict V.

Streaming entry (``v_init``): the V scratch tiles normally initialize to
zero — one call owns the whole presentation. For streaming execution
(core/pipeline `stream_step`, serve/snn_engine) the caller passes the
per-layer membrane state carried from the previous tick as extra inputs;
the kernel seeds its VMEM V tiles from them and runs the same loop for a
one-timestep (or any chunk-length) call. Because integer accumulation is
exact, chunked calls that thread V compose bit-identically with one full-T
call — the macro's "V_MEM never leaves the array" claim, restated at the
call boundary as "V leaves VMEM only between ticks".
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import clamp_v, spike_compare

LANE = 128              # MXU lane tile == the macro's 128-row fan-in
GATE_GRANULARITIES = (1, 2, 4, 8)
MAX_SKIP_COLS = 1024    # gate-site columns the skip output will carry


def skip_layout(in_widths: tuple, granularity: int
                ) -> tuple[tuple, tuple, int]:
    """Column map of the skip-count output: gate site (layer i, block g)
    reports in column ``offsets[i] + g``.

    ``in_widths``: per-layer *logical* (pre-padding) input widths. At
    granularity 1 every layer is one gate (whole input tile — the legacy
    layout, one column per layer); at G > 1 each layer has
    ceil(width / (128/G)) counted blocks — blocks living entirely in lane
    padding are never emitted, so they hold no column. Returns
    (n_cols per layer, column offsets per layer, padded lane width of the
    output). Raises a ValueError when the layout exceeds ``MAX_SKIP_COLS``
    (the former fixed 128-lane output silently truncated instead)."""
    if granularity not in GATE_GRANULARITIES:
        raise ValueError(f"gate granularity must be one of "
                         f"{GATE_GRANULARITIES}, got {granularity}")
    if granularity == 1:
        n_cols = tuple(1 for _ in in_widths)
    else:
        bw = LANE // granularity
        n_cols = tuple(-(-w // bw) for w in in_widths)
    total = sum(n_cols)
    if total > MAX_SKIP_COLS:
        raise ValueError(
            f"skip-count layout needs {total} gate columns "
            f"({len(in_widths)} layers at granularity {granularity}) but the "
            f"output carries at most MAX_SKIP_COLS={MAX_SKIP_COLS}; lower "
            "the granularity or split the stack")
    offsets, off = [], 0
    for n in n_cols:
        offsets.append(off)
        off += n
    lanes = max(LANE, -(-total // LANE) * LANE)
    return n_cols, tuple(offsets), lanes


def _net_kernel(*refs, n_spiking: int, has_readout: bool, neuron: str,
                clamp_mode: str, timesteps: int, emit_rasters: bool,
                sparse: bool, granularity: int, logical_widths: tuple,
                batch_logical: int, block_b: int, has_v_init: bool):
    """Ref layout (inputs, outputs, scratch):
      inputs : spikes_ref (T, Bt, N0p) int8; w_refs[i] (Nip, Nop) int8 for
               the n_spiking FCs (+ readout when has_readout); params_ref
               (n_spiking, 2) int32 rows of [threshold, leak];
               v_init_refs[i] (Bt, Nop) int32 per layer (only when
               has_v_init) — membrane state carried in from a previous
               streaming tick;
      outputs: raster_refs[i] (T, Bt, Nop) int8 per spiking FC (only when
               emit_rasters); v_out_refs[i] (Bt, Nop) int32 per layer
               (readout last); skip_ref (1, skip_lanes) int32 (only when
               sparse) — gate site (layer i, block g) counts skipped
               matmuls in column skip_layout offsets[i] + g;
      scratch: v_refs[i] (Bt, Nop) int32 per layer — the fused V_MEM tiles.

    ``has_readout=False`` runs an all-spiking stack (no accumulate-only
    tail) — the shape conv layers lowered onto im2col patch rasters take.
    """
    n_w = n_spiking + (1 if has_readout else 0)
    spikes_ref = refs[0]
    w_refs = refs[1:1 + n_w]
    params_ref = refs[1 + n_w]
    pos = 2 + n_w
    v_init_refs = refs[pos:pos + n_w] if has_v_init else ()
    pos += n_w if has_v_init else 0
    raster_refs = refs[pos:pos + n_spiking] if emit_rasters else ()
    pos += n_spiking if emit_rasters else 0
    v_out_refs = refs[pos:pos + n_w]
    pos += n_w
    skip_ref = refs[pos] if sparse else None
    pos += 1 if sparse else 0
    v_refs = refs[pos:]

    ws = [w_refs[i][...] for i in range(n_w)]     # VMEM-resident weights
    for i, vref in enumerate(v_refs):
        vref[...] = v_init_refs[i][...] if has_v_init else jnp.zeros_like(vref)
    if sparse:
        skip_ref[...] = jnp.zeros_like(skip_ref)
        b0 = pl.program_id(0) * block_b
        n_cols, col_off, skip_lanes = skip_layout(
            logical_widths[:n_w], granularity)

    def mask_pad(x, n_logical):
        """Zero padded lanes (>= n_logical) and padded batch rows. Padded
        positions carry junk spikes whose downstream weight rows are zero —
        masking changes no visible output, but keeps the occupancy test on
        logical events only."""
        bt, n = x.shape
        lane_ok = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1) < n_logical
        row_ok = (jax.lax.broadcasted_iota(jnp.int32, (bt, n), 0) + b0
                  ) < batch_logical
        return jnp.where(lane_ok & row_ok, x, 0)

    def accumulate(i, cur):
        """AccW2V for a whole layer: binary matmul on the MXU. Returns the
        accumulated (clamped; readout unclamped) V value. Dense mode is
        pure compute — the caller stores V once after the neuron update.
        Sparse mode must go through the ref (only ref writes can be
        predicated): each of the layer's row blocks (one at granularity 1)
        issues its partial matmul under `@pl.when(block occupied)`; silent
        blocks skip the MXU work entirely and bump their skip column.
        Partials add to V *unclamped*; one clamp after the last block
        equals the dense single clamp-after-accumulate bit for bit (and a
        fully silent layer reduces to clamp_v(v), which is idempotent)."""
        if not sparse:
            acc = jax.lax.dot_general(cur, ws[i], (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.int32)
            v = v_refs[i][...] + acc
            return clamp_v(v, clamp_mode) if i < n_spiking else v
        bw = ws[i].shape[0] if granularity == 1 else LANE // granularity
        upd = jnp.zeros_like(skip_ref)
        lane_iota = jax.lax.broadcasted_iota(jnp.int32, (1, skip_lanes), 1)
        for g in range(n_cols[i]):     # counted blocks cover logical lanes
            blk = cur[:, g * bw:(g + 1) * bw]
            occupied = jnp.sum(blk.astype(jnp.int32)) > 0

            @pl.when(occupied)
            def _do(i=i, g=g, blk=blk):
                acc = jax.lax.dot_general(
                    blk, ws[i][g * bw:(g + 1) * bw, :],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                v_refs[i][...] = v_refs[i][...] + acc

            upd = upd + jnp.where(lane_iota == col_off[i] + g,
                                  jnp.logical_not(occupied).astype(jnp.int32),
                                  0)
        skip_ref[...] = skip_ref[...] + upd
        v = v_refs[i][...]
        if i < n_spiking:
            v = clamp_v(v, clamp_mode)
        v_refs[i][...] = v
        return v

    def body(t, carry):
        cur = spikes_ref[t]                                    # (Bt, N0p) int8
        if sparse:
            cur = mask_pad(cur, logical_widths[0])
        for i in range(n_spiking):
            v = accumulate(i, cur)
            if neuron == "lif":                                # AccV2V(-leak)
                v = clamp_v(v - params_ref[i, 1], clamp_mode)
            fired = spike_compare(v, params_ref[i, 0], clamp_mode)  # SpikeCheck
            if neuron == "rmp":                                # AccV2V(-th), gated
                v = clamp_v(jnp.where(fired, v - params_ref[i, 0], v),
                            clamp_mode)
            else:                                              # ResetV
                v = jnp.where(fired, 0, v)
            v_refs[i][...] = v
            cur = fired.astype(jnp.int8)                       # stays in VMEM
            if sparse:
                cur = mask_pad(cur, logical_widths[i + 1])
            if emit_rasters:
                pl.store(raster_refs[i],
                         (pl.dslice(t, 1), slice(None), slice(None)),
                         cur[None])
        if has_readout:
            # readout: wide int32 accumulate, no 11b clamp
            v_out = accumulate(n_spiking, cur)
            if not sparse:              # sparse mode already wrote the ref
                v_refs[n_spiking][...] = v_out
        return carry

    jax.lax.fori_loop(0, timesteps, body, 0)
    for i in range(n_w):
        v_out_refs[i][...] = v_refs[i][...]


def fused_snn_net_pallas(spikes: jax.Array, ws: list, params: jax.Array, *,
                         neuron: str, clamp_mode: str, block_b: int,
                         emit_rasters: bool, interpret: bool = False,
                         sparse: bool = False, granularity: int = 1,
                         logical_widths: tuple = (),
                         batch_logical: int = 0, has_readout: bool = True,
                         v_init: list = None):
    """Dispatch the network kernel. Shapes must be pre-padded: spikes
    (T, B, N0p) int8 with B % block_b == 0; ws[i] (Nip, Nop) int8 with every
    dim a 128 multiple and Nip == previous Nop; params (n_spiking, 2) int32.
    ``has_readout=False`` treats every layer in ws as spiking (conv stacks
    lowered to patch rasters run this way — no accumulate-only tail).

    ``sparse`` selects the event-gated kernel; it needs ``logical_widths``
    (the pre-padding width of the input raster and of every layer's output,
    len(ws)+1 entries) and ``batch_logical`` (pre-padding B) to mask padding
    junk out of the occupancy test. ``granularity`` sets the gate's
    sub-tile resolution (`skip_layout`): 1 gates whole input tiles, G in
    {2, 4, 8} gates row blocks of 128/G lanes independently.

    ``v_init`` (streaming entry): per-layer (B, Nop) int32 membrane state,
    pre-padded like ws, seeding the VMEM V scratch instead of zeros — the
    carried state of a `stream_step` tick.

    Returns (rasters, v_finals, skips): rasters — list of (T, B, Nop) int8
    per spiking layer ([] when emit_rasters=False); v_finals — list of
    (B, Nop) int32 per layer, readout last; skips — (B // block_b, n_sites)
    int32 skipped-matmul counts per (batch tile, gate site) in sparse mode
    (site columns per `skip_layout`; n_sites == len(ws) at granularity 1),
    None otherwise.
    """
    T, B, _ = spikes.shape
    n_spiking = len(ws) - 1 if has_readout else len(ws)
    grid = (B // block_b,)
    if sparse and len(logical_widths) != len(ws) + 1:
        raise ValueError("sparse mode needs len(ws)+1 logical widths, got "
                         f"{len(logical_widths)} for {len(ws)} layers")
    if sparse:
        n_cols, _, skip_lanes = skip_layout(tuple(logical_widths[:len(ws)]),
                                            granularity)
    kernel = functools.partial(
        _net_kernel, n_spiking=n_spiking, has_readout=has_readout,
        neuron=neuron, clamp_mode=clamp_mode, timesteps=T,
        emit_rasters=emit_rasters, sparse=sparse, granularity=granularity,
        logical_widths=tuple(logical_widths),
        batch_logical=batch_logical, block_b=block_b,
        has_v_init=v_init is not None)

    in_specs = [pl.BlockSpec((T, block_b, spikes.shape[2]),
                             lambda b: (0, b, 0))]
    in_specs += [pl.BlockSpec(w.shape, lambda b: (0, 0)) for w in ws]
    in_specs += [pl.BlockSpec(params.shape, lambda b: (0, 0))]
    if v_init is not None:
        if len(v_init) != len(ws):
            raise ValueError(f"v_init needs one (B, Nop) state per layer "
                             f"({len(ws)}), got {len(v_init)}")
        in_specs += [pl.BlockSpec((block_b, w.shape[1]), lambda b: (b, 0))
                     for w in ws]

    out_specs, out_shape = [], []
    if emit_rasters:
        for w in ws[:n_spiking]:
            out_specs.append(pl.BlockSpec((T, block_b, w.shape[1]),
                                          lambda b: (0, b, 0)))
            out_shape.append(jax.ShapeDtypeStruct((T, B, w.shape[1]), jnp.int8))
    for w in ws:
        out_specs.append(pl.BlockSpec((block_b, w.shape[1]), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, w.shape[1]), jnp.int32))
    if sparse:
        out_specs.append(pl.BlockSpec((1, skip_lanes), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B // block_b, skip_lanes),
                                              jnp.int32))

    scratch = [pltpu.VMEM((block_b, w.shape[1]), jnp.int32) for w in ws]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(spikes, *ws, params, *(v_init if v_init is not None else ()))
    outs = list(outs)
    skips = outs.pop()[:, :sum(n_cols)] if sparse else None
    rasters = outs[:n_spiking] if emit_rasters else []
    v_finals = outs[n_spiking:] if emit_rasters else outs
    return rasters, v_finals, skips
