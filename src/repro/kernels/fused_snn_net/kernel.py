"""Pallas TPU kernel: the ENTIRE SNN stack fused into one kernel.

`fused_snn_step` realizes IMPULSE's W/V fusion within one layer; this kernel
is the network-level analogue of the paper's fused array. One `pallas_call`
executes encoder-spikes -> every spiking FC -> accumulate readout for the
whole `T_total` presentation:

  * every layer's V tile is a VMEM *scratch* buffer that persists across the
    in-kernel timestep loop — membrane potentials never visit HBM at all
    (not even once per layer as in per-layer dispatch);
  * inter-layer spike activations are kernel-local values: layer i's fired
    vector feeds layer i+1's MXU matmul in the same loop iteration, so the
    T*B*N spike traffic between layers also never touches HBM;
  * weights for ALL layers are loaded HBM->VMEM once per batch tile and
    stay resident (the IMDB stack is ~33 KB of int8 — V_MEM-sized).

HBM traffic: per-layer dispatch moves O(L*T*B*N) spike bytes + O(L*B*N) V
bytes; fused-net moves O(T*B*N_in) input + O(B*N) final V. The optional
raster outputs (`emit_rasters`, needed for event/energy accounting) add the
output spike stores back — serving uses emit_rasters=False.

Event-gated mode (``sparse=True``) is the execution-side realization of the
paper's sparsity claim (Fig. 11): per (timestep, layer, batch-tile) the
kernel reduces the in-VMEM int8 spike tile to occupancy counts and wraps
the MXU matmul + V accumulate in `@pl.when(count > 0)` — an all-silent tile
issues zero AccW2V work, exactly like silent input rows issue no AccW2V
cycles on silicon. ``granularity`` selects the gate's sub-tile resolution:
at 1 a layer's whole input tile is one gate (the original tile gate); at
G in {2, 4, 8} each 128-lane macro-row tile splits into G row blocks of
128/G lanes and every block's *partial* matmul is predicated independently.
Partial sums accumulate unclamped into the same V scratch and the 11-bit
clamp is applied once after the last block — exactly the dense kernel's
single clamp-after-accumulate, so row-block gating stays bit-identical in
both clamp modes (intermediate saturation would not commute). The *neuron
update* (leak / SpikeCheck / reset) still runs every timestep: LIF leaks
and RMP can re-fire with zero input, and the macro's update sequence is
unconditional too (the `u` term in the Fig. 11b EDP model). Padded
lanes/rows are zero-masked before occupancy is taken (their junk spikes
multiply zero weight rows, so masking changes no visible output but keeps
silence detection on logical lanes); row blocks made entirely of padding
are not emitted at all (a masked block contributes zero) and are excluded
from the skip count. Skipped-matmul counts per (batch-tile, gate site)
come back as an extra output — `skip_layout` defines the column map.

Grid: (B // block_b,). The network dimension is NOT gridded: layer widths
are padded to the 128-lane MXU tile and the whole stack fits VMEM (the
macro's 128x12 geometry guarantees layer tiles are tiny). The timestep loop
is an in-kernel fori_loop — a grid dimension over T would evict V.

Streaming entry (``v_init``): the V scratch tiles normally initialize to
zero — one call owns the whole presentation. For streaming execution
(core/pipeline `stream_step`, serve/snn_engine) the caller passes the
per-layer membrane state carried from the previous tick as extra inputs;
the kernel seeds its VMEM V tiles from them and runs the same loop for a
one-timestep (or any chunk-length) call. Because integer accumulation is
exact, chunked calls that thread V compose bit-identically with one full-T
call — the macro's "V_MEM never leaves the array" claim, restated at the
call boundary as "V leaves VMEM only between ticks".

Event-list mode (``events=True``) is the fully event-driven execution the
gated modes approximate: instead of predicating dense matmuls on tile /
row-block occupancy, each (timestep, layer, example) int8 spike frame is
*compacted* in VMEM and AccW2V becomes a gather-matvec over the active
rows only — executed work proportional to events at every sparsity
structure, including the iid-Bernoulli rasters that defeat tile and block
gates entirely (an 85%-sparse iid frame runs 15% of its row work here, vs
~100% under any block gate).

  Compaction layout: the inclusive prefix sum ``pos = cumsum(frame)`` over
  the padded n_in lanes IS the fixed-capacity active-row index list —
  entry p (0-based) of the list is the unique lane r with ``pos[r] == p+1``
  and ``frame[r] == 1``, decoded with a one-hot lane match; the list's
  count is ``pos[-1]`` and its capacity is the padded n_in (so no frame
  can overflow it). The occupancy-based early-out is the gather loop's
  dynamic trip count: a `fori_loop(0, count)` issues exactly ``count``
  weight-row gathers (`pl.ds` dynamic row loads from the VMEM-resident
  weight tile) and rank-1 accumulates into the V scratch — an all-silent
  frame issues zero AccW2V work without any gate test beyond the cumsum.

  Dense fallback (``event_crossover``): gathering beats the MXU only while
  frames are sparse. Per (timestep, layer, batch-tile), when the tile's
  event count exceeds ``event_crossover`` of its (block_b x logical-width)
  capacity, the whole tile falls back to the existing dense matmul under
  `@pl.when` — the same single-clamp-after-accumulate dense path, so the
  fallback is bit-identical by construction (integer addition commutes:
  gathering rows in ascending-index order equals the dense row sum
  exactly). Fallback trips are counted per layer in an extra output.

  Accounting: the kernel reduces every masked input frame to per-row event
  counts (an extra (tiles, n_in) output); summed over tiles these equal
  `events.EventStats.row_events` EXACTLY — the word-level per-row skip
  contract `ref_events` defines — independent of which execution path ran.
  Padded lanes and padded batch rows are zero-masked before compaction
  (junk spikes would gather zero weight rows — harmless numerically, but
  they would burn gather iterations and corrupt the event counts).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.quant import clamp_v, spike_compare

LANE = 128              # MXU lane tile == the macro's 128-row fan-in
GATE_GRANULARITIES = (1, 2, 4, 8)
MAX_SKIP_COLS = 1024    # gate-site columns the skip output will carry


def skip_layout(in_widths: tuple, granularity: int
                ) -> tuple[tuple, tuple, int]:
    """Column map of the skip-count output: gate site (layer i, block g)
    reports in column ``offsets[i] + g``.

    ``in_widths``: per-layer *logical* (pre-padding) input widths. At
    granularity 1 every layer is one gate (whole input tile — the legacy
    layout, one column per layer); at G > 1 each layer has
    ceil(width / (128/G)) counted blocks — blocks living entirely in lane
    padding are never emitted, so they hold no column. Returns
    (n_cols per layer, column offsets per layer, padded lane width of the
    output). Raises a ValueError when the layout exceeds ``MAX_SKIP_COLS``
    (the former fixed 128-lane output silently truncated instead)."""
    if granularity not in GATE_GRANULARITIES:
        raise ValueError(f"gate granularity must be one of "
                         f"{GATE_GRANULARITIES}, got {granularity}")
    if granularity == 1:
        n_cols = tuple(1 for _ in in_widths)
    else:
        bw = LANE // granularity
        n_cols = tuple(-(-w // bw) for w in in_widths)
    total = sum(n_cols)
    if total > MAX_SKIP_COLS:
        raise ValueError(
            f"skip-count layout needs {total} gate columns "
            f"({len(in_widths)} layers at granularity {granularity}) but the "
            f"output carries at most MAX_SKIP_COLS={MAX_SKIP_COLS}; lower "
            "the granularity or split the stack")
    offsets, off = [], 0
    for n in n_cols:
        offsets.append(off)
        off += n
    lanes = max(LANE, -(-total // LANE) * LANE)
    return n_cols, tuple(offsets), lanes


def _net_kernel(*refs, n_spiking: int, has_readout: bool, neuron: str,
                clamp_mode: str, timesteps: int, emit_rasters: bool,
                sparse: bool, granularity: int, logical_widths: tuple,
                batch_logical: int, block_b: int, has_v_init: bool,
                events: bool = False, dense_thresholds: tuple = ()):
    """Ref layout (inputs, outputs, scratch):
      inputs : spikes_ref (T, Bt, N0p) int8; w_refs[i] (Nip, Nop) int8 for
               the n_spiking FCs (+ readout when has_readout); params_ref
               (n_spiking, 2) int32 rows of [threshold, leak];
               v_init_refs[i] (Bt, Nop) int32 per layer (only when
               has_v_init) — membrane state carried in from a previous
               streaming tick;
      outputs: raster_refs[i] (T, Bt, Nop) int8 per spiking FC (only when
               emit_rasters); v_out_refs[i] (Bt, Nop) int32 per layer
               (readout last); skip_ref (1, skip_lanes) int32 (only when
               sparse) — gate site (layer i, block g) counts skipped
               matmuls in column skip_layout offsets[i] + g; in events
               mode instead row_refs[i] (1, Nip) int32 per layer — this
               tile's per-input-row event counts — then fallback_ref
               (1, LANE) int32, column i counting the timesteps layer i
               took the dense-crossover fallback;
      scratch: v_refs[i] (Bt, Nop) int32 per layer — the fused V_MEM tiles.

    ``has_readout=False`` runs an all-spiking stack (no accumulate-only
    tail) — the shape conv layers lowered onto im2col patch rasters take.
    ``events`` selects the compacted event-list execution of AccW2V (module
    docs); ``dense_thresholds[i]`` is the per-layer tile event count above
    which the dense fallback fires.
    """
    n_w = n_spiking + (1 if has_readout else 0)
    spikes_ref = refs[0]
    w_refs = refs[1:1 + n_w]
    params_ref = refs[1 + n_w]
    pos = 2 + n_w
    v_init_refs = refs[pos:pos + n_w] if has_v_init else ()
    pos += n_w if has_v_init else 0
    raster_refs = refs[pos:pos + n_spiking] if emit_rasters else ()
    pos += n_spiking if emit_rasters else 0
    v_out_refs = refs[pos:pos + n_w]
    pos += n_w
    skip_ref = refs[pos] if sparse else None
    pos += 1 if sparse else 0
    row_refs = refs[pos:pos + n_w] if events else ()
    pos += n_w if events else 0
    fallback_ref = refs[pos] if events else None
    pos += 1 if events else 0
    v_refs = refs[pos:]

    ws = [w_refs[i][...] for i in range(n_w)]     # VMEM-resident weights
    for i, vref in enumerate(v_refs):
        vref[...] = v_init_refs[i][...] if has_v_init else jnp.zeros_like(vref)
    if sparse or events:
        b0 = pl.program_id(0) * block_b
    if sparse:
        skip_ref[...] = jnp.zeros_like(skip_ref)
        n_cols, col_off, skip_lanes = skip_layout(
            logical_widths[:n_w], granularity)
    if events:
        for rref in row_refs:
            rref[...] = jnp.zeros_like(rref)
        fallback_ref[...] = jnp.zeros_like(fallback_ref)

    def mask_pad(x, n_logical):
        """Zero padded lanes (>= n_logical) and padded batch rows. Padded
        positions carry junk spikes whose downstream weight rows are zero —
        masking changes no visible output, but keeps the occupancy test on
        logical events only."""
        bt, n = x.shape
        lane_ok = jax.lax.broadcasted_iota(jnp.int32, (bt, n), 1) < n_logical
        row_ok = (jax.lax.broadcasted_iota(jnp.int32, (bt, n), 0) + b0
                  ) < batch_logical
        return jnp.where(lane_ok & row_ok, x, 0)

    def accumulate_events(i, cur):
        """Event-list AccW2V (module docs): compact each example's masked
        frame to (cumsum position map, count) and gather-accumulate the
        active weight rows with a dynamic-trip-count fori_loop — work
        proportional to events. Above the dense-crossover event count the
        whole tile falls back to one dense matmul. Both paths add to V
        *unclamped* through the ref (predicated writes must go through
        refs); one clamp after the accumulate — outside the `@pl.when`s —
        equals the dense single clamp-after-accumulate bit for bit. The
        per-row event counters accumulate unconditionally, so the
        accounting contract (== ref_events' EventStats) is independent of
        which path executed."""
        n_in_p = ws[i].shape[0]
        cur32 = cur.astype(jnp.int32)
        row_refs[i][...] = row_refs[i][...] + jnp.sum(cur32, axis=0,
                                                      keepdims=True)
        total = jnp.sum(cur32)
        go_dense = total > dense_thresholds[i]

        @pl.when(go_dense)
        def _dense(i=i, cur=cur):
            acc = jax.lax.dot_general(cur, ws[i], (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.int32)
            v_refs[i][...] = v_refs[i][...] + acc
            lane = jax.lax.broadcasted_iota(jnp.int32,
                                            fallback_ref.shape, 1)
            fallback_ref[...] = fallback_ref[...] + jnp.where(lane == i, 1, 0)

        @pl.when(jnp.logical_not(go_dense))
        def _gather(i=i, cur32=cur32, n_in_p=n_in_p):
            n_out_p = ws[i].shape[1]
            lanes = jax.lax.broadcasted_iota(jnp.int32, (1, n_in_p), 1)
            for b in range(block_b):
                s = cur32[b:b + 1, :]                    # (1, Nip) 0/1
                pos_map = jnp.cumsum(s, axis=1)          # the compacted list
                count = pos_map[0, n_in_p - 1]

                def ev_body(p, acc, s=s, pos_map=pos_map, lanes=lanes, i=i):
                    hit = (pos_map == p + 1) & (s > 0)   # one-hot lane match
                    idx = jnp.sum(jnp.where(hit, lanes, 0))
                    row = w_refs[i][pl.ds(idx, 1), :]    # gather one W row
                    return acc + row.astype(jnp.int32)

                acc_b = jax.lax.fori_loop(
                    0, count, ev_body,
                    jnp.zeros((1, n_out_p), jnp.int32))
                v_refs[i][b:b + 1, :] = v_refs[i][b:b + 1, :] + acc_b

        v = v_refs[i][...]
        if i < n_spiking:
            v = clamp_v(v, clamp_mode)
        v_refs[i][...] = v
        return v

    def accumulate(i, cur):
        """AccW2V for a whole layer: binary matmul on the MXU. Returns the
        accumulated (clamped; readout unclamped) V value. Dense mode is
        pure compute — the caller stores V once after the neuron update.
        Sparse mode must go through the ref (only ref writes can be
        predicated): each of the layer's row blocks (one at granularity 1)
        issues its partial matmul under `@pl.when(block occupied)`; silent
        blocks skip the MXU work entirely and bump their skip column.
        Partials add to V *unclamped*; one clamp after the last block
        equals the dense single clamp-after-accumulate bit for bit (and a
        fully silent layer reduces to clamp_v(v), which is idempotent)."""
        if events:
            return accumulate_events(i, cur)
        if not sparse:
            acc = jax.lax.dot_general(cur, ws[i], (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.int32)
            v = v_refs[i][...] + acc
            return clamp_v(v, clamp_mode) if i < n_spiking else v
        bw = ws[i].shape[0] if granularity == 1 else LANE // granularity
        upd = jnp.zeros_like(skip_ref)
        lane_iota = jax.lax.broadcasted_iota(jnp.int32, (1, skip_lanes), 1)
        for g in range(n_cols[i]):     # counted blocks cover logical lanes
            blk = cur[:, g * bw:(g + 1) * bw]
            occupied = jnp.sum(blk.astype(jnp.int32)) > 0

            @pl.when(occupied)
            def _do(i=i, g=g, blk=blk):
                acc = jax.lax.dot_general(
                    blk, ws[i][g * bw:(g + 1) * bw, :],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                v_refs[i][...] = v_refs[i][...] + acc

            upd = upd + jnp.where(lane_iota == col_off[i] + g,
                                  jnp.logical_not(occupied).astype(jnp.int32),
                                  0)
        skip_ref[...] = skip_ref[...] + upd
        v = v_refs[i][...]
        if i < n_spiking:
            v = clamp_v(v, clamp_mode)
        v_refs[i][...] = v
        return v

    def body(t, carry):
        cur = spikes_ref[t]                                    # (Bt, N0p) int8
        if sparse or events:
            cur = mask_pad(cur, logical_widths[0])
        for i in range(n_spiking):
            v = accumulate(i, cur)
            if neuron == "lif":                                # AccV2V(-leak)
                v = clamp_v(v - params_ref[i, 1], clamp_mode)
            fired = spike_compare(v, params_ref[i, 0], clamp_mode)  # SpikeCheck
            if neuron == "rmp":                                # AccV2V(-th), gated
                v = clamp_v(jnp.where(fired, v - params_ref[i, 0], v),
                            clamp_mode)
            else:                                              # ResetV
                v = jnp.where(fired, 0, v)
            v_refs[i][...] = v
            cur = fired.astype(jnp.int8)                       # stays in VMEM
            if sparse or events:
                cur = mask_pad(cur, logical_widths[i + 1])
            if emit_rasters:
                pl.store(raster_refs[i],
                         (pl.dslice(t, 1), slice(None), slice(None)),
                         cur[None])
        if has_readout:
            # readout: wide int32 accumulate, no 11b clamp
            v_out = accumulate(n_spiking, cur)
            if not sparse and not events:   # gated modes already wrote the ref
                v_refs[n_spiking][...] = v_out
        return carry

    jax.lax.fori_loop(0, timesteps, body, 0)
    for i in range(n_w):
        v_out_refs[i][...] = v_refs[i][...]


def fused_snn_net_pallas(spikes: jax.Array, ws: list, params: jax.Array, *,
                         neuron: str, clamp_mode: str, block_b: int,
                         emit_rasters: bool, interpret: bool = False,
                         sparse: bool = False, granularity: int = 1,
                         logical_widths: tuple = (),
                         batch_logical: int = 0, has_readout: bool = True,
                         v_init: list = None, events: bool = False,
                         event_crossover: float = 1.0):
    """Dispatch the network kernel. Shapes must be pre-padded: spikes
    (T, B, N0p) int8 with B % block_b == 0; ws[i] (Nip, Nop) int8 with every
    dim a 128 multiple and Nip == previous Nop; params (n_spiking, 2) int32.
    ``has_readout=False`` treats every layer in ws as spiking (conv stacks
    lowered to patch rasters run this way — no accumulate-only tail).

    ``sparse`` selects the event-gated kernel; it needs ``logical_widths``
    (the pre-padding width of the input raster and of every layer's output,
    len(ws)+1 entries) and ``batch_logical`` (pre-padding B) to mask padding
    junk out of the occupancy test. ``granularity`` sets the gate's
    sub-tile resolution (`skip_layout`): 1 gates whole input tiles, G in
    {2, 4, 8} gates row blocks of 128/G lanes independently.

    ``v_init`` (streaming entry): per-layer (B, Nop) int32 membrane state,
    pre-padded like ws, seeding the VMEM V scratch instead of zeros — the
    carried state of a `stream_step` tick.

    ``events`` selects the compacted event-list execution of AccW2V (module
    docs) — mutually exclusive with ``sparse``; needs the same
    ``logical_widths`` / ``batch_logical`` masking inputs. A tile whose
    event count exceeds ``event_crossover`` of its block_b x logical-width
    capacity takes the dense fallback (1.0 can never trip — strict >; 0.0
    always trips).

    Returns (rasters, v_finals, skips): rasters — list of (T, B, Nop) int8
    per spiking layer ([] when emit_rasters=False); v_finals — list of
    (B, Nop) int32 per layer, readout last; skips — (B // block_b, n_sites)
    int32 skipped-matmul counts per (batch tile, gate site) in sparse mode
    (site columns per `skip_layout`; n_sites == len(ws) at granularity 1);
    in events mode the pair (row_counts, fallbacks) with row_counts[i]
    (B // block_b, Nip) int32 per-input-row event counts per tile and
    fallbacks (B // block_b, len(ws)) int32 dense-fallback trip counts;
    None otherwise.
    """
    T, B, _ = spikes.shape
    n_spiking = len(ws) - 1 if has_readout else len(ws)
    grid = (B // block_b,)
    if sparse and events:
        raise ValueError("sparse (row-block gating) and events (event-list "
                         "execution) are mutually exclusive kernel modes")
    if (sparse or events) and len(logical_widths) != len(ws) + 1:
        raise ValueError("sparse/events mode needs len(ws)+1 logical widths, "
                         f"got {len(logical_widths)} for {len(ws)} layers")
    if sparse:
        n_cols, _, skip_lanes = skip_layout(tuple(logical_widths[:len(ws)]),
                                            granularity)
    dense_thresholds = ()
    if events:
        if len(ws) > LANE:
            raise ValueError(f"events mode carries one fallback column per "
                             f"layer in a {LANE}-lane output; got {len(ws)} "
                             "layers")
        # tile event capacity is block_b x logical input width; strict >
        # means crossover 1.0 never trips and 0.0 always does (count >= 0)
        dense_thresholds = tuple(
            int(event_crossover * block_b * logical_widths[i]) if
            event_crossover > 0.0 else -1
            for i in range(len(ws)))
    kernel = functools.partial(
        _net_kernel, n_spiking=n_spiking, has_readout=has_readout,
        neuron=neuron, clamp_mode=clamp_mode, timesteps=T,
        emit_rasters=emit_rasters, sparse=sparse, granularity=granularity,
        logical_widths=tuple(logical_widths),
        batch_logical=batch_logical, block_b=block_b,
        has_v_init=v_init is not None, events=events,
        dense_thresholds=dense_thresholds)

    in_specs = [pl.BlockSpec((T, block_b, spikes.shape[2]),
                             lambda b: (0, b, 0))]
    in_specs += [pl.BlockSpec(w.shape, lambda b: (0, 0)) for w in ws]
    in_specs += [pl.BlockSpec(params.shape, lambda b: (0, 0))]
    if v_init is not None:
        if len(v_init) != len(ws):
            raise ValueError(f"v_init needs one (B, Nop) state per layer "
                             f"({len(ws)}), got {len(v_init)}")
        in_specs += [pl.BlockSpec((block_b, w.shape[1]), lambda b: (b, 0))
                     for w in ws]

    out_specs, out_shape = [], []
    if emit_rasters:
        for w in ws[:n_spiking]:
            out_specs.append(pl.BlockSpec((T, block_b, w.shape[1]),
                                          lambda b: (0, b, 0)))
            out_shape.append(jax.ShapeDtypeStruct((T, B, w.shape[1]), jnp.int8))
    for w in ws:
        out_specs.append(pl.BlockSpec((block_b, w.shape[1]), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B, w.shape[1]), jnp.int32))
    if sparse:
        out_specs.append(pl.BlockSpec((1, skip_lanes), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B // block_b, skip_lanes),
                                              jnp.int32))
    if events:
        for w in ws:
            out_specs.append(pl.BlockSpec((1, w.shape[0]), lambda b: (b, 0)))
            out_shape.append(jax.ShapeDtypeStruct((B // block_b, w.shape[0]),
                                                  jnp.int32))
        out_specs.append(pl.BlockSpec((1, LANE), lambda b: (b, 0)))
        out_shape.append(jax.ShapeDtypeStruct((B // block_b, LANE), jnp.int32))

    scratch = [pltpu.VMEM((block_b, w.shape[1]), jnp.int32) for w in ws]

    outs = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=interpret,
    )(spikes, *ws, params, *(v_init if v_init is not None else ()))
    outs = list(outs)
    skips = None
    if sparse:
        skips = outs.pop()[:, :sum(n_cols)]
    elif events:
        fallbacks = outs.pop()[:, :len(ws)]
        row_counts = outs[-len(ws):]
        del outs[-len(ws):]
        skips = (row_counts, fallbacks)
    rasters = outs[:n_spiking] if emit_rasters else []
    v_finals = outs[n_spiking:] if emit_rasters else outs
    return rasters, v_finals, skips
