"""jit'd public wrapper for the network-level fused SNN kernel: padding,
dispatch, and the pure-JAX fallback for non-TPU backends.

Padding correctness: layer widths pad to the 128-lane tile. Padded *input*
lanes are harmless because the next layer's padded weight ROWS are zero, so
junk spikes fired by padded lanes (their V integrates only leak) contribute
exactly nothing downstream; rasters and V are sliced back to logical widths
before returning.

``use_sparse`` selects the event-gated execution path (see kernel.py): the
AccW2V matmul of a layer is skipped whenever its input tile is all-silent,
while the neuron update still runs every timestep — bit-identical to the
dense path by construction. Both the Pallas kernel and the pure-jnp
reference implement the gate (`@pl.when` / `lax.cond`), and both report
skipped-matmul counts for the accounting layer.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_snn_net.kernel import fused_snn_net_pallas

LANE = 128


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _check_stack(spikes: jax.Array, ws: list) -> None:
    """Chain-alignment on LOGICAL widths (padded widths can coincide for
    mismatched stacks): layer i's fan-in == layer i-1's fan-out. Raises
    (rather than asserts) so the contract survives ``python -O``."""
    if not ws:
        raise ValueError("fused_snn_net needs a non-empty weight stack "
                         "(spiking FCs first, readout last); got ws=[]")
    prev = spikes.shape[2]
    for i, w in enumerate(ws):
        if w.ndim != 2:
            raise ValueError(f"ws[{i}] must be a 2-D (n_in, n_out) weight "
                             f"matrix, got shape {w.shape}")
        if w.shape[0] != prev:
            raise ValueError(
                f"layer chain misaligned: ws[{i}] has fan-in {w.shape[0]} "
                f"but the previous layer emits {prev} lanes")
        prev = w.shape[1]


@partial(jax.jit, static_argnames=("thresholds", "leaks", "neuron",
                                   "clamp_mode", "block_b", "use_pallas",
                                   "interpret", "emit_rasters", "use_sparse",
                                   "readout"))
def fused_snn_net(spikes: jax.Array, ws: list, *, thresholds: tuple,
                  leaks: tuple, neuron: str = "rmp",
                  clamp_mode: str = "saturate", block_b: int = 8,
                  use_pallas: bool = True, interpret: bool = False,
                  emit_rasters: bool = True, use_sparse: bool = False,
                  readout: bool = True):
    """Run a (T, B, N0) encoder spike raster through the whole fc stack.

    ``ws``: per-layer int8 weights, spiking FCs first, readout last;
    ``thresholds``/``leaks``: per-spiking-layer ints on each layer's grid.
    ``readout=False`` runs an all-spiking stack — every layer in ``ws`` is a
    spiking FC (one threshold/leak each, no accumulate-only tail); conv
    layers lowered onto im2col patch rasters execute this way.
    Returns (rasters, v_finals, skips): per-spiking-layer output rasters
    (T, B, N_i) int8 (empty list when emit_rasters=False), per-layer
    final V (B, N_i) int32 (readout last), and — in ``use_sparse`` mode —
    skipped-matmul counts, (B_tiles, n_layers) int32 for the Pallas kernel
    (one row per batch tile) or (1, n_layers) for the reference (whose
    gate granularity is the whole batch); ``skips`` is None when dense.

    ``use_pallas=False`` selects a pure-jnp reference with identical
    semantics (scan of isa.layer_timestep_int over the stack).
    """
    thresholds, leaks = tuple(thresholds), tuple(leaks)
    _check_stack(spikes, ws)
    n_spiking = len(ws) - 1 if readout else len(ws)
    if len(thresholds) != n_spiking or len(leaks) != n_spiking:
        raise ValueError(
            f"need one threshold/leak per spiking layer ({n_spiking} with "
            f"readout={readout}), got {len(thresholds)}/{len(leaks)}")
    if not use_pallas:
        return _fused_snn_net_ref(spikes, ws, thresholds, leaks, neuron,
                                  clamp_mode, emit_rasters, use_sparse,
                                  readout)
    T, B, N0 = spikes.shape
    s = _pad_axis(_pad_axis(spikes.astype(jnp.int8), 2, LANE), 1, block_b)
    ws_p = [_pad_axis(_pad_axis(w.astype(jnp.int8), 0, LANE), 1, LANE)
            for w in ws]
    params = jnp.asarray([[t, l] for t, l in zip(thresholds, leaks)],
                         jnp.int32).reshape(len(thresholds), 2)
    rasters, v_finals, skips = fused_snn_net_pallas(
        s, ws_p, params, neuron=neuron, clamp_mode=clamp_mode,
        block_b=block_b, emit_rasters=emit_rasters, interpret=interpret,
        sparse=use_sparse, has_readout=readout,
        logical_widths=(N0,) + tuple(w.shape[1] for w in ws),
        batch_logical=B)
    rasters = [r[:, :B, :w.shape[1]]
               for r, w in zip(rasters, ws[:n_spiking])]
    v_finals = [v[:B, :w.shape[1]] for v, w in zip(v_finals, ws)]
    return rasters, v_finals, skips


def _fused_snn_net_ref(spikes, ws, thresholds, leaks, neuron, clamp_mode,
                       emit_rasters, use_sparse=False, readout=True):
    """Pure-jnp oracle: the word-level ISA scanned over the network. In
    ``use_sparse`` mode the AccW2V matmul of each layer is wrapped in a
    `lax.cond` on whole-batch occupancy (the reference's tile = the whole
    batch) and per-layer skipped-step counts ride along."""
    from repro.core.isa import layer_timestep_int, neuron_dynamics_int
    from repro.core.quant import clamp_v
    B = spikes.shape[1]
    n_w = len(ws)
    spiking_ws = ws[:-1] if readout else ws

    def gated_acc(v, w, cur):
        occupied = jnp.sum(cur) > 0
        v = jax.lax.cond(
            occupied,
            lambda v: clamp_v(v + cur @ w.astype(jnp.int32), clamp_mode),
            lambda v: v, v)
        return v, jnp.logical_not(occupied).astype(jnp.int32)

    def step(carry, s_t):
        vs, skips = list(carry[0]), carry[1]
        cur = s_t.astype(jnp.int32)
        rasters = []
        skipped = []
        for i, w in enumerate(spiking_ws):
            if use_sparse:
                v, sk = gated_acc(vs[i], w, cur)
                skipped.append(sk)
                vs[i], cur = neuron_dynamics_int(
                    v, neuron=neuron, threshold=jnp.int32(thresholds[i]),
                    leak=jnp.int32(leaks[i]), reset=jnp.int32(0),
                    clamp_mode=clamp_mode)
            else:
                vs[i], cur = layer_timestep_int(
                    vs[i], w, cur, neuron=neuron,
                    threshold=jnp.int32(thresholds[i]),
                    leak=jnp.int32(leaks[i]),
                    reset=jnp.int32(0), clamp_mode=clamp_mode)
            rasters.append(cur.astype(jnp.int8))
        if readout:
            if use_sparse:
                occupied = jnp.sum(cur) > 0
                vs[-1] = jax.lax.cond(
                    occupied,
                    lambda v: v + cur @ ws[-1].astype(jnp.int32),
                    lambda v: v, vs[-1])
                skipped.append(jnp.logical_not(occupied).astype(jnp.int32))
            else:
                vs[-1] = vs[-1] + cur @ ws[-1].astype(jnp.int32)
        if use_sparse:
            skips = skips + jnp.stack(skipped)
        return (tuple(vs), skips), tuple(rasters)

    vs0 = tuple(jnp.zeros((B, w.shape[1]), jnp.int32) for w in ws)
    skips0 = jnp.zeros((n_w,), jnp.int32)
    (vs, skips), rasters = jax.lax.scan(step, (vs0, skips0),
                                        spikes.astype(jnp.int8))
    return ((list(rasters) if emit_rasters else []), list(vs),
            skips[None] if use_sparse else None)
