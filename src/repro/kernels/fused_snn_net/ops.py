"""jit'd public wrapper for the network-level fused SNN kernel: padding,
dispatch, and the pure-JAX fallback for non-TPU backends.

Padding correctness: layer widths pad to the 128-lane tile. Padded *input*
lanes are harmless because the next layer's padded weight ROWS are zero, so
junk spikes fired by padded lanes (their V integrates only leak) contribute
exactly nothing downstream; rasters and V are sliced back to logical widths
before returning.

``use_sparse`` selects the event-gated execution path (see kernel.py): the
AccW2V matmul of a layer is skipped whenever its input tile is all-silent,
while the neuron update still runs every timestep — bit-identical to the
dense path by construction. ``gate_granularity`` refines the gate below
the tile: at G in {2, 4, 8} each 128-lane macro-row tile splits into G row
blocks whose partial matmuls are predicated independently (partials add
unclamped, one clamp after the last block — still bit-identical). Both the
Pallas kernel and the pure-jnp reference implement the gate (`@pl.when` /
`lax.cond`), and both report skipped-matmul counts for the accounting
layer: a (tiles, n_layers) array at granularity 1, a per-layer list of
(tiles, n_blocks_i) arrays at finer granularities (block counts vary with
each layer's fan-in — `kernel.skip_layout`).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

try:                                     # moved out of experimental in 0.6
    from jax.experimental.shard_map import shard_map
except ImportError:                      # pragma: no cover - newer jax
    from jax import shard_map

from repro.kernels.fused_snn_net.kernel import (fused_snn_net_pallas,
                                                skip_layout)

LANE = 128


def _ref_blocks(n_in: int, granularity: int) -> list:
    """Lane-block spans of one layer's logical input width — the same
    counted blocks `kernel.skip_layout` assigns skip columns to."""
    if granularity == 1:
        return [(0, n_in)]
    bw = LANE // granularity
    return [(lo, min(lo + bw, n_in)) for lo in range(0, n_in, bw)]


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _check_stack(spikes: jax.Array, ws: list) -> None:
    """Chain-alignment on LOGICAL widths (padded widths can coincide for
    mismatched stacks): layer i's fan-in == layer i-1's fan-out. Raises
    (rather than asserts) so the contract survives ``python -O``."""
    if not ws:
        raise ValueError("fused_snn_net needs a non-empty weight stack "
                         "(spiking FCs first, readout last); got ws=[]")
    prev = spikes.shape[2]
    for i, w in enumerate(ws):
        if w.ndim != 2:
            raise ValueError(f"ws[{i}] must be a 2-D (n_in, n_out) weight "
                             f"matrix, got shape {w.shape}")
        if w.shape[0] != prev:
            raise ValueError(
                f"layer chain misaligned: ws[{i}] has fan-in {w.shape[0]} "
                f"but the previous layer emits {prev} lanes")
        prev = w.shape[1]


@partial(jax.jit, static_argnames=("thresholds", "leaks", "neuron",
                                   "clamp_mode", "block_b", "use_pallas",
                                   "interpret", "emit_rasters", "use_sparse",
                                   "gate_granularity", "readout",
                                   "use_events", "event_crossover"))
def fused_snn_net(spikes: jax.Array, ws: list, *, thresholds: tuple,
                  leaks: tuple, neuron: str = "rmp",
                  clamp_mode: str = "saturate", block_b: int = 8,
                  use_pallas: bool = True, interpret: bool = False,
                  emit_rasters: bool = True, use_sparse: bool = False,
                  gate_granularity: int = 1, readout: bool = True,
                  v_init: list = None, use_events: bool = False,
                  event_crossover: float = 1.0):
    """Run a (T, B, N0) encoder spike raster through the whole fc stack.

    ``ws``: per-layer int8 weights, spiking FCs first, readout last;
    ``thresholds``/``leaks``: per-spiking-layer ints on each layer's grid.
    ``readout=False`` runs an all-spiking stack — every layer in ``ws`` is a
    spiking FC (one threshold/leak each, no accumulate-only tail); conv
    layers lowered onto im2col patch rasters execute this way.
    Returns (rasters, v_finals, skips): per-spiking-layer output rasters
    (T, B, N_i) int8 (empty list when emit_rasters=False), per-layer
    final V (B, N_i) int32 (readout last), and — in ``use_sparse`` mode —
    skipped-matmul counts; at ``gate_granularity`` 1 a (B_tiles, n_layers)
    int32 array for the Pallas kernel (one row per batch tile) or
    (1, n_layers) for the reference (whose tile is the whole batch); at
    granularity G in {2, 4, 8} a per-layer list of (B_tiles, n_blocks_i)
    arrays, one column per 128/G-lane row block of that layer's fan-in;
    ``skips`` is None when dense.

    ``use_pallas=False`` selects a pure-jnp reference with identical
    semantics (scan of isa.layer_timestep_int over the stack).

    ``v_init`` (streaming entry): per-layer (B, n_out) int32 membrane state
    (logical widths, readout last) resuming a previous call instead of
    starting from V = 0. Integer accumulation is exact, so splitting a
    presentation into chunks that thread final V back in as ``v_init``
    reproduces the single-call result bit for bit — the contract
    `core.pipeline.stream_step` is built on.

    ``use_events`` selects the Pallas event-list execution (kernel.py
    module docs): on-device compaction + gather-matvec AccW2V with a dense
    fallback above ``event_crossover`` occupancy. ``skips`` is then a dict
    ``{"row_events": [per-layer (B_tiles, n_in) int32 counts],
    "dense_fallbacks": (B_tiles, n_layers) int32}`` — wrap with
    `fused_snn_net_device_events` to get an `events.EventStats`.
    """
    thresholds, leaks = tuple(thresholds), tuple(leaks)
    _check_stack(spikes, ws)
    if v_init is not None and len(v_init) != len(ws):
        raise ValueError(f"v_init needs one (B, n_out) state per layer "
                         f"({len(ws)}), got {len(v_init)}")
    if gate_granularity != 1 and not use_sparse:
        raise ValueError("gate_granularity is an event-gating knob; pass "
                         "use_sparse=True to gate at granularity "
                         f"{gate_granularity}")
    if use_events and use_sparse:
        raise ValueError("use_events (event-list execution) and use_sparse "
                         "(row-block gating) are mutually exclusive")
    if use_events and not use_pallas:
        raise ValueError("use_events is the Pallas event-list kernel; the "
                         "host-side executor is events.fused_snn_net_events")
    if use_events and not 0.0 <= event_crossover <= 1.0:
        raise ValueError("event_crossover is a fraction of tile event "
                         f"capacity and must lie in [0, 1], got "
                         f"{event_crossover}")
    # validates granularity and enforces the gate-column cap for BOTH
    # execution paths (the reference mirrors the kernel's counted blocks)
    widths = (spikes.shape[2],) + tuple(w.shape[1] for w in ws)
    if use_sparse:
        n_blocks, _, _ = skip_layout(widths[:len(ws)], gate_granularity)
    n_spiking = len(ws) - 1 if readout else len(ws)
    if len(thresholds) != n_spiking or len(leaks) != n_spiking:
        raise ValueError(
            f"need one threshold/leak per spiking layer ({n_spiking} with "
            f"readout={readout}), got {len(thresholds)}/{len(leaks)}")
    if not use_pallas:
        return _fused_snn_net_ref(spikes, ws, thresholds, leaks, neuron,
                                  clamp_mode, emit_rasters, use_sparse,
                                  readout, gate_granularity, v_init)
    T, B, N0 = spikes.shape
    s = _pad_axis(_pad_axis(spikes.astype(jnp.int8), 2, LANE), 1, block_b)
    ws_p = [_pad_axis(_pad_axis(w.astype(jnp.int8), 0, LANE), 1, LANE)
            for w in ws]
    v_init_p = None
    if v_init is not None:
        # padded batch rows / lanes resume from 0 V, exactly as a
        # from-scratch call initializes them — padding junk stays invisible
        v_init_p = [_pad_axis(_pad_axis(v.astype(jnp.int32), 1, LANE),
                              0, block_b) for v in v_init]
    params = jnp.asarray([[t, lk] for t, lk in zip(thresholds, leaks)],
                         jnp.int32).reshape(len(thresholds), 2)
    rasters, v_finals, skips = fused_snn_net_pallas(
        s, ws_p, params, neuron=neuron, clamp_mode=clamp_mode,
        block_b=block_b, emit_rasters=emit_rasters, interpret=interpret,
        sparse=use_sparse, granularity=gate_granularity, has_readout=readout,
        logical_widths=widths, batch_logical=B, v_init=v_init_p,
        events=use_events, event_crossover=event_crossover)
    rasters = [r[:, :B, :w.shape[1]]
               for r, w in zip(rasters, ws[:n_spiking])]
    v_finals = [v[:B, :w.shape[1]] for v, w in zip(v_finals, ws)]
    if use_events:
        row_counts, fallbacks = skips
        skips = {"row_events": [rc[:, :w.shape[0]]      # logical rows only
                                for rc, w in zip(row_counts, ws)],
                 "dense_fallbacks": fallbacks}
    if use_sparse and gate_granularity != 1:
        split, off = [], 0
        for n in n_blocks:             # site columns -> per-layer arrays
            split.append(skips[:, off:off + n])
            off += n
        skips = split
    return rasters, v_finals, skips


def fused_snn_net_device_events(spikes, ws, *, thresholds: tuple,
                                leaks: tuple, neuron: str = "rmp",
                                clamp_mode: str = "saturate",
                                block_b: int = 8, interpret: bool = False,
                                emit_rasters: bool = True,
                                readout: bool = True, v_init: list = None,
                                event_crossover: float = 1.0):
    """`fused_snn_net(use_events=True)` with the device counters folded into
    an `events.EventStats` — the same third-element contract the host
    `events.fused_snn_net_events` executor returns, so the accounting layer
    (`core.pipeline._attach_event_stats`) treats both identically.

    Not jit'd (the jit boundary is the inner `fused_snn_net` call): the
    per-tile int32 row counts come off device here and sum to host int64 —
    per-layer totals over a long presentation overflow int32 at scale, and
    `EventStats.row_events` is specified int64.
    """
    import numpy as np

    from repro.kernels.fused_snn_net.events import EventStats

    rasters, v_finals, skips = fused_snn_net(
        spikes, ws, thresholds=tuple(thresholds), leaks=tuple(leaks),
        neuron=neuron, clamp_mode=clamp_mode, block_b=block_b,
        use_pallas=True, interpret=interpret, emit_rasters=emit_rasters,
        readout=readout, v_init=v_init, use_events=True,
        event_crossover=event_crossover)
    T, B = spikes.shape[0], spikes.shape[1]
    row_events = tuple(np.asarray(rc, np.int64).sum(axis=0)
                       for rc in skips["row_events"])
    fallbacks = tuple(int(c) for c in
                      np.asarray(skips["dense_fallbacks"],
                                 np.int64).sum(axis=0))
    stats = EventStats(row_events=row_events, frames=T * B,
                       dense_fallbacks=fallbacks)
    return rasters, v_finals, stats


# ---------------------------------------------------------------------------
# mesh execution — the multi-device entry (`repro.dist` wiring)
# ---------------------------------------------------------------------------

def mesh_axis_extents(mesh) -> tuple:
    """``(n_data, n_model)`` extents of the SNN mesh axes — "data" carries
    serving lanes / macro banks (batch), "model" carries macro row tiles
    (fan-in) — with 1 for an axis the mesh does not name."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return int(sizes.get("data", 1)), int(sizes.get("model", 1))


def mesh_padded_widths(widths: tuple, n_model: int) -> tuple:
    """Layer widths padded up to multiples of the model-axis extent so
    every layer's fan-in rows split evenly over the shards. Shared with
    `analysis.kernel_contracts` — the ``mesh_split`` contract row
    re-derives exactly these numbers."""
    return tuple(-(-int(w) // n_model) * n_model for w in widths)


def mesh_rowpartial_tick(vs, counts, frame, ws_l, *, widths: tuple,
                         n_spiking: int, thresholds: tuple, leaks: tuple,
                         neuron: str, clamp_mode: str, use_events: bool):
    """One model-parallel frame tick — the AccV2V reduction across devices,
    exposed at module level so `analysis.trace_check` can trace exactly the
    dispatched body under an abstract mesh (`jax.make_jaxpr(...,
    axis_env=...)`), no devices needed.

    Each model shard owns a row tile of every layer's weights (``ws_l``,
    already sliced by shard_map) and computes that tile's UNCLAMPED int32
    partial V; the cross-shard integer psum is the word-level AccV2V cycle
    (exact under mod-2^11 wrap: int32 addition is associative and clamp_v
    composes after the full sum — the same single-clamp-after-partials
    trick sub-tile gating uses), and the one clamp runs after the
    reduction. ``vs``/``counts`` are the per-layer carry (``counts`` empty
    unless ``use_events``); ``frame`` is the (B_local, pw[0]) int spike
    frame. Returns ``(vs, counts, rasters_t)``.
    """
    from repro.core.isa import neuron_dynamics_int
    from repro.core.quant import clamp_v
    vs, counts = list(vs), list(counts)
    cur = frame.astype(jnp.int32)                # (B_l, pw[0])
    rasters_t = []
    for i, w_l in enumerate(ws_l):
        if use_events:
            # path-independent per-row event counters on the LOGICAL
            # input rows (the padded tail is junk)
            counts[i] = counts[i] + jnp.sum(cur[:, :widths[i]], axis=0)
        rows = w_l.shape[0]                      # pw[i] // n_model
        lo = jax.lax.axis_index("model") * rows
        blk = jax.lax.dynamic_slice_in_dim(cur, lo, rows, axis=1)
        total = jax.lax.psum(blk @ w_l.astype(jnp.int32), "model")
        if i < n_spiking:
            v = clamp_v(vs[i] + total, clamp_mode)
            vs[i], spk = neuron_dynamics_int(
                v, neuron=neuron, threshold=jnp.int32(thresholds[i]),
                leak=jnp.int32(leaks[i]), reset=jnp.int32(0),
                clamp_mode=clamp_mode)
            cur = spk.astype(jnp.int32)
            rasters_t.append(spk.astype(jnp.int8))
        else:                                    # unclamped readout
            vs[i] = vs[i] + total
    return tuple(vs), tuple(counts), tuple(rasters_t)


@partial(jax.jit, static_argnames=("mesh", "thresholds", "leaks", "neuron",
                                   "clamp_mode", "block_b", "use_pallas",
                                   "interpret", "emit_rasters", "use_sparse",
                                   "gate_granularity", "readout",
                                   "use_events", "event_crossover"))
def _fused_snn_net_mesh_core(spikes, ws, v_init, *, mesh, thresholds, leaks,
                             neuron, clamp_mode, block_b, use_pallas,
                             interpret, emit_rasters, use_sparse,
                             gate_granularity, readout, use_events,
                             event_crossover):
    """The traced mesh body (see `fused_snn_net_mesh` for the contract).
    ``mesh`` is hashable, hence a static argname: the shard_map in/out
    specs are built per (mesh, shapes, flags) trace. ``v_init`` is always
    a concrete per-layer list here (zeros for a from-scratch run) so the
    shard_map operand tree is structurally fixed."""
    from repro.dist.sharding import logical_spec
    n_data, n_model = mesh_axis_extents(mesh)
    T, B, N0 = spikes.shape
    widths = (N0,) + tuple(w.shape[1] for w in ws)
    n_spiking = len(ws) - 1 if readout else len(ws)
    s = _pad_axis(spikes.astype(jnp.int8), 1, n_data)
    vi = [_pad_axis(v.astype(jnp.int32), 0, n_data) for v in v_init]

    if n_model == 1:
        # pure lane (data) parallelism: every shard runs the REAL
        # single-device executor — fused pallas kernel, gated kernel, or
        # jnp reference — on its contiguous lane slice. Lanes never
        # interact, so per-shard results equal the single-device values
        # bit for bit and reassemble by concatenation.
        def body(s_l, ws_l, vi_l):
            r, v, sk = fused_snn_net(
                s_l, list(ws_l), thresholds=thresholds, leaks=leaks,
                neuron=neuron, clamp_mode=clamp_mode, block_b=block_b,
                use_pallas=use_pallas, interpret=interpret,
                emit_rasters=emit_rasters, use_sparse=use_sparse,
                gate_granularity=gate_granularity, readout=readout,
                v_init=list(vi_l), use_events=use_events,
                event_crossover=event_crossover)
            return list(r), list(v), sk

        lane_spec = logical_spec(mesh, (None, "lane", None), s.shape,
                                 required=("lane",))
        in_specs = (lane_spec, [P()] * len(ws), [P("data")] * len(ws))
        r_spec = [P(None, "data", None)] * (n_spiking if emit_rasters else 0)
        v_spec = [P("data")] * len(ws)
        if use_events:
            # per-shard kernel counter blocks: one row per local batch
            # tile — global assembly stacks the tile rows in lane order
            sk_spec = {"row_events": [P("data")] * len(ws),
                       "dense_fallbacks": P("data")}
        elif use_sparse:
            sk_spec = ([P("data")] * len(ws) if gate_granularity != 1
                       else P("data"))
        else:
            sk_spec = None
        rasters, v_finals, skips = shard_map(
            body, mesh=mesh, in_specs=in_specs,
            out_specs=(r_spec, v_spec, sk_spec),
            check_rep=False)(s, list(ws), vi)
        return ([r[:, :B] for r in rasters], [v[:B] for v in v_finals],
                skips)

    # model parallelism: the AccV2V reduction across devices — see
    # `mesh_rowpartial_tick` (the traceable per-frame body). Widths pad to
    # n_model multiples; padded output lanes may fire junk spikes (their V
    # only integrates leak) but feed zero weight rows downstream, exactly
    # the LANE-padding argument of the single-device wrapper.
    pw = mesh_padded_widths(widths, n_model)
    s = _pad_axis(s, 2, n_model)
    ws_p = [_pad_axis(_pad_axis(w.astype(jnp.int8), 0, n_model), 1, n_model)
            for w in ws]
    vi = [_pad_axis(v, 1, n_model) for v in vi]

    def body(s_l, ws_l, vi_l):
        def tick(carry, frame):
            vs, counts, rasters_t = mesh_rowpartial_tick(
                carry[0], carry[1], frame, ws_l, widths=widths,
                n_spiking=n_spiking, thresholds=thresholds, leaks=leaks,
                neuron=neuron, clamp_mode=clamp_mode, use_events=use_events)
            return ((vs, counts), rasters_t if emit_rasters else ())

        counts0 = tuple(jnp.zeros((widths[i],), jnp.int32)
                        for i in range(len(ws_l))) if use_events else ()
        (vs, counts), rasters = jax.lax.scan(
            tick, (tuple(vi_l), counts0), s_l)
        rasters = [r[:, :, :w] for r, w in zip(rasters, widths[1:])]
        vs = [v[:, :w] for v, w in zip(vs, widths[1:])]
        # lane-partition counters pool over the data axis; every model
        # shard then holds the identical global counts
        counts = [jax.lax.psum(c, "data") for c in counts]
        return list(rasters), list(vs), list(counts)

    lane_spec = logical_spec(mesh, (None, "lane", None), s.shape,
                             required=("lane",))
    w_specs = [logical_spec(mesh, ("macro_row_tile", None), w.shape,
                            required=("macro_row_tile",)) for w in ws_p]
    in_specs = (lane_spec, w_specs, [P("data")] * len(ws))
    r_spec = [P(None, "data", None)] * (n_spiking if emit_rasters else 0)
    v_spec = [P("data")] * len(ws)
    c_spec = [P(None)] * len(ws) if use_events else []
    rasters, v_finals, counts = shard_map(
        body, mesh=mesh, in_specs=in_specs,
        out_specs=(r_spec, v_spec, c_spec),
        check_rep=False)(s, ws_p, vi)
    return ([r[:, :B] for r in rasters], [v[:B] for v in v_finals],
            counts if use_events else None)


def fused_snn_net_mesh(spikes: jax.Array, ws: list, *, mesh,
                       thresholds: tuple, leaks: tuple, neuron: str = "rmp",
                       clamp_mode: str = "saturate", block_b: int = 8,
                       use_pallas: bool = True, interpret: bool = False,
                       emit_rasters: bool = True, use_sparse: bool = False,
                       gate_granularity: int = 1, readout: bool = True,
                       v_init: list = None, use_events: bool = False,
                       event_crossover: float = 1.0):
    """`fused_snn_net` on a `jax.sharding.Mesh` — same stack, same
    results, executed under shard_map. Placement is config-driven through
    `repro.dist.sharding`'s logical axes: "lane" (batch) partitions over
    the data axis, "macro_row_tile" (fan-in rows) over the model axis.

    Execution splits on the model extent:

      * model extent 1 — pure lane parallelism: each shard runs the real
        single-device executor (fused pallas kernel included) on its lane
        slice; lanes never interact, so results are bit-identical and
        concatenate. Skip/event counters are the per-shard kernels' own
        blocks stacked in lane order — identical to the single-device
        counters whenever ``block_b`` divides the per-shard batch.
      * model extent > 1 — the AccV2V all-reduce: each shard computes its
        row tile's unclamped int32 partial V, an integer ``psum`` reduces
        across shards (exact — int32 addition is associative and mod-2^11
        wrap composes), and the single clamp runs after the reduction.
        The body is the XLA row-partial scan (a pallas kernel cannot span
        the cross-device reduction); ``use_pallas`` then only selects
        counter conventions. Row-block gate counters are a per-device
        kernel feature and come back as None on this path.

    Args/shapes match `fused_snn_net` (spikes (T, B, N0) int8, per-layer
    ws (n_in, n_out) int8, optional per-layer ``v_init`` (B, n_out)
    int32). Batch pads to the data extent and widths to the model extent
    with zeros — padded lanes integrate nothing and are sliced off.

    Returns (rasters, v_finals, skips); on the event path (``use_events``)
    ``skips`` is an `events.EventStats` folded on the host — do not call
    that combination under an outer jit.

    Raises ValueError on a misaligned stack or invalid flag combination,
    `repro.dist.sharding.ShardingError` if a required axis cannot be
    honoured (cannot happen after padding; defensive).
    """
    thresholds, leaks = tuple(thresholds), tuple(leaks)
    _check_stack(spikes, ws)
    if v_init is not None and len(v_init) != len(ws):
        raise ValueError(f"v_init needs one (B, n_out) state per layer "
                         f"({len(ws)}), got {len(v_init)}")
    if gate_granularity != 1 and not use_sparse:
        raise ValueError("gate_granularity is an event-gating knob; pass "
                         "use_sparse=True to gate at granularity "
                         f"{gate_granularity}")
    if use_events and use_sparse:
        raise ValueError("use_events (event-list execution) and use_sparse "
                         "(row-block gating) are mutually exclusive")
    if use_events and not use_pallas:
        raise ValueError("use_events is the device event-list path; the "
                         "host executor shards at the pipeline level "
                         "(core.pipeline._host_events_sharded)")
    T, B = int(spikes.shape[0]), int(spikes.shape[1])
    n_data, n_model = mesh_axis_extents(mesh)
    if v_init is None:
        v_init = [jnp.zeros((B, w.shape[1]), jnp.int32) for w in ws]
    rasters, v_finals, skips = _fused_snn_net_mesh_core(
        spikes, list(ws), list(v_init), mesh=mesh, thresholds=thresholds,
        leaks=leaks, neuron=neuron, clamp_mode=clamp_mode, block_b=block_b,
        use_pallas=use_pallas, interpret=interpret,
        emit_rasters=emit_rasters, use_sparse=use_sparse,
        gate_granularity=gate_granularity, readout=readout,
        use_events=use_events, event_crossover=event_crossover)
    if use_events:
        import numpy as np

        from repro.kernels.fused_snn_net.events import EventStats
        if n_model == 1:
            row_events = tuple(np.asarray(rc, np.int64).sum(axis=0)
                               for rc in skips["row_events"])
            fallbacks = tuple(int(c) for c in
                              np.asarray(skips["dense_fallbacks"],
                                         np.int64).sum(axis=0))
        else:
            row_events = tuple(np.asarray(c, np.int64) for c in skips)
            fallbacks = ()       # no dense-fallback machinery on this path
        skips = EventStats(row_events=row_events, frames=T * B,
                           dense_fallbacks=fallbacks)
    return rasters, v_finals, skips


def _fused_snn_net_ref(spikes, ws, thresholds, leaks, neuron, clamp_mode,
                       emit_rasters, use_sparse=False, readout=True,
                       gate_granularity=1, v_init=None):
    """Pure-jnp oracle: the word-level ISA scanned over the network. In
    ``use_sparse`` mode the AccW2V matmul of each lane block (the whole
    layer at granularity 1) is wrapped in a `lax.cond` on whole-batch
    occupancy (the reference's tile = the whole batch) and per-(layer,
    block) skipped-step counts ride along. Block partials accumulate
    unclamped; one clamp after the last block matches the dense
    clamp-after-accumulate bit for bit (clamp_v is idempotent when every
    block is silent)."""
    from repro.core.isa import layer_timestep_int, neuron_dynamics_int
    from repro.core.quant import clamp_v
    B = spikes.shape[1]
    spiking_ws = ws[:-1] if readout else ws
    blocks = [_ref_blocks(w.shape[0], gate_granularity) for w in ws]

    def gated_acc(v, w, cur, spans, clamp):
        skipped = []
        for lo, hi in spans:
            blk = cur[:, lo:hi]
            occupied = jnp.sum(blk) > 0
            v = jax.lax.cond(
                occupied,
                lambda v, blk=blk, lo=lo, hi=hi:
                    v + blk @ w[lo:hi].astype(jnp.int32),
                lambda v: v, v)
            skipped.append(jnp.logical_not(occupied).astype(jnp.int32))
        v = clamp_v(v, clamp_mode) if clamp else v
        return v, jnp.stack(skipped)

    def step(carry, s_t):
        vs, skips = list(carry[0]), list(carry[1])
        cur = s_t.astype(jnp.int32)
        rasters = []
        skipped = []
        for i, w in enumerate(spiking_ws):
            if use_sparse:
                v, sk = gated_acc(vs[i], w, cur, blocks[i], clamp=True)
                skipped.append(sk)
                vs[i], cur = neuron_dynamics_int(
                    v, neuron=neuron, threshold=jnp.int32(thresholds[i]),
                    leak=jnp.int32(leaks[i]), reset=jnp.int32(0),
                    clamp_mode=clamp_mode)
            else:
                vs[i], cur = layer_timestep_int(
                    vs[i], w, cur, neuron=neuron,
                    threshold=jnp.int32(thresholds[i]),
                    leak=jnp.int32(leaks[i]),
                    reset=jnp.int32(0), clamp_mode=clamp_mode)
            rasters.append(cur.astype(jnp.int8))
        if readout:
            if use_sparse:
                vs[-1], sk = gated_acc(vs[-1], ws[-1], cur, blocks[-1],
                                       clamp=False)
                skipped.append(sk)
            else:
                vs[-1] = vs[-1] + cur @ ws[-1].astype(jnp.int32)
        if use_sparse:
            skips = [s + d for s, d in zip(skips, skipped)]
        return (tuple(vs), tuple(skips)), tuple(rasters)

    if v_init is not None:
        vs0 = tuple(v.astype(jnp.int32) for v in v_init)
    else:
        vs0 = tuple(jnp.zeros((B, w.shape[1]), jnp.int32) for w in ws)
    skips0 = tuple(jnp.zeros((len(b),), jnp.int32) for b in blocks)
    (vs, skips), rasters = jax.lax.scan(step, (vs0, skips0),
                                        spikes.astype(jnp.int8))
    if not use_sparse:
        out_skips = None
    elif gate_granularity == 1:        # legacy (1, n_layers) layout
        out_skips = jnp.stack([s[0] for s in skips])[None]
    else:
        out_skips = [s[None] for s in skips]
    return ((list(rasters) if emit_rasters else []), list(vs), out_skips)
