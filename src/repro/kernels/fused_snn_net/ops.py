"""jit'd public wrapper for the network-level fused SNN kernel: padding,
dispatch, and the pure-JAX fallback for non-TPU backends.

Padding correctness: layer widths pad to the 128-lane tile. Padded *input*
lanes are harmless because the next layer's padded weight ROWS are zero, so
junk spikes fired by padded lanes (their V integrates only leak) contribute
exactly nothing downstream; rasters and V are sliced back to logical widths
before returning.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.fused_snn_net.kernel import fused_snn_net_pallas

LANE = 128


def _pad_axis(x: jax.Array, axis: int, mult: int) -> jax.Array:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@partial(jax.jit, static_argnames=("thresholds", "leaks", "neuron",
                                   "clamp_mode", "block_b", "use_pallas",
                                   "interpret", "emit_rasters"))
def fused_snn_net(spikes: jax.Array, ws: list, *, thresholds: tuple,
                  leaks: tuple, neuron: str = "rmp",
                  clamp_mode: str = "saturate", block_b: int = 8,
                  use_pallas: bool = True, interpret: bool = False,
                  emit_rasters: bool = True):
    """Run a (T, B, N0) encoder spike raster through the whole fc stack.

    ``ws``: per-layer int8 weights, spiking FCs first, readout last;
    ``thresholds``/``leaks``: per-spiking-layer ints on each layer's grid.
    Returns (rasters, v_finals): per-spiking-layer output rasters
    (T, B, N_i) int8 (empty list when emit_rasters=False) and per-layer
    final V (B, N_i) int32, readout last.

    ``use_pallas=False`` selects a pure-jnp reference with identical
    semantics (scan of isa.layer_timestep_int over the stack).
    """
    thresholds, leaks = tuple(thresholds), tuple(leaks)
    if not use_pallas:
        return _fused_snn_net_ref(spikes, ws, thresholds, leaks, neuron,
                                  clamp_mode, emit_rasters)
    T, B, N0 = spikes.shape
    # chain alignment on LOGICAL widths (padded widths can coincide for
    # mismatched stacks): layer i's fan-in == layer i-1's fan-out
    prev = N0
    for w in ws:
        assert w.shape[0] == prev, (w.shape, prev)
        prev = w.shape[1]
    s = _pad_axis(_pad_axis(spikes.astype(jnp.int8), 2, LANE), 1, block_b)
    ws_p = [_pad_axis(_pad_axis(w.astype(jnp.int8), 0, LANE), 1, LANE)
            for w in ws]
    params = jnp.asarray([[t, l] for t, l in zip(thresholds, leaks)],
                         jnp.int32).reshape(len(thresholds), 2)
    rasters, v_finals = fused_snn_net_pallas(
        s, ws_p, params, neuron=neuron, clamp_mode=clamp_mode,
        block_b=block_b, emit_rasters=emit_rasters, interpret=interpret)
    rasters = [r[:, :B, :w.shape[1]] for r, w in zip(rasters, ws[:-1])]
    v_finals = [v[:B, :w.shape[1]] for v, w in zip(v_finals, ws)]
    return rasters, v_finals


def _fused_snn_net_ref(spikes, ws, thresholds, leaks, neuron, clamp_mode,
                       emit_rasters):
    """Pure-jnp oracle: the word-level ISA scanned over the network."""
    from repro.core.isa import layer_timestep_int
    B = spikes.shape[1]

    def step(carry, s_t):
        vs = list(carry)
        cur = s_t.astype(jnp.int32)
        rasters = []
        for i, w in enumerate(ws[:-1]):
            vs[i], cur = layer_timestep_int(
                vs[i], w, cur, neuron=neuron,
                threshold=jnp.int32(thresholds[i]), leak=jnp.int32(leaks[i]),
                reset=jnp.int32(0), clamp_mode=clamp_mode)
            rasters.append(cur.astype(jnp.int8))
        vs[-1] = vs[-1] + cur @ ws[-1].astype(jnp.int32)
        return tuple(vs), tuple(rasters)

    vs0 = tuple(jnp.zeros((B, w.shape[1]), jnp.int32) for w in ws)
    vs, rasters = jax.lax.scan(step, vs0, spikes.astype(jnp.int8))
    return (list(rasters) if emit_rasters else []), list(vs)
