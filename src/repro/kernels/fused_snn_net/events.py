"""Spike-list compaction reference: the fully event-driven executor.

Event-based CIM designs (and the IMPULSE macro itself, at word level:
`isa.timestep` walks `np.nonzero(in_spikes)`) do not scan dense frames —
they consume a compacted event list. This module is that execution model
for the whole fused stack: every (timestep, example) frame is compacted to
``(indices, count)`` and the AccW2V accumulate becomes a gather-matvec
over the **active rows only** — work is exactly proportional to the event
count, which makes this backend the honest upper bound on skippable work
(iid-Bernoulli sparsity that defeats tile- and block-level gates is fully
exploited here) and the word-level contract for per-row skip accounting
(`isa.count_skipped_instructions_from_events`).

Host/numpy on purpose: the compaction is data-dependent (ragged event
lists do not jit), and the per-event arithmetic routes through
`quant.clamp_v_np` / `quant.spike_compare_np` in int32, so results are
bit-identical to every other backend. Use it for accounting and verification, not
throughput.
"""
from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.quant import clamp_v_np as _clamp
from repro.core.quant import spike_compare_np as _spike


class EventStats(NamedTuple):
    """Per-layer event statistics of one event-driven execution."""
    row_events: tuple          # per layer: (n_in,) int64 events per input row
    frames: int                # (timestep, example) frames each layer ran
    dense_fallbacks: tuple = ()  # per layer: dense-crossover trips (device
    #                              event backend only; host executor never
    #                              falls back, so it reports ())

    @property
    def events(self) -> tuple:
        """Total input events (== active compacted rows) per layer."""
        return tuple(int(r.sum()) for r in self.row_events)

    @property
    def skipped_rows(self) -> tuple:
        """Silent (frame, input-row) pairs per layer — AccW2V work an
        event-driven macro never issues."""
        return tuple(self.frames * len(r) - int(r.sum())
                     for r in self.row_events)

    @property
    def skipped_row_fraction(self) -> float:
        """Fraction of all (frame, row) gate sites that were silent."""
        possible = sum(self.frames * len(r) for r in self.row_events)
        return sum(self.skipped_rows) / possible if possible else 0.0


def fused_snn_net_events(spikes, ws, *, thresholds: tuple, leaks: tuple,
                         neuron: str = "rmp", clamp_mode: str = "saturate",
                         emit_rasters: bool = True, readout: bool = True,
                         v_init: list = None):
    """Event-list execution of the fused stack — same contract as
    `ops.fused_snn_net` (rasters, v_finals, stats), but the third element
    is an `EventStats` (per-row event counts) instead of gate-site skip
    counts: the event list has no tiles or blocks to skip; *every* silent
    row is skipped by construction.

    Bit-identity argument: the gather-matvec over active rows equals the
    dense matmul exactly (silent rows multiply weight rows by zero), the
    accumulate clamps once after the full per-frame sum — the same single
    clamp-after-accumulate every other backend applies — and the neuron
    update runs unconditionally every timestep.

    ``v_init`` (streaming entry): per-layer (B, n_out) membrane state
    resuming a previous call instead of zeros — integer arithmetic makes
    chunked calls that thread V back in equal one long call exactly.
    """
    spikes = np.asarray(spikes).astype(np.int8)
    if spikes.ndim != 3:
        raise ValueError(f"spikes must be (T, B, N), got {spikes.shape}")
    ws = [np.asarray(w, np.int32) for w in ws]
    prev = spikes.shape[2]
    for i, w in enumerate(ws):
        if w.ndim != 2 or w.shape[0] != prev:
            raise ValueError(f"layer chain misaligned at ws[{i}]: "
                             f"{w.shape} after {prev} lanes")
        prev = w.shape[1]
    T, B, _ = spikes.shape
    n_spiking = len(ws) - 1 if readout else len(ws)
    if len(thresholds) != n_spiking or len(leaks) != n_spiking:
        raise ValueError(f"need {n_spiking} thresholds/leaks, got "
                         f"{len(thresholds)}/{len(leaks)}")
    if v_init is not None:
        if len(v_init) != len(ws):
            raise ValueError(f"v_init needs one (B, n_out) state per layer "
                             f"({len(ws)}), got {len(v_init)}")
        vs = [np.array(v, np.int32, copy=True) for v in v_init]
    else:
        vs = [np.zeros((B, w.shape[1]), np.int32) for w in ws]
    row_events = [np.zeros(w.shape[0], np.int64) for w in ws]
    rasters = [np.zeros((T, B, w.shape[1]), np.int8)
               for w in ws[:n_spiking]] if emit_rasters else []
    for t in range(T):
        cur = spikes[t]
        for i, w in enumerate(ws):
            row_events[i] += cur.astype(np.int64).sum(axis=0)
            acc = np.zeros((B, w.shape[1]), np.int32)
            # batch-flattened event list: np.nonzero is the compaction
            # (each example's segment of r_idx is its active-row index
            # list), one reduceat segment-sums the gathered weight rows of
            # every non-empty example at once — same gather-matvec work
            # model, no per-example python loop. reduceat needs strictly
            # in-range start offsets, so empty examples (whose start would
            # collide with the next segment's and corrupt it) are excluded
            # and keep their zero rows.
            b_idx, r_idx = np.nonzero(cur)
            if b_idx.size:
                counts = np.bincount(b_idx, minlength=B)
                nz = counts > 0
                starts = np.cumsum(counts) - counts
                acc[nz] = np.add.reduceat(w[r_idx], starts[nz], axis=0)
            v = vs[i] + acc                         # readout stays unclamped
            if i >= n_spiking:
                vs[i] = v
                continue
            v = _clamp(v, clamp_mode)
            th, lk = int(thresholds[i]), int(leaks[i])
            if neuron == "lif":
                v = _clamp(v - lk, clamp_mode)
            fired = _spike(v, th, clamp_mode)
            if neuron == "rmp":                     # soft reset, gated
                v = _clamp(np.where(fired, v - th, v), clamp_mode)
            elif neuron in ("if", "lif"):
                v = np.where(fired, 0, v)
            else:
                raise ValueError(f"unknown neuron {neuron!r}")
            vs[i] = v.astype(np.int32)
            cur = fired.astype(np.int8)
            if emit_rasters:
                rasters[i][t] = cur
    stats = EventStats(row_events=tuple(row_events), frames=T * B)
    return rasters, vs, stats
