from repro.kernels.fused_snn_net.ops import fused_snn_net  # noqa: F401
