"""Distribution layer: sharding rules, wire compression, pipeline parallelism.

Three orthogonal pieces, each consumed by a different part of the stack:

  * sharding  — logical-axis -> mesh placement rules. Parameters and
    activations name *logical* axes ("batch", "vocab", "experts", ...); the
    rules engine fits them onto whatever mesh is active, dropping any axis
    whose size does not divide its mesh extent (`_fit`). Layers call
    `constrain` freely: it is a no-op unless `activation_rules` is active.
  * compress  — int8-wire gradient all-reduce with error feedback, and the
    single-host `fake_compress` used to study its numerics without a mesh.
  * pipeline  — GPipe-style pipeline parallelism over a mesh axis.
"""
from repro.dist import compress, pipeline, sharding  # noqa: F401
