"""Sharding rules: logical axes -> mesh placement with divisibility fitting.

The engine has three layers:

  1. `_fit(axes, shape, mesh)` — the single primitive every rule goes
     through: a per-dimension proposal (mesh axis name, tuple of names, or
     None) is kept only if the dimension size divides the product of the
     proposed mesh extents. Everything else degrades to replication, so the
     same rules serve every (arch x mesh) cell without per-model tables.
  2. spec builders — `param_specs`, `batch_specs`, `cache_specs`,
     `logits_spec`, `replicated`: pytree -> NamedSharding trees for jit
     in/out shardings.
  3. `activation_rules(mesh, parallel)` + `constrain(x, logical_axes)` —
     a context that maps *logical* activation axis names onto the mesh.
     `constrain` is a no-op outside the context, so model code can pin
     activations unconditionally (single-device tests, dry-runs without a
     mesh, and production traces all share one code path).
"""
from __future__ import annotations

import contextlib
import logging
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

logger = logging.getLogger("repro.dist.sharding")

# Logical activation-axis name -> mesh axis. "batch" always maps to the data
# axis; the model-parallel names collapse onto the model axis. The SNN names
# map the IMPULSE macro structure onto the mesh: "macro_row_tile" is the
# row-tiled fan-in dimension (each model shard owns a tile's rows and
# contributes an unclamped int32 partial V; the cross-shard psum is the
# AccV2V reduction), "bank"/"lane" are the frame-bank and serving-lane
# (batch) dimensions, which never interact across lanes and so partition
# over the data axis.
_LOGICAL_TO_MESH = {
    "batch": "data",
    "vocab": "model",
    "experts": "model",
    "ffn": "model",
    "heads": "model",
    "embed": "model",
    "seq": "model",          # only applied when parallel.seq_parallel
    # --- SNN axes (core.pipeline / serve.snn_engine) ---
    "macro_row_tile": "model",
    "bank": "data",
    "lane": "data",
}


class ShardingError(ValueError):
    """A logical axis that was explicitly required could not be honoured —
    its dimension does not divide the proposed mesh extent (or the mesh has
    no such axis). Raised by `_fit`/`logical_spec` instead of silently
    degrading to replication, so config-driven placements fail loudly."""


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fit(axes: tuple, shape: tuple, mesh: Mesh, *,
         required: tuple = ()) -> P:
    """Fit a per-dimension mesh-axis proposal onto concrete dimension sizes.

    ``axes`` entries are a mesh axis name, a tuple of names (sharded over
    their product), or None. A proposal is dropped (-> None) when the
    dimension does not divide the proposed mesh extent, or when the axis was
    already consumed by an earlier dimension. Every divisibility drop is
    logged on the ``repro.dist.sharding`` logger with the axis and extents.

    ``required``: mesh-axis names that must not degrade — dropping one
    raises `ShardingError` instead of replicating (a size-1 mesh axis
    counts as honoured: sharding over it IS replication).
    """
    sizes = _axis_sizes(mesh)
    required = set(required)
    used: set[str] = set()
    out = []
    for i, (dim, prop) in enumerate(
            zip(shape, tuple(axes) + (None,) * (len(shape) - len(axes)))):
        if prop is None:
            out.append(None)
            continue
        names = prop if isinstance(prop, tuple) else (prop,)
        if any(n not in sizes or n in used for n in names):
            if required.intersection(names):
                raise ShardingError(
                    f"required mesh axis {sorted(required & set(names))} "
                    f"cannot shard dim {i} (size {dim}) of shape {shape}: "
                    f"axis missing from mesh {sorted(sizes)} or already "
                    f"consumed by an earlier dimension")
            out.append(None)
            continue
        extent = int(np.prod([sizes[n] for n in names]))
        if extent == 1:
            # size-1 mesh axis: sharding degenerates to replication; the
            # proposal is honoured trivially, not dropped
            out.append(None)
        elif dim % extent == 0:
            out.append(prop)
            used.update(names)
        else:
            logger.warning(
                "sharding._fit: dropping axis %r on dim %d of shape %s — "
                "size %d does not divide mesh extent %d; degrading to "
                "replication", prop, i, shape, dim, extent)
            if required.intersection(names):
                raise ShardingError(
                    f"required mesh axis {sorted(required & set(names))} "
                    f"cannot shard dim {i} of shape {shape}: size {dim} "
                    f"does not divide mesh extent {extent}")
            out.append(None)
    return P(*out)


def replicated(mesh: Mesh) -> NamedSharding:
    """Fully-replicated placement on ``mesh`` (the empty PartitionSpec)."""
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# parameter / batch / cache placement
# ---------------------------------------------------------------------------

def _param_rule(shape: tuple, parallel) -> tuple:
    """Generic parameter rule: tensor-parallel on the trailing (output)
    axis, FSDP on the leading (input) axis. `_fit` drops whatever does not
    divide, so this single rule covers embeddings, dense kernels, per-expert
    stacks and 1-D norm scales alike."""
    if len(shape) == 0:
        return ()
    if len(shape) == 1:
        return ("data",) if parallel.fsdp else (None,)
    prop: list = [None] * len(shape)
    prop[-1] = "model"
    if parallel.fsdp:
        prop[0] = "data"
    return tuple(prop)


def param_specs(params: Any, mesh: Mesh, parallel) -> Any:
    """Pytree of params (arrays or ShapeDtypeStructs) -> NamedSharding tree."""
    def spec(leaf):
        shape = tuple(leaf.shape)
        return NamedSharding(mesh, _fit(_param_rule(shape, parallel), shape, mesh))
    return jax.tree_util.tree_map(spec, params)


def batch_specs(batch: Any, mesh: Mesh, parallel) -> Any:
    """NamedSharding tree for an input ``batch`` pytree on ``mesh``: each
    leaf's leading axis shards over data; with ``parallel.seq_parallel``
    the sequence axis additionally shards over model."""
    def spec(leaf):
        shape = tuple(leaf.shape)
        prop: list = [None] * len(shape)
        if len(shape) >= 1:
            prop[0] = "data"
        if parallel.seq_parallel and len(shape) >= 2:
            prop[1] = "model"
        return NamedSharding(mesh, _fit(tuple(prop), shape, mesh))
    return jax.tree_util.tree_map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh, parallel, cfg=None) -> Any:
    """NamedSharding tree for a KV / latent / state ``cache`` pytree on
    ``mesh``: batch over data, heads (axis 2 of (B, S, H, D) layouts) over
    model when divisible (``parallel``/``cfg`` reserved for rule
    variants)."""
    def spec(leaf):
        shape = tuple(leaf.shape)
        prop: list = [None] * len(shape)
        if len(shape) >= 1:
            prop[0] = "data"
        if len(shape) >= 3:
            prop[2] = "model"
        return NamedSharding(mesh, _fit(tuple(prop), shape, mesh))
    return jax.tree_util.tree_map(spec, cache)


def logits_spec(mesh: Mesh, shape: tuple) -> NamedSharding:
    """Placement on ``mesh`` for (batch, vocab) logits of ``shape``:
    batch over data, vocab over model."""
    return NamedSharding(mesh, _fit(("data", "model"), tuple(shape), mesh))


# ---------------------------------------------------------------------------
# logical-axis placement (SNN pipeline entry point)
# ---------------------------------------------------------------------------

def logical_spec(mesh: Mesh, logical_axes: tuple, shape: tuple, *,
                 required: tuple = ()) -> P:
    """Resolve per-dimension *logical* axis names to a PartitionSpec.

    ``logical_axes``: one entry per dimension of ``shape`` — a logical name
    from `_LOGICAL_TO_MESH` ("lane", "macro_row_tile", "bank", "batch",
    ...), a raw mesh-axis name, or None. Divisibility fitting and
    degradation follow `_fit`.

    ``required``: logical names that must be honoured; resolving one onto a
    mesh axis that cannot shard its dimension raises `ShardingError`. An
    unknown logical name in ``required`` also raises (a typo would
    otherwise silently replicate).
    """
    sizes = _axis_sizes(mesh)

    def to_mesh(name):
        if name is None:
            return None
        if isinstance(name, tuple):
            resolved = tuple(m for m in (to_mesh(n) for n in name)
                             if m is not None)
            return resolved or None
        return _LOGICAL_TO_MESH.get(
            name, name if name in sizes else None)

    req_mesh = []
    for name in required:
        m = to_mesh(name)
        if m is None:
            raise ShardingError(
                f"required logical axis {name!r} resolves to no mesh axis "
                f"(known logical names: {sorted(_LOGICAL_TO_MESH)}; mesh "
                f"axes: {sorted(sizes)})")
        req_mesh.extend(m if isinstance(m, tuple) else (m,))
    prop = tuple(to_mesh(n) for n in logical_axes)
    return _fit(prop, tuple(shape), mesh, required=tuple(req_mesh))


def logical_sharding(mesh: Mesh, logical_axes: tuple, shape: tuple, *,
                     required: tuple = ()) -> NamedSharding:
    """`logical_spec(mesh, logical_axes, shape, required=...)` wrapped
    into a NamedSharding — the device_put / in_shardings form."""
    return NamedSharding(
        mesh, logical_spec(mesh, logical_axes, shape, required=required))


def snn_state_specs(state: Any, mesh: Mesh) -> Any:
    """Streaming-state pytree (`core.pipeline.StreamState`) -> NamedSharding
    tree: every array leaf's leading axis is the serving-lane (batch) axis
    and partitions over the data mesh axis when divisible; scalars (the
    frame clock ``t``) replicate. Used by `serve.snn_engine.SNNServeEngine`
    to place each page of the paged V-slot pool onto a mesh."""
    def spec(leaf):
        # the tick counter is a plain int leaf — shapeless, replicated
        shape = tuple(getattr(leaf, "shape", ()))
        prop = ("lane",) + (None,) * (len(shape) - 1) if shape else ()
        return NamedSharding(mesh, logical_spec(mesh, prop, shape))
    return jax.tree_util.tree_map(spec, state)


# ---------------------------------------------------------------------------
# activation rules context + constrain
# ---------------------------------------------------------------------------

class _Rules(threading.local):
    mesh: Optional[Mesh] = None
    parallel: Any = None


_RULES = _Rules()


@contextlib.contextmanager
def activation_rules(mesh: Mesh, parallel):
    """Activate logical-axis constraints (onto ``mesh``, interpreted under
    the ``parallel`` flags) for traces entered inside the context. Traces
    outside it see `constrain` as the identity."""
    prev = (_RULES.mesh, _RULES.parallel)
    _RULES.mesh, _RULES.parallel = mesh, parallel
    try:
        yield
    finally:
        _RULES.mesh, _RULES.parallel = prev


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """Pin an activation's logical axes onto the active mesh; identity when
    no rules are active. Entries of ``logical_axes`` are logical names
    ("batch", "seq", "vocab", "experts", "ffn", "heads"), tuples of names,
    or None."""
    mesh, parallel = _RULES.mesh, _RULES.parallel
    if mesh is None:
        return x

    def to_mesh(name):
        if name is None:
            return None
        if isinstance(name, tuple):
            resolved = tuple(m for m in (to_mesh(n) for n in name) if m is not None)
            return resolved or None
        if name == "seq" and parallel is not None and not parallel.seq_parallel:
            return None
        return _LOGICAL_TO_MESH.get(name, name if name in mesh.axis_names else None)

    prop = tuple(to_mesh(n) for n in logical_axes)
    spec = _fit(prop, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
