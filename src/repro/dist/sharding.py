"""Sharding rules: logical axes -> mesh placement with divisibility fitting.

The engine has three layers:

  1. `_fit(axes, shape, mesh)` — the single primitive every rule goes
     through: a per-dimension proposal (mesh axis name, tuple of names, or
     None) is kept only if the dimension size divides the product of the
     proposed mesh extents. Everything else degrades to replication, so the
     same rules serve every (arch x mesh) cell without per-model tables.
  2. spec builders — `param_specs`, `batch_specs`, `cache_specs`,
     `logits_spec`, `replicated`: pytree -> NamedSharding trees for jit
     in/out shardings.
  3. `activation_rules(mesh, parallel)` + `constrain(x, logical_axes)` —
     a context that maps *logical* activation axis names onto the mesh.
     `constrain` is a no-op outside the context, so model code can pin
     activations unconditionally (single-device tests, dry-runs without a
     mesh, and production traces all share one code path).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# Logical activation-axis name -> mesh axis. "batch" always maps to the data
# axis; the model-parallel names collapse onto the model axis.
_LOGICAL_TO_MESH = {
    "batch": "data",
    "vocab": "model",
    "experts": "model",
    "ffn": "model",
    "heads": "model",
    "embed": "model",
    "seq": "model",          # only applied when parallel.seq_parallel
}


def _axis_sizes(mesh: Mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def _fit(axes: tuple, shape: tuple, mesh: Mesh) -> P:
    """Fit a per-dimension mesh-axis proposal onto concrete dimension sizes.

    ``axes`` entries are a mesh axis name, a tuple of names (sharded over
    their product), or None. A proposal is dropped (-> None) when the
    dimension does not divide the proposed mesh extent, or when the axis was
    already consumed by an earlier dimension.
    """
    sizes = _axis_sizes(mesh)
    used: set[str] = set()
    out = []
    for dim, prop in zip(shape, tuple(axes) + (None,) * (len(shape) - len(axes))):
        if prop is None:
            out.append(None)
            continue
        names = prop if isinstance(prop, tuple) else (prop,)
        if any(n not in sizes or n in used for n in names):
            out.append(None)
            continue
        extent = int(np.prod([sizes[n] for n in names]))
        if extent > 1 and dim % extent == 0:
            out.append(prop)
            used.update(names)
        else:
            out.append(None)
    return P(*out)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


# ---------------------------------------------------------------------------
# parameter / batch / cache placement
# ---------------------------------------------------------------------------

def _param_rule(shape: tuple, parallel) -> tuple:
    """Generic parameter rule: tensor-parallel on the trailing (output)
    axis, FSDP on the leading (input) axis. `_fit` drops whatever does not
    divide, so this single rule covers embeddings, dense kernels, per-expert
    stacks and 1-D norm scales alike."""
    if len(shape) == 0:
        return ()
    if len(shape) == 1:
        return ("data",) if parallel.fsdp else (None,)
    prop: list = [None] * len(shape)
    prop[-1] = "model"
    if parallel.fsdp:
        prop[0] = "data"
    return tuple(prop)


def param_specs(params: Any, mesh: Mesh, parallel) -> Any:
    """Pytree of params (arrays or ShapeDtypeStructs) -> NamedSharding tree."""
    def spec(leaf):
        shape = tuple(leaf.shape)
        return NamedSharding(mesh, _fit(_param_rule(shape, parallel), shape, mesh))
    return jax.tree_util.tree_map(spec, params)


def batch_specs(batch: Any, mesh: Mesh, parallel) -> Any:
    """Input batches shard their leading axis over data; with seq_parallel
    the sequence axis additionally shards over model."""
    def spec(leaf):
        shape = tuple(leaf.shape)
        prop: list = [None] * len(shape)
        if len(shape) >= 1:
            prop[0] = "data"
        if parallel.seq_parallel and len(shape) >= 2:
            prop[1] = "model"
        return NamedSharding(mesh, _fit(tuple(prop), shape, mesh))
    return jax.tree_util.tree_map(spec, batch)


def cache_specs(cache: Any, mesh: Mesh, parallel, cfg=None) -> Any:
    """KV / latent / state caches: batch over data, heads (axis 2 of
    (B, S, H, D) layouts) over model when divisible."""
    def spec(leaf):
        shape = tuple(leaf.shape)
        prop: list = [None] * len(shape)
        if len(shape) >= 1:
            prop[0] = "data"
        if len(shape) >= 3:
            prop[2] = "model"
        return NamedSharding(mesh, _fit(tuple(prop), shape, mesh))
    return jax.tree_util.tree_map(spec, cache)


def logits_spec(mesh: Mesh, shape: tuple) -> NamedSharding:
    """(batch, vocab) logits: batch over data, vocab over model."""
    return NamedSharding(mesh, _fit(("data", "model"), tuple(shape), mesh))


# ---------------------------------------------------------------------------
# activation rules context + constrain
# ---------------------------------------------------------------------------

class _Rules(threading.local):
    mesh: Optional[Mesh] = None
    parallel: Any = None


_RULES = _Rules()


@contextlib.contextmanager
def activation_rules(mesh: Mesh, parallel):
    """Activate logical-axis constraints for traces entered inside the
    context. Traces outside it see `constrain` as the identity."""
    prev = (_RULES.mesh, _RULES.parallel)
    _RULES.mesh, _RULES.parallel = mesh, parallel
    try:
        yield
    finally:
        _RULES.mesh, _RULES.parallel = prev


def constrain(x: jax.Array, logical_axes: tuple) -> jax.Array:
    """Pin an activation's logical axes onto the active mesh; identity when
    no rules are active. Entries of ``logical_axes`` are logical names
    ("batch", "seq", "vocab", "experts", "ffn", "heads"), tuples of names,
    or None."""
    mesh, parallel = _RULES.mesh, _RULES.parallel
    if mesh is None:
        return x

    def to_mesh(name):
        if name is None:
            return None
        if isinstance(name, tuple):
            resolved = tuple(m for m in (to_mesh(n) for n in name) if m is not None)
            return resolved or None
        if name == "seq" and parallel is not None and not parallel.seq_parallel:
            return None
        return _LOGICAL_TO_MESH.get(name, name if name in mesh.axis_names else None)

    prop = tuple(to_mesh(n) for n in logical_axes)
    spec = _fit(prop, tuple(x.shape), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
