"""int8-wire gradient reduction with error feedback.

The cross-data-axis gradient mean is the dominant wire cost of data-parallel
training. `compressed_psum_mean` quantizes each shard's contribution to int8
before the reduction (4x wire bytes vs fp32) and carries the quantization
error in a per-leaf residual that is added back the next step — the standard
error-feedback construction, which makes the *time-averaged* reduction
unbiased even though any single step is quantized.

`fake_compress` applies the same quantize-dequantize to a gradient pytree
without any collective: the single-host numerics study used by
ParallelConfig.grad_compress.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def _quantize_int8(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Per-tensor symmetric int8: returns (q int8, scale)."""
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum_mean(grads: Any, residuals: Any, axis_name: str
                         ) -> tuple[Any, Any]:
    """Mean-reduce a gradient pytree across ``axis_name`` on an int8 wire.

    Per leaf: the shard's contribution (grad + carried residual) is
    quantized to int8 + one fp32 scale, the dequantized value is
    mean-reduced, and the local quantization error becomes the new residual.
    Must be called inside shard_map/pmap with ``axis_name`` bound.

    Returns (mean_grads, new_residuals) with the input tree structure.
    """
    def leaf(g, r):
        inp = g.astype(jnp.float32) + r
        q, scale = _quantize_int8(inp)
        deq = _dequantize(q, scale)
        mean = jax.lax.pmean(deq, axis_name)
        return mean.astype(g.dtype), inp - deq

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [leaf(g, r) for g, r in zip(flat_g, flat_r)]
    means = treedef.unflatten([m for m, _ in out])
    new_res = treedef.unflatten([r for _, r in out])
    return means, new_res


def fake_compress(grads: Any) -> Any:
    """Quantize-dequantize each leaf of the ``grads`` pytree through the
    int8 wire format (no collective, no residual): isolates the per-step
    quantization noise."""
    def leaf(g):
        q, scale = _quantize_int8(g.astype(jnp.float32))
        return _dequantize(q, scale).astype(g.dtype)
    return jax.tree_util.tree_map(leaf, grads)
