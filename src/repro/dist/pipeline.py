"""GPipe pipeline parallelism over one mesh axis.

`make_pipeline_fn(stage_fn, mesh, axis_name, n_micro)` returns a function
``pipe(Ws, xs)`` where ``Ws`` stacks one stage's parameters per pipeline
rank (leading axis == mesh extent) and ``xs`` stacks the microbatches
(leading axis == n_micro). Execution is the classic schedule: microbatch m
enters stage 0 at tick m and advances one stage per tick via a ring
`ppermute`; the last stage emits microbatch m at tick m + S - 1, so the
whole run takes n_micro + S - 1 ticks with every stage busy in the steady
state. Output equals sequentially composing the stages over each
microbatch (bubble overhead changes time, not values).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def make_pipeline_fn(stage_fn: Callable, mesh: Mesh, axis_name: str,
                     n_micro: int) -> Callable:
    """Build the GPipe executor (module docs): ``stage_fn(w, x)`` is one
    pipeline stage, staged over ``mesh``'s ``axis_name`` extent; the
    returned ``pipe(Ws, xs)`` runs ``n_micro`` microbatches through the
    classic fill/steady/drain schedule."""
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis_name]
    ring = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def body(w_local, xs):
        # w_local: (1, ...) this rank's stage params; xs: (M, B, d) replicated
        idx = jax.lax.axis_index(axis_name)
        w = jax.tree_util.tree_map(lambda t: t[0], w_local)
        m_total = xs.shape[0]

        def tick(t, carry):
            x_cur, buf = carry
            # stage 0 injects microbatch t (clamped reads past the end feed
            # garbage that is never emitted — see schedule note above)
            inp = jnp.where(idx == 0, xs[jnp.clip(t, 0, m_total - 1)], x_cur)
            y = stage_fn(w, inp)
            m = t - (n_stages - 1)                   # micro finishing this tick
            emit = (idx == n_stages - 1) & (m >= 0)
            upd = jax.lax.dynamic_update_index_in_dim(
                buf, y, jnp.clip(m, 0, m_total - 1), axis=0)
            buf = jnp.where(emit, upd, buf)
            x_next = jax.lax.ppermute(y, axis_name, ring)
            return x_next, buf

        x0 = jnp.zeros_like(xs[0])
        buf0 = jnp.zeros_like(xs)
        _, buf = jax.lax.fori_loop(0, m_total + n_stages - 1, tick, (x0, buf0))
        # only the last rank holds real outputs; psum replicates them
        return jax.lax.psum(jnp.where(idx == n_stages - 1, buf, 0.0), axis_name)

    return shard_map(body, mesh=mesh, in_specs=(P(axis_name), P()),
                     out_specs=P(), check_rep=False)
