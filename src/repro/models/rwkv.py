"""RWKV6 (Finch) blocks — attention-free, data-dependent decay.

The wkv state is the direct LM-scale analogue of the IMPULSE membrane
potential (decay == learned leak); the recurrence runs through
kernels/wkv6 (fused VMEM-resident-state Pallas kernel on TPU, chunked
pure-jnp when lowering elsewhere).

Block = time-mix (ddlerp token shift -> r,k,v,g,w -> wkv6 -> groupnorm*silu(g)
-> out proj) + channel-mix (token shift -> relu^2 FFN with receptance gate).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.kernels.wkv6.ops import wkv6, wkv6_decode_step
from repro.models.layers import dense_init

LORA_R = 32
N_MIX = 5  # r, k, v, g, w


def init_rwkv_block(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    H, K = cfg.n_heads, cfg.rwkv.head_size
    ks = jax.random.split(key, 16)
    return {
        "tm": {  # time mix
            "mu": jnp.zeros((N_MIX, d), dtype),
            "ddlerp_w1": dense_init(ks[0], (d, N_MIX * LORA_R), dtype=dtype),
            "ddlerp_w2": dense_init(ks[1], (N_MIX, LORA_R, d), dtype=dtype),
            "decay_base": jnp.asarray(
                np.log(np.exp(-np.linspace(0.2, 5.0, d)) * 0 + 1.0)
                - np.linspace(0.0, 3.0, d), jnp.float32),      # w0 (fp32)
            "decay_w1": dense_init(ks[2], (d, LORA_R * 2), dtype=dtype),
            "decay_w2": dense_init(ks[3], (LORA_R * 2, d), dtype=dtype),
            "bonus": (jax.random.normal(ks[4], (H, K), jnp.float32) * 0.3),
            "wr": dense_init(ks[5], (d, d), dtype=dtype),
            "wk": dense_init(ks[6], (d, d), dtype=dtype),
            "wv": dense_init(ks[7], (d, d), dtype=dtype),
            "wg": dense_init(ks[8], (d, d), dtype=dtype),
            "wo": dense_init(ks[9], (d, d), dtype=dtype),
            "gn_scale": jnp.ones((d,), dtype),
        },
        "cm": {  # channel mix
            "mu_k": jnp.zeros((d,), dtype),
            "mu_r": jnp.zeros((d,), dtype),
            "wk": dense_init(ks[10], (d, ff), dtype=dtype),
            "wv": dense_init(ks[11], (ff, d), dtype=dtype),
            "wr": dense_init(ks[12], (d, d), dtype=dtype),
        },
    }


def _token_shift(x: jax.Array, last: Optional[jax.Array]) -> jax.Array:
    """x: (B, T, d) -> previous-token tensor; `last` is the carry from the
    preceding segment ((B, d)) or None for zeros."""
    prev = jnp.roll(x, 1, axis=1)
    first = jnp.zeros_like(x[:, 0]) if last is None else last.astype(x.dtype)
    return prev.at[:, 0].set(first)


def _group_norm(y: jax.Array, scale: jax.Array, n_heads: int, eps=1e-5) -> jax.Array:
    B, T, d = y.shape
    yh = y.reshape(B, T, n_heads, d // n_heads).astype(jnp.float32)
    mu = yh.mean(-1, keepdims=True)
    var = yh.var(-1, keepdims=True)
    yh = (yh - mu) * jax.lax.rsqrt(var + eps)
    return (yh.reshape(B, T, d) * scale).astype(y.dtype)


def time_mix(x: jax.Array, p: dict, cfg: ModelConfig,
             state: Optional[dict] = None, use_pallas: bool = False,
             unroll: bool = False):
    """x: (B, T, d). state: {"shift": (B, d), "wkv": (B, H, K, K)} or None.
    Returns (out, new_state)."""
    B, T, d = x.shape
    H, K = cfg.n_heads, cfg.rwkv.head_size
    prev = _token_shift(x, None if state is None else state["shift"])
    xx = prev - x
    # data-dependent lerp (ddlerp)
    base = x + xx * p["mu"][0]
    a = jnp.tanh(base @ p["ddlerp_w1"]).reshape(B, T, N_MIX, LORA_R)
    mix = jnp.einsum("btnr,nrd->btnd", a, p["ddlerp_w2"]) + p["mu"][None, None]
    xs = x[:, :, None, :] + xx[:, :, None, :] * mix           # (B, T, 5, d)
    xr, xk, xv, xg, xw = (xs[:, :, i] for i in range(N_MIX))

    r = (xr @ p["wr"]).reshape(B, T, H, K)
    k = (xk @ p["wk"]).reshape(B, T, H, K)
    v = (xv @ p["wv"]).reshape(B, T, H, K)
    g = jax.nn.silu(xg @ p["wg"])
    # data-dependent decay in (0, 1)
    dlora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(jnp.clip(p["decay_base"] + dlora.astype(jnp.float32),
                                  -8.0, 1.0))).reshape(B, T, H, K)

    s0 = None if state is None else state["wkv"]
    y, s_new = wkv6(r, k, v, w, p["bonus"], s0=s0, use_pallas=use_pallas,
                    unroll=unroll)
    y = y.reshape(B, T, d)
    out = (_group_norm(y, p["gn_scale"], H) * g) @ p["wo"]
    new_state = {"shift": x[:, -1], "wkv": s_new}
    return out, new_state


def time_mix_decode(x: jax.Array, p: dict, cfg: ModelConfig, state: dict):
    """Single-token decode. x: (B, 1, d). Mirrors time_mix with T==1 via the
    O(1) wkv state update (the fused-membrane serving path)."""
    B, _, d = x.shape
    H, K = cfg.n_heads, cfg.rwkv.head_size
    prev = state["shift"][:, None].astype(x.dtype)
    xx = prev - x
    base = x + xx * p["mu"][0]
    a = jnp.tanh(base @ p["ddlerp_w1"]).reshape(B, 1, N_MIX, LORA_R)
    mix = jnp.einsum("btnr,nrd->btnd", a, p["ddlerp_w2"]) + p["mu"][None, None]
    xs = x[:, :, None, :] + xx[:, :, None, :] * mix
    xr, xk, xv, xg, xw = (xs[:, 0, i] for i in range(N_MIX))  # (B, d)

    r = (xr @ p["wr"]).reshape(B, H, K)
    k = (xk @ p["wk"]).reshape(B, H, K)
    v = (xv @ p["wv"]).reshape(B, H, K)
    g = jax.nn.silu(xg @ p["wg"])
    dlora = jnp.tanh(xw @ p["decay_w1"]) @ p["decay_w2"]
    w = jnp.exp(-jnp.exp(jnp.clip(p["decay_base"] + dlora.astype(jnp.float32),
                                  -8.0, 1.0))).reshape(B, H, K)
    y, s_new = wkv6_decode_step(r.astype(jnp.float32), k.astype(jnp.float32),
                                v.astype(jnp.float32), w, p["bonus"],
                                state["wkv"])
    y = y.reshape(B, 1, d).astype(x.dtype)
    out = (_group_norm(y, p["gn_scale"], H) * g[:, None]) @ p["wo"]
    return out, {"shift": x[:, -1], "wkv": s_new}


def channel_mix(x: jax.Array, p: dict, state: Optional[jax.Array] = None):
    """ReLU^2 channel mix with receptance gate. state: (B, d) last token."""
    prev = _token_shift(x, state)
    xk = x + (prev - x) * p["mu_k"]
    xr = x + (prev - x) * p["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ p["wk"]))
    r = jax.nn.sigmoid(xr @ p["wr"])
    return r * (k @ p["wv"]), x[:, -1]


def init_rwkv_state(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> dict:
    H, K = cfg.n_heads, cfg.rwkv.head_size
    return {"shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
            "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
            "wkv": jnp.zeros((batch, H, K, K), jnp.float32)}
