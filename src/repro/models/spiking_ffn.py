"""SpikingFFN: IMPULSE's spiking layer as a drop-in transformer FFN.

Beyond-paper integration: the FFN hidden layer runs cfg.spiking.timesteps of
IF/LIF/RMP dynamics (rate coding) with 6-bit fake-quantized weights; energy
for the layer is then governed by the spike-count instruction model
(core.energy), giving the LM stack the same sparsity -> energy lever the
macro gives SNNs. Gradients flow via the surrogate spike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import pipeline
from repro.core.quant import fake_quant_w
from repro.models.layers import dense_init


def init_spiking_ffn(key, d_model: int, d_ff: int, dtype=jnp.bfloat16) -> dict:
    k1, k2 = jax.random.split(key)
    return {"up": dense_init(k1, (d_model, d_ff), dtype=dtype),
            "down": dense_init(k2, (d_ff, d_model), dtype=dtype)}


def spiking_ffn(x: jax.Array, p: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, d). Returns (out, mean_spike_rate). Rate-coded: the hidden
    spiking population integrates the same current for `timesteps` steps; the
    normalized spike count is the activation. The temporal loop is the
    pipeline's float executor on a single-population program."""
    sp = cfg.spiking
    w_up = fake_quant_w(p["up"].astype(jnp.float32)).astype(x.dtype)
    current = (x @ w_up).astype(jnp.float32)

    program = pipeline.rate_coded_program(sp, current.shape[1:])
    res = pipeline.run_network(program, current, "float", collect_sums=True,
                               static_input=True)
    h = (res.aux["spike_sums"][0] / sp.timesteps).astype(x.dtype)
    w_down = fake_quant_w(p["down"].astype(jnp.float32)).astype(x.dtype)
    return h @ w_down, res.aux["spike_rates"].mean()
