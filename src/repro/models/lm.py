"""Full language models for every assigned architecture family.

One functional API over all families (dense / moe / hybrid / ssm / audio / vlm):

  init_params(key, cfg)                         -> params pytree
  loss_fn(params, batch, cfg, parallel)         -> (loss, aux)      [train]
  prefill(params, batch, cfg)                   -> (logits_last, cache)
  decode_step(params, tokens, cache, cfg)       -> (logits, cache)  [serve]
  init_cache(cfg, batch, max_len)               -> cache pytree

Layer stacks are scanned over *super-blocks* (the LCM of the attention/MoE
interleave periods) so heterogeneous archs (Jamba 1:7 Mamba:attn with MoE
every 2; Llama-4 dense/MoE alternation) still compile to a single compact
scan. Remat is applied per super-block.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ParallelConfig
from repro.dist.sharding import constrain
from repro.models import layers as L
from repro.models import mamba as M
from repro.models import rwkv as R
from repro.models.spiking_ffn import init_spiking_ffn, spiking_ffn


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------

def super_period(cfg: ModelConfig) -> int:
    p = cfg.attn_layer_period
    if cfg.moe is not None and cfg.moe.n_experts:
        p = math.lcm(p, cfg.moe.every)
    return p


def n_prelude(cfg: ModelConfig) -> int:
    """Leading layers handled outside the scan (deepseek's first dense layer)."""
    if cfg.moe is not None and cfg.moe.first_k_dense:
        return cfg.moe.first_k_dense
    return 0


def n_super(cfg: ModelConfig) -> int:
    body = cfg.n_layers - n_prelude(cfg)
    sp = super_period(cfg)
    if body % sp != 0:
        raise ValueError(
            f"{cfg.arch_id}: {body} body layers do not divide into "
            f"super-blocks of period {sp}")
    return body // sp


def layer_kind(cfg: ModelConfig, idx: int) -> tuple[str, str]:
    """(mixer, ffn) kinds for global layer index idx."""
    if cfg.rwkv is not None:
        return "rwkv", "none"
    mixer = "attn" if cfg.is_attention_layer(idx) else "ssm"
    if cfg.spiking is not None:
        f = "spiking"
    elif cfg.is_moe_layer(idx):
        f = "moe"
    else:
        f = "dense"
    return mixer, f


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ModelConfig, idx: int, dtype) -> dict:
    mixer, f = layer_kind(cfg, idx)
    ks = jax.random.split(key, 4)
    p: dict = {"norm1": jnp.ones((cfg.d_model,), dtype)}
    if mixer == "rwkv":
        p["rwkv"] = R.init_rwkv_block(ks[0], cfg, dtype)
        p["norm2"] = jnp.ones((cfg.d_model,), dtype)
        return p
    if mixer == "attn":
        p["attn"] = (L.init_mla(ks[0], cfg, dtype) if cfg.mla is not None
                     else L.init_attention(ks[0], cfg, dtype=dtype))
        if cfg.is_encoder_decoder:
            p["cross"] = L.init_attention(ks[3], cfg, cross=True, dtype=dtype)
            p["norm_cross"] = jnp.ones((cfg.d_model,), dtype)
    else:
        p["ssm"] = M.init_mamba_block(ks[0], cfg, dtype)
    p["norm2"] = jnp.ones((cfg.d_model,), dtype)
    if f == "moe":
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    elif f == "spiking":
        p["ffn"] = init_spiking_ffn(ks[1], cfg.d_model, cfg.d_ff, dtype)
    else:
        d_ff = cfg.d_ff
        if cfg.moe is not None and cfg.moe.dense_d_ff:
            d_ff = cfg.moe.dense_d_ff
        p["ffn"] = L.init_ffn(ks[1], cfg.d_model, d_ff, cfg.ffn_type, dtype)
    return p


def _init_encoder_block(key, cfg: ModelConfig, dtype) -> dict:
    ks = jax.random.split(key, 2)
    return {"norm1": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(ks[0], cfg, cross=True, dtype=dtype),  # MHA
            "norm2": jnp.ones((cfg.d_model,), dtype),
            "ffn": L.init_ffn(ks[1], cfg.d_model, cfg.d_ff, cfg.ffn_type, dtype)}


def init_params(key: jax.Array, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    params: dict = {
        "embed": (jax.random.normal(ks[0], (cfg.vocab_size, d), jnp.float32)
                  * 0.02).astype(dtype),
        "final_norm": jnp.ones((d,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(ks[1], (d, cfg.vocab_size), dtype=dtype)
    # prelude layers (python-level, heterogeneous head of the stack)
    pre = [
        _init_block(jax.random.fold_in(ks[2], i), cfg, i, dtype)
        for i in range(n_prelude(cfg))
    ]
    if pre:
        params["prelude"] = pre
    # scanned body: stack n_super super-blocks
    sp = super_period(cfg)
    off = n_prelude(cfg)

    def one_super(k):
        return {f"pos{j}": _init_block(jax.random.fold_in(k, j), cfg, off + j, dtype)
                for j in range(sp)}

    supers = [one_super(jax.random.fold_in(ks[3], s)) for s in range(n_super(cfg))]
    params["blocks"] = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *supers)
    if cfg.is_encoder_decoder:
        encs = [_init_encoder_block(jax.random.fold_in(ks[4], i), cfg, dtype)
                for i in range(cfg.n_encoder_layers)]
        params["encoder"] = {
            "blocks": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *encs),
            "final_norm": jnp.ones((d,), dtype),
        }
    return params


# ---------------------------------------------------------------------------
# block application (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _norm(x, w, cfg: ModelConfig):
    return L.rms_norm(x, w, cfg.norm_eps)


def _apply_block(x, p, cfg: ModelConfig, idx: int, positions, *,
                 cache: Optional[dict], pos=None, enc_out=None,
                 parallel: Optional[ParallelConfig] = None):
    """One layer. Returns (x, new_cache_entry, aux_scalar)."""
    mixer, f = layer_kind(cfg, idx)
    aux = jnp.zeros((), jnp.float32)
    decode = cache is not None and x.shape[1] == 1 and pos is not None

    if mixer == "rwkv":
        if decode:
            st = {"shift": cache["shift_tm"], "wkv": cache["wkv"]}
            h, st = R.time_mix_decode(_norm(x, p["norm1"], cfg), p["rwkv"]["tm"], cfg, st)
            x = x + h.astype(x.dtype)
            h, shift_cm = R.channel_mix(_norm(x, p["norm2"], cfg), p["rwkv"]["cm"],
                                        cache["shift_cm"])
            x = x + h.astype(x.dtype)
            new_cache = {"shift_tm": st["shift"], "wkv": st["wkv"],
                         "shift_cm": shift_cm}
        else:
            st_in = cache
            h, st = R.time_mix(_norm(x, p["norm1"], cfg), p["rwkv"]["tm"], cfg,
                               None if st_in is None else
                               {"shift": st_in["shift_tm"], "wkv": st_in["wkv"]},
                               unroll=(parallel.unroll_time_scans
                                       if parallel else False))
            x = x + h.astype(x.dtype)
            h, shift_cm = R.channel_mix(_norm(x, p["norm2"], cfg), p["rwkv"]["cm"],
                                        None if st_in is None else st_in["shift_cm"])
            x = x + h.astype(x.dtype)
            new_cache = {"shift_tm": st["shift"], "wkv": st["wkv"],
                         "shift_cm": shift_cm}
        return x, new_cache, aux

    # --- mixer ---
    h_in = _norm(x, p["norm1"], cfg)
    if mixer == "attn":
        if cfg.mla is not None:
            if decode:
                h, latent_new = L.mla_attention(h_in, p["attn"], cfg, positions,
                                                latent_cache=cache["latent"],
                                                pos=pos)
                new_cache = {"latent": latent_new}
            else:
                h, latent_all = L.mla_attention(h_in, p["attn"], cfg, positions)
                if cache is not None:                   # prefill: fill cache
                    lc = jax.lax.dynamic_update_slice_in_dim(
                        cache["latent"], latent_all.astype(cache["latent"].dtype),
                        0, axis=1)
                    new_cache = {"latent": lc}
                else:
                    new_cache = None
        elif decode:
            h, kv = L.attention_decode(h_in, p["attn"], cfg,
                                       {"k": cache["k"], "v": cache["v"]}, pos)
            new_cache = kv
        else:
            h = L.attention(h_in, p["attn"], cfg, positions,
                            q_chunk=(parallel.attn_q_chunk if parallel else 0),
                            kv_block=(parallel.attn_kv_block if parallel else 1024),
                            unroll=(parallel.unroll_time_scans if parallel else False))
            if cache is not None:                       # prefill: fill cache
                hd = cfg.head_dim
                B, T, _ = h_in.shape
                k = (h_in @ p["attn"]["wk"]).reshape(B, T, -1, hd)
                v = (h_in @ p["attn"]["wv"]).reshape(B, T, -1, hd)
                if cfg.rope_theta > 0:
                    k = L.apply_rope(k, positions, cfg.rope_theta)
                kc = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
                new_cache = {"k": kc, "v": vc}
            else:
                new_cache = None
        x = x + h.astype(x.dtype)
        if cfg.is_encoder_decoder and enc_out is not None:
            h = L.attention(_norm(x, p["norm_cross"], cfg), p["cross"], cfg,
                            positions, causal=False, kv_x=enc_out)
            x = x + h.astype(x.dtype)
    else:  # ssm (mamba)
        st = cache if cache is None else {"conv": cache["conv"], "ssm": cache["ssm"]}
        if decode:
            h, st = M.mamba_decode(h_in, p["ssm"], cfg, st)
        else:
            h, st = M.mamba_forward(h_in, p["ssm"], cfg, st,
                                    unroll=(parallel.unroll_time_scans
                                            if parallel else False),
                                    constraints=(parallel.state_constraints
                                                 if parallel else False))
        new_cache = st
        x = x + h.astype(x.dtype)

    # --- ffn ---
    h_in = _norm(x, p["norm2"], cfg)
    if f == "moe":
        h, lb = L.moe_ffn(h_in, p["moe"], cfg,
                          constraints=(parallel.moe_constraints
                                       if parallel else False),
                          gather_dispatch=(parallel.moe_gather_dispatch
                                           if parallel else False))
        aux = aux + lb
    elif f == "spiking":
        h, rate = spiking_ffn(h_in, p["ffn"], cfg)
        aux = aux + rate
    else:
        d_ff_type = cfg.ffn_type
        h = L.ffn(h_in, p["ffn"], d_ff_type)
    x = x + h.astype(x.dtype)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stack runners
# ---------------------------------------------------------------------------

def _run_stack(params, x, cfg: ModelConfig, positions, *, cache=None, pos=None,
               enc_out=None, parallel: Optional[ParallelConfig] = None):
    """Prelude layers + scanned super-blocks. Returns (x, new_cache, aux)."""
    parallel = parallel or ParallelConfig()
    aux_total = jnp.zeros((), jnp.float32)
    new_pre = []
    for i, p in enumerate(params.get("prelude", [])):
        c = None if cache is None else cache["prelude"][i]
        x, c_new, aux = _apply_block(x, p, cfg, i, positions, cache=c, pos=pos,
                                     enc_out=enc_out, parallel=parallel)
        new_pre.append(c_new)
        aux_total = aux_total + aux

    sp = super_period(cfg)
    off = n_prelude(cfg)

    def super_fn(carry, inp):
        x, aux_acc = carry
        p_s, c_s = inp
        # boundary activations: batch over DP axes, seq over the model axis
        # (Megatron-style sequence parallelism; no-op without active rules)
        x = constrain(x, ("batch", "seq", None))
        c_new = {} if c_s is not None else None
        for j in range(sp):
            c = None if c_s is None else c_s[f"pos{j}"]
            x, c_j, aux = _apply_block(x, p_s[f"pos{j}"], cfg, off + j, positions,
                                       cache=c, pos=pos, enc_out=enc_out,
                                       parallel=parallel)
            if c_new is not None:
                c_new[f"pos{j}"] = c_j
            aux_acc = aux_acc + aux
        return (x, aux_acc), c_new

    fn = super_fn
    if parallel.remat != "none":
        fn = jax.checkpoint(super_fn, prevent_cse=False)

    cache_blocks = None if cache is None else cache["blocks"]
    if parallel.scan_layers:
        (x, aux_total), new_blocks = jax.lax.scan(
            fn, (x, aux_total), (params["blocks"], cache_blocks))
    else:
        ns = n_super(cfg)
        new_list = []
        for s in range(ns):
            p_s = jax.tree_util.tree_map(lambda a: a[s], params["blocks"])
            c_s = (None if cache_blocks is None else
                   jax.tree_util.tree_map(lambda a: a[s], cache_blocks))
            (x, aux_total), c_new = fn((x, aux_total), (p_s, c_s))
            new_list.append(c_new)
        new_blocks = (None if cache_blocks is None else
                      jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *new_list))

    new_cache = None
    if cache is not None:
        new_cache = dict(cache)
        new_cache["blocks"] = new_blocks
        if new_pre:
            new_cache["prelude"] = new_pre
    return x, new_cache, aux_total


def _run_encoder(params, frames, cfg: ModelConfig,
                 parallel: Optional[ParallelConfig] = None):
    """Whisper-style encoder over stub frame embeddings (B, S, d)."""
    parallel = parallel or ParallelConfig()
    x = frames + L.sinusoidal_positions(frames.shape[1], cfg.d_model
                                        ).astype(frames.dtype)[None]

    def enc_fn(x, p):
        h = L.attention(_norm(x, p["norm1"], cfg), p["attn"], cfg,
                        jnp.arange(x.shape[1])[None], causal=False,
                        use_rope=False,
                        q_chunk=parallel.attn_q_chunk,
                        kv_block=parallel.attn_kv_block,
                        unroll=parallel.unroll_time_scans)
        x = x + h
        x = x + L.ffn(_norm(x, p["norm2"], cfg), p["ffn"], cfg.ffn_type)
        return x, None

    fn = enc_fn
    if parallel.remat != "none":
        fn = jax.checkpoint(enc_fn, prevent_cse=False)
    x, _ = jax.lax.scan(fn, x, params["encoder"]["blocks"])
    return _norm(x, params["encoder"]["final_norm"], cfg)


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------

def _embed_inputs(params, batch: dict, cfg: ModelConfig):
    """tokens (+ modality stubs) -> (x, positions, enc_out)."""
    emb = params["embed"]
    enc_out = None
    if cfg.is_encoder_decoder:
        x = jnp.take(emb, batch["tokens"], axis=0)
        x = x + L.sinusoidal_positions(x.shape[1], cfg.d_model).astype(x.dtype)[None]
        positions = jnp.arange(x.shape[1])[None]
        return x, positions, batch["frames"]                  # frames: encoder input
    if cfg.frontend == "vision_stub" and "patches" in batch:
        tok = jnp.take(emb, batch["tokens"], axis=0)
        x = jnp.concatenate([batch["patches"].astype(tok.dtype), tok], axis=1)
    else:
        x = jnp.take(emb, batch["tokens"], axis=0)
    positions = jnp.arange(x.shape[1])[None]
    return x, positions, enc_out


def _logits(params, x, cfg: ModelConfig):
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return (x.astype(jnp.float32) @ head.astype(jnp.float32))


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------

def loss_fn(params, batch: dict, cfg: ModelConfig,
            parallel: Optional[ParallelConfig] = None):
    """Causal-LM (or enc-dec) cross entropy. batch: tokens/targets (+frames/
    patches). Returns (loss, aux)."""
    parallel = parallel or ParallelConfig()
    x, positions, enc_src = _embed_inputs(params, batch, cfg)
    enc_out = None
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(params, enc_src, cfg, parallel)
    x, _, aux = _run_stack(params, x, cfg, positions, enc_out=enc_out,
                           parallel=parallel)
    x = _norm(x, params["final_norm"], cfg)
    targets = batch["targets"]
    if cfg.frontend == "vision_stub" and "patches" in batch:
        x = x[:, batch["patches"].shape[1]:]                  # text positions only
    n_chunks = max(parallel.vocab_chunking, 1)
    B, T, _ = x.shape
    if T % n_chunks != 0:
        raise ValueError(f"vocab_chunking={n_chunks} must divide the "
                         f"sequence length, got T={T}")

    def ce(xc, tc):
        lg = _logits(params, xc, cfg)
        lg = constrain(lg, ("batch", None, "vocab"))
        lp = jax.nn.log_softmax(lg, axis=-1)
        return -jnp.take_along_axis(lp, tc[..., None], axis=-1)[..., 0]

    if n_chunks == 1:
        losses = ce(x, targets)
    else:
        # python loop (not lax.map): each chunk is rematerialized in the
        # backward pass so only one (B, T/n, vocab) logits buffer is ever
        # live, and XLA cost analysis sees every chunk (while-loop bodies
        # are counted once — see dryrun.py).
        ck = jax.checkpoint(ce, prevent_cse=False)
        step = T // n_chunks
        losses = jnp.concatenate(
            [ck(x[:, i * step:(i + 1) * step], targets[:, i * step:(i + 1) * step])
             for i in range(n_chunks)], axis=1)
    loss = losses.mean() + 0.01 * aux
    return loss, {"ce": losses.mean(), "aux": aux}


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               enc_len: int = 0) -> dict:
    """Pre-allocated serving cache for every layer kind."""
    def entry(idx: int):
        mixer, _ = layer_kind(cfg, idx)
        if mixer == "rwkv":
            H, K = cfg.n_heads, cfg.rwkv.head_size
            return {"shift_tm": jnp.zeros((batch, cfg.d_model), dtype),
                    "shift_cm": jnp.zeros((batch, cfg.d_model), dtype),
                    "wkv": jnp.zeros((batch, H, K, K), jnp.float32)}
        if mixer == "ssm":
            s = cfg.ssm
            d_in = s.expand * cfg.d_model
            return {"conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
                    "ssm": jnp.zeros((batch, d_in, s.d_state), jnp.float32)}
        if cfg.mla is not None:
            m = cfg.mla
            return {"latent": jnp.zeros(
                (batch, max_len, m.kv_lora_rank + m.rope_head_dim), dtype)}
        return {"k": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype),
                "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, cfg.head_dim), dtype)}

    sp = super_period(cfg)
    off = n_prelude(cfg)
    supers = [{f"pos{j}": entry(off + j) for j in range(sp)}] * n_super(cfg)
    cache = {"blocks": jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *supers),
             "len": jnp.zeros((batch,), jnp.int32)}
    if n_prelude(cfg):
        cache["prelude"] = [entry(i) for i in range(n_prelude(cfg))]
    if cfg.is_encoder_decoder:
        cache["enc_out"] = jnp.zeros((batch, enc_len, cfg.d_model), dtype)
    return cache


def prefill(params, batch: dict, cfg: ModelConfig, max_len: int,
            parallel: Optional[ParallelConfig] = None, length=None):
    """Process the prompt; return (last-token logits, populated cache).

    ``length`` (scalar int32, may be traced): the true prompt length when
    ``batch["tokens"]`` is right-padded to a compile-shape bucket. Logits
    are read at position length-1 and ``cache["len"]`` is set to length, so
    one compiled variant serves every prompt length in the bucket. Exact
    for causal-attention stacks: position length-1 never attends the
    padding (causality), padded K/V slots beyond length are masked out of
    decode by ``kv_len`` and overwritten as decode advances. NOT valid for
    recurrent mixers (ssm/rwkv), whose state would integrate the padding —
    callers gate on the config (see ServeEngine._bucket_prompts)."""
    parallel = parallel or ParallelConfig()
    x, positions, enc_src = _embed_inputs(params, batch, cfg)
    enc_out = None
    cache = init_cache(cfg, x.shape[0], max_len,
                       enc_len=(enc_src.shape[1] if cfg.is_encoder_decoder else 0))
    if cfg.is_encoder_decoder:
        enc_out = _run_encoder(params, enc_src, cfg, parallel)
        cache["enc_out"] = enc_out
    x, cache, _ = _run_stack(params, x, cfg, positions, cache=cache,
                             enc_out=enc_out, parallel=parallel)
    x = _norm(x, params["final_norm"], cfg)
    if length is None:
        last = x[:, -1:]
        n = jnp.int32(x.shape[1])
    else:
        n = jnp.asarray(length, jnp.int32)
        last = jax.lax.dynamic_slice_in_dim(x, n - 1, 1, axis=1)
    logits = _logits(params, last, cfg)[:, 0]
    cache["len"] = jnp.full((x.shape[0],), n, jnp.int32)
    return logits, cache


def decode_step(params, tokens: jax.Array, cache: dict, cfg: ModelConfig,
                parallel: Optional[ParallelConfig] = None):
    """One serving step: tokens (B, 1) -> (logits (B, vocab), cache')."""
    parallel = parallel or ParallelConfig()
    pos = cache["len"]
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.is_encoder_decoder:
        d = cfg.d_model
        i = jnp.arange(d // 2, dtype=jnp.float32)
        ang = pos[:, None].astype(jnp.float32) / jnp.power(10000.0, 2 * i / d)
        pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)
        x = x + pe[:, None].astype(x.dtype)
    enc_out = cache.get("enc_out")
    positions = pos[:, None]
    x, cache, _ = _run_stack(params, x, cfg, positions, cache=cache, pos=pos,
                             enc_out=enc_out, parallel=parallel)
    x = _norm(x, params["final_norm"], cfg)
    logits = _logits(params, x, cfg)[:, 0]
    cache = dict(cache)
    cache["len"] = pos + 1
    return logits, cache
