"""Mamba (selective SSM) block for the Jamba hybrid architecture.

The SSM hidden state h (d_inner x d_state per token stream) is another
membrane-potential analogue: h_t = a_t * h_{t-1} + b_t with data-dependent
decay a_t = exp(dt_t * A). Train/prefill uses a chunked associative scan
(compile-friendly, bounded working set); decode is the O(1) state update.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init


def init_mamba_block(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    d_in = s.expand * d
    ks = jax.random.split(key, 8)
    a_init = np.tile(np.arange(1, s.d_state + 1, dtype=np.float32), (d_in, 1))
    return {
        "in_proj": dense_init(ks[0], (d, 2 * d_in), dtype=dtype),
        "conv_w": dense_init(ks[1], (s.d_conv, d_in), dtype=dtype),
        "conv_b": jnp.zeros((d_in,), dtype),
        "x_proj": dense_init(ks[2], (d_in, s.dt_rank + 2 * s.d_state), dtype=dtype),
        "dt_proj": dense_init(ks[3], (s.dt_rank, d_in), dtype=dtype),
        "dt_bias": jnp.asarray(np.log(np.expm1(np.full(d_in, 0.01))), jnp.float32),
        "a_log": jnp.asarray(np.log(a_init), jnp.float32),    # (d_in, N)
        "d_skip": jnp.ones((d_in,), jnp.float32),
        "out_proj": dense_init(ks[4], (d_in, d), dtype=dtype),
    }


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 conv_state: Optional[jax.Array] = None):
    """Depthwise causal conv over time. x: (B, T, d_in); w: (d_conv, d_in).
    conv_state: (B, d_conv-1, d_in) carry-in. Returns (y, new_state)."""
    d_conv = w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], d_conv - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(d_conv)) + b
    return y, xp[:, -(d_conv - 1):]


def _ssm_chunked(a_log_dt, bx, c, h0, chunk: int, unroll: bool = False,
                 remat_chunks: bool = False):
    """Selective scan. a_log_dt (=dt*A, the log-decay), bx (=dt*B*x): both
    (B, T, d_in, N); c: (B, T, N). h0: (B, d_in, N). Chunked: scan over T/chunk
    with an associative scan inside each chunk. Returns (y (B,T,d_in), h_T).

    remat_chunks: checkpoint each chunk body — the backward pass then saves
    only the (B, d_in, N) chunk-boundary states instead of the full
    (B, T, d_in, N) associative-scan residuals (a TB-scale saving at pod
    batch sizes; §Perf jamba hillclimb)."""
    B, T, d_in, N = bx.shape
    if T % chunk != 0:
        raise ValueError(f"chunked ssm scan needs T % chunk == 0, got "
                         f"T={T}, chunk={chunk}")
    nch = T // chunk
    a_c = a_log_dt.reshape(B, nch, chunk, d_in, N)
    b_c = bx.reshape(B, nch, chunk, d_in, N)
    c_c = c.reshape(B, nch, chunk, N)

    def combine(p, q):
        (la1, b1), (la2, b2) = p, q
        return la1 + la2, jnp.exp(la2) * b1 + b2

    def per_chunk(h, inp):
        la, b, cc = inp                                        # (B, chunk, d_in, N), ..., (B, chunk, N)
        la_cum, b_scan = jax.lax.associative_scan(combine, (la, b), axis=1)
        h_all = b_scan + jnp.exp(la_cum) * h[:, None]          # include carry-in
        y = jnp.einsum("btdn,btn->btd", h_all, cc)
        return h_all[:, -1], y

    fn = jax.checkpoint(per_chunk, prevent_cse=False) if remat_chunks else per_chunk
    xs = (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0))
    h, ys = jax.lax.scan(fn, h0, xs, unroll=nch if unroll else 1)
    return jnp.moveaxis(ys, 0, 1).reshape(B, T, d_in), h


def mamba_forward(x: jax.Array, p: dict, cfg: ModelConfig,
                  state: Optional[dict] = None, chunk: int = 128,
                  unroll: bool = False, constraints: bool = False):
    """x: (B, T, d). state: {"conv": (B, d_conv-1, d_in), "ssm": (B, d_in, N)}.
    Returns (out, new_state). ``constraints`` pins the (B,T,d_in,N) scan
    tensors to (batch x model) — without it GSPMD replicates them (§Perf)."""
    s = cfg.ssm
    B, T, d = x.shape
    d_in = s.expand * d
    xz = x @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv_state = None if state is None else state["conv"]
    xs, conv_new = _causal_conv(xs, p["conv_w"], p["conv_b"], conv_state)
    xs = jax.nn.silu(xs)

    proj = xs @ p["x_proj"]
    dt_r, b_mat, c_mat = jnp.split(proj, [s.dt_rank, s.dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])                                   # (d_in, N)
    la = dt[..., None] * a                                     # log decay (B,T,d_in,N)
    bx = dt[..., None] * b_mat[:, :, None, :].astype(jnp.float32) \
        * xs[..., None].astype(jnp.float32)

    h0 = (jnp.zeros((B, d_in, s.d_state), jnp.float32)
          if state is None else state["ssm"])
    if constraints:
        from repro.dist.sharding import constrain
        la = constrain(la, ("batch", None, "ffn", None))
        bx = constrain(bx, ("batch", None, "ffn", None))
    if unroll:
        # dry-run accounting mode (never executed): the cost-equivalent
        # log-space cumsum form h_t = e^{L_t} (h0 + sum_{s<=t} e^{-L_s} b_s)
        # — identical O(T d N) op mix, no while loop, compiles in seconds.
        # (Numerically unstable; the executed path below is the chunked scan.)
        L = jnp.cumsum(la, axis=1)
        hs = jnp.exp(L) * (jnp.cumsum(jnp.exp(-L) * bx, axis=1) + h0[:, None])
        y = jnp.einsum("btdn,btn->btd", hs, c_mat.astype(jnp.float32))
        h = hs[:, -1]
    else:
        pad = (-T) % chunk
        if pad:
            la = jnp.pad(la, ((0, 0), (0, pad), (0, 0), (0, 0)))
            bx = jnp.pad(bx, ((0, 0), (0, pad), (0, 0), (0, 0)))
            c_pad = jnp.pad(c_mat.astype(jnp.float32), ((0, 0), (0, pad), (0, 0)))
        else:
            c_pad = c_mat.astype(jnp.float32)
        y, h = _ssm_chunked(la, bx, c_pad, h0, chunk,
                            remat_chunks=constraints)
    y = y[:, :T] + xs.astype(jnp.float32) * p["d_skip"]
    out = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["out_proj"]
    return out, {"conv": conv_new, "ssm": h}


def mamba_decode(x: jax.Array, p: dict, cfg: ModelConfig, state: dict):
    """One-token decode. x: (B, 1, d). O(1) state update."""
    s = cfg.ssm
    xz = x[:, 0] @ p["in_proj"]
    xs, z = jnp.split(xz, 2, axis=-1)
    conv = state["conv"]
    window = jnp.concatenate([conv.astype(xs.dtype), xs[:, None]], axis=1)
    xc = jnp.einsum("bcd,cd->bd", window, p["conv_w"]) + p["conv_b"]
    xc = jax.nn.silu(xc)
    proj = xc @ p["x_proj"]
    dt_r, b_mat, c_mat = jnp.split(proj, [s.dt_rank, s.dt_rank + s.d_state], axis=-1)
    dt = jax.nn.softplus((dt_r @ p["dt_proj"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    decay = jnp.exp(dt[..., None] * a)                         # (B, d_in, N)
    bx = dt[..., None] * b_mat[:, None, :].astype(jnp.float32) * xc[..., None].astype(jnp.float32)
    h = decay * state["ssm"] + bx
    y = jnp.einsum("bdn,bn->bd", h, c_mat.astype(jnp.float32)) \
        + xc.astype(jnp.float32) * p["d_skip"]
    out = (y.astype(x.dtype) * jax.nn.silu(z))[:, None] @ p["out_proj"]
    return out, {"conv": window[:, 1:], "ssm": h}


def init_mamba_state(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    s = cfg.ssm
    d_in = s.expand * cfg.d_model
    return {"conv": jnp.zeros((batch, s.d_conv - 1, d_in), dtype),
            "ssm": jnp.zeros((batch, d_in, s.d_state), jnp.float32)}
