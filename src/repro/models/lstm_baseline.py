"""2-layer LSTM baseline (the paper's comparison network, Fig. 9b).

hidden=128, 2 layers + scalar head = 248.5K params (paper: 247.8K) vs the
SNN's 29.3K — the 8.5x parameter ratio the paper reports.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def init_lstm(key, in_dim: int = 100, hidden: int = 128, layers: int = 2) -> dict:
    ks = jax.random.split(key, layers + 1)
    out = {"layers": []}
    d = in_dim
    for i in range(layers):
        k1, k2 = jax.random.split(ks[i])
        out["layers"].append({
            "wx": jax.random.normal(k1, (d, 4 * hidden)) / np.sqrt(d),
            "wh": jax.random.normal(k2, (hidden, 4 * hidden)) / np.sqrt(hidden),
            "b": jnp.zeros((4 * hidden,)),
        })
        d = hidden
    out["head"] = jax.random.normal(ks[-1], (hidden, 1)) / np.sqrt(hidden)
    out["head_b"] = jnp.zeros((1,))
    return out


def param_count(params: dict) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


def lstm_apply(params: dict, x: jax.Array) -> jax.Array:
    """x: (B, T, in_dim) -> logits (B,)."""
    B = x.shape[0]
    hs = [jnp.zeros((B, p["wh"].shape[0])) for p in params["layers"]]
    cs = [jnp.zeros_like(h) for h in hs]

    def step(carry, xt):
        hs, cs = carry
        inp = xt
        hs2, cs2 = [], []
        for p, h, c in zip(params["layers"], hs, cs):
            z = inp @ p["wx"] + h @ p["wh"] + p["b"]
            i, f, g, o = jnp.split(z, 4, axis=-1)
            c = jax.nn.sigmoid(f + 1.0) * c + jax.nn.sigmoid(i) * jnp.tanh(g)
            h = jax.nn.sigmoid(o) * jnp.tanh(c)
            hs2.append(h)
            cs2.append(c)
            inp = h
        return (hs2, cs2), None

    (hs, _), _ = jax.lax.scan(step, (hs, cs), jnp.moveaxis(x, 1, 0))
    return (hs[-1] @ params["head"] + params["head_b"])[:, 0]


def lstm_loss(params, x, labels):
    z = lstm_apply(params, x)
    loss = jnp.mean(jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z))))
    acc = jnp.mean((z > 0) == (labels > 0.5))
    return loss, acc
