"""Input specifications per (architecture x shape): real arrays for smoke
tests, ShapeDtypeStructs for the dry-run (same code path, no allocation).

Shape semantics (documented in EXPERIMENTS.md):
  train    -> loss_fn batch  {tokens, targets [, frames | patches]}
  prefill  -> prefill batch  {tokens [, frames | patches]}
  decode   -> decode_step    (tokens (B,1), cache with len=seq_len)

Modality stubs per the assignment: whisper gets precomputed frame embeddings
(B, S, d_model); llava gets patch embeddings for vision_patch_frac of the
sequence. Encoder-decoder: prefill runs the encoder over seq_len frames plus a
seq_len//8-token decoder prefill; decode attends a seq_len self-cache and a
min(seq_len, 4096)-frame cross cache.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import lm


def train_batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    spec: dict = {}
    if cfg.is_encoder_decoder:
        spec["frames"] = jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16)
        spec["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        spec["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return spec
    if cfg.frontend == "vision_stub":
        n_patch = int(S * cfg.vision_patch_frac)
        spec["patches"] = jax.ShapeDtypeStruct((B, n_patch, cfg.d_model), jnp.bfloat16)
        spec["tokens"] = jax.ShapeDtypeStruct((B, S - n_patch), jnp.int32)
        spec["targets"] = jax.ShapeDtypeStruct((B, S - n_patch), jnp.int32)
        return spec
    spec["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    spec["targets"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return spec


def prefill_batch_spec(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    if cfg.is_encoder_decoder:
        return {"frames": jax.ShapeDtypeStruct((B, S, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, max(S // 8, 1)), jnp.int32)}
    if cfg.frontend == "vision_stub":
        n_patch = int(S * cfg.vision_patch_frac)
        return {"patches": jax.ShapeDtypeStruct((B, n_patch, cfg.d_model), jnp.bfloat16),
                "tokens": jax.ShapeDtypeStruct((B, S - n_patch), jnp.int32)}
    return {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}


def decode_spec(cfg: ModelConfig, shape: ShapeConfig) -> tuple[Any, dict]:
    """(tokens spec, cache spec). Cache is built with jax.eval_shape so no
    memory is allocated."""
    B, S = shape.global_batch, shape.seq_len
    enc_len = min(S, 4096) if cfg.is_encoder_decoder else 0
    cache = jax.eval_shape(lambda: lm.init_cache(cfg, B, S, enc_len=enc_len))
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    return tokens, cache


def materialize(spec, seed: int = 0):
    """Turn a spec pytree into concrete arrays (smoke tests only)."""
    rng = np.random.default_rng(seed)

    def gen(s):
        if jnp.issubdtype(s.dtype, jnp.integer):
            return jnp.asarray(rng.integers(0, 64, s.shape), s.dtype)
        return jnp.asarray(rng.standard_normal(s.shape) * 0.02, s.dtype)

    return jax.tree_util.tree_map(gen, spec)
