"""Shared layer library: norms, RoPE, FFNs, GQA/MLA attention, MoE.

All layers are pure functions over explicit param pytrees. Parameters carry
*logical axis* names via dist.sharding.logical_axes metadata (set at init by
the `with_axes` helpers) so the sharding-rules engine can place them on the
mesh without the layers knowing about meshes.

Numerics: params/activations bf16 by default; norms, softmax, router and
logits accumulate in fp32.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

Param = dict  # {"value": array} plus logical axes registered in dist.sharding


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    fan_in = shape[in_axis]
    return (jax.random.normal(key, shape, jnp.float32) / np.sqrt(fan_in)).astype(dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w


def layer_norm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype) * w + b


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, D); positions: (..., T) int32."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                              # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs    # (..., T, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def sinusoidal_positions(seq: int, d_model: int) -> jax.Array:
    pos = np.arange(seq)[:, None]
    i = np.arange(d_model // 2)[None, :]
    ang = pos / np.power(10000.0, 2 * i / d_model)
    return jnp.asarray(np.concatenate([np.sin(ang), np.cos(ang)], axis=-1),
                       jnp.float32)


# ---------------------------------------------------------------------------
# FFN
# ---------------------------------------------------------------------------

def init_ffn(key, d_model: int, d_ff: int, ffn_type: str, dtype=jnp.bfloat16) -> dict:
    ks = jax.random.split(key, 3)
    if ffn_type == "swiglu":
        return {"gate": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
                "up": dense_init(ks[1], (d_model, d_ff), dtype=dtype),
                "down": dense_init(ks[2], (d_ff, d_model), dtype=dtype)}
    return {"up": dense_init(ks[0], (d_model, d_ff), dtype=dtype),
            "down": dense_init(ks[1], (d_ff, d_model), dtype=dtype)}


def ffn(x: jax.Array, p: dict, ffn_type: str) -> jax.Array:
    if ffn_type == "swiglu":
        h = jax.nn.silu(x @ p["gate"]) * (x @ p["up"])
    else:
        h = jax.nn.gelu(x @ p["up"])
    return h @ p["down"]


# ---------------------------------------------------------------------------
# Attention (GQA; optional cross-attention; decode with KV cache)
# ---------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, cross: bool = False, dtype=jnp.bfloat16) -> dict:
    d, hd = cfg.d_model, cfg.head_dim
    nh, nkv = cfg.n_heads, (cfg.n_heads if cross else cfg.n_kv_heads)
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], (d, nh * hd), dtype=dtype),
            "wk": dense_init(ks[1], (d, nkv * hd), dtype=dtype),
            "wv": dense_init(ks[2], (d, nkv * hd), dtype=dtype),
            "wo": dense_init(ks[3], (nh * hd, d), dtype=dtype)}


def _sdpa(q, k, v, *, causal: bool, q_pos=None, kv_len=None):
    """q: (B, T, H, D); k, v: (B, S, KV, D). GQA by head repetition.
    fp32 softmax. ``kv_len`` masks a pre-allocated cache to its valid length;
    ``q_pos`` gives absolute positions of queries for causal masking."""
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    qf = q.astype(jnp.float32) / np.sqrt(D)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    qf = qf.reshape(B, T, KV, rep, D)
    logits = jnp.einsum("btkrd,bskd->bkrts", qf, kf)          # (B, KV, rep, T, S)
    mask = None
    if causal:
        qp = q_pos if q_pos is not None else jnp.arange(T)[None]
        sp = jnp.arange(S)[None]
        mask = qp[:, :, None] >= sp[:, None, :]               # (B, T, S)
    if kv_len is not None:
        valid = jnp.arange(S)[None] < kv_len[:, None] if kv_len.ndim else jnp.arange(S)[None] < kv_len
        valid = jnp.broadcast_to(valid[:, None, :], (B, T, S))
        mask = valid if mask is None else (mask & valid)
    if mask is not None:
        logits = jnp.where(mask[:, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkrts,bskd->btkrd", probs, vf)
    return out.reshape(B, T, H, v.shape[-1]).astype(q.dtype)  # v head dim may != q's (MLA)


def blocked_attention(q, k, v, *, causal: bool, q_chunk: int,
                      kv_block: int, unroll: bool = False) -> jax.Array:
    """Flash-style two-level blocked attention (pure JAX, TPU-friendly):
    a static python loop over q chunks, a lax.scan over kv blocks carrying the
    running (max, denominator, accumulator). Working set per step is
    O(q_chunk x kv_block) instead of O(T x S), and causal q chunks skip
    entirely-future kv blocks at trace time — a true ~2x FLOP saving.
    Positions are assumed to be arange(T) (train/prefill self-attention).
    """
    B, T, H, D = q.shape
    S, KV = k.shape[1], k.shape[2]
    rep = H // KV
    q_chunk = min(q_chunk, T)
    kv_block = min(kv_block, S)
    if T % q_chunk != 0 or S % kv_block != 0:
        raise ValueError(
            f"chunked attention needs T % q_chunk == 0 and S % kv_block "
            f"== 0, got T={T}, q_chunk={q_chunk}, S={S}, "
            f"kv_block={kv_block}")
    qf = (q.astype(jnp.float32) / np.sqrt(D)).reshape(B, T, KV, rep, D)
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)

    outs = []
    for ci in range(T // q_chunk):
        qs = ci * q_chunk
        qc = qf[:, qs:qs + q_chunk]                         # (B,QC,KV,rep,D)
        n_blocks = S // kv_block
        if causal:                                           # static causal skip
            n_blocks = min(n_blocks, (qs + q_chunk + kv_block - 1) // kv_block)
        kb = kf[:, :n_blocks * kv_block].reshape(B, n_blocks, kv_block, KV, D)
        vb = vf[:, :n_blocks * kv_block].reshape(B, n_blocks, kv_block, KV, D)
        qpos = qs + jnp.arange(q_chunk)

        def body(carry, inp):
            m, den, acc = carry
            bi, k_blk, v_blk = inp                           # (), (B,KB,KV,D)x2
            s = jnp.einsum("bqkrd,bskd->bkrqs", qc, k_blk)   # (B,KV,rep,QC,KB)
            if causal:
                kpos = bi * kv_block + jnp.arange(kv_block)
                mask = qpos[:, None] >= kpos[None, :]
                s = jnp.where(mask[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            den_new = den * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkrqs,bskd->bkrqd", p, v_blk)
            return (m_new, den_new, acc_new), None

        m0 = jnp.full((B, KV, rep, q_chunk), -1e30, jnp.float32)
        l0 = jnp.zeros((B, KV, rep, q_chunk), jnp.float32)
        a0 = jnp.zeros((B, KV, rep, q_chunk, D), jnp.float32)
        (m, den, acc), _ = jax.lax.scan(
            body, (m0, l0, a0),
            (jnp.arange(n_blocks), jnp.moveaxis(kb, 1, 0), jnp.moveaxis(vb, 1, 0)),
            unroll=n_blocks if unroll else 1)
        o = acc / jnp.maximum(den[..., None], 1e-30)           # (B,KV,rep,QC,D)
        outs.append(jnp.moveaxis(o, 3, 1).reshape(B, q_chunk, H, D))
    return jnp.concatenate(outs, axis=1).astype(q.dtype)


def attention(x: jax.Array, p: dict, cfg: ModelConfig, positions: jax.Array,
              *, causal: bool = True, kv_x: Optional[jax.Array] = None,
              use_rope: bool = True, q_chunk: int = 0, kv_block: int = 1024,
              unroll: bool = False) -> jax.Array:
    """Full (train/prefill) attention. kv_x -> cross attention source.
    q_chunk > 0 selects the flash-style blocked path."""
    B, T, d = x.shape
    hd = cfg.head_dim
    src = x if kv_x is None else kv_x
    S = src.shape[1]
    q = (x @ p["wq"]).reshape(B, T, -1, hd)
    k = (src @ p["wk"]).reshape(B, S, -1, hd)
    v = (src @ p["wv"]).reshape(B, S, -1, hd)
    if use_rope and cfg.rope_theta > 0 and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if q_chunk and T > 1:
        out = blocked_attention(q, k, v, causal=causal and kv_x is None,
                                q_chunk=q_chunk, kv_block=kv_block,
                                unroll=unroll)
    else:
        out = _sdpa(q, k, v, causal=causal and kv_x is None, q_pos=positions)
    return out.reshape(B, T, -1) @ p["wo"]


def attention_decode(x: jax.Array, p: dict, cfg: ModelConfig, cache: dict,
                     pos: jax.Array, *, use_rope: bool = True,
                     cross_kv: Optional[tuple] = None) -> tuple[jax.Array, dict]:
    """One-token decode against a pre-allocated cache.
    x: (B, 1, d); cache: {"k": (B, S_max, KV, D), "v": ...}; pos: (B,) int32.
    cross_kv: optional fixed (k, v) for encoder-decoder cross attention."""
    B, T, d = x.shape
    hd = cfg.head_dim
    q = (x @ p["wq"]).reshape(B, T, -1, hd)
    if cross_kv is not None:
        k, v = cross_kv
        out = _sdpa(q, k, v, causal=False)
        return out.reshape(B, T, -1) @ p["wo"], cache
    k_new = (x @ p["wk"]).reshape(B, T, -1, hd)
    v_new = (x @ p["wv"]).reshape(B, T, -1, hd)
    if use_rope and cfg.rope_theta > 0:
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k_new = apply_rope(k_new, pos[:, None], cfg.rope_theta)
    # per-lane scatter at each lane's own position: continuous-batching
    # slots sit at different sequence lengths, so a shared pos[0] write
    # (the old dynamic_update_slice) would corrupt every other lane's cache
    b_idx = jnp.arange(B)
    k_cache = cache["k"].at[b_idx, pos].set(k_new[:, 0].astype(cache["k"].dtype))
    v_cache = cache["v"].at[b_idx, pos].set(v_new[:, 0].astype(cache["v"].dtype))
    out = _sdpa(q, k_cache, v_cache, causal=False, kv_len=pos + 1)
    return out.reshape(B, T, -1) @ p["wo"], {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA (DeepSeek multi-head latent attention) — compressed KV cache
# ---------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m = cfg.mla
    d, nh = cfg.d_model, cfg.n_heads
    qd = nh * (m.nope_head_dim + m.rope_head_dim)
    ks = jax.random.split(key, 5)
    return {
        "wq": dense_init(ks[0], (d, qd), dtype=dtype),
        "w_dkv": dense_init(ks[1], (d, m.kv_lora_rank + m.rope_head_dim), dtype=dtype),
        "w_uk": dense_init(ks[2], (m.kv_lora_rank, nh * m.nope_head_dim), dtype=dtype),
        "w_uv": dense_init(ks[3], (m.kv_lora_rank, nh * m.v_head_dim), dtype=dtype),
        "wo": dense_init(ks[4], (nh * m.v_head_dim, d), dtype=dtype),
    }


def mla_attention(x: jax.Array, p: dict, cfg: ModelConfig, positions: jax.Array,
                  latent_cache: Optional[jax.Array] = None,
                  pos: Optional[jax.Array] = None):
    """MLA with the latent (kv_lora + rope_k) cache. Train/prefill when
    latent_cache is None; decode (T==1) updates and attends to the cache.
    Returns (out, new_latent) where new_latent is the (B, S, r+rd) cache."""
    m: MLAConfig = cfg.mla
    B, T, d = x.shape
    nh = cfg.n_heads
    q = (x @ p["wq"]).reshape(B, T, nh, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent_new = x @ p["w_dkv"]                               # (B, T, r + rd)
    c_kv, k_rope_flat = jnp.split(latent_new, [m.kv_lora_rank], axis=-1)
    k_rope_new = apply_rope(k_rope_flat[:, :, None, :], positions, cfg.rope_theta)

    if latent_cache is None:
        latent_all = jnp.concatenate(
            [c_kv, k_rope_new[:, :, 0]], axis=-1)             # rotated rope part
        kv_len, causal = None, True
        q_pos = positions
    else:
        upd = jnp.concatenate([c_kv, k_rope_new[:, :, 0]], axis=-1)
        # per-lane scatter (decode is T == 1): same heterogeneous-length
        # continuous-batching fix as attention_decode
        latent_all = latent_cache.at[jnp.arange(B), pos].set(
            upd[:, 0].astype(latent_cache.dtype))
        kv_len, causal = pos + 1, False
        q_pos = positions

    c_all, kr_all = jnp.split(latent_all, [m.kv_lora_rank], axis=-1)
    S = c_all.shape[1]
    k_nope = (c_all @ p["w_uk"]).reshape(B, S, nh, m.nope_head_dim)
    v = (c_all @ p["w_uv"]).reshape(B, S, nh, m.v_head_dim)
    k_rope = jnp.broadcast_to(kr_all[:, :, None, :], (B, S, nh, m.rope_head_dim))
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    k_full = jnp.concatenate([k_nope, k_rope], axis=-1)
    out = _sdpa(q_full, k_full, v, causal=causal, q_pos=q_pos, kv_len=kv_len)
    out = out.reshape(B, T, nh * m.v_head_dim) @ p["wo"]
    return out, latent_all


# ---------------------------------------------------------------------------
# MoE — sort-based (event-driven) dispatch: FLOPs scale with ACTIVE experts,
# the LM-scale analogue of IMPULSE's spike-count-proportional energy.
# ---------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    m: MoEConfig = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 4)
    p = {"router": dense_init(ks[0], (d, m.n_experts), dtype=jnp.float32),
         "experts": {
             "gate": dense_init(ks[1], (m.n_experts, d, m.d_ff), dtype=dtype),
             "up": dense_init(ks[2], (m.n_experts, d, m.d_ff), dtype=dtype),
             "down": dense_init(ks[3], (m.n_experts, m.d_ff, d), dtype=dtype)}}
    if m.n_shared_experts:
        p["shared"] = init_ffn(jax.random.fold_in(key, 7), d,
                               m.d_ff * m.n_shared_experts, "swiglu", dtype)
    return p


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig,
            capacity_factor: float = 1.25, groups: int | None = None,
            constraints: bool = False, gather_dispatch: bool = False
            ) -> tuple[jax.Array, jax.Array]:
    """Token-choice top-k routing with capacity; sort-based bucketing so the
    expert matmuls are (G, E, C, d) batched GEMMs whose FLOPs scale with the
    ACTIVE experts only — the LM-scale analogue of IMPULSE's event-driven
    (spike-count-proportional) execution.

    Routing groups: tokens are routed within groups of the flattened token
    axis (default: one group per batch row for T>1, a single group for
    decode). Sorting/bucketing then stays group-local, which under the mesh
    (batch sharded on `data`, experts on `model`) lowers to the expected EP
    all-to-all-style redistribution rather than a global sort.

    Returns (out, load_balance_aux_loss).
    """
    m: MoEConfig = cfg.moe
    B, T, d = x.shape
    N = B * T
    k = m.top_k
    G = groups if groups else (B if T > 1 else 1)
    n = N // G
    xg = x.reshape(G, n, d)
    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                   # (G, n, E)
    gate_vals, eidx = jax.lax.top_k(probs, k)                 # (G, n, k)
    gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9, None)

    # Switch-style load-balance aux: mean(prob per expert) * mean(assignment)
    assign = jnp.zeros_like(probs).at[
        jnp.arange(G)[:, None, None],
        jnp.arange(n)[None, :, None], eidx].add(1.0) / k
    lb_loss = m.n_experts * jnp.mean(jnp.mean(probs, axis=1) * jnp.mean(assign, axis=1))

    cap = max(int(np.ceil(n * k / m.n_experts * capacity_factor)), 4)
    flat_e = eidx.reshape(G, n * k)
    order = jnp.argsort(flat_e, axis=-1)                      # group-local sort
    e_sorted = jnp.take_along_axis(flat_e, order, axis=-1)
    tok_sorted = order // k
    gate_sorted = jnp.take_along_axis(gate_vals.reshape(G, n * k), order, axis=-1)
    # position-in-expert via bucket starts (vectorized over groups)
    starts = jnp.sum(e_sorted[:, :, None] < jnp.arange(m.n_experts)[None, None, :],
                     axis=1).astype(jnp.int32)                # (G, E)
    slot = jnp.arange(n * k, dtype=jnp.int32)[None] \
        - jnp.take_along_axis(starts, e_sorted, axis=-1)
    keep = slot < cap
    # overflow routes to a trash slot so it can't clobber a real token
    dest = jnp.where(keep, e_sorted * cap + slot, m.n_experts * cap)

    gi = jnp.arange(G)[:, None]
    if gather_dispatch:
        # Gather-only dispatch (§Perf): the ONLY scatter is the scalar-payload
        # slot->token map — XLA lowers wide-payload scatters with indices
        # broadcast across the feature dim (a 48 GiB u32 all-gather on the
        # deepseek baseline); gathers don't have that pathology.
        slot_tok = jnp.zeros((G, m.n_experts * cap + 1), jnp.int32
                             ).at[gi, dest].set(tok_sorted)[:, :-1]
        slot_valid = jnp.zeros((G, m.n_experts * cap + 1), bool
                               ).at[gi, dest].set(keep)[:, :-1]
        buckets = jnp.take_along_axis(xg, slot_tok[..., None], axis=1)
        buckets = jnp.where(slot_valid[..., None], buckets, 0)
    else:
        gathered = jnp.where(keep[..., None], xg[gi, tok_sorted], 0)
        buckets = jnp.zeros((G, m.n_experts * cap + 1, d), xg.dtype
                            ).at[gi, dest].set(gathered)[:, :-1]
    be = buckets.reshape(G, m.n_experts, cap, d)
    if constraints:
        # EP: pin the bucket tensors to (batch-groups x experts) so the
        # dispatch lowers to a data->model redistribution instead of a
        # replicating all-gather (§Perf hillclimb; no-op outside a mesh)
        from repro.dist.sharding import constrain
        be = constrain(be, ("batch", "experts", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", be, p["experts"]["gate"])) \
        * jnp.einsum("gecd,edf->gecf", be, p["experts"]["up"])
    if constraints:
        from repro.dist.sharding import constrain
        h = constrain(h, ("batch", "experts", None, None))
    ye = jnp.einsum("gecf,efd->gecd", h, p["experts"]["down"]).reshape(G, m.n_experts * cap, d)

    safe_dest = jnp.minimum(dest, m.n_experts * cap - 1)      # trash masked below
    if gather_dispatch:
        # combine by gathers: token t's k contributions sit at inv_order[t,k]
        contrib = jnp.take_along_axis(ye, safe_dest[..., None], axis=1) \
            * (gate_sorted * keep)[..., None].astype(ye.dtype)
        inv_order = jnp.argsort(order, axis=-1)               # (G, n*k)
        per_tok = jnp.take_along_axis(contrib, inv_order[..., None], axis=1)
        out = per_tok.reshape(G, n, k, d).sum(axis=2)
    else:
        contrib = ye[gi, safe_dest] * (gate_sorted * keep)[..., None].astype(ye.dtype)
        out = jnp.zeros((G, n, d), xg.dtype).at[gi, tok_sorted].add(contrib)
    out = out.reshape(B, T, d)
    if "shared" in p:
        out = out + ffn(x, p["shared"], "swiglu")
    return out, lb_loss.astype(jnp.float32)
