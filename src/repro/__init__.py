"""repro: IMPULSE (fused-weight/membrane-potential CIM macro) as a JAX framework.

Layers:
  core/        -- the paper's contribution: quantization, neurons, macro ISA,
                  bit-accurate silicon model, energy model, spiking layers.
  kernels/     -- Pallas TPU kernels (fused SNN timestep, RWKV6 fused state).
  models/      -- assigned LM architectures + paper SNNs.
  data/        -- data pipelines.
  optim/       -- optimizers.
  checkpoint/  -- sharded async checkpointing.
  train/       -- fault-tolerant training loop.
  serve/       -- batched serving engine.
  dist/        -- sharding rules, grad compression, pipeline parallelism.
  configs/     -- architecture configs (one per assigned arch).
  launch/      -- mesh / dryrun / train / serve entry points.
"""

__version__ = "0.1.0"
