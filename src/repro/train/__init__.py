from repro.train.loop import LoopConfig, LoopResult, train_loop
from repro.train.train_state import TrainState, init_train_state, make_train_step
