"""Train state + the jit-able train step builder (microbatching, grad
clipping, optional int8 gradient compression)."""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.configs.base import RunConfig
from repro.models import lm
from repro.optim import apply_updates, clip_by_global_norm, make_optimizer
from repro.optim.schedule import cosine_warmup


class TrainState(NamedTuple):
    params: Any
    opt_state: Any
    step: jax.Array


def init_train_state(key, run: RunConfig, total_steps: int = 10_000,
                     dtype=jnp.bfloat16) -> tuple[TrainState, Any]:
    params = lm.init_params(key, run.model, dtype=dtype)
    opt = _make_opt(run, total_steps)
    return TrainState(params, opt.init(params), jnp.zeros((), jnp.int32)), opt


def _make_opt(run: RunConfig, total_steps: int):
    lr = cosine_warmup(run.learning_rate, run.warmup_steps, total_steps)
    return make_optimizer(run.optimizer, lr, run.weight_decay)


def make_train_step(run: RunConfig, opt, loss_fn: Callable | None = None,
                    max_grad_norm: float = 1.0) -> Callable:
    """Returns train_step(state, batch) -> (state, metrics). Microbatching
    splits the batch on the leading axis and accumulates grads in fp32
    (sequential lax.scan — the standard grad-accumulation memory trade)."""
    cfg, parallel = run.model, run.parallel
    loss_fn = loss_fn or (lambda p, b: lm.loss_fn(p, b, cfg, parallel))

    def grads_of(params, batch):
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        return loss, aux, grads

    def train_step(state: TrainState, batch: dict):
        mb = parallel.microbatches
        if mb > 1:
            def split(x):
                return x.reshape((mb, x.shape[0] // mb) + x.shape[1:])
            batches = jax.tree_util.tree_map(split, batch)

            def acc_fn(acc, mbatch):
                loss, aux, grads = grads_of(state.params, mbatch)
                acc_loss, acc_grads = acc
                acc_grads = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32) / mb, acc_grads, grads)
                return (acc_loss + loss / mb, acc_grads), None

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), state.params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.zeros(()), zero), batches)
        else:
            loss, _aux, grads = grads_of(state.params, batch)

        if parallel.grad_compress:
            from repro.dist.compress import fake_compress
            grads = fake_compress(grads)

        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        updates, opt_state = opt.update(grads, state.opt_state, state.params)
        params = apply_updates(state.params, updates)
        metrics = {"loss": loss, "grad_norm": gnorm, "step": state.step + 1}
        return TrainState(params, opt_state, state.step + 1), metrics

    return train_step
