"""Fault-tolerant training loop.

Pod-scale behaviours implemented here (validated in tests on CPU):
  * checkpoint/restart -- async CheckpointManager every `ckpt_every` steps;
    on start, auto-restore the latest step and fast-forward the deterministic
    data stream (loader batches are pure functions of step).
  * preemption hook    -- SIGTERM sets a flag; the loop finishes the current
    step, writes a final blocking checkpoint, and exits cleanly.
  * elastic restart    -- restore() reshapes onto the *current* mesh via the
    sharding rules; the loader reshards by (shard_id, num_shards).
  * straggler watchdog -- per-step wall time is tracked; steps slower than
    `straggler_factor` x the running median are counted and surfaced in
    metrics (on real pods this feeds the job controller's replace-node
    decision; on CPU we just detect).
"""
from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.train.train_state import TrainState


@dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 50
    ckpt_dir: Optional[str] = None
    log_every: int = 10
    straggler_factor: float = 3.0
    keep_ckpts: int = 3


@dataclass
class LoopResult:
    state: Any
    metrics_history: list = field(default_factory=list)
    straggler_steps: int = 0
    resumed_from: Optional[int] = None
    preempted: bool = False


class PreemptionGuard:
    """SIGTERM -> graceful checkpoint-and-exit."""

    def __init__(self):
        self.requested = False
        self._prev = None

    def install(self):
        def handler(signum, frame):
            self.requested = True
        try:
            self._prev = signal.signal(signal.SIGTERM, handler)
        except ValueError:
            pass                                       # non-main thread (tests)
        return self

    def uninstall(self):
        if self._prev is not None:
            signal.signal(signal.SIGTERM, self._prev)


def train_loop(train_step: Callable, state: TrainState, loader,
               loop_cfg: LoopConfig, *, device_put_fn: Callable = None,
               on_metrics: Callable = None) -> LoopResult:
    result = LoopResult(state=state)
    ckpt = CheckpointManager(loop_cfg.ckpt_dir, keep=loop_cfg.keep_ckpts) \
        if loop_cfg.ckpt_dir else None

    # ---- auto-resume ----
    if ckpt is not None and ckpt.latest_step() is not None:
        step, state = ckpt.restore(like=state)
        result.resumed_from = step
        result.state = state

    guard = PreemptionGuard().install()
    times: list[float] = []
    try:
        for step_idx, batch in loader:
            if int(state.step) > step_idx:
                continue                              # fast-forward after resume
            if step_idx >= loop_cfg.total_steps:
                break
            if device_put_fn is not None:
                batch = device_put_fn(batch)
            t0 = time.perf_counter()
            state, metrics = train_step(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            if len(times) >= 5:
                med = float(np.median(times[-50:]))
                if dt > loop_cfg.straggler_factor * med:
                    result.straggler_steps += 1
            times.append(dt)
            if (step_idx + 1) % loop_cfg.log_every == 0 or step_idx == 0:
                m = {k: float(v) for k, v in metrics.items()}
                m["sec_per_step"] = dt
                result.metrics_history.append(m)
                if on_metrics:
                    on_metrics(m)
            if ckpt is not None and (step_idx + 1) % loop_cfg.ckpt_every == 0:
                ckpt.save(int(state.step), state)
            if guard.requested:
                result.preempted = True
                break
        if ckpt is not None:
            ckpt.save(int(state.step), state, blocking=True)
    finally:
        guard.uninstall()
        if hasattr(loader, "close"):
            loader.close()
    result.state = state
    return result
