"""Static range / bit-width verification of compiled `SNNProgram`s.

`check_program` abstract-interprets the word-level ISA semantics
(isa.layer_timestep_int, the contract every backend is tested against)
over the interval lattice of `intervals.py` and proves, per macro-stack
layer, that:

  * every weight lies on the 6-bit QAT grid [-W_MAX, W_MAX];
  * every threshold / leak constant lies in the 11-bit V word
    [V_MIN, V_MAX] (what `quant.quantize_neuron_const` guarantees by
    construction — a constant outside the word cannot be stored in a
    const row);
  * the **unclamped int32 accumulator can never overflow**. Spiking
    layers clamp once per timestep, so their pre-clamp value is bounded
    by ``V_interval + [sum min(w,0), sum max(w,0)]`` independent of T —
    and in wrap mode even int32 rollover is harmless, because 2^11
    divides 2^32 (``v mod 2^32 mod 2^11 == v mod 2^11``): the silicon's
    wrap composes through any wider two's-complement container. Saturate
    mode has no such algebra — clamping a value that already overflowed
    clips the wrong number — so there the analyzer demands the proof.
    The readout is the genuinely T-dependent hazard: it accumulates
    **unclamped across every frame of the presentation** in all backends,
    so its bound scales linearly in the frame count and `max_safe_frames`
    is the largest horizon the int32 word survives.

Matmul intermediates are covered by the same bounds: a prefix sum over
input rows of column j lies in [sum_i min(w_ij, 0), sum_i max(w_ij, 0)]
(dropping terms can only move toward zero from either end), so no
partial-row accumulation order — including the multi-macro row-tiled
AccV2V reduction, which is exactly these partial sums — escapes the
per-frame increment interval.

Spiking-layer membrane invariants are found by fixed-point iteration:
start at V = [0, 0], push one timestep through the transfer functions
(accumulate -> clamp -> leak -> SpikeCheck -> reset/soft-reset), widen by
hull, repeat until the post-update interval is contained. Every
post-update interval is a subset of the clamped V domain, so the chain is
finite and convergence is guaranteed (in practice 2-3 iterations).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.analysis.intervals import (INT32, AnalysisError, Interval,
                                      V_DOMAIN, clamp_interval, wrap_is_exact)
from repro.core.quant import W_MAX, W_MIN

_MAX_FIXPOINT_ITERS = 4096       # > 2 * V_SPAN: hull growth is integral


class RangeError(AnalysisError):
    """A value range escaped its word: weight off the 6-bit grid, constant
    outside the 11-bit V word, or an int32 accumulator that can overflow."""


@dataclass(frozen=True)
class LayerRange:
    """Proven value ranges of one macro-stack layer."""
    index: int                 # position in program.macro_stack
    name: str                  # e.g. "fc[1] 128x128"
    kind: str                  # conv | fc | readout
    n_in: int
    n_out: int
    row_tiles: int             # multi-macro fan-in split (mapping.fc_tiling)
    increment: Interval        # per-frame AccW2V sum, hull over columns
    v_pre_clamp: Interval      # widest unclamped accumulator value seen
    v_post: Interval           # post-update membrane invariant (V at rest)
    wrap_exact: bool           # wrap-mode clamp transfer lost no precision
    max_safe_frames: Optional[int] = None   # None: any horizon is safe


@dataclass(frozen=True)
class RangeReport:
    """Per-layer proven ranges of one program at one frame horizon."""
    domain: str
    clamp_mode: str
    neuron: str
    frames: int                # horizon the readout bound was proven for
    layers: tuple              # tuple[LayerRange, ...]

    @property
    def max_safe_frames(self) -> Optional[int]:
        """Largest frame count every layer's int32 word survives
        (None: unbounded — e.g. a zero readout increment)."""
        bounds = [ly.max_safe_frames for ly in self.layers
                  if ly.max_safe_frames is not None]
        return min(bounds) if bounds else None


def _layer_name(idx: int, spec) -> str:
    return f"{spec.kind}[{idx}] {spec.n_in}x{spec.n_out}"


def _weight_matrix(spec) -> Optional[np.ndarray]:
    """(n_in, n_out) integer weight matrix of a macro-stack layer, or None
    when the spec carries no weights (synthetic geometry — worst case)."""
    if spec.w is None:
        return None
    w = np.asarray(spec.w)
    if spec.kind == "conv":                  # HWIO -> im2col row-major
        from repro.core import mapping
        w = np.asarray(mapping.pack_conv_weights(spec.w))
    return w.astype(np.int64)


def _increment_interval(spec, name: str) -> Interval:
    """Per-frame AccW2V sum bound of one layer: hull over output columns of
    [sum_i min(w_ij, 0), sum_i max(w_ij, 0)] — attained by the spike frame
    that activates exactly the negative (resp. positive) rows. With no
    weights, the worst case over the whole 6-bit grid."""
    w = _weight_matrix(spec)
    if w is None:
        bound = spec.n_in * W_MAX
        return Interval(-bound, bound)
    if w.size == 0:
        return Interval.point(0)
    wmin, wmax = int(w.min()), int(w.max())
    if wmin < W_MIN - 1 or wmax > W_MAX:     # -32 is representable on chip
        raise RangeError(
            f"weight range [{wmin}, {wmax}] leaves the 6-bit grid "
            f"[{W_MIN - 1}, {W_MAX}]", where=name)
    lo = int(np.minimum(w, 0).sum(axis=0).min())
    hi = int(np.maximum(w, 0).sum(axis=0).max())
    return Interval(lo, hi)


def _check_const(value, what: str, name: str) -> int:
    """A neuron constant must fit the 11-bit V word of its const row."""
    v = int(value)
    if not V_DOMAIN.contains_value(v):
        raise RangeError(
            f"{what}={v} does not fit the 11-bit V word {V_DOMAIN} "
            "(quantize via quant.quantize_neuron_const)", where=name)
    return v


def _spike_update(v: Interval, th: int, neuron: str, mode: str) -> Interval:
    """Transfer of SpikeCheck + reset on a clamped membrane interval."""
    if mode == "wrap":
        # the comparator itself wraps (quant.spike_compare), so the fired
        # set is non-contiguous in v — hull both branches (sound, not tight)
        if neuron == "rmp":
            return v.hull(clamp_interval(v.shift(-th), "wrap"))
        return v.hull(Interval.point(0))
    fired = v.intersect(Interval(th, max(v.hi, th)))
    unfired = v.intersect(Interval(min(v.lo, th - 1), th - 1))
    parts = []
    if unfired is not None:
        parts.append(unfired)
    if fired is not None:
        if neuron == "rmp":                  # soft reset: v - th, clamped
            parts.append(clamp_interval(fired.shift(-th), "saturate"))
        else:                                # if / lif: hard reset to 0
            parts.append(Interval.point(0))
    out = parts[0]
    for p in parts[1:]:
        out = out.hull(p)
    return out


def _check_spiking_layer(idx: int, spec, neuron: str, mode: str
                         ) -> LayerRange:
    name = _layer_name(idx, spec)
    inc = _increment_interval(spec, name)
    th = _check_const(spec.threshold, "threshold", name)
    lk = _check_const(spec.leak, "leak", name)

    v = Interval.point(0)
    widest_pre = v
    wrap_exact = True
    for _ in range(_MAX_FIXPOINT_ITERS):
        acc = v + inc                        # unclamped int32 accumulator
        widest_pre = widest_pre.hull(acc)
        if mode == "saturate" and not INT32.contains(acc):
            raise RangeError(
                f"unclamped accumulator {acc} can overflow int32 {INT32} "
                f"before the saturate clamp (fan-in {spec.n_in}, per-frame "
                f"increment {inc}); wrap mode would compose through "
                "overflow, saturate cannot", where=name)
        if mode == "wrap" and not wrap_is_exact(acc):
            wrap_exact = False
        vc = clamp_interval(acc, mode)
        if neuron == "lif":                  # AccV2V(-leak), clamped
            vc = clamp_interval(vc.shift(-lk), mode)
        post = _spike_update(vc, th, neuron, mode)
        if v.contains(post):
            break
        v = v.hull(post)
    else:                                    # pragma: no cover - lattice is
        raise AnalysisError("membrane fixed point did not converge",
                            where=name)      # finite; unreachable
    return LayerRange(
        index=idx, name=name, kind=spec.kind, n_in=spec.n_in,
        n_out=spec.n_out, row_tiles=spec.tiling.row_tiles, increment=inc,
        v_pre_clamp=widest_pre, v_post=v, wrap_exact=wrap_exact,
        max_safe_frames=None)                # per-timestep clamp: T-free


def _check_readout_layer(idx: int, spec, frames: int) -> LayerRange:
    """The readout accumulates UNCLAMPED int32 across all frames in every
    backend — the one genuinely T-dependent overflow hazard."""
    name = _layer_name(idx, spec)
    inc = _increment_interval(spec, name)
    total = Interval(frames * min(inc.lo, 0), frames * max(inc.hi, 0))
    safe = []
    if inc.hi > 0:
        safe.append(INT32.hi // inc.hi)
    if inc.lo < 0:
        safe.append(INT32.lo // inc.lo)
    max_safe = min(safe) if safe else None
    if not INT32.contains(total):
        raise RangeError(
            f"unclamped readout accumulator reaches {total} over {frames} "
            f"frames and overflows int32 {INT32} (per-frame increment "
            f"{inc}; max safe frames: {max_safe})", where=name)
    return LayerRange(
        index=idx, name=name, kind=spec.kind, n_in=spec.n_in,
        n_out=spec.n_out, row_tiles=spec.tiling.row_tiles, increment=inc,
        v_pre_clamp=total, v_post=total, wrap_exact=False,
        max_safe_frames=max_safe)


def check_program(program, *, frames: Optional[int] = None) -> RangeReport:
    """Prove the per-layer value ranges of a compiled program, or raise a
    `RangeError` naming the first offending layer.

    ``frames`` is the presentation horizon the readout bound is proven for
    (default ``program.timesteps`` — one presentation step block). Pass the
    true total frame count for long streams; the report's
    ``max_safe_frames`` is horizon-independent and is what streaming
    admission control should budget against.

    Float-domain programs carry no word-level semantics to verify — they
    return an empty (trivially valid) report.
    """
    if frames is None:
        frames = int(program.timesteps)
    if frames < 0:
        raise ValueError(f"frames must be >= 0, got {frames}")
    if program.domain != "int":
        return RangeReport(domain=program.domain,
                           clamp_mode=program.clamp_mode,
                           neuron=program.neuron, frames=frames, layers=())
    mode = program.clamp_mode
    if mode not in ("saturate", "wrap"):
        raise AnalysisError(f"unknown clamp mode {mode!r}", where="program")
    layers = []
    for idx, spec in enumerate(program.macro_stack):
        if spec.kind == "readout":
            layers.append(_check_readout_layer(idx, spec, frames))
        else:
            layers.append(_check_spiking_layer(idx, spec, program.neuron,
                                               mode))
    return RangeReport(domain=program.domain, clamp_mode=mode,
                       neuron=program.neuron, frames=frames,
                       layers=tuple(layers))
