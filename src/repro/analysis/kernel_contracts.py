"""Static pre-dispatch verification of the fused-kernel contracts.

The Pallas kernels (kernels/fused_snn_net) assume properties of the
compiled program + dispatch parameters that, when violated, surface as
opaque `pallas_call` lowering failures or silent VMEM thrash. This pass
re-derives each assumption **from config alone** — no tracing, no device —
and rejects a bad dispatch with a `ContractError` naming the contract and
the offending call, before any kernel is built:

  contract            | what is verified
  --------------------|---------------------------------------------------
  backend             | known execution backend; bitmacro demands wrap
                      | arithmetic (silicon has no saturation logic)
  chain_alignment     | layer i's fan-in == layer i-1's fan-out (flattened
                      | across the conv->fc boundary) — the property that
                      | keeps every `pl.ds` gather row inside its weight
                      | tile
  grid_divisibility   | block_b >= 1; the wrapper pads B up to a block_b
                      | multiple, so grid = ceil(B / block_b) always
                      | divides evenly after padding
  gate_granularity    | granularity in GATE_GRANULARITIES, and only the
                      | gated backend may request sub-tile gating
  skip_layout         | the gate-site column map fits MAX_SKIP_COLS
  event_crossover     | dense-fallback crossover in [0, 1]
  fallback_columns    | events mode carries one fallback column per layer
                      | in a LANE-wide output: len(ws) <= LANE per call
  gather_bounds       | events-mode index lists are capacity-bounded by
                      | the padded fan-in (index < padded rows of the
                      | VMEM-resident weight tile, by construction of the
                      | cumsum/one-hot decode — reported with the numbers)
  vmem_budget         | the per-`pallas_call` VMEM residency — spike block
                      | across the whole T loop + all weight tiles + all V
                      | scratch/out tiles + rasters + counters — fits the
                      | per-core budget
  megastep            | streaming dispatches advance K >= 1 frames per
                      | call (`pipeline.stream_megastep`); the VMEM
                      | estimate scales its spike/raster blocks with K
                      | (``frames=K``), so a K that overflows the budget
                      | is rejected here, before the engine's first tick
  mesh_axes           | a mesh-sharded dispatch names "data"/"model"
                      | extents; float/bitmacro have no mesh execution
  mesh_split          | per fused call under model-parallel row tiling:
                      | the padded fan-in divides evenly into per-shard
                      | row tiles (chain alignment is preserved because
                      | every shard slices rows of the same padded
                      | fan-in and the integer psum reassembles the full
                      | width), and the per-shard residency — weight
                      | tiles shrink 1/n_model, spike/V blocks stay full
                      | width — fits the VMEM budget

Each on-macro conv layer dispatches its own fused call over its im2col
patch raster (T stays, batch becomes B*P, per-grid-cell residency is
B-independent); the fc stack is one further call. The budget estimate is
deliberately a slight over-count (it ignores nothing that is resident) and
excludes only compiler temporaries, which the default margin absorbs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np

from repro.analysis.intervals import AnalysisError
from repro.kernels.fused_snn_net.kernel import (GATE_GRANULARITIES, LANE,
                                                MAX_SKIP_COLS, skip_layout)

#: per-core VMEM (~16 MiB on current TPUs — see the Pallas guide); the
#: checker budgets a margin below it for compiler temporaries
VMEM_BYTES = 16 * 2 ** 20
VMEM_BUDGET_BYTES = int(VMEM_BYTES * 0.75)

PALLAS_BACKENDS = ("pallas", "pallas_sparse", "pallas_events")
KNOWN_BACKENDS = PALLAS_BACKENDS + ("float", "int_ref", "ref_events",
                                    "bitmacro")


class ContractError(AnalysisError):
    """A kernel contract is violated for this (program, dispatch) pair."""


@dataclass(frozen=True)
class ContractCheck:
    """One verified contract: name, where it was checked, the numbers."""
    contract: str
    where: str
    detail: str


@dataclass(frozen=True)
class KernelCall:
    """Checked geometry of one fused `pallas_call` dispatch."""
    name: str                  # "conv[i]" | "fc_stack"
    layer_names: tuple
    logical_widths: tuple      # (n_in, n_out_0, n_out_1, ...)
    padded_widths: tuple
    vmem_bytes: int


@dataclass(frozen=True)
class ContractReport:
    backend: str
    block_b: int
    frames: int
    calls: tuple               # tuple[KernelCall, ...] (empty off-device)
    checks: tuple              # tuple[ContractCheck, ...] all satisfied

    @property
    def vmem_bytes(self) -> int:
        """Largest single-call VMEM residency (calls run sequentially)."""
        return max((c.vmem_bytes for c in self.calls), default=0)


def _pad_lane(n: int) -> int:
    return max(LANE, -(-n // LANE) * LANE)


def _mesh_extents(mesh) -> dict:
    """Mesh axis extents from a `jax.sharding.Mesh` or a plain
    ``{axis_name: extent}`` dict (the device-free form `tools/
    check_invariants.py --mesh` validates geometries with)."""
    if isinstance(mesh, dict):
        return {str(k): int(v) for k, v in mesh.items()}
    return {str(n): int(s)
            for n, s in zip(mesh.axis_names, mesh.devices.shape)}


def _flat_width(spec) -> int:
    """Flattened output width of a layer (conv output maps flatten into
    the first FC's fan-in)."""
    if spec.state_shape:
        return int(np.prod(spec.state_shape))
    return int(spec.n_out)


def _check_chain(program, checks: list) -> None:
    """Fan-in / fan-out alignment across the whole stack: the property
    that keeps every gather row inside its weight tile."""
    cur: Optional[int] = None
    for idx, spec in enumerate(program.layers):
        name = f"{spec.kind}[{idx}] {spec.n_in}x{spec.n_out}"
        if spec.kind in ("fc", "readout"):
            if cur is not None and spec.n_in != cur:
                raise ContractError(
                    f"chain_alignment: fan-in {spec.n_in} != {cur} lanes "
                    "emitted by the previous layer", where=name)
        elif spec.kind == "conv" and spec.w is not None:
            # .shape, not np.asarray: float-domain programs compile under
            # jit/grad traces and tracers cannot materialize
            kh, kw, c_in = spec.w.shape[:3]
            if spec.n_in != kh * kw * c_in:
                raise ContractError(
                    f"chain_alignment: im2col fan-in {spec.n_in} != "
                    f"{kh}x{kw}x{c_in} patch width", where=name)
        cur = _flat_width(spec)
    checks.append(ContractCheck(
        "chain_alignment", "program",
        f"{len(program.layers)} layers aligned"))


def _call_vmem_bytes(widths: tuple, *, n_spiking: int, frames: int,
                     block_b: int, backend: str, gate_granularity: int,
                     emit_rasters: bool, streaming: bool,
                     staged_in_elems: int = 0) -> int:
    """VMEM bytes resident in one grid step of one fused call.

    ``staged_in_elems`` — raw input elements per frame of the streamed
    presentation (prod of ``cfg.in_shape`` for conv-led programs, the
    input-layer width otherwise). A K-frame megastep pre-stages the next
    K frames of every lane as one ``(K, B, *in_shape)`` float32 block
    alongside the kernel's own operands, so its residency scales with K
    too; pass it for the call that consumes the staged block (the first).
    """
    inp = _pad_lane(widths[0])
    outs = [_pad_lane(w) for w in widths[1:]]
    ins_p = [inp] + outs[:-1]
    n = frames * block_b * inp                       # spike block, int8
    n += sum(i * o for i, o in zip(ins_p, outs))     # weight tiles, int8
    n += len(widths[1:]) * 2 * 4                     # params rows
    n += 2 * sum(block_b * o * 4 for o in outs)      # V scratch + V out
    if streaming:
        n += sum(block_b * o * 4 for o in outs)      # v_init blocks
        n += frames * block_b * staged_in_elems * 4  # staged frame block
    if emit_rasters:
        n += frames * block_b * sum(outs[:n_spiking])
    if backend == "pallas_sparse":
        _, _, lanes = skip_layout(tuple(widths[:-1]), gate_granularity)
        n += lanes * 4
    if backend == "pallas_events":
        n += sum(i * 4 for i in ins_p) + LANE * 4    # row counters + fallback
    return n


def _program_calls(program) -> list:
    """(name, layer_names, logical widths, n_spiking) per fused dispatch."""
    calls = []
    for i, spec in enumerate(program.int_conv_stack):
        calls.append((f"conv[{i}]",
                      (f"conv[{i}] {spec.n_in}x{spec.n_out}",),
                      (spec.n_in, spec.n_out), 1))
    stack = program.fc_stack
    if stack:
        names = tuple(f"{s.kind} {s.n_in}x{s.n_out}" for s in stack)
        widths = (stack[0].n_in,) + tuple(s.n_out for s in stack)
        calls.append(("fc_stack", names, widths, len(stack) - 1))
    return calls


def check_kernel_contracts(program, backend: str = "pallas", *,
                           frames: Optional[int] = None, block_b: int = 8,
                           gate_granularity: int = 1,
                           event_crossover: float = 1.0,
                           use_sparse: bool = False,
                           emit_rasters: bool = True,
                           streaming: bool = False,
                           vmem_budget_bytes: int = VMEM_BUDGET_BYTES,
                           mesh=None) -> ContractReport:
    """Verify every kernel contract of dispatching ``program`` on
    ``backend`` with these parameters; raise `ContractError` naming the
    violated contract and call otherwise.

    ``frames`` is the per-dispatch raster length the VMEM estimate uses
    (default ``program.timesteps``; streaming ticks pass 1). Off-device
    backends (float / int_ref / ref_events) have no kernel contracts
    beyond chain alignment and return an empty-call report; ``bitmacro``
    additionally demands wrap arithmetic.

    ``mesh`` — a `jax.sharding.Mesh` or a plain ``{axis: extent}`` dict
    (no devices needed) — additionally verifies the mesh-execution
    contracts: float/bitmacro reject a mesh, the model-parallel row split
    of every fused call keeps chain alignment (per-shard row tiles of the
    same padded fan-in, reassembled by the integer psum), and the
    per-shard VMEM residency fits the budget.
    """
    if frames is None:
        frames = int(program.timesteps)
    checks: list = []
    if streaming:
        if not isinstance(frames, int) or frames < 1:
            raise ContractError(
                f"megastep: a streaming dispatch advances K >= 1 frames "
                f"per call, got K={frames!r}", where="stream")
        checks.append(ContractCheck(
            "megastep", "stream",
            f"K={frames} frame(s) per dispatch; spike/raster VMEM blocks "
            "scale linearly with K"))
    if backend not in KNOWN_BACKENDS:
        raise ContractError(
            f"unknown execution backend {backend!r}; have "
            f"{sorted(KNOWN_BACKENDS)}", where="backend")
    if backend != "float" and program.domain != "int":
        raise ContractError(
            f"backend {backend!r} executes int-domain programs only; this "
            f"program is domain={program.domain!r} "
            "(compile_network(..., domain='int'))", where="backend")
    if backend == "bitmacro" and program.clamp_mode != "wrap":
        raise ContractError(
            "bitmacro executes silicon wrap arithmetic; compile the "
            "program with clamp_mode='wrap'", where="backend")
    n_data = n_model = 1
    if mesh is not None:
        if backend in ("float", "bitmacro"):
            raise ContractError(
                f"mesh_axes: backend {backend!r} has no mesh execution "
                "(float reductions are not order-exact; bitmacro state "
                "lives in host BitMacro objects)", where="mesh")
        sizes = _mesh_extents(mesh)
        n_data = sizes.get("data", 1)
        n_model = sizes.get("model", 1)
        if n_data < 1 or n_model < 1:
            raise ContractError(
                f"mesh_axes: axis extents must be >= 1, got data={n_data} "
                f"model={n_model}", where="mesh")
        checks.append(ContractCheck(
            "mesh_axes", "mesh",
            f"data={n_data} (lanes/banks partition) x model={n_model} "
            f"(row-tiled fan-in partition); axes {sorted(sizes)}"))
    _check_chain(program, checks)

    if gate_granularity not in GATE_GRANULARITIES:
        raise ContractError(
            f"gate_granularity: must be one of {GATE_GRANULARITIES}, got "
            f"{gate_granularity}", where=backend)
    if (gate_granularity != 1 and backend != "pallas_sparse"
            and not use_sparse):
        raise ContractError(
            f"gate_granularity: sub-tile gating (granularity "
            f"{gate_granularity}) needs the gated path (pallas_sparse, or "
            f"int_ref with use_sparse=True), not {backend!r}",
            where=backend)
    if backend == "pallas_events" and not 0.0 <= event_crossover <= 1.0:
        raise ContractError(
            f"event_crossover: dense-fallback crossover must lie in "
            f"[0, 1], got {event_crossover}", where=backend)

    if backend not in PALLAS_BACKENDS:
        return ContractReport(backend=backend, block_b=block_b,
                              frames=frames, calls=(), checks=tuple(checks))

    if not isinstance(block_b, int) or block_b < 1:
        raise ContractError(
            f"grid_divisibility: block_b must be a positive int, got "
            f"{block_b!r}", where=backend)
    checks.append(ContractCheck(
        "grid_divisibility", backend,
        f"block_b={block_b}; B pads to the next multiple, grid=ceil(B/"
        f"{block_b})"))

    # the K-frame megastep stages a (K, B, *in_shape) float32 frame block
    # for the call that consumes the raw presentation (the first)
    staged_in_elems = 0
    if streaming:
        staged_in_elems = int(np.prod(
            program.cfg.in_shape if program.layers[0].kind == "conv"
            else program.layers[0].state_shape))

    calls = []
    for ci, (name, layer_names, widths, n_spiking) in enumerate(
            _program_calls(program)):
        if backend == "pallas_sparse":
            try:
                n_cols, _, _ = skip_layout(tuple(widths[:-1]),
                                           gate_granularity)
            except ValueError as e:
                raise ContractError(f"skip_layout: {e}", where=name) from e
            checks.append(ContractCheck(
                "skip_layout", name,
                f"{sum(n_cols)} gate columns <= MAX_SKIP_COLS="
                f"{MAX_SKIP_COLS} at granularity {gate_granularity}"))
        if backend == "pallas_events":
            n_layers = len(widths) - 1
            if n_layers > LANE:
                raise ContractError(
                    f"fallback_columns: events mode carries one fallback "
                    f"column per layer in a {LANE}-lane output; got "
                    f"{n_layers} layers", where=name)
            caps = tuple(_pad_lane(w) for w in widths[:-1])
            checks.append(ContractCheck(
                "gather_bounds", name,
                f"event-list capacity per layer = padded fan-in {caps}; "
                "cumsum/one-hot indices < capacity by construction"))
        vmem = _call_vmem_bytes(
            widths, n_spiking=n_spiking, frames=frames, block_b=block_b,
            backend=backend, gate_granularity=gate_granularity,
            emit_rasters=emit_rasters, streaming=streaming,
            staged_in_elems=staged_in_elems if ci == 0 else 0)
        if vmem > vmem_budget_bytes:
            raise ContractError(
                f"vmem_budget: one grid step holds {vmem} bytes resident "
                f"(T={frames} spike block + staged frames + weight tiles "
                f"+ V tiles + counters) > budget {vmem_budget_bytes} "
                f"({VMEM_BYTES} per core with compiler margin); shrink "
                "block_b, chunk the presentation, or split the stack",
                where=name)
        checks.append(ContractCheck(
            "vmem_budget", name,
            f"{vmem} bytes resident <= {vmem_budget_bytes}"))
        if mesh is not None:
            from repro.kernels.fused_snn_net.ops import mesh_padded_widths
            mw = mesh_padded_widths(widths, n_model)
            rows = tuple(w // n_model for w in mw[:-1])
            if any(w % n_model for w in mw):
                raise ContractError(       # unreachable by construction
                    f"mesh_split: padded widths {mw} do not divide "
                    f"n_model={n_model}", where=name)
            # per-shard residency: weight tiles shrink 1/n_model (each
            # shard holds its row tile), spike/V blocks stay full width
            # (cur is replicated, the partial V is full width pre-psum)
            ins_p = [_pad_lane(widths[0])] + [_pad_lane(w)
                                              for w in widths[1:-1]]
            w_bytes = sum(i * _pad_lane(o)
                          for i, o in zip(ins_p, widths[1:]))
            vmem_shard = vmem - w_bytes + -(-w_bytes // n_model)
            if vmem_shard > vmem_budget_bytes:
                raise ContractError(
                    f"mesh_split: one model shard holds {vmem_shard} "
                    f"bytes resident (weights/{n_model} + full-width "
                    f"spike/V blocks) > budget {vmem_budget_bytes}",
                    where=name)
            checks.append(ContractCheck(
                "mesh_split", name,
                f"fan-in rows {mw[:-1]} split {n_model}-way into "
                f"{rows}-row shard tiles (chain alignment preserved: "
                f"every shard slices the same padded fan-in; psum "
                f"reassembles the full width); per-shard residency "
                f"{vmem_shard} bytes <= {vmem_budget_bytes}"))
        calls.append(KernelCall(
            name=name, layer_names=layer_names,
            logical_widths=tuple(int(w) for w in widths),
            padded_widths=tuple(_pad_lane(w) for w in widths),
            vmem_bytes=vmem))
    return ContractReport(backend=backend, block_b=block_b, frames=frames,
                          calls=tuple(calls), checks=tuple(checks))
