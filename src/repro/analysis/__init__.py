"""Static verification of compiled SNN programs (DESIGN.md §"Static
verification").

Three passes, composable and individually importable:

  * `check_program` — interval/bit-width abstract interpretation over the
    word-level ISA semantics: proves weights on the 6-bit grid, constants
    in the 11-bit V word, and that no unclamped int32 accumulator can
    overflow (per-layer `RangeReport`, or `RangeError` naming the layer).
  * `check_kernel_contracts` — pre-dispatch verification of everything the
    Pallas kernels assume from config alone: VMEM residency, skip_layout
    caps, event crossover, grid/gather bounds (`ContractReport`, or
    `ContractError` naming the contract and call).
  * `lint_paths` — AST repo lint (ANA001 bare asserts, ANA002 ad-hoc
    clamps, ANA003 unseeded randomness); pure stdlib.

`compile_network(..., validate=True)` (the default) runs the first two via
`validate_program`; `tools/check_invariants.py` runs all three in CI.
"""
from __future__ import annotations

from typing import Optional

from repro.analysis.intervals import (INT32, AnalysisError, Interval,
                                      V_DOMAIN, clamp_interval,
                                      wrap_is_exact)
from repro.analysis.kernel_contracts import (PALLAS_BACKENDS, ContractCheck,
                                             ContractError, ContractReport,
                                             KernelCall, VMEM_BUDGET_BYTES,
                                             check_kernel_contracts)
from repro.analysis.lint import (RULES, LintViolation, lint_file,
                                 lint_paths, lint_source)
from repro.analysis.program_check import (LayerRange, RangeError,
                                          RangeReport, check_program)

__all__ = [
    "AnalysisError", "ContractCheck", "ContractError", "ContractReport",
    "INT32", "Interval", "KernelCall", "LayerRange", "LintViolation",
    "PALLAS_BACKENDS", "RULES", "RangeError", "RangeReport", "V_DOMAIN",
    "VMEM_BUDGET_BYTES", "check_kernel_contracts", "check_program",
    "clamp_interval", "lint_file", "lint_paths", "lint_source",
    "validate_program", "wrap_is_exact",
]


def validate_program(program, *, frames: Optional[int] = None,
                     backends: Optional[tuple] = None, **contract_kw
                     ) -> tuple:
    """Run the range pass plus the kernel-contract pass and return
    ``(RangeReport, {backend: ContractReport})``; raise the first
    `AnalysisError` found. This is what
    `compile_network(..., validate=True)` executes at compile time.

    ``backends`` defaults to the dense Pallas contract for int-domain
    programs (the dispatch every integer backend shares its geometry
    with) and the trivial float contract otherwise; pass an explicit
    tuple to verify gated/event dispatches with their own knobs
    (``gate_granularity``, ``event_crossover``, ... via ``contract_kw``).
    """
    if backends is None:
        backends = ("pallas",) if program.domain == "int" else ("float",)
    ranges = check_program(program, frames=frames)
    contracts = {b: check_kernel_contracts(program, b, frames=frames,
                                           **contract_kw)
                 for b in backends}
    return ranges, contracts
