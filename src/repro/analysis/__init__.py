"""Static verification of compiled SNN programs (DESIGN.md §"Static
verification").

Four passes, composable and individually importable:

  * `check_program` — interval/bit-width abstract interpretation over the
    word-level ISA semantics: proves weights on the 6-bit grid, constants
    in the 11-bit V word, and that no unclamped int32 accumulator can
    overflow (per-layer `RangeReport`, or `RangeError` naming the layer).
  * `check_kernel_contracts` — pre-dispatch verification of everything the
    Pallas kernels assume from config alone: VMEM residency, skip_layout
    caps, event crossover, grid/gather bounds (`ContractReport`, or
    `ContractError` naming the contract and call).
  * `check_trace` — jaxpr-level verification of the *compiled artifact*:
    every int backend's real dispatch (batch, step, megastep, and the
    mesh row-partial tick under an abstract mesh) is traced and checked
    for dtype discipline, clamp placement/dominance, provable index
    bounds, and determinism, plus a static MAC/byte cost model that
    closes against the ISA instruction counts (`TraceReport`, or
    `TraceError` naming primitive + eqn + backend; DESIGN.md §7.5).
  * `lint_paths` — AST repo lint (ANA001 bare asserts, ANA002 ad-hoc
    clamps, ANA003 unseeded randomness, ANA005 float casts in int-domain
    modules); pure stdlib.

`compile_network(..., validate=True)` (the default) runs the first three
via `validate_program`; `tools/check_invariants.py` runs all four in CI
(`--trace` adds the full backend x surface trace matrix).
"""
from __future__ import annotations

from typing import Optional

from repro.analysis.intervals import (INT32, AnalysisError, Interval,
                                      V_DOMAIN, clamp_interval,
                                      wrap_is_exact)
from repro.analysis.kernel_contracts import (PALLAS_BACKENDS, ContractCheck,
                                             ContractError, ContractReport,
                                             KernelCall, VMEM_BUDGET_BYTES,
                                             check_kernel_contracts)
from repro.analysis.lint import (RULES, LintViolation, lint_file,
                                 lint_paths, lint_source)
from repro.analysis.program_check import (LayerRange, RangeError,
                                          RangeReport, check_program)
from repro.analysis.trace_check import (HOST_BACKENDS, SURFACES,
                                        TRACE_BACKENDS, TraceCheck,
                                        TraceError, TraceExpectation,
                                        TraceReport, check_closed_jaxpr,
                                        check_trace)
from repro.analysis.trace_cost import (CallCost, TraceCostReport,
                                       check_cost_closure, dense_instr)

__all__ = [
    "AnalysisError", "CallCost", "ContractCheck", "ContractError",
    "ContractReport", "HOST_BACKENDS", "INT32", "Interval", "KernelCall",
    "LayerRange", "LintViolation", "PALLAS_BACKENDS", "RULES",
    "RangeError", "RangeReport", "SURFACES", "TRACE_BACKENDS",
    "TraceCheck", "TraceCostReport", "TraceError", "TraceExpectation",
    "TraceReport", "V_DOMAIN", "VMEM_BUDGET_BYTES", "check_closed_jaxpr",
    "check_cost_closure", "check_kernel_contracts", "check_program",
    "check_trace", "clamp_interval", "dense_instr", "lint_file",
    "lint_paths", "lint_source", "validate_program", "wrap_is_exact",
]


def validate_program(program, *, frames: Optional[int] = None,
                     backends: Optional[tuple] = None,
                     trace: Optional[bool] = None,
                     trace_backends: Optional[tuple] = None, **contract_kw
                     ) -> tuple:
    """Run the range pass, the kernel-contract pass, and the trace pass;
    return ``(RangeReport, {backend: ContractReport}, {backend:
    TraceReport})`` and raise the first `AnalysisError` found. This is
    what `compile_network(..., validate=True)` executes at compile time.

    ``backends`` defaults to the dense Pallas contract for int-domain
    programs (the dispatch every integer backend shares its geometry
    with) and the trivial float contract otherwise; pass an explicit
    tuple to verify gated/event dispatches with their own knobs
    (``gate_granularity``, ``event_crossover``, ... via ``contract_kw``).

    ``trace`` defaults on for int-domain programs; ``trace_backends``
    defaults to every registered int backend — the XLA-dispatched ones
    (`TRACE_BACKENDS`) get the full batch/step/megastep/mesh surface
    matrix, the host executors (`HOST_BACKENDS`) a named skip row. Trace
    results are memoized by geometry, so re-validating an unchanged
    program is free.
    """
    if backends is None:
        backends = ("pallas",) if program.domain == "int" else ("float",)
    ranges = check_program(program, frames=frames)
    contracts = {b: check_kernel_contracts(program, b, frames=frames,
                                           **contract_kw)
                 for b in backends}
    if trace is None:
        trace = program.domain == "int"
    traces = {}
    if trace:
        if trace_backends is None:
            trace_backends = TRACE_BACKENDS + HOST_BACKENDS
        trace_kw = {k: contract_kw[k] for k in
                    ("gate_granularity", "event_crossover", "mesh",
                     "block_b") if k in contract_kw}
        for b in trace_backends:
            # a backend whose own kernel contract refuses this program
            # (layer-count caps, clamp-mode requirements, ...) has no
            # dispatch to trace — record the refusal, don't fail compile;
            # requesting that backend explicitly raises the ContractError
            try:
                bkw = dict(trace_kw)
                bkw.pop("mesh", None)
                if b != "pallas_sparse":
                    bkw.pop("gate_granularity", None)
                if b != "pallas_events":
                    bkw.pop("event_crossover", None)
                check_kernel_contracts(program, b, frames=frames, **bkw)
            except ContractError as e:
                traces[b] = TraceReport(
                    backend=b, surfaces=(), cost=None,
                    checks=(TraceCheck("contract_skip", b, str(e)),))
                continue
            traces[b] = check_trace(program, b, **trace_kw)
    return ranges, contracts, traces
