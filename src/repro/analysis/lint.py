"""Repo-invariant AST lint: custom rules the stock ruff families cannot
express, enforced over `src/repro` library code (tests are exempt — pytest
rewrites their asserts and they may exercise raw randomness on purpose).

  ANA001  no bare ``assert`` in library code. `python -O` strips asserts,
          so a contract guarded by one silently vanishes in optimized
          deployments — raise ValueError/TypeError instead.
  ANA002  no ad-hoc membrane clamping outside `core/quant.py`: any
          ``clip(...)`` bounded by the V-word constants (V_MIN / V_MAX /
          +-1024 / 1023) or any ``% V_SPAN`` wrap. Exactly one wrap and
          one saturate implementation may exist (`quant.clamp_v` /
          `clamp_v_np`), or backends drift apart one copied clamp at a
          time.
  ANA003  no unseeded randomness in library paths: legacy global-state
          ``np.random.<fn>()`` draws, or ``default_rng()`` /
          ``RandomState()`` constructed without a seed. Reproducibility
          (bit-identical rasters, deterministic benchmarks, the CI gate)
          requires every stream of randomness to be explicitly keyed.
  ANA004  the user-facing API surface (`core/pipeline.py`, `serve/`,
          `dist/`) documents itself: every public function or public-class
          method there needs a docstring, and when it takes parameters the
          docstring must mention at least one by name (a docstring that
          names no parameter documents the *idea* but not the *call* —
          the repo's entry points are exactly where call contracts live).
  ANA005  no float casts in int-domain modules (`kernels/fused_snn_net/`,
          `core/isa.py`, `core/macro.py`): any ``.astype(<float dtype>)``
          or ``jnp.float*`` / ``np.float*`` dtype reference. The word-level
          semantics are exact-integer end to end; one stray f32 round-trip
          breaks bit-identity silently on values past 2**24. Float lives
          in `core/quant.py` (the QAT boundary) and the float backend only.
          The trace pass (`check_trace`) proves the same property on the
          compiled jaxpr; ANA005 catches it at the source level, pre-jax.

Suppress a finding with ``# noqa: ANA00x`` on the offending line.

Pure stdlib (ast) on purpose: `tools/check_invariants.py` runs the lint
in environments without jax installed.
"""
from __future__ import annotations

import ast
import re
from dataclasses import dataclass
from pathlib import Path
from typing import Iterable, Optional

RULES = {
    "ANA001": "bare assert in library code (stripped under python -O); "
              "raise ValueError/TypeError",
    "ANA002": "ad-hoc membrane clamp; route through quant.clamp_v / "
              "quant.clamp_v_np / quant.spike_compare",
    "ANA003": "unseeded randomness in library code; pass an explicit "
              "seed/key",
    "ANA004": "public API function without a parameter-documenting "
              "docstring (core/pipeline.py, serve/, dist/)",
    "ANA005": "float cast in int-domain module; integer kernels are exact "
              "end to end — float belongs in core/quant.py or the float "
              "backend",
}

#: files whose public surface ANA004 holds to documented-call standard:
#: exact path suffixes and directory fragments under src/repro
_DOC_SCOPE_SUFFIXES = ("core/pipeline.py",)
_DOC_SCOPE_DIRS = ("/serve/", "/dist/")

#: modules whose arithmetic must stay exact-integer (ANA005): the fused
#: kernels and the word-level macro/ISA models
_INT_DOMAIN_DIRS = ("/kernels/fused_snn_net/",)
_INT_DOMAIN_SUFFIXES = ("core/isa.py", "core/macro.py")
#: floating dtype attribute names on jnp/np (jnp.float32, np.bfloat16, ...)
_FLOAT_DTYPE_ATTRS = {"float16", "float32", "float64", "float128",
                      "bfloat16", "float_", "half", "single", "double"}
#: module roots those attributes are flagged under
_ARRAY_ROOTS = {"jnp", "np", "numpy", "jax", "jax_numpy"}

#: the one module allowed to implement clamping
_CLAMP_HOME = ("core", "quant.py")
#: names/constants that mark a clip call as a *membrane* clamp
_V_NAMES = {"V_MIN", "V_MAX"}
_V_CONSTS = {-1024, 1023, 1024}
#: legacy numpy global-RNG draw functions (always unseeded global state)
_NP_GLOBAL_DRAWS = {
    "rand", "randn", "randint", "random", "random_sample", "ranf",
    "sample", "choice", "bytes", "shuffle", "permutation", "uniform",
    "normal", "standard_normal", "poisson", "binomial", "beta", "gamma",
    "exponential", "geometric",
}


@dataclass(frozen=True)
class LintViolation:
    path: str
    line: int
    col: int
    rule: str
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}:{self.col}: {self.rule} {self.message}"


def _attr_chain(node: ast.AST) -> list:
    """['np', 'random', 'default_rng'] for np.random.default_rng."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
    return parts[::-1]


def _mentions_v_const(node: ast.AST) -> bool:
    for sub in ast.walk(node):
        if isinstance(sub, ast.Name) and sub.id in _V_NAMES:
            return True
        if isinstance(sub, ast.Constant) and sub.value in _V_CONSTS:
            return True
        if (isinstance(sub, ast.UnaryOp) and isinstance(sub.op, ast.USub)
                and isinstance(sub.operand, ast.Constant)
                and isinstance(sub.operand.value, int)
                and -sub.operand.value in _V_CONSTS):
            return True
    return False


class _Visitor(ast.NodeVisitor):
    def __init__(self, path: str, clamp_home: bool,
                 doc_scope: bool = False, int_scope: bool = False) -> None:
        self.path = path
        self.clamp_home = clamp_home
        self.doc_scope = doc_scope
        self.int_scope = int_scope
        self._class_public: list[bool] = []   # enclosing-class publicness
        self._fn_depth = 0
        self.found: list[LintViolation] = []

    def _add(self, node: ast.AST, rule: str, message: str) -> None:
        self.found.append(LintViolation(
            path=self.path, line=node.lineno, col=node.col_offset + 1,
            rule=rule, message=message))

    # ANA001 ---------------------------------------------------------------
    def visit_Assert(self, node: ast.Assert) -> None:
        self._add(node, "ANA001", RULES["ANA001"])
        self.generic_visit(node)

    # ANA004 ---------------------------------------------------------------
    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self._class_public.append(not node.name.startswith("_"))
        self.generic_visit(node)
        self._class_public.pop()

    def _check_doc(self, node) -> None:
        """ANA004: public functions of the API surface carry docstrings
        that name at least one of their parameters."""
        public = (not node.name.startswith("_")
                  and self._fn_depth == 0
                  and all(self._class_public))
        if not (self.doc_scope and public):
            return
        doc = ast.get_docstring(node)
        if not doc:
            self._add(node, "ANA004",
                      f"'{node.name}' has no docstring; " + RULES["ANA004"])
            return
        a = node.args
        params = [p.arg for p in (a.posonlyargs + a.args + a.kwonlyargs)]
        params += [p.arg for p in (a.vararg, a.kwarg) if p is not None]
        params = [p for p in params if p not in ("self", "cls")]
        if params and not any(
                re.search(rf"\b{re.escape(p)}\b", doc) for p in params):
            self._add(node, "ANA004",
                      f"'{node.name}' docstring names none of its "
                      f"parameters {params}; " + RULES["ANA004"])

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._check_doc(node)
        self._fn_depth += 1
        self.generic_visit(node)
        self._fn_depth -= 1

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._check_doc(node)
        self._fn_depth += 1
        self.generic_visit(node)
        self._fn_depth -= 1

    # ANA002 ---------------------------------------------------------------
    def visit_BinOp(self, node: ast.BinOp) -> None:
        if (not self.clamp_home and isinstance(node.op, ast.Mod)
                and isinstance(node.right, ast.Name)
                and node.right.id == "V_SPAN"):
            self._add(node, "ANA002", "wrap via '% V_SPAN'; "
                      + RULES["ANA002"])
        self.generic_visit(node)

    # ANA002 + ANA003 ------------------------------------------------------
    def visit_Call(self, node: ast.Call) -> None:
        chain = _attr_chain(node.func)
        if (not self.clamp_home and chain and chain[-1] == "clip"
                and any(_mentions_v_const(a) for a in node.args[1:])):
            self._add(node, "ANA002",
                      "clip to the V word; " + RULES["ANA002"])
        if len(chain) >= 2 and chain[-2] == "random" and chain[0] in (
                "np", "numpy"):
            fn = chain[-1]
            if fn in _NP_GLOBAL_DRAWS:
                self._add(node, "ANA003", f"np.random.{fn} draws from "
                          "global state; " + RULES["ANA003"])
            elif fn in ("default_rng", "RandomState") and not node.args \
                    and not node.keywords:
                self._add(node, "ANA003", f"np.random.{fn}() without a "
                          "seed; " + RULES["ANA003"])
        if (self.int_scope and chain and chain[-1] == "astype"
                and node.args and self._float_dtype_arg(node.args[0])):
            self._add(node, "ANA005",
                      "astype to a float dtype; " + RULES["ANA005"])
        self.generic_visit(node)

    # ANA005 ---------------------------------------------------------------
    @staticmethod
    def _float_dtype_arg(node: ast.AST) -> bool:
        """True for the astype args visit_Attribute can't see: the builtin
        ``float`` and dtype strings ("float32", "bfloat16", ...).
        jnp.float* / np.float* attribute args are caught by
        visit_Attribute directly."""
        if isinstance(node, ast.Name) and node.id == "float":
            return True
        return (isinstance(node, ast.Constant)
                and isinstance(node.value, str)
                and node.value.lstrip("b").startswith("float"))

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self.int_scope and node.attr in _FLOAT_DTYPE_ATTRS:
            chain = _attr_chain(node)
            if chain and chain[0] in _ARRAY_ROOTS:
                self._add(node, "ANA005",
                          f"{'.'.join(chain)} in an int-domain module; "
                          + RULES["ANA005"])
        self.generic_visit(node)


def _noqa_lines(source: str) -> dict:
    """line number -> set of suppressed rule ids ({'*'} for bare noqa)."""
    out: dict[int, set] = {}
    for i, line in enumerate(source.splitlines(), start=1):
        if "noqa" not in line:
            continue
        _, _, tail = line.partition("noqa")
        tail = tail.lstrip(" :")
        rules = {t.strip().rstrip(",") for t in tail.split()
                 if t.strip().startswith("ANA")}
        out[i] = rules or {"*"}
    return out


def lint_source(source: str, path: str = "<string>") -> list:
    """Lint one module's ``source``; returns the surviving violations
    (``path`` scopes the path-dependent rules and labels findings)."""
    norm = path.replace("\\", "/")
    clamp_home = norm.endswith("/".join(_CLAMP_HOME))
    doc_scope = (norm.endswith(_DOC_SCOPE_SUFFIXES)
                 or any(d in norm for d in _DOC_SCOPE_DIRS))
    int_scope = (norm.endswith(_INT_DOMAIN_SUFFIXES)
                 or any(d in norm for d in _INT_DOMAIN_DIRS))
    tree = ast.parse(source, filename=path)
    visitor = _Visitor(path, clamp_home, doc_scope, int_scope)
    visitor.visit(tree)
    noqa = _noqa_lines(source)
    return [v for v in visitor.found
            if not (v.line in noqa
                    and ("*" in noqa[v.line] or v.rule in noqa[v.line]))]


def lint_file(path) -> list:
    p = Path(path)
    return lint_source(p.read_text(), str(p))


def lint_paths(paths: Iterable, *, exclude: Optional[Iterable] = None
               ) -> list:
    """Lint every ``*.py`` under the given files/directories (sorted), for
    stable, diffable output. ``exclude``: path substrings to skip."""
    exclude = tuple(exclude or ())
    files: list[Path] = []
    for root in paths:
        root = Path(root)
        files.extend(sorted(root.rglob("*.py")) if root.is_dir() else [root])
    out = []
    for f in files:
        s = str(f)
        if any(e in s for e in exclude):
            continue
        out.extend(lint_file(f))
    return out
