"""Static cost model over the traced dispatch jaxprs (DESIGN.md §7.5).

Walks the same closed jaxprs `trace_check` verifies and counts, per fused
call, the MXU MACs and the HBM<->VMEM bytes the compiled artifact will
move — then ties that machine-level tally back to the ISA contract:

* **geometry validation** — the traced timestep scan must run exactly
  ``program.timesteps`` iterations, every dense `dot_general` must
  contract the (lane-padded) layer widths the program declares, and every
  `pallas_call` grid must cover exactly ``ceil(batch / block_b)`` batch
  blocks. A dot that contracts anything else means the compiled path
  silently changed shape — that is a `TraceError`, not a cost.
* **cost closure** — `dense_instr` folds the *trace-validated* geometry
  (T, batch, logical widths, neuron kind) through
  `isa.count_layer_instructions_from_events` with dense (every-input-
  spiking) events; `check_cost_closure` proves this equals
  `pipeline.count_network_instructions` on explicit all-ones rasters
  exactly — the jaxpr, the config-derived counter, and the ISA
  accounting all describe the same workload or the check fails.

Conventions of the bytes model (documented, not inferred): a
`pallas_call` moves each operand/result array once, plus one extra fetch
per additional grid step for *grid-invariant* operands — the 2-D arrays
(weight tiles, per-layer parameter rows) that every batch block re-reads;
3-D operands (the spike frames) are partitioned across the grid. Backends
with no `pallas_call` (``int_ref``) charge the top-level dispatch
operands/results once. MACs are *dense* MXU work: `lax.cond` branches
count as their maximum (the event kernel's gather fallback is bounded by
its dense branch), a `dot_general` inside an unbounded `while` is
rejected outright.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.analysis.trace_check import (TraceCheck, TraceError, _aval_dtype,
                                        _aval_shape, _grid_size,
                                        _program_calls, _sub_regions,
                                        root_region)
from repro.core import isa


@dataclass(frozen=True)
class DotSite:
    """One traced `dot_general`: contracted geometry and its static trip
    count (product of enclosing scan lengths and pallas grids)."""
    m: int
    k: int
    n: int
    trip: int

    @property
    def macs(self) -> int:
        return self.m * self.k * self.n * self.trip


@dataclass(frozen=True)
class CallCost:
    """Machine-level cost of one fused call's batch dispatch."""
    call: str
    macs: int
    hbm_bytes: int
    dots: tuple                    # tuple[DotSite, ...]
    scan_lengths: tuple
    grids: tuple


@dataclass(frozen=True)
class TraceCostReport:
    """Per-dispatch MAC/byte tallies plus the dense ISA instruction
    counts derived from the trace-validated geometry. ``instr`` must
    close exactly against `pipeline.count_network_instructions` on
    all-ones rasters (`check_cost_closure`)."""
    backend: str
    batch: int
    timesteps: int
    calls: tuple                   # tuple[CallCost, ...]
    instr: isa.InstrCount

    @property
    def macs(self) -> int:
        return sum(c.macs for c in self.calls)

    @property
    def hbm_bytes(self) -> int:
        return sum(c.hbm_bytes for c in self.calls)


def _nbytes(atom) -> int:
    shape = _aval_shape(atom) or ()
    dt = _aval_dtype(atom)
    if dt is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * np.dtype(dt).itemsize


def _dot_mkn(eqn) -> tuple:
    """(M, K, N) of a dot_general from its dimension_numbers: M = lhs
    free x batch dims, K = contracted dims, N = rhs free dims."""
    (lc, rc), (lb, _rb) = eqn.params["dimension_numbers"]
    lshape = _aval_shape(eqn.invars[0]) or ()
    rshape = _aval_shape(eqn.invars[1]) or ()
    k = int(np.prod([lshape[i] for i in lc], dtype=np.int64)) if lc else 1
    m = int(np.prod([d for i, d in enumerate(lshape) if i not in lc],
                    dtype=np.int64))
    n = int(np.prod([d for i, d in enumerate(rshape)
                     if i not in rc and i not in _rb],
                    dtype=np.int64))
    del lb
    return m, k, n


def _walk_cost(region, trip: int, dots: list, scans: list, grids: list,
               bytes_acc: list, where: str) -> None:
    for eqn in region.jaxpr.eqns:
        p = eqn.primitive.name
        if p == "dot_general":
            m, k, n = _dot_mkn(eqn)
            dots.append(DotSite(m=m, k=k, n=n, trip=trip))
        elif p == "scan":
            length = int(eqn.params.get("length", 1))
            scans.append(length)
            for sub in _sub_regions(eqn, region):
                _walk_cost(sub, trip * length, dots, scans, grids,
                           bytes_acc, where)
        elif p == "while":
            for sub in _sub_regions(eqn, region):
                before = len(dots)
                _walk_cost(sub, trip, dots, scans, grids, bytes_acc, where)
                if len(dots) != before:
                    raise TraceError(
                        "cost: dot_general inside an unbounded 'while' at "
                        f"{sub.path or '/'} — MXU work with a dynamic "
                        "trip count cannot be statically accounted",
                        where=where)
        elif p == "cond":
            branch_dots: list = []
            for sub in _sub_regions(eqn, region):
                bd: list = []
                _walk_cost(sub, trip, bd, scans, grids, bytes_acc, where)
                branch_dots.append(bd)
            if branch_dots:        # dense bound: the costliest branch
                branch_dots.sort(key=lambda bd: sum(d.macs for d in bd))
                dots.extend(branch_dots[-1])
        elif p == "pallas_call":
            g = _grid_size(eqn)
            grids.append(g)
            operands = list(eqn.invars)
            moved = sum(_nbytes(a) for a in (*operands, *eqn.outvars))
            invariant = sum(_nbytes(a) for a in operands
                            if len(_aval_shape(a) or ()) == 2)
            bytes_acc.append(trip * (moved + (g - 1) * invariant))
            for sub in _sub_regions(eqn, region):
                _walk_cost(sub, trip * g, dots, scans, grids, bytes_acc,
                           where)
        else:
            for sub in _sub_regions(eqn, region):
                _walk_cost(sub, trip, dots, scans, grids, bytes_acc, where)


def _padded(widths: tuple, backend: str) -> tuple:
    if backend == "int_ref":
        return tuple(int(w) for w in widths)
    from repro.analysis.kernel_contracts import _pad_lane
    return tuple(_pad_lane(int(w)) for w in widths)


def _validate_geometry(program, backend: str, call: str, widths: tuple,
                       cost: CallCost, *, batch: int, block_b: int,
                       where: str) -> None:
    T = int(program.timesteps)
    if T not in cost.scan_lengths:
        raise TraceError(
            f"cost: no scan of length {T} (the timestep loop) in the "
            f"traced '{call}' dispatch — scan lengths {cost.scan_lengths}",
            where=where)
    if backend != "int_ref":
        grid_want = -(-batch // block_b)
        bad = [g for g in cost.grids if g != grid_want]
        if not cost.grids or bad:
            raise TraceError(
                f"cost: pallas grid(s) {cost.grids} in '{call}' do not "
                f"cover batch {batch} in {block_b}-row blocks "
                f"(want {grid_want})", where=where)
    pw = _padded(widths, backend)
    m_want = batch if backend == "int_ref" else min(block_b, batch)
    for i in range(len(widths) - 1):
        k_want, n_want = pw[i], pw[i + 1]
        if backend == "pallas_sparse":
            hit = [d for d in cost.dots
                   if d.n == n_want and k_want % d.k == 0]
        else:
            hit = [d for d in cost.dots if d.k == k_want and d.n == n_want]
        if not hit:
            raise TraceError(
                f"cost: no dot_general contracting layer {i} of '{call}' "
                f"(want K={k_want} N={n_want}; traced "
                f"{[(d.m, d.k, d.n) for d in cost.dots]}) — the compiled "
                "path changed shape", where=where)
        if any(d.m != m_want for d in hit):
            raise TraceError(
                f"cost: dot_general M={sorted({d.m for d in hit})} for "
                f"layer {i} of '{call}', want the {m_want}-row batch "
                "block", where=where)


def _conv_input_maps(program) -> list:
    """(H, W, C) input spike-map shape of every conv macro-stack layer:
    the previous conv layer's state shape (the first takes H, W from the
    network input), with channels always the packed kernel's c_in — the
    channel count the macro's patch rows actually carry."""
    shapes, hw = [], tuple(getattr(program.cfg, "in_shape", ())[:2])
    for spec in program.macro_stack:
        if spec.kind != "conv":
            continue
        shapes.append((*hw, int(spec.w.shape[2])))
        hw = tuple(spec.state_shape[:2])
    return shapes


def _dense_conv_counts(in_map: tuple, kernel: int, stride: int) -> tuple:
    """(positions, events_per_frame-pair): for a SAME-padded conv over an
    all-ones (H, W, C) map, the output position count and the total
    non-padding patch cells per (example, timestep) — border patches see
    the zero padding, so the dense event count is *less* than
    positions x k*k*C. Pure numpy re-derivation of the im2col geometry."""
    from repro.core.mapping import same_pads
    h, w, c = in_map
    h_out, lo_h, hi_h = same_pads(h, kernel, stride)
    w_out, lo_w, hi_w = same_pads(w, kernel, stride)
    p = np.pad(np.ones((h, w), np.int64), ((lo_h, hi_h), (lo_w, hi_w)))
    cells = 0
    for di in range(kernel):
        for dj in range(kernel):
            cells += int(p[di:di + (h_out - 1) * stride + 1:stride,
                           dj:dj + (w_out - 1) * stride + 1:stride].sum())
    return h_out * w_out, cells * c


def dense_instr(program, batch: int) -> isa.InstrCount:
    """ISA instruction counts for the dense (every-input-spiking)
    workload, folded from the trace-validated geometry: per macro-stack
    layer, frames = T * batch * output-positions and events from the
    SAME-padded patch geometry (conv) or frames * fan-in (fc), through
    the same `count_layer_instructions_from_events` the raster accounting
    uses."""
    T = int(program.timesteps)
    counts = isa.InstrCount()
    conv_maps = iter(_conv_input_maps(program))
    for spec in program.macro_stack:
        if spec.kind == "conv":
            in_map = next(conv_maps)
            pos, ev_frame = _dense_conv_counts(
                in_map, int(spec.w.shape[0]), int(spec.stride))
            want_pos = int(np.prod(spec.state_shape[:-1], dtype=np.int64))
            if pos != want_pos:
                raise TraceError(
                    f"cost: conv geometry drift — SAME-padded im2col of "
                    f"{in_map} gives {pos} output positions, the program "
                    f"state shape {spec.state_shape} declares {want_pos}",
                    where="cost_closure")
            frames = T * batch * pos
            events = T * batch * ev_frame
        else:
            frames = T * batch
            events = frames * int(spec.n_in)
        neuron = "none" if spec.kind == "readout" else program.neuron
        counts += isa.count_layer_instructions_from_events(
            events, frames, int(spec.n_in), int(spec.n_out), neuron)
    return counts


def dense_rasters(program, batch: int) -> list:
    """All-ones input rasters for every macro-stack layer — the explicit
    dense workload `pipeline.count_network_instructions` counts. Conv
    layers take their full input spike *map*, which the counter lowers
    through the same im2col the macro executes (so its dense events
    include the SAME-padding zeros `dense_instr` accounts analytically)."""
    T = int(program.timesteps)
    conv_maps = iter(_conv_input_maps(program))
    out = []
    for spec in program.macro_stack:
        if spec.kind == "conv":
            out.append(np.ones((T, batch, *next(conv_maps)), np.int8))
        else:
            out.append(np.ones((T, batch, int(spec.n_in)), np.int8))
    return out


def check_cost_closure(program, batch: int = 8) -> isa.InstrCount:
    """Prove the trace-geometry dense counts equal the raster-accounting
    dense counts exactly; returns the agreed `InstrCount` or raises
    `TraceError` naming the first diverging field."""
    from repro.core.pipeline import count_network_instructions
    got = dense_instr(program, batch)
    want = count_network_instructions(program,
                                      rasters=dense_rasters(program, batch))
    if got != want:
        raise TraceError(
            f"cost: dense instruction closure failed — trace-geometry "
            f"counts {got} != raster-accounting counts {want}; the "
            "compiled dispatch and the ISA accounting describe different "
            "workloads", where="cost_closure")
    return got


def build_cost_report(program, backend: str, batch_jaxprs: dict, *,
                      batch: int, block_b: int,
                      checks: list = None) -> TraceCostReport:
    """Cost-walk every fused call's traced batch jaxpr, validate its
    geometry against the program, and fold the dense ISA counts. Appends
    `TraceCheck` rows to ``checks`` when given."""
    calls = []
    for name, _layer_names, widths, _n_spiking in _program_calls(program):
        closed = batch_jaxprs.get(name)
        if closed is None:
            continue
        where = f"{backend}:cost:{name}"
        dots: list = []
        scans: list = []
        grids: list = []
        bytes_acc: list = []
        root = root_region(closed, path="")
        _walk_cost(root, 1, dots, scans, grids, bytes_acc, where)
        if not bytes_acc:          # no pallas_call: charge the dispatch
            jaxpr = closed.jaxpr if hasattr(closed, "jaxpr") else closed
            bytes_acc.append(sum(_nbytes(a) for a in
                                 (*jaxpr.invars, *jaxpr.outvars)))
        cost = CallCost(call=name, macs=sum(d.macs for d in dots),
                        hbm_bytes=int(sum(bytes_acc)), dots=tuple(dots),
                        scan_lengths=tuple(scans), grids=tuple(grids))
        _validate_geometry(program, backend, name, widths, cost,
                           batch=batch, block_b=block_b, where=where)
        if checks is not None:
            checks.append(TraceCheck(
                "cost_geometry", where,
                f"{len(dots)} dot site(s) match declared widths; "
                f"macs={cost.macs} hbm_bytes={cost.hbm_bytes}"))
        calls.append(cost)
    return TraceCostReport(backend=backend, batch=batch,
                           timesteps=int(program.timesteps),
                           calls=tuple(calls),
                           instr=dense_instr(program, batch))
