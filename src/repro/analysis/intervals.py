"""Integer interval lattice for word-level range analysis.

The abstract domain is closed integer intervals [lo, hi] ordered by
inclusion. Every transfer function here is *sound*: if a concrete value v
lies in the input interval, the transformed value lies in the output
interval. Two transfers are additionally *exact* in ways the analyzer
exploits:

  * saturate clamp: monotone, so clamping the endpoints clamps the set.
  * wrap clamp: ``((v - V_MIN) % V_SPAN) + V_MIN`` is a translation on any
    interval that stays inside a single wrap window (the half-open spans
    ``[V_MIN + k*V_SPAN, V_MIN + (k+1)*V_SPAN)``); crossing a window
    boundary splits the image into two arcs whose hull is the full 11-bit
    domain — sound, and the only over-approximation wrap introduces.

Because 2^11 divides 2^32, int32 two's-complement overflow is itself a
wrap mod a multiple of V_SPAN, so wrap-mode V words survive int32 overflow
unchanged (``v mod 2^32 mod 2^11 == v mod 2^11``). Saturate mode has no
such luck: an accumulator that overflows *before* the clip clips the wrong
value, which is exactly what `program_check` must prove cannot happen.
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.quant import V_MAX, V_MIN, V_SPAN

INT32_MIN = -(2 ** 31)
INT32_MAX = 2 ** 31 - 1


class AnalysisError(ValueError):
    """Base class for every static-analysis rejection.

    Carries ``where`` — the layer / op / contract the verdict names — so
    callers (and tests) can assert the analyzer identified the offender,
    not merely that something failed.
    """

    def __init__(self, message: str, *, where: str = "") -> None:
        super().__init__(f"{where}: {message}" if where else message)
        self.where = where


@dataclass(frozen=True)
class Interval:
    """Closed integer interval [lo, hi] (requires lo <= hi)."""
    lo: int
    hi: int

    def __post_init__(self) -> None:
        if self.lo > self.hi:
            raise ValueError(f"empty interval [{self.lo}, {self.hi}]")

    # --- lattice ---------------------------------------------------------
    def hull(self, other: "Interval") -> "Interval":
        return Interval(min(self.lo, other.lo), max(self.hi, other.hi))

    def intersect(self, other: "Interval") -> "Interval | None":
        lo, hi = max(self.lo, other.lo), min(self.hi, other.hi)
        return Interval(lo, hi) if lo <= hi else None

    def contains(self, other: "Interval") -> bool:
        return self.lo <= other.lo and other.hi <= self.hi

    def contains_value(self, v: int) -> bool:
        return self.lo <= int(v) <= self.hi

    # --- arithmetic transfers (exact) ------------------------------------
    def __add__(self, other: "Interval") -> "Interval":
        return Interval(self.lo + other.lo, self.hi + other.hi)

    def __sub__(self, other: "Interval") -> "Interval":
        return Interval(self.lo - other.hi, self.hi - other.lo)

    def shift(self, k: int) -> "Interval":
        return Interval(self.lo + k, self.hi + k)

    def scale(self, k: int) -> "Interval":
        """Image under multiplication by an integer constant k."""
        a, b = self.lo * k, self.hi * k
        return Interval(min(a, b), max(a, b))

    @property
    def width(self) -> int:
        return self.hi - self.lo

    @property
    def magnitude(self) -> int:
        """max |v| over the interval."""
        return max(abs(self.lo), abs(self.hi))

    def __repr__(self) -> str:  # compact in reports
        return f"[{self.lo}, {self.hi}]"

    @staticmethod
    def point(v: int) -> "Interval":
        return Interval(int(v), int(v))


#: the 11-bit signed membrane word domain, [-1024, 1023]
V_DOMAIN = Interval(V_MIN, V_MAX)
#: the int32 accumulator domain every backend carries partials in
INT32 = Interval(INT32_MIN, INT32_MAX)


def clamp_interval(iv: Interval, mode: str) -> Interval:
    """Transfer function of `quant.clamp_v` on intervals.

    saturate is exact (monotone). wrap is exact iff the interval lies in
    one wrap window — ``floor((lo - V_MIN) / V_SPAN) ==
    floor((hi - V_MIN) / V_SPAN)`` — and widens to the full domain
    otherwise (the image is two arcs; we keep a single-interval lattice).
    """
    if mode == "saturate":
        return Interval(min(max(iv.lo, V_MIN), V_MAX),
                        min(max(iv.hi, V_MIN), V_MAX))
    if mode == "wrap":
        k_lo = (iv.lo - V_MIN) // V_SPAN
        k_hi = (iv.hi - V_MIN) // V_SPAN
        if k_lo == k_hi:
            return iv.shift(-k_lo * V_SPAN)
        return V_DOMAIN
    raise ValueError(f"unknown clamp mode {mode!r}")


def wrap_is_exact(iv: Interval) -> bool:
    """True when `clamp_interval(iv, "wrap")` loses no precision."""
    return (iv.lo - V_MIN) // V_SPAN == (iv.hi - V_MIN) // V_SPAN
