"""Jaxpr-level verification of the compiled kernel dispatches (DESIGN.md
§7.5 "Trace verification").

`program_check` and `kernel_contracts` prove properties *re-derived from
config*; this pass verifies the artifact JAX actually compiles. Every
registered int backend's real dispatch — `ops.fused_snn_net` (batch), the
``v_init`` step entry, the K-frame megastep int tail (fused call + readout
trajectory cumsum), and the model-parallel row-partial tick of
`fused_snn_net_mesh` under an *abstract* mesh (`jax.make_jaxpr(...,
axis_env=...)`, no devices) — is traced to a closed jaxpr and statically
checked:

  property         | what is verified on the traced jaxpr
  -----------------|----------------------------------------------------
  dtype            | no float avals anywhere on the int-domain path, no
                   | ``convert_element_type`` to float; every
                   | `dot_general` accumulates in int32
  determinism      | no RNG primitives; float reductions are excluded by
                   | the dtype rule, so nothing reorder-sensitive remains
  clamp placement  | exactly the contracted number of V-word clamp heads
                   | (``max`` against V_MIN / ``% V_SPAN``, incl. their
                   | jnp ``pjit`` wrappings) per dispatch; every clamp in
                   | the program's mode; no clamp inside a predicated
                   | (`@pl.when` / `lax.cond`) branch — partials must add
                   | unclamped and the single clamp runs after; every
                   | SpikeCheck (``ge``) SSA chain hits a clamp before
                   | reaching a `dot_general`/`psum` accumulation source;
                   | no clamp upstream of a cross-shard ``psum`` (the
                   | AccV2V reduction sums *unclamped* partials)
  bounds           | every ``dynamic_slice`` start and every dynamic
                   | Pallas ``get``/``swap`` row index is provably
                   | in-bounds by interval analysis (event-list gather
                   | indices bounded by the padded fan-in via the
                   | cumsum/one-hot decode pattern; mesh row-tile starts
                   | bounded by ``axis_index * rows``)

Violations raise `TraceError` naming the primitive, the eqn's region path
inside the jaxpr, and the backend/surface. The companion `trace_cost`
module walks the same jaxprs into a `TraceCostReport` (MXU MACs, HBM<->
VMEM bytes) whose macro-cycle tally must close exactly against
`isa.count_network_instructions` dense counts.

The clamp-dominance argument has one documented blind spot: dataflow
through Pallas *refs* (`get`/`swap`) is invisible to the SSA walk, so a
ref-mediated accumulate->clamp chain (the event-list kernel) is covered by
the clamp-*count* closure and the no-clamp-in-branch rule rather than the
per-read dominance walk — the walk simply terminates at the ref read.

Entry points: `check_trace(program, backend)` (per-backend `TraceReport`,
memoized by geometry) and the low-level `check_closed_jaxpr(jaxpr,
expect)` that the negative-path tests drive with deliberately broken
kernels. `analysis.validate_program` runs `check_trace` for every int
backend by default; `tools/check_invariants.py --trace` is the CI entry.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.analysis.intervals import AnalysisError, Interval
from repro.core.quant import V_MAX, V_MIN, V_SPAN

#: int backends whose dispatch is an XLA computation we can trace
TRACE_BACKENDS = ("int_ref", "pallas", "pallas_sparse", "pallas_events")
#: int backends that execute on the host (numpy / BitMacro objects) — no
#: jaxpr exists; `check_trace` returns a named skip row for them
HOST_BACKENDS = ("ref_events", "bitmacro")
#: the dispatch surfaces one backend trace covers
SURFACES = ("batch", "step", "megastep", "mesh")
#: abstract mesh extents the mesh surface traces under by default
DEFAULT_MESH_AXES = (("data", 2), ("model", 2))

_CALL_PRIMS = {"pjit", "closed_call", "core_call", "xla_call", "remat",
               "remat2", "checkpoint", "custom_jvp_call", "custom_vjp_call",
               "custom_jvp_call_jaxpr", "custom_vjp_call_jaxpr"}
_RNG_PRIMS = {"threefry2x32", "random_seed", "random_bits", "random_wrap",
              "random_unwrap", "random_fold_in", "random_gamma",
              "rng_uniform", "rng_bit_generator"}
#: primitives a clamp-head call body may consist of (a pure elementwise
#: chain — anything else means the call *contains* a clamp rather than
#: *being* one, e.g. the outer jit'd dispatch itself)
_ELEMENTWISE = {"max", "min", "rem", "add", "sub", "mul", "neg", "sign",
                "convert_element_type", "select_n", "lt", "le", "gt", "ge",
                "eq", "ne", "and", "or", "not", "xor", "broadcast_in_dim",
                "reshape", "squeeze", "expand_dims", "clamp", "div",
                "floor", "integer_pow", "copy"}
#: interval/dominance passthrough primitives (bounds preserved or shrunk)
_PASSTHROUGH = {"convert_element_type", "broadcast_in_dim", "reshape",
                "squeeze", "expand_dims", "slice", "transpose", "copy",
                "rev", "reduce_max", "reduce_min", "stop_gradient",
                "reduce_precision", "abs"}

_MAX_DEPTH = 64


class TraceError(AnalysisError):
    """A traced dispatch violates the ISA contract (the finding names the
    primitive, its region path in the jaxpr, and the backend/surface)."""


@dataclass(frozen=True)
class TraceCheck:
    """One verified trace property: name, where it held, the numbers."""
    prop: str
    where: str
    detail: str


@dataclass(frozen=True)
class TraceExpectation:
    """What the checker demands of one traced dispatch surface."""
    where: str                     # "backend:surface:call" finding label
    neuron: str = "rmp"
    clamp_mode: str = "saturate"
    n_spiking: int = 1
    mesh_axes: tuple = ()          # (("data", n), ("model", m)) on mesh
    extra_clamps: int = 0          # heads beyond the neuron contract

    @property
    def expected_clamps(self) -> int:
        per = {"if": 1, "lif": 2, "rmp": 2}[self.neuron]
        if self.clamp_mode == "wrap":
            per += 1               # the SpikeCheck comparison itself wraps
        return self.n_spiking * per + self.extra_clamps


@dataclass(frozen=True)
class SurfaceTrace:
    """Checked facts of one traced (surface, call) dispatch."""
    surface: str
    call: str
    clamps: int
    spike_reads: int
    bounds_checked: int
    eqns: int


@dataclass(frozen=True)
class TraceReport:
    backend: str
    surfaces: tuple                # tuple[SurfaceTrace, ...]
    checks: tuple                  # tuple[TraceCheck, ...] all satisfied
    cost: Any = None               # trace_cost.TraceCostReport (batch)


# ---------------------------------------------------------------------------
# jaxpr regions: one (sub)jaxpr + const env + parent linkage
# ---------------------------------------------------------------------------

def _is_literal(atom) -> bool:
    return hasattr(atom, "val") and not hasattr(atom, "count")


def _aval(atom):
    return getattr(atom, "aval", None)


def _aval_dtype(atom):
    av = _aval(atom)
    dt = getattr(av, "dtype", None)
    if dt is None:
        dt = getattr(getattr(av, "inner_aval", None), "dtype", None)
    return dt


def _aval_shape(atom):
    av = _aval(atom)
    shape = getattr(av, "shape", None)
    if shape is None:
        shape = getattr(getattr(av, "inner_aval", None), "shape", None)
    return shape


class _Region:
    """One jaxpr nesting level: local defs, const bindings, the mapping of
    its invars onto parent atoms, and whether it executes predicated."""

    __slots__ = ("jaxpr", "path", "parent", "bindings", "consts",
                 "predicated", "axis_sizes", "defs", "carry_facts")

    def __init__(self, jaxpr, consts, path, parent=None, bindings=None,
                 predicated=False, axis_sizes=None, carry_facts=None):
        self.jaxpr = jaxpr
        self.path = path
        self.parent = parent
        self.bindings = bindings or {}
        self.carry_facts = carry_facts or {}
        self.predicated = predicated
        self.axis_sizes = dict(axis_sizes if axis_sizes is not None
                               else (parent.axis_sizes if parent else {}))
        self.consts = dict(zip(jaxpr.constvars, consts))
        self.defs = {}
        for eqn in jaxpr.eqns:
            for ov in eqn.outvars:
                self.defs[ov] = eqn


def _open(j) -> tuple:
    """(jaxpr, consts) of a ClosedJaxpr or a bare Jaxpr."""
    inner = getattr(j, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner, list(getattr(j, "consts", ()) or ())
    return j, []


def _looks_like_jaxpr(obj) -> bool:
    return (hasattr(obj, "eqns") and hasattr(obj, "invars")) or (
        hasattr(obj, "jaxpr") and hasattr(getattr(obj, "jaxpr"), "eqns"))


def _grid_size(eqn) -> int:
    """Static grid-step count of a pallas_call eqn (1 when unknown)."""
    gm = eqn.params.get("grid_mapping")
    grid = getattr(gm, "grid", None)
    if grid is None:
        grid = eqn.params.get("grid") or ()
    try:
        return int(np.prod([int(g) for g in grid])) if grid else 1
    except (TypeError, ValueError):
        return 1


def _sub_regions(eqn, region) -> list:
    """Child regions of one eqn, with invar bindings where the primitive's
    calling convention is known (version-defensive: unknown primitives that
    carry jaxpr params still get an unbound region, so no eqn is ever
    skipped — checks just lose cross-boundary const facts there)."""
    p = eqn.primitive.name
    params = eqn.params
    out = []
    if p in _CALL_PRIMS or (p.endswith("_call") and p != "pallas_call"
                            and ("jaxpr" in params or "call_jaxpr" in params)):
        body, consts = _open(params.get("jaxpr", params.get("call_jaxpr")))
        name = params.get("name", p)
        out.append(_Region(body, consts, f"{region.path}/{name}", region,
                           dict(zip(body.invars, eqn.invars)),
                           region.predicated))
    elif p == "scan":
        body, consts = _open(params["jaxpr"])
        nc = int(params.get("num_consts", 0))
        ncar = int(params.get("num_carry", 0))
        bind = dict(zip(body.invars[:nc], eqn.invars[:nc]))
        # xs slices: each body slice var is an element of the parent xs —
        # sound for intervals and for upstream walks (subset relation)
        bind.update(zip(body.invars[nc + ncar:], eqn.invars[nc + ncar:]))
        out.append(_Region(body, consts, f"{region.path}/scan", region,
                           bind, region.predicated,
                           carry_facts=_scan_carry_facts(
                               eqn, body, nc, ncar, region)))
    elif p == "while":
        cond, cc = _open(params["cond_jaxpr"])
        body, bc = _open(params["body_jaxpr"])
        cn = int(params.get("cond_nconsts", 0))
        bn = int(params.get("body_nconsts", 0))
        out.append(_Region(cond, cc, f"{region.path}/while.cond", region,
                           dict(zip(cond.invars[:cn], eqn.invars[:cn])),
                           region.predicated))
        # carry vars deliberately stay unbound: binding them to the init
        # values would be wrong from iteration 2 on
        out.append(_Region(body, bc, f"{region.path}/while.body", region,
                           dict(zip(body.invars[:bn],
                                    eqn.invars[cn:cn + bn])),
                           region.predicated))
    elif p == "cond":
        for k, br in enumerate(params.get("branches", ())):
            body, consts = _open(br)
            out.append(_Region(body, consts,
                               f"{region.path}/cond[{k}]", region,
                               dict(zip(body.invars, eqn.invars[1:])),
                               True))
    elif p == "pallas_call":
        body, consts = _open(params["jaxpr"])
        # kernel invars = [*input refs, *output refs, *scratch]; the zip
        # binds exactly the input-ref prefix to the operand arrays
        out.append(_Region(body, consts, f"{region.path}/pallas_call",
                           region, dict(zip(body.invars, eqn.invars)),
                           region.predicated))
    else:
        for key, val in params.items():
            vals = val if isinstance(val, (tuple, list)) else (val,)
            for k, v in enumerate(vals):
                if _looks_like_jaxpr(v):
                    body, consts = _open(v)
                    out.append(_Region(body, consts,
                                       f"{region.path}/{p}.{key}[{k}]",
                                       region, None, region.predicated))
    return out


def _scan_carry_facts(eqn, body, nc: int, ncar: int, region) -> dict:
    """Intervals of affine scan carries: a carry initialized to a known
    scalar and advanced by ``add(carry, const)`` (the lowered
    `fori_loop` counter) is bounded over all ``length`` iterations; a
    carry returned unchanged keeps its init value. Keyed by body invar."""
    length = eqn.params.get("length")
    if length is None:
        return {}
    length = int(length)
    defs = {ov: e for e in body.eqns for ov in e.outvars}
    facts = {}
    for j in range(ncar):
        bv = body.invars[nc + j]
        ov = body.outvars[j]
        c0 = _const_scalar(eqn.invars[nc + j], region)
        if c0 is None or isinstance(c0, float):
            continue
        if ov is bv:                      # carry threaded through unchanged
            facts[bv] = Interval(int(c0), int(c0))
            continue
        d = defs.get(ov)
        if d is None or d.primitive.name != "add" or len(d.invars) != 2:
            continue
        a, b = d.invars
        step = None
        if a is bv:
            step = _const_scalar(b, _Region(body, [], ""))
        elif b is bv:
            step = _const_scalar(a, _Region(body, [], ""))
        if step is None or isinstance(step, float):
            continue
        lo = int(c0) + min(0, (length - 1) * int(step))
        hi = int(c0) + max(0, (length - 1) * int(step))
        facts[bv] = Interval(lo, hi)
    return facts


def _walk(region):
    """Yield (eqn, region) for every eqn at every nesting depth."""
    for eqn in region.jaxpr.eqns:
        yield eqn, region
        for sub in _sub_regions(eqn, region):
            yield from _walk(sub)


def root_region(closed_jaxpr, *, axis_sizes: Optional[dict] = None,
                path: str = "") -> _Region:
    """Wrap a traced `ClosedJaxpr` for walking/checking. ``axis_sizes``
    supplies mesh axis extents (``{"model": 4, ...}``) for `axis_index`
    interval facts on traces made under an ``axis_env``; ``path`` labels
    findings."""
    jaxpr, consts = _open(closed_jaxpr)
    return _Region(jaxpr, consts, path, axis_sizes=axis_sizes or {})


# ---------------------------------------------------------------------------
# const propagation (through pjit boundaries and elementwise chains)
# ---------------------------------------------------------------------------

_CONST_BINOPS = {
    "add": lambda a, b: a + b, "sub": lambda a, b: a - b,
    "mul": lambda a, b: a * b, "max": max, "min": min,
    "eq": lambda a, b: int(a == b), "ne": lambda a, b: int(a != b),
    "lt": lambda a, b: int(a < b), "le": lambda a, b: int(a <= b),
    "gt": lambda a, b: int(a > b), "ge": lambda a, b: int(a >= b),
    "and": lambda a, b: int(bool(a) and bool(b)),
    "or": lambda a, b: int(bool(a) or bool(b)),
}


def _const_scalar(atom, region, depth: int = 0):
    """The python scalar an atom is statically known to hold, evaluated
    through passthroughs, call boundaries, `select_n` and elementwise
    arithmetic/comparisons (jnp's ``remainder`` computes its divisor as
    ``select_n(eq(d, 0), d, 1)`` — head detection needs to see through
    that); None when not statically known."""
    if depth > _MAX_DEPTH:
        return None
    val = None
    if _is_literal(atom):
        val = atom.val
    elif atom in region.consts:
        val = region.consts[atom]
    elif atom in region.bindings and region.parent is not None:
        return _const_scalar(region.bindings[atom], region.parent, depth + 1)
    else:
        eqn = region.defs.get(atom)
        if eqn is None:
            return None
        p = eqn.primitive.name
        if p in ("convert_element_type", "broadcast_in_dim", "reshape",
                 "squeeze", "expand_dims", "copy"):
            return _const_scalar(eqn.invars[0], region, depth + 1)
        if p in _CALL_PRIMS:
            subs = _sub_regions(eqn, region)
            if len(subs) == 1:
                k = list(eqn.outvars).index(atom)
                return _const_scalar(subs[0].jaxpr.outvars[k], subs[0],
                                     depth + 1)
            return None
        if p == "select_n":
            pred = _const_scalar(eqn.invars[0], region, depth + 1)
            if pred is not None and 0 <= int(pred) < len(eqn.invars) - 1:
                return _const_scalar(eqn.invars[1 + int(pred)], region,
                                     depth + 1)
            return None
        if p == "neg":
            a = _const_scalar(eqn.invars[0], region, depth + 1)
            return -a if a is not None else None
        if p == "not":
            a = _const_scalar(eqn.invars[0], region, depth + 1)
            return int(not a) if a is not None else None
        if p in _CONST_BINOPS and len(eqn.invars) == 2:
            a = _const_scalar(eqn.invars[0], region, depth + 1)
            b = _const_scalar(eqn.invars[1], region, depth + 1)
            if a is None or b is None:
                return None
            try:
                return _CONST_BINOPS[p](a, b)
            except (TypeError, ValueError):
                return None
        return None
    try:
        arr = np.asarray(val)
        return arr.reshape(()).item() if arr.size == 1 else None
    except (TypeError, ValueError):
        return None


# ---------------------------------------------------------------------------
# clamp-head classification
# ---------------------------------------------------------------------------

def _bare_clamp_kind(eqn, region) -> Optional[str]:
    """'saturate'/'wrap' when this single eqn is a V-word clamp head: the
    ``max`` against V_MIN (``jnp.clip``'s lower arm — the counted head; the
    paired ``min`` rides along) or the ``rem`` by V_SPAN of the wrap."""
    p = eqn.primitive.name
    if p == "max" and any(_const_scalar(a, region) == V_MIN
                          for a in eqn.invars):
        return "saturate"
    if p == "rem" and len(eqn.invars) == 2 and \
            _const_scalar(eqn.invars[1], region) == V_SPAN:
        return "wrap"
    if p == "clamp":               # direct lax.clamp lowering (version drift)
        lo = _const_scalar(eqn.invars[0], region)
        hi = _const_scalar(eqn.invars[2], region)
        if lo == V_MIN and hi == V_MAX:
            return "saturate"
    return None


def _head_scan(region, kinds: list, depth: int) -> bool:
    """Scan a candidate head body: collect bare clamp patterns, allow
    nested small elementwise calls (``remainder`` wraps a ``_where``
    pjit), reject anything non-elementwise. True = body is elementwise."""
    if depth > 4 or len(region.jaxpr.eqns) > 16:
        return False
    for e in region.jaxpr.eqns:
        k = _bare_clamp_kind(e, region)
        if k is not None:
            kinds.append(k)
            continue
        if e.primitive.name in _CALL_PRIMS:
            subs = _sub_regions(e, region)
            if len(subs) != 1 or not _head_scan(subs[0], kinds, depth + 1):
                return False
            continue
        if e.primitive.name not in _ELEMENTWISE:
            return False
    return True


def _clamp_kind(eqn, region) -> Optional[str]:
    """Clamp-head kind of an eqn: a bare head, or a small pure-elementwise
    call (jnp's ``clip``/``remainder`` pjit wrappers, nested calls
    allowed) containing exactly one head pattern. A call with control
    flow / dots in its body *contains* clamps but is not itself a head."""
    kind = _bare_clamp_kind(eqn, region)
    if kind is not None:
        return kind
    if eqn.primitive.name not in _CALL_PRIMS:
        return None
    subs = _sub_regions(eqn, region)
    if len(subs) != 1:
        return None
    kinds: list = []
    if not _head_scan(subs[0], kinds, 0):
        return None
    return kinds[0] if len(kinds) == 1 else None


def _collect_clamps(region, out: list, pred: bool) -> None:
    """All clamp heads under ``region`` as (eqn, region, kind,
    predicated); recognized heads are not descended into (their inner
    ``max``/``rem`` would double-count)."""
    for eqn in region.jaxpr.eqns:
        kind = _clamp_kind(eqn, region)
        if kind is not None:
            out.append((eqn, region, kind, pred))
            continue
        for sub in _sub_regions(eqn, region):
            _collect_clamps(sub, out, pred or sub.predicated)


# ---------------------------------------------------------------------------
# interval analysis (the bounds pass)
# ---------------------------------------------------------------------------

def _dtype_interval(atom) -> Optional[Interval]:
    dt = _aval_dtype(atom)
    if dt is None:
        return None
    dt = np.dtype(dt)
    if dt == np.bool_:
        return Interval(0, 1)
    if np.issubdtype(dt, np.integer) and dt.itemsize == 1:
        ii = np.iinfo(dt)
        return Interval(int(ii.min), int(ii.max))
    return None


def _value_interval(val) -> Optional[Interval]:
    try:
        arr = np.asarray(val)
        if arr.dtype == np.bool_:
            arr = arr.astype(np.int32)
        if arr.size == 0 or not np.issubdtype(arr.dtype, np.number):
            return None
        return Interval(int(arr.min()), int(arr.max()))
    except (TypeError, ValueError):
        return None


def _cmp_interval(p: str, a: Optional[Interval], b: Optional[Interval]
                  ) -> Interval:
    """Bool interval of a comparison from its operand intervals."""
    if a is not None and b is not None:
        if p in ("lt", "le"):
            strict = p == "lt"
            if (a.hi < b.lo) or (not strict and a.hi <= b.lo):
                return Interval(1, 1)
            if (a.lo > b.hi) or (strict and a.lo >= b.hi):
                return Interval(0, 0)
        elif p in ("gt", "ge"):
            strict = p == "gt"
            if (a.lo > b.hi) or (not strict and a.lo >= b.hi):
                return Interval(1, 1)
            if (a.hi < b.lo) or (strict and a.hi <= b.lo):
                return Interval(0, 0)
        elif p == "eq" and (a.hi < b.lo or a.lo > b.hi):
            return Interval(0, 0)
        elif p == "ne" and (a.hi < b.lo or a.lo > b.hi):
            return Interval(1, 1)
    return Interval(0, 1)


def _chain_has_cumsum(atom, region, limit: int = 300) -> bool:
    """True when the def chain of ``atom`` (crossing call boundaries)
    contains a cumulative-sum — the structural certificate of the
    event-list one-hot decode."""
    stack, seen, steps = [(atom, region)], set(), 0
    while stack and steps < limit:
        a, r = stack.pop()
        steps += 1
        if _is_literal(a):
            continue
        key = (id(r), a)
        if key in seen:
            continue
        seen.add(key)
        eqn = r.defs.get(a)
        if eqn is None:
            if a in r.bindings and r.parent is not None:
                stack.append((r.bindings[a], r.parent))
            continue
        p = eqn.primitive.name
        if p == "cumsum" or "cumsum" in str(eqn.params.get("name", "")):
            return True
        subs = _sub_regions(eqn, r) if p in _CALL_PRIMS else ()
        if subs:
            k = list(eqn.outvars).index(a)
            stack.append((subs[0].jaxpr.outvars[k], subs[0]))
        else:
            stack.extend((iv, r) for iv in eqn.invars)
    return False


def _onehot_bound(eqn, region, env, depth) -> Optional[Interval]:
    """Interval of ``reduce_sum(select_n(pred, 0, iota-derived))`` when
    ``pred``'s chain contains a cumsum comparison — the event-list one-hot
    decode. At most one position matches (the running count of a {0,1}
    raster — the range pass's raster fact — first reaches p+1 exactly
    once), so the sum is bounded by the iota values themselves: the padded
    fan-in, which is the `gather_bounds` kernel contract."""
    op, r, d = eqn.invars[0], region, None
    for _ in range(_MAX_DEPTH):    # unwrap jnp.where's pjit and bindings
        if _is_literal(op):
            return None
        if op in r.bindings and r.parent is not None:
            op, r = r.bindings[op], r.parent
            continue
        d = r.defs.get(op)
        if d is None:
            break
        if d.primitive.name in _CALL_PRIMS:
            subs = _sub_regions(d, r)
            if len(subs) == 1:
                op, r = subs[0].jaxpr.outvars[list(d.outvars).index(op)], \
                    subs[0]
                continue
        elif d.primitive.name in ("convert_element_type", "reshape",
                                  "broadcast_in_dim", "squeeze", "copy"):
            op = d.invars[0]
            continue
        break
    if d is None or d.primitive.name != "select_n" or len(d.invars) != 3:
        return None
    pred, case0, case1 = d.invars
    for zero, cand in ((case0, case1), (case1, case0)):
        if _const_scalar(zero, r) == 0 and _chain_has_cumsum(pred, r):
            return _ival(cand, r, env, depth + 1)
    return None


def _ival(atom, region, env: dict, depth: int) -> Optional[Interval]:
    """Best-effort interval of an atom's value (None = unknown)."""
    if depth > _MAX_DEPTH:
        return None
    if _is_literal(atom):
        return _value_interval(atom.val)
    key = (id(region), atom)
    if key in env:
        return env[key]
    env[key] = None                # cycle guard
    iv = _ival_raw(atom, region, env, depth)
    env[key] = iv
    return iv


def _ival_raw(atom, region, env, depth) -> Optional[Interval]:
    if atom in region.consts:
        return _value_interval(region.consts[atom])
    if atom in region.bindings and region.parent is not None:
        return _ival(region.bindings[atom], region.parent, env, depth + 1)
    eqn = region.defs.get(atom)
    if eqn is None:                # unbound invar (carry, kernel ref, ...)
        fact = region.carry_facts.get(atom)
        return fact if fact is not None else _dtype_interval(atom)
    p = eqn.primitive.name

    def op(k):
        return _ival(eqn.invars[k], region, env, depth + 1)

    if p in _PASSTHROUGH:
        iv = op(0)
        return iv if iv is not None else _dtype_interval(atom)
    if p == "add":
        a, b = op(0), op(1)
        return a + b if a is not None and b is not None else None
    if p == "sub":
        a, b = op(0), op(1)
        return a - b if a is not None and b is not None else None
    if p == "mul":
        a, b = op(0), op(1)
        if a is None or b is None:
            return None
        prods = [a.lo * b.lo, a.lo * b.hi, a.hi * b.lo, a.hi * b.hi]
        return Interval(min(prods), max(prods))
    if p == "neg":
        a = op(0)
        return Interval(-a.hi, -a.lo) if a is not None else None
    if p == "max":
        a, b = op(0), op(1)
        if a is None or b is None:
            return None
        return Interval(max(a.lo, b.lo), max(a.hi, b.hi))
    if p == "min":
        a, b = op(0), op(1)
        if a is None or b is None:
            return None
        return Interval(min(a.lo, b.lo), min(a.hi, b.hi))
    if p == "rem":
        d = _const_scalar(eqn.invars[1], region)
        if d is None or d == 0:
            return None
        d = abs(int(d))
        a = op(0)
        if a is not None and a.lo >= 0:
            return Interval(0, d - 1)
        return Interval(-(d - 1), d - 1)
    if p == "clamp":
        lo, hi = op(0), op(2)
        if lo is not None and hi is not None:
            return Interval(lo.lo, hi.hi)
        return None
    if p == "select_n":
        pred = op(0)
        cases = eqn.invars[1:]
        if pred is not None and pred.lo == pred.hi and \
                0 <= pred.lo < len(cases):
            return _ival(cases[int(pred.lo)], region, env, depth + 1)
        ivs = [_ival(c, region, env, depth + 1) for c in cases]
        if any(iv is None for iv in ivs):
            return None
        return Interval(min(iv.lo for iv in ivs),
                        max(iv.hi for iv in ivs))
    if p in ("lt", "le", "gt", "ge", "eq", "ne"):
        return _cmp_interval(p, op(0), op(1))
    if p in ("and", "or", "not", "xor"):
        return (Interval(0, 1) if np.dtype(_aval_dtype(atom)) == np.bool_
                else None)
    if p in ("iota", "broadcasted_iota"):
        shape = _aval_shape(atom)
        dim = eqn.params.get("dimension", 0)
        if shape:
            return Interval(0, max(int(shape[int(dim)]) - 1, 0))
        return None
    if p == "axis_index":
        name = str(eqn.params.get("axis_name"))
        n = region.axis_sizes.get(name)
        return Interval(0, int(n) - 1) if n else None
    if p == "reduce_sum":
        onehot = _onehot_bound(eqn, region, env, depth)
        if onehot is not None:
            return onehot
        a = op(0)
        in_shape, out_shape = _aval_shape(eqn.invars[0]), _aval_shape(atom)
        if a is None or in_shape is None:
            return None
        n_in = int(np.prod(in_shape)) if in_shape else 1
        n_out = int(np.prod(out_shape)) if out_shape else 1
        n = max(n_in // max(n_out, 1), 1)
        return Interval(min(a.lo * n, a.lo), max(a.hi * n, a.hi))
    if p == "cumsum":
        a = op(0)
        shape = _aval_shape(atom)
        if a is None or shape is None:
            return None
        n = int(shape[int(eqn.params.get("axis", 0))]) if shape else 1
        return Interval(min(a.lo * n, a.lo), max(a.hi * n, a.hi))
    if p == "psum":
        a = op(0)
        axes = eqn.params.get("axes", ())
        n = 1
        for ax in axes:
            n *= int(region.axis_sizes.get(str(ax), 1))
        if a is None:
            return None
        return Interval(min(a.lo * n, a.lo), max(a.hi * n, a.hi))
    if p in _CALL_PRIMS:
        subs = _sub_regions(eqn, region)
        if len(subs) == 1:
            k = list(eqn.outvars).index(atom)
            return _ival(subs[0].jaxpr.outvars[k], subs[0], env, depth + 1)
    return None


# ---------------------------------------------------------------------------
# the four passes
# ---------------------------------------------------------------------------

def _check_dtypes(root: _Region, expect: TraceExpectation, checks: list
                  ) -> int:
    n = 0
    for eqn, region in _walk(root):
        n += 1
        p = eqn.primitive.name
        if p in _RNG_PRIMS:
            raise TraceError(
                f"determinism: RNG primitive '{p}' at {region.path or '/'}"
                f" — int-domain dispatches must be replay-exact",
                where=expect.where)
        for a in (*eqn.invars, *eqn.outvars):
            dt = _aval_dtype(a)
            if dt is not None and np.issubdtype(np.dtype(dt), np.floating):
                raise TraceError(
                    f"dtype: float {np.dtype(dt).name} aval on primitive "
                    f"'{p}' at {region.path or '/'} — the int domain "
                    "admits no float math (a cast, a float constant, or a "
                    "float reduction leaked in)", where=expect.where)
        if p == "dot_general":
            odt = _aval_dtype(eqn.outvars[0])
            if odt is None or np.dtype(odt) != np.dtype(np.int32):
                raise TraceError(
                    f"dtype: dot_general accumulates in "
                    f"{np.dtype(odt).name if odt is not None else '?'} at "
                    f"{region.path or '/'} — AccW2V must accumulate int32",
                    where=expect.where)
    checks.append(TraceCheck(
        "dtype", expect.where,
        f"{n} eqn(s): no float avals, no RNG primitives, int32 "
        "dot accumulators"))
    return n


def _check_clamps(root: _Region, expect: TraceExpectation, checks: list
                  ) -> int:
    found: list = []
    _collect_clamps(root, found, False)
    for eqn, region, kind, pred in found:
        if pred:
            raise TraceError(
                f"clamp: V-word clamp ('{eqn.primitive.name}') inside a "
                f"predicated branch at {region.path or '/'} — partials "
                "must accumulate unclamped under @pl.when/lax.cond and "
                "the single clamp runs after the predication",
                where=expect.where)
        if kind != expect.clamp_mode:
            raise TraceError(
                f"clamp: {kind} clamp at {region.path or '/'} in a "
                f"{expect.clamp_mode}-mode program — one clamp policy per "
                "program", where=expect.where)
    want = expect.expected_clamps
    if len(found) != want:
        raise TraceError(
            f"clamp: {len(found)} V-word clamp head(s) in the trace, the "
            f"ISA contract requires exactly {want} ({expect.n_spiking} "
            f"spiking layer(s) x {expect.neuron}/{expect.clamp_mode}"
            + (f" + {expect.extra_clamps} extra" if expect.extra_clamps
               else "") + ") — a duplicated or missing clamp changes "
            "11-bit semantics silently", where=expect.where)
    checks.append(TraceCheck(
        "clamp_count", expect.where,
        f"exactly {want} {expect.clamp_mode} clamp head(s), none "
        "predicated"))
    return len(found)


def _upstream(atom, region, *, stop_on_clamp: bool, limit: int = 500):
    """BFS the SSA def chain upstream. Yields (eqn, region) for every
    non-clamp def reached; clamp heads terminate their branch when
    ``stop_on_clamp``. Ref reads (`get`) and loop boundaries terminate
    (documented blind spot — see module docstring)."""
    stack, seen, steps = [(atom, region)], set(), 0
    while stack and steps < limit:
        a, r = stack.pop()
        steps += 1
        if _is_literal(a):
            continue
        key = (id(r), a)
        if key in seen:
            continue
        seen.add(key)
        eqn = r.defs.get(a)
        if eqn is None:
            if a in r.bindings and r.parent is not None:
                stack.append((r.bindings[a], r.parent))
            continue
        if stop_on_clamp and _clamp_kind(eqn, r) is not None:
            continue
        p = eqn.primitive.name
        yield eqn, r
        if p in ("get", "scan", "while", "cond", "pallas_call"):
            continue               # memory / loop boundary: out of SSA scope
        if p in _CALL_PRIMS:
            subs = _sub_regions(eqn, r)
            if len(subs) == 1:
                k = list(eqn.outvars).index(a)
                stack.append((subs[0].jaxpr.outvars[k], subs[0]))
            continue
        stack.extend((iv, r) for iv in eqn.invars)


def _check_dominance(root: _Region, expect: TraceExpectation, checks: list
                     ) -> int:
    """Every SpikeCheck (``ge``) must read a clamped V: its upstream SSA
    chain may not reach a `dot_general` or `psum` without passing a clamp
    head. Symmetrically, no clamp may sit upstream of a cross-shard
    ``psum`` — the AccV2V reduction sums unclamped int32 partials and the
    single clamp composes after the full sum."""
    n_ge = n_psum = 0
    for eqn, region in _walk(root):
        p = eqn.primitive.name
        if p == "ge":
            n_ge += 1
            for d, r in _upstream(eqn.invars[0], region, stop_on_clamp=True):
                if d.primitive.name in ("dot_general", "psum"):
                    raise TraceError(
                        f"clamp: SpikeCheck 'ge' at {region.path or '/'} "
                        f"reads a '{d.primitive.name}' accumulation with "
                        "no V-word clamp in between — on the mesh path "
                        "the clamp must run AFTER the cross-shard psum",
                        where=expect.where)
        elif p == "psum":
            n_psum += 1
            for inv in eqn.invars:
                for d, r in _upstream(inv, region, stop_on_clamp=False):
                    if _clamp_kind(d, r) is not None:
                        raise TraceError(
                            f"clamp: V-word clamp upstream of the "
                            f"cross-shard psum at {region.path or '/'} — "
                            "row-tile partials must reduce UNCLAMPED "
                            "(int32 addition is associative; clamp_v "
                            "composes only after the full AccV2V sum)",
                            where=expect.where)
                    if d.primitive.name == "dot_general":
                        break      # reached the accumulation source
    checks.append(TraceCheck(
        "clamp_dominance", expect.where,
        f"{n_ge} SpikeCheck read(s) dominated by a clamp; "
        f"{n_psum} psum(s) reduce unclamped partials"))
    return n_ge


def _dynamic_get_targets(eqn, base: int) -> Optional[list]:
    """``(dim, size, index_atom)`` for every *dynamic* index of a Pallas
    ``get``/``swap``: the eqn's trailing invars are the flattened dynamic
    leaves of its NDIndexer ``tree`` param, so unflattening recovers which
    ref dim each one indexes. None when the indexer is unreadable."""
    dyn = list(eqn.invars[base:])
    if not dyn:
        return []
    tree = eqn.params.get("tree")
    try:
        indexers = tree.unflatten(dyn)
    except Exception:
        return None
    stack, found = [indexers], []
    while stack:
        node = stack.pop()
        if isinstance(node, (tuple, list)):
            stack.extend(node)
            continue
        indices = getattr(node, "indices", None)
        if indices is None:
            continue
        for d, ix in enumerate(indices):
            if isinstance(ix, (int, np.integer)):
                continue
            start = getattr(ix, "start", None)
            if start is None:              # bare scalar index atom
                found.append((d, 1, ix))
                continue
            if isinstance(start, (int, np.integer)):
                continue                   # static slice
            found.append((d, int(getattr(ix, "size", 1)), start))
    return found if len(found) == len(dyn) else None


def _check_bounds(root: _Region, expect: TraceExpectation, checks: list
                  ) -> int:
    n = 0
    env: dict = {}
    for eqn, region in _walk(root):
        p = eqn.primitive.name
        if p in ("dynamic_slice", "dynamic_update_slice"):
            base = 1 if p == "dynamic_slice" else 2
            starts = eqn.invars[base:]
            shape = _aval_shape(eqn.invars[0])
            sizes = (eqn.params.get("slice_sizes")
                     if p == "dynamic_slice"
                     else _aval_shape(eqn.invars[1]))
            for d, (s, sz) in enumerate(zip(starts, sizes)):
                iv = _ival(s, region, env, 0)
                if iv is None:
                    raise TraceError(
                        f"bounds: cannot bound the dim-{d} start of "
                        f"'{p}' at {region.path or '/'} — index not "
                        "provably in-bounds", where=expect.where)
                if iv.lo < 0 or iv.hi + int(sz) > int(shape[d]):
                    raise TraceError(
                        f"bounds: '{p}' dim-{d} start in [{iv.lo}, "
                        f"{iv.hi}] with size {sz} exceeds operand extent "
                        f"{shape[d]} at {region.path or '/'}",
                        where=expect.where)
                n += 1
        elif p in ("get", "swap"):
            base = 2 if p == "swap" else 1
            if len(eqn.invars) <= base:
                continue           # fully static indexer
            shape = _aval_shape(eqn.invars[0])
            targets = _dynamic_get_targets(eqn, base)
            if targets is None:
                raise TraceError(
                    f"bounds: cannot map the dynamic index operand(s) of "
                    f"'{p}' onto ref dims at {region.path or '/'}",
                    where=expect.where)
            for d, sz, s in targets:
                iv = _ival(s, region, env, 0)
                if iv is None:
                    raise TraceError(
                        f"bounds: cannot bound the dynamic dim-{d} index "
                        f"of '{p}' at {region.path or '/'} — gather row "
                        "not provably inside its weight tile",
                        where=expect.where)
                if iv.lo < 0 or iv.hi + int(sz) > int(shape[d]):
                    raise TraceError(
                        f"bounds: '{p}' dynamic dim-{d} index in "
                        f"[{iv.lo}, {iv.hi}] (+size {sz}) exceeds ref "
                        f"extent {shape[d]} at {region.path or '/'} — an "
                        "event-list gather row would leave its padded "
                        "fan-in tile", where=expect.where)
                n += 1
    checks.append(TraceCheck(
        "bounds", expect.where,
        f"{n} dynamic index/start(s) proven in-bounds by interval "
        "analysis"))
    return n


def check_closed_jaxpr(closed_jaxpr, expect: TraceExpectation,
                       ) -> tuple:
    """Run all four trace passes over one traced dispatch. Returns
    ``(checks, stats)`` where ``stats`` is a `SurfaceTrace`-shaped dict;
    raises `TraceError` (naming primitive + eqn region + ``expect.where``)
    on the first violation. This is the low-level entry the negative-path
    tests drive with deliberately broken kernels."""
    root = root_region(closed_jaxpr, axis_sizes=dict(expect.mesh_axes))
    checks: list = []
    n_eqns = _check_dtypes(root, expect, checks)
    n_clamps = _check_clamps(root, expect, checks)
    n_ge = _check_dominance(root, expect, checks)
    n_bounds = _check_bounds(root, expect, checks)
    return checks, dict(clamps=n_clamps, spike_reads=n_ge,
                        bounds_checked=n_bounds, eqns=n_eqns)


# ---------------------------------------------------------------------------
# program surfaces: trace the real dispatches of one backend
# ---------------------------------------------------------------------------

def _program_calls(program) -> list:
    from repro.analysis.kernel_contracts import _program_calls as pc
    return pc(program)


def _call_params(program, name: str) -> tuple:
    """(thresholds, leaks, readout) of one fused call."""
    if name == "fc_stack":
        stack = program.fc_stack
        return (tuple(int(s.threshold) for s in stack[:-1]),
                tuple(int(s.leak) for s in stack[:-1]), True)
    idx = int(name[name.index("[") + 1:name.index("]")])
    spec = program.int_conv_stack[idx]
    return ((int(spec.threshold),), (int(spec.leak),), False)


def _backend_flags(backend: str, gate_granularity: int,
                   event_crossover: float) -> dict:
    return dict(
        use_pallas=backend != "int_ref",
        use_sparse=backend == "pallas_sparse",
        use_events=backend == "pallas_events",
        gate_granularity=(gate_granularity
                          if backend == "pallas_sparse" else 1),
        event_crossover=event_crossover)


def _trace_surfaces(program, backend: str, surfaces: tuple, *, batch: int,
                    block_b: int, megastep_k: int, mesh_axes: tuple,
                    gate_granularity: int, event_crossover: float) -> list:
    """[(surface, call, closed_jaxpr, TraceExpectation), ...] for every
    requested dispatch surface of ``backend``."""
    from repro.kernels.fused_snn_net.ops import (fused_snn_net,
                                                 mesh_padded_widths,
                                                 mesh_rowpartial_tick)
    flags = _backend_flags(backend, gate_granularity, event_crossover)
    T = int(program.timesteps)
    sds = jax.ShapeDtypeStruct
    out = []
    for name, _names, widths, n_spiking in _program_calls(program):
        ths, lks, readout = _call_params(program, name)
        ws_sds = [sds((widths[i], widths[i + 1]), jnp.int8)
                  for i in range(len(widths) - 1)]
        vi_sds = [sds((batch, w), jnp.int32) for w in widths[1:]]

        def run(spikes, ws, vi=None, _t=ths, _l=lks, _r=readout):
            return fused_snn_net(
                spikes, ws, thresholds=_t, leaks=_l,
                neuron=program.neuron, clamp_mode=program.clamp_mode,
                block_b=block_b, interpret=True, emit_rasters=True,
                readout=_r, v_init=vi, **flags)

        expect_kw = dict(neuron=program.neuron,
                         clamp_mode=program.clamp_mode,
                         n_spiking=n_spiking)
        if "batch" in surfaces:
            j = jax.make_jaxpr(lambda s, w: run(s, w))(
                sds((T, batch, widths[0]), jnp.int8), ws_sds)
            out.append(("batch", name, j, TraceExpectation(
                where=f"{backend}:batch:{name}", **expect_kw)))
        if "step" in surfaces:
            j = jax.make_jaxpr(lambda s, w, v: run(s, w, v))(
                sds((1, batch, widths[0]), jnp.int8), ws_sds, vi_sds)
            out.append(("step", name, j, TraceExpectation(
                where=f"{backend}:step:{name}", **expect_kw)))
        if "megastep" in surfaces:
            if readout:
                # the int megastep tail of `pipeline.stream_megastep`:
                # K-frame fused call resuming v_init + the exact readout
                # trajectory v_init + cumsum(raster @ w_ro)
                def mega(s, w, v):
                    r, vf, _sk = run(s, w, v)
                    ro_in = (r[-1] if len(r) else s).astype(jnp.int32)
                    traj = v[-1][None] + jnp.cumsum(
                        ro_in @ w[-1].astype(jnp.int32), axis=0)
                    return vf, traj
                fn = mega
            else:
                def fn(s, w, v):
                    return run(s, w, v)
            j = jax.make_jaxpr(fn)(
                sds((megastep_k, batch, widths[0]), jnp.int8), ws_sds,
                vi_sds)
            out.append(("megastep", name, j, TraceExpectation(
                where=f"{backend}:megastep:{name}", **expect_kw)))
        if "mesh" in surfaces and mesh_axes:
            sizes = dict(mesh_axes)
            nm = int(sizes.get("model", 1))
            if nm > 1:
                pw = mesh_padded_widths(widths, nm)
                wsl_sds = [sds((pw[i] // nm, pw[i + 1]), jnp.int8)
                           for i in range(len(widths) - 1)]
                vs_sds = [sds((batch, w), jnp.int32) for w in pw[1:]]
                use_events = flags["use_events"]

                def tick(frame, ws_l, vs, _w=widths, _n=n_spiking,
                         _t=ths, _l=lks, _e=use_events):
                    counts = (tuple(jnp.zeros((wi,), jnp.int32)
                                    for wi in _w[:len(ws_l)])
                              if _e else ())
                    return mesh_rowpartial_tick(
                        vs, counts, frame, ws_l, widths=_w, n_spiking=_n,
                        thresholds=_t, leaks=_l, neuron=program.neuron,
                        clamp_mode=program.clamp_mode, use_events=_e)

                try:
                    j = jax.make_jaxpr(
                        tick, axis_env=list(sizes.items()))(
                        sds((batch, pw[0]), jnp.int32), wsl_sds, vs_sds)
                except TypeError:  # axis_env removed in a future jax
                    j = None
                if j is not None:
                    out.append(("mesh", name, j, TraceExpectation(
                        where=f"{backend}:mesh:{name}",
                        mesh_axes=tuple(sizes.items()), **expect_kw)))
    return out


def _geometry_signature(program, backend, surfaces, batch, block_b,
                        megastep_k, mesh_axes, gate_granularity,
                        event_crossover) -> tuple:
    calls = tuple((name, widths, ns)
                  for name, _ln, widths, ns in _program_calls(program))
    params = tuple((_call_params(program, name)[:2])
                   for name, _ln, _w, _ns in _program_calls(program))
    return (backend, tuple(surfaces), batch, block_b, megastep_k,
            tuple(mesh_axes), gate_granularity, float(event_crossover),
            program.neuron, program.clamp_mode, int(program.timesteps),
            calls, params)


#: geometry-keyed memo — equivalence sweeps re-validate identical
#: geometries hundreds of times; tracing is pure in the signature
_TRACE_CACHE: dict = {}


def check_trace(program, backend: str = "pallas", *,
                surfaces: tuple = SURFACES, batch: Optional[int] = None,
                block_b: int = 8, megastep_k: int = 2,
                mesh: Any = None, gate_granularity: int = 1,
                event_crossover: float = 1.0, with_cost: bool = True,
                use_cache: bool = True) -> TraceReport:
    """Trace every requested dispatch ``surfaces`` of ``program`` on
    ``backend`` and verify the dtype / clamp / bounds / determinism
    contracts; raise `TraceError` naming primitive + eqn + backend on any
    violation. Host backends (`HOST_BACKENDS`) have no jaxpr and return a
    named skip row.

    ``mesh`` is an ``{axis: extent}`` dict or a `jax.sharding.Mesh`
    (default `DEFAULT_MESH_AXES`): the mesh surface traces the
    model-parallel row-partial tick under an abstract ``axis_env`` — no
    devices needed. ``batch`` (default ``block_b``) sizes the traced
    dispatch; ``with_cost`` attaches the `trace_cost.TraceCostReport`
    built from the batch surface. Results are memoized by geometry
    (``use_cache``)."""
    if backend in HOST_BACKENDS:
        return TraceReport(
            backend=backend, surfaces=(), cost=None,
            checks=(TraceCheck(
                "host_backend", backend,
                "host-side executor (numpy/BitMacro) — no XLA dispatch "
                "to trace; covered by the bit-equivalence sweep"),))
    if backend not in TRACE_BACKENDS:
        raise TraceError(
            f"trace: backend {backend!r} has no int-domain trace "
            f"contract; traceable: {sorted(TRACE_BACKENDS)}, host "
            f"(skipped): {sorted(HOST_BACKENDS)}", where=backend)
    if program.domain != "int":
        raise TraceError(
            f"trace: program domain {program.domain!r} — the trace "
            "contract covers int-domain dispatches only", where=backend)
    if batch is None:
        batch = block_b
    if mesh is None:
        mesh_axes = DEFAULT_MESH_AXES if "mesh" in surfaces else ()
    else:
        from repro.analysis.kernel_contracts import _mesh_extents
        mesh_axes = tuple(sorted(_mesh_extents(mesh).items()))
    key = _geometry_signature(program, backend, surfaces, batch, block_b,
                              megastep_k, mesh_axes, gate_granularity,
                              event_crossover) + (bool(with_cost),)
    if use_cache and key in _TRACE_CACHE:
        return _TRACE_CACHE[key]

    traced = _trace_surfaces(
        program, backend, tuple(surfaces), batch=batch, block_b=block_b,
        megastep_k=megastep_k, mesh_axes=mesh_axes,
        gate_granularity=gate_granularity, event_crossover=event_crossover)
    checks: list = []
    stats: list = []
    batch_jaxprs = {}
    for surface, call, closed, expect in traced:
        cs, st = check_closed_jaxpr(closed, expect)
        checks.extend(cs)
        stats.append(SurfaceTrace(surface=surface, call=call, **st))
        if surface == "batch":
            batch_jaxprs[call] = closed
    cost = None
    if with_cost and batch_jaxprs:
        from repro.analysis.trace_cost import build_cost_report
        cost = build_cost_report(program, backend, batch_jaxprs,
                                 batch=batch, block_b=block_b,
                                 checks=checks)
    report = TraceReport(backend=backend, surfaces=tuple(stats),
                         checks=tuple(checks), cost=cost)
    if use_cache:
        _TRACE_CACHE[key] = report
    return report
