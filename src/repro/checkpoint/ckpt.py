"""Sharded async checkpointing (no orbax): atomic, keep-N, elastic restore.

Layout:  <dir>/step_<N>/
            manifest.json            tree structure, shapes, dtypes
            shard_<k>.npz            one file per host-local save group

Properties required at pod scale (DESIGN.md §5):
  * async  -- device->host transfer happens on the caller thread (cheap),
    serialization+fsync on a background thread; training continues.
  * atomic -- writes go to step_<N>.tmp, fsync'd, then os.rename'd; a crash
    mid-save never corrupts the latest complete checkpoint.
  * elastic -- the manifest stores LOGICAL (global) shapes; restore reshards
    onto whatever mesh/sharding the restoring job passes (device_put with the
    new sharding), so pod counts can change across restarts.
  * keep-N -- old steps garbage-collected after a successful save.

In multi-host deployment each host saves only addressable shards (the
`local_slice` hook); this container is single-host so shard_0 holds all.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

# numpy's npz format doesn't round-trip ml_dtypes; store them as bit views
_VIEW_AS = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}


def _to_storable(x: np.ndarray) -> np.ndarray:
    view = _VIEW_AS.get(str(x.dtype))
    return x.view(view) if view is not None else x


def _from_storable(x: np.ndarray, dtype_str: str) -> np.ndarray:
    if dtype_str in _VIEW_AS:
        return x.view(getattr(ml_dtypes, dtype_str))
    return x


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
            for path, _ in flat]
    return keys, [leaf for _, leaf in flat], treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, blocking: bool = False) -> None:
        self.wait()                                  # one in-flight save max
        keys, leaves, _ = _flatten_with_paths(tree)
        # device -> host on caller thread (consistent snapshot)
        host_leaves = [np.asarray(x) for x in leaves]

        def _write():
            try:
                tmp = self.dir / f"step_{step}.tmp"
                final = self.dir / f"step_{step}"
                if tmp.exists():
                    shutil.rmtree(tmp)
                tmp.mkdir(parents=True)
                manifest = {
                    "step": step,
                    "keys": keys,
                    "shapes": [list(x.shape) for x in host_leaves],
                    "dtypes": [str(x.dtype) for x in host_leaves],
                    "format": 1,
                }
                np.savez(tmp / "shard_0.npz",
                         **{f"a{i}": _to_storable(x)
                            for i, x in enumerate(host_leaves)})
                with open(tmp / "manifest.json", "w") as f:
                    json.dump(manifest, f)
                    f.flush()
                    os.fsync(f.fileno())
                if final.exists():
                    shutil.rmtree(final)
                os.rename(tmp, final)
                self._gc()
            except BaseException as e:               # surfaced on next wait()
                self._error = e

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _gc(self) -> None:
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self.dir / f"step_{s}", ignore_errors=True)

    # -- restore ------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for p in self.dir.glob("step_*"):
            if p.is_dir() and not p.name.endswith(".tmp"):
                try:
                    out.append(int(p.name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, like: Any = None,
                shardings: Any = None) -> tuple[int, Any]:
        """Load a checkpoint. `like` (a pytree of arrays or ShapeDtypeStructs)
        provides the treedef; `shardings` (matching pytree of NamedSharding)
        reshards onto the current mesh — the elastic-restart path."""
        self.wait()
        if step is None:
            step = self.latest_step()
            if step is None:
                raise FileNotFoundError(f"no checkpoints under {self.dir}")
        d = self.dir / f"step_{step}"
        manifest = json.loads((d / "manifest.json").read_text())
        data = np.load(d / "shard_0.npz")
        leaves = [_from_storable(data[f"a{i}"], manifest["dtypes"][i])
                  for i in range(len(manifest["keys"]))]
        if like is not None:
            like_keys, like_leaves, treedef = _flatten_with_paths(like)
            if like_keys != manifest["keys"]:
                raise ValueError(
                    "checkpoint/tree mismatch: the `like` tree's leaf "
                    "paths differ from the saved manifest")
            if shardings is not None:
                _, shard_leaves, _ = _flatten_with_paths(shardings)
                leaves = [jax.device_put(x.astype(lk.dtype), s)
                          for x, lk, s in zip(leaves, like_leaves, shard_leaves)]
            else:
                leaves = [jax.device_put(x.astype(lk.dtype))
                          for x, lk in zip(leaves, like_leaves)]
            return step, jax.tree_util.tree_unflatten(treedef, leaves)
        return step, leaves
