"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch with pre-allocated caches.

Real-system behaviours kept:
  * fixed B decode slots; finished/empty slots are refilled from the request
    queue by prefilling into per-slot cache lanes;
  * one jit'd decode_step for the whole batch every tick (padded slots decode
    garbage that is masked out — standard continuous-batching trade);
  * per-slot stop conditions (max tokens / eos);
  * prompt lengths bucket to powers of two (pad + true-length mask) so the
    prefill jit cache stays bounded instead of compiling one variant per
    distinct length.

serve_step (= lm.decode_step under jit) is exactly what the dry-run lowers
for the decode_* shapes.

Dispatch discipline: the engine issues exactly one device decode and one
host->device token-buffer upload per tick. ``last_tokens`` lives on the
host (per-slot writes are free numpy stores) and crosses to the device
once, in `_token_batch` — the former per-slot ``.at[i, 0].set`` pattern
dispatched one scatter kernel per active slot per tick.
"""
from __future__ import annotations

import queue
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import lm

# prompt-length bucketing: smallest pad-to size, and the most compiled
# prefill variants kept live (LRU) — N distinct prompt lengths cost at most
# log2(max_len) compilations, and at most this many stay cached
PREFILL_BUCKET_MIN = 8
PREFILL_CACHE_MAX = 8


class EngineUndrained(RuntimeError):
    """`run_until_drained` hit its tick cap with work still queued/active.

    Carries what DID finish (``finished``) and how many requests are still
    pending (``pending`` = queued + occupying a slot), so callers can
    distinguish a partial drain from a complete one instead of silently
    treating the truncated ``finished`` list as the full result."""

    def __init__(self, finished: list, pending: int, max_ticks: int):
        # snapshot, not the engine's live list: the engine may keep
        # draining after the raise, and a caught exception must keep
        # describing the state it was raised in
        self.finished = list(finished)
        self.pending = pending
        self.max_ticks = max_ticks
        super().__init__(
            f"engine undrained after max_ticks={max_ticks}: "
            f"{len(finished)} request(s) finished, {pending} still pending")


def probe_batch_axes(state, probe):
    """Per-leaf batch axis of a state tree, determined structurally: the
    unique axis whose extent follows the batch argument, found by comparing
    against a B+1 probe tree. Probing (rather than shape-guessing) stays
    unambiguous even when B coincides with another dimension (B == 1 would
    make every size-1 axis a candidate). Leaves without a batch axis map
    to None."""
    return jax.tree_util.tree_map(
        lambda full, grown: next(
            (ax for ax in range(getattr(full, "ndim", 0))
             if full.shape[ax] != grown.shape[ax]), None),
        state, probe)


def lane_scatter(lane_tree, full_tree, axes, i: int):
    """Scatter a single-lane state tree into batch lane i of the full tree
    along each leaf's batch axis (axes from `probe_batch_axes`; ax-None
    leaves are shared and left untouched). The admit-by-lane-copy primitive
    both serving engines use — on the LM engine the lanes are KV-cache
    slots, on the SNN engine they are membrane-potential slots."""
    def put(lane, full, ax):
        if ax is None:
            return full
        idx = [slice(None)] * full.ndim
        idx[ax] = slice(i, i + 1)
        return full.at[tuple(idx)].set(jnp.asarray(lane).astype(full.dtype))
    return jax.tree_util.tree_map(put, lane_tree, full_tree, axes)


class SlotEngine:
    """Shared continuous-batching mechanics: the drain loop and its
    undrained contract, plus the paged-slot-pool addressing subclasses
    with more lanes than one dispatch batch share. Subclasses provide
    ``step() -> int`` (active slots after the tick), ``queue``, ``slots``
    (entries with a ``req`` field), ``finished``, and ``B`` (lanes per
    page); a paged engine additionally sets ``pages`` (slot i lives on
    page i // B, lane i % B) — the default single-page engine keeps 1."""

    pages: int = 1

    def page_lanes(self, page: int) -> range:
        """Slot indices of one page (B contiguous lanes per page)."""
        return range(page * self.B, (page + 1) * self.B)

    def active_by_page(self) -> dict:
        """Occupied slot indices grouped by page — the dispatch work-list
        (pages with no active lane are not dispatched at all)."""
        out: dict = {}
        for i, s in enumerate(self.slots):
            if s.req is not None:
                out.setdefault(i // self.B, []).append(i)
        return out

    def run_until_drained(self, max_ticks: int = 10_000) -> list:
        """Tick until queue and slots are empty; returns the ``finished``
        request list. Raises `EngineUndrained` (carrying the partial
        ``finished`` list) when the ``max_ticks`` engine-tick cap is hit
        with work still pending — a truncated run never masquerades as a
        complete one."""
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and self.queue.empty():
                return self.finished
        if self.queue.empty() and all(s.req is None for s in self.slots):
            return self.finished
        pending = self.queue.qsize() + sum(
            1 for s in self.slots if s.req is not None)
        raise EngineUndrained(self.finished, pending, max_ticks)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                # -1: run to max_new_tokens
    out_tokens: list = field(default_factory=list)


@dataclass
class _Slot:
    req: Optional[Request] = None
    remaining: int = 0


class ServeEngine(SlotEngine):
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 max_len: int = 256, parallel: Optional[ParallelConfig] = None):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.parallel = parallel or ParallelConfig(remat="none")
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.finished: list[Request] = []
        self.cache = lm.init_cache(cfg, batch_slots, max_len)
        # host-resident token buffer; uploaded once per tick (_token_batch)
        self.last_tokens = np.zeros((batch_slots, 1), np.int32)
        probe = jax.eval_shape(lambda: lm.init_cache(cfg, batch_slots + 1,
                                                     max_len))
        self._batch_axes = probe_batch_axes(self.cache, probe)

        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, t, c, cfg, self.parallel))
        self._prefill_cache = OrderedDict()   # per prompt-length bucket (LRU)
        # Length bucketing (pad + mask in lm.prefill) is exact only when no
        # mixer integrates the padded positions into recurrent state:
        # causal attention ignores them at the true last position, and the
        # kv_len decode mask hides their cache slots. Recurrent families
        # (ssm / rwkv), MLA, and enc-dec fall back to exact-length variants
        # (still LRU-capped).
        self._bucket_prompts = (
            cfg.mla is None and not cfg.is_encoder_decoder
            and all(cfg.is_attention_layer(i) for i in range(cfg.n_layers)))

    # -- request intake ------------------------------------------------------
    def submit(self, req: Request) -> None:
        """Enqueue ``req`` for FIFO admission into a free decode lane."""
        self.queue.put(req)

    def _prefill_bucket(self, plen: int) -> int:
        """Compile-shape bucket for a prompt length: next power of two (at
        least PREFILL_BUCKET_MIN, at most max_len) when the config admits
        pad+mask prefill; the exact length otherwise."""
        if not self._bucket_prompts:
            return plen
        bucket = max(PREFILL_BUCKET_MIN, 1 << max(plen - 1, 0).bit_length())
        return max(plen, min(bucket, self.max_len))

    def _prefill_fn(self, bucket: int):
        if bucket in self._prefill_cache:
            self._prefill_cache.move_to_end(bucket)
        else:
            self._prefill_cache[bucket] = jax.jit(
                lambda p, b, n: lm.prefill(p, b, self.cfg, self.max_len,
                                           self.parallel, length=n))
            while len(self._prefill_cache) > PREFILL_CACHE_MAX:
                self._prefill_cache.popitem(last=False)
        return self._prefill_cache[bucket]

    def _prefill(self, prompt: np.ndarray):
        plen = len(prompt)
        bucket = self._prefill_bucket(plen)
        toks = np.zeros((1, bucket), np.int32)
        toks[0, :plen] = prompt
        return self._prefill_fn(bucket)(
            self.params, {"tokens": jnp.asarray(toks)}, jnp.int32(plen))

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.req is not None:
                continue
            # a request can finish at prefill (max_new_tokens=1, or the
            # prefill token is eos); keep draining the queue until one
            # actually needs decode ticks, so the slot never runs a
            # spurious tick for an already-complete request
            while not self.queue.empty():
                req = self.queue.get()
                if req.max_new_tokens <= 0:      # nothing to generate
                    self.finished.append(req)
                    continue
                logits, cache1 = self._prefill(np.asarray(req.prompt))
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                if req.max_new_tokens <= 1 or tok == req.eos_id:
                    self.finished.append(req)
                    continue
                # copy the single-lane cache into slot lane i, along each
                # leaf's structurally-determined batch axis
                self.cache = lane_scatter(cache1, self.cache,
                                          self._batch_axes, i)
                self.last_tokens[i, 0] = tok     # host write, no dispatch
                slot.req = req
                slot.remaining = req.max_new_tokens - 1
                break

    # -- decode tick ----------------------------------------------------------
    def _token_batch(self) -> jax.Array:
        """The single host->device token upload of a tick."""
        return jnp.asarray(self.last_tokens)

    def step(self) -> int:
        """One engine tick: admit + batched decode. Returns #active slots."""
        self._admit()
        active = [s.req is not None for s in self.slots]
        if not any(active):
            return 0
        logits, self.cache = self._decode(self.params, self._token_batch(),
                                          self.cache)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            tok = int(next_tokens[i])
            slot.req.out_tokens.append(tok)
            slot.remaining -= 1
            self.last_tokens[i, 0] = tok         # host write, no dispatch
            if slot.remaining <= 0 or tok == slot.req.eos_id:
                self.finished.append(slot.req)
                self.slots[i] = _Slot()
        return sum(1 for s in self.slots if s.req is not None)
