"""Batched serving engine: slot-based continuous batching over a fixed-size
decode batch with pre-allocated caches.

Real-system behaviours kept:
  * fixed B decode slots; finished/empty slots are refilled from the request
    queue by prefilling into per-slot cache lanes;
  * one jit'd decode_step for the whole batch every tick (padded slots decode
    garbage that is masked out — standard continuous-batching trade);
  * per-slot stop conditions (max tokens / eos).

serve_step (= lm.decode_step under jit) is exactly what the dry-run lowers
for the decode_* shapes.
"""
from __future__ import annotations

import queue
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ParallelConfig
from repro.models import lm


@dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (T,) int32
    max_new_tokens: int = 16
    eos_id: int = -1                # -1: run to max_new_tokens
    out_tokens: list = field(default_factory=list)


@dataclass
class _Slot:
    req: Optional[Request] = None
    remaining: int = 0


class ServeEngine:
    def __init__(self, params, cfg: ModelConfig, *, batch_slots: int = 4,
                 max_len: int = 256, parallel: Optional[ParallelConfig] = None):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.parallel = parallel or ParallelConfig(remat="none")
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: "queue.Queue[Request]" = queue.Queue()
        self.finished: list[Request] = []
        self.cache = lm.init_cache(cfg, batch_slots, max_len)
        self.last_tokens = jnp.zeros((batch_slots, 1), jnp.int32)
        # Per-leaf batch axis of the cache tree, determined structurally: the
        # unique axis whose extent follows the batch argument. Probing with
        # batch_slots + 1 makes the comparison unambiguous even when
        # batch_slots coincides with another dimension (batch_slots == 1
        # would make a shape-based guess ambiguous on every size-1 axis).
        probe = jax.eval_shape(lambda: lm.init_cache(cfg, batch_slots + 1,
                                                     max_len))
        self._batch_axes = jax.tree_util.tree_map(
            lambda full, grown: next(
                (ax for ax in range(full.ndim)
                 if full.shape[ax] != grown.shape[ax]), None),
            self.cache, probe)

        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, t, c, cfg, self.parallel))
        self._prefill_cache = {}    # per prompt length bucket

    # -- request intake ------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.put(req)

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_cache:
            self._prefill_cache[plen] = jax.jit(
                lambda p, b: lm.prefill(p, b, self.cfg, self.max_len,
                                        self.parallel))
        return self._prefill_cache[plen]

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.req is not None:
                continue
            # a request can finish at prefill (max_new_tokens=1, or the
            # prefill token is eos); keep draining the queue until one
            # actually needs decode ticks, so the slot never runs a
            # spurious tick for an already-complete request
            while not self.queue.empty():
                req = self.queue.get()
                if req.max_new_tokens <= 0:      # nothing to generate
                    self.finished.append(req)
                    continue
                plen = len(req.prompt)
                logits, cache1 = self._prefill_fn(plen)(
                    self.params,
                    {"tokens": jnp.asarray(req.prompt[None], jnp.int32)})
                tok = int(jnp.argmax(logits[0]))
                req.out_tokens.append(tok)
                if req.max_new_tokens <= 1 or tok == req.eos_id:
                    self.finished.append(req)
                    continue
                # copy the single-lane cache into slot lane i, along each
                # leaf's structurally-determined batch axis
                def put(lane, full, ax):
                    if ax is None:
                        return full
                    idx = [slice(None)] * full.ndim
                    idx[ax] = slice(i, i + 1)
                    return full.at[tuple(idx)].set(lane.astype(full.dtype))
                self.cache = jax.tree_util.tree_map(
                    put, cache1, self.cache, self._batch_axes)
                self.last_tokens = self.last_tokens.at[i, 0].set(tok)
                slot.req = req
                slot.remaining = req.max_new_tokens - 1
                break

    # -- decode tick ----------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + batched decode. Returns #active slots."""
        self._admit()
        active = [s.req is not None for s in self.slots]
        if not any(active):
            return 0
        logits, self.cache = self._decode(self.params, self.last_tokens, self.cache)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        for i, slot in enumerate(self.slots):
            if slot.req is None:
                continue
            tok = int(next_tokens[i])
            slot.req.out_tokens.append(tok)
            slot.remaining -= 1
            self.last_tokens = self.last_tokens.at[i, 0].set(tok)
            if slot.remaining <= 0 or tok == slot.req.eos_id:
                self.finished.append(slot.req)
                self.slots[i] = _Slot()
        return sum(1 for s in self.slots if s.req is not None)

    def run_until_drained(self, max_ticks: int = 10_000) -> list[Request]:
        for _ in range(max_ticks):
            n = self.step()
            if n == 0 and self.queue.empty():
                break
        return self.finished
