from repro.serve.engine import EngineUndrained, Request, ServeEngine
from repro.serve.snn_engine import SNNRequest, SNNServeEngine

__all__ = ["EngineUndrained", "Request", "ServeEngine", "SNNRequest",
           "SNNServeEngine"]
