from repro.serve.engine import EngineUndrained, Request, ServeEngine
from repro.serve.snn_engine import (ReportUnavailable, SNNRequest,
                                    SNNServeEngine)

__all__ = ["EngineUndrained", "ReportUnavailable", "Request", "ServeEngine",
           "SNNRequest", "SNNServeEngine"]
