"""Streaming SNN serving engine: continuous batching over persistent
membrane-potential slots.

The LM engine's per-slot state is a KV-cache lane; IMPULSE's per-slot state
is the membrane-potential tree — V_MEM fused next to the weights is exactly
the state that makes streaming serving natural on this architecture. This
engine mirrors `ServeEngine`:

  * fixed B decode slots, each owning one batch lane of a single
    `pipeline.StreamState` tree (every layer's V for that stream);
  * admit-by-lane-copy: a fresh request's zero state is scattered into the
    slot's lane along each leaf's structurally-determined batch axis (the
    same B-vs-B+1 probe the LM engine uses on its cache tree);
  * one `stream_step` per tick for the whole batch — idle lanes integrate
    zero current and are masked out, the standard continuous-batching
    trade. Batch lanes never interact (every op is per-lane), so each
    request's output is bit-identical to serving it alone;
  * per-slot stop conditions: fixed tick budget (the frame sequence runs
    out) or readout-threshold early exit (|logit| confidence);
  * per-slot event accounting: input events per macro-stack layer row are
    accumulated from each tick's rasters and finalize into a per-request
    `pipeline.SparsityReport` — the skipped-work fractions and instruction
    counts feed `energy.measured_edp` exactly like the batch path's
    reports do (tests close the loop against isolated runs).

Event-gated ticks come from the backend choice: ``pallas_sparse`` /
``int_ref(use_sparse=True)`` skip silent-tile work inside the tick,
``ref_events`` executes the spike-list upper bound on the host, and
``pallas_events`` executes it on device (VMEM compaction + gather-matvec).
The per-slot row-skip accounting is backend-independent (it reads the
rasters); the event backends additionally feed a pooled *device ledger*
(`device_event_stats`) — the counters the executing kernel itself reports,
over ALL lanes. On a fully-occupied engine (every lane serving every tick)
the ledger closes exactly against the summed per-slot reports; with idle
lanes it can only exceed them (vacated lanes' deeper layers may keep firing
from carried V until the lane is re-seeded), which is why per-request
accounting stays raster-based.
"""
from __future__ import annotations

import queue
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline
from repro.core.pipeline import SNNProgram, SparsityReport
from repro.serve.engine import SlotEngine, lane_scatter, probe_batch_axes


@dataclass
class SNNRequest:
    rid: int
    frames: np.ndarray                    # (T, *in_shape) input currents
    max_ticks: Optional[int] = None       # default: len(frames)
    stop_threshold: Optional[float] = None  # early exit when max|logit| >= thr
    # -- filled at finish ----------------------------------------------------
    logits: Optional[np.ndarray] = None
    v_out: Optional[np.ndarray] = None
    ticks: int = 0
    report: Optional[SparsityReport] = None


@dataclass
class _Slot:
    req: Optional[SNNRequest] = None
    cursor: int = 0                       # next frame index to present
    ticks: int = 0
    row_events: list = field(default_factory=list)


def merge_reports(reports: list) -> SparsityReport:
    """Pool per-request reports (batch=1 each) into one workload report:
    events/row_events/frame counts add; the merged report's instruction
    counts equal the sum of the parts (counting is linear in events and
    frames), so engine-level EDP accounting stays exact."""
    if not reports:
        raise ValueError("merge_reports needs at least one report")
    head = reports[0]
    for r in reports[1:]:
        if (r.n_in, r.n_out, r.neurons) != (head.n_in, head.n_out,
                                            head.neurons):
            raise ValueError("cannot merge reports of different programs")
    return SparsityReport(
        n_in=head.n_in, n_out=head.n_out, neurons=head.neurons,
        events=tuple(sum(r.events[i] for r in reports)
                     for i in range(len(head.n_in))),
        frames=sum(r.frames for r in reports),
        timesteps=sum(r.timesteps for r in reports),
        batch=1,
        layer_frames=tuple(sum(r.frames_by_layer[i] for r in reports)
                           for i in range(len(head.n_in))),
        row_events=tuple(
            sum(np.asarray(r.row_events[i], np.int64) for r in reports)
            for i in range(len(head.n_in))))


class SNNServeEngine(SlotEngine):
    """Continuous batching for streaming SNN inference (see module docs).

    ``backend`` is any `pipeline.STREAM_BACKENDS` entry; ``step_kw`` passes
    through to `stream_step` (block_b / interpret / gate_granularity /
    use_sparse). ``track_events=False`` disables raster emission and
    per-slot accounting — the pure-serving configuration in which
    inter-layer spikes never leave the kernel.

    ``validate`` (default on) runs the static analyzer at engine build
    time: the kernel contracts of this exact (backend, step_kw) dispatch
    are verified before the first tick, and the program's `RangeReport`
    caps admission — a request whose tick budget exceeds the readout's
    proven ``max_safe_frames`` (the horizon past which the unclamped int32
    accumulator can overflow) is rejected at `submit` with a named
    `RangeError` instead of silently serving garbage logits."""

    def __init__(self, program: SNNProgram, *, batch_slots: int = 4,
                 backend: str = "int_ref", track_events: bool = True,
                 step_kw: Optional[dict] = None, validate: bool = True):
        self.program = program
        self.backend = backend
        self.B = batch_slots
        self.track_events = track_events
        self.step_kw = dict(step_kw or {})
        self.max_safe_ticks: Optional[int] = None
        if validate:
            from repro.analysis import check_kernel_contracts, check_program
            check_kernel_contracts(
                program, backend, frames=1, streaming=True,
                emit_rasters=track_events,
                block_b=self.step_kw.get("block_b", 8),
                gate_granularity=self.step_kw.get("gate_granularity", 1),
                event_crossover=self.step_kw.get("event_crossover", 1.0),
                use_sparse=self.step_kw.get("use_sparse", False))
            self.max_safe_ticks = check_program(
                program, frames=1).max_safe_frames
        self.state = pipeline.init_stream_state(program, batch_slots, backend)
        self._fresh = pipeline.init_stream_state(program, 1, backend)
        # structurally-determined batch axis per state leaf (same B-vs-B+1
        # probe ServeEngine runs on its cache tree, shapes only — no
        # device allocation); leaves without a batch axis (the tick
        # counter) map to None and stay shared
        probe = jax.eval_shape(lambda: pipeline.init_stream_state(
            program, batch_slots + 1, backend))
        self._batch_axes = probe_batch_axes(self.state, probe)
        self.slots = [_Slot() for _ in range(batch_slots)]
        self.queue: "queue.Queue[SNNRequest]" = queue.Queue()
        self.finished: list[SNNRequest] = []
        self._n_in, self._n_out, self._neurons = \
            pipeline._report_geometry(program)
        # frames each macro-stack layer runs per engine tick and per lane:
        # 1 for FC layers, H_out*W_out output positions for im2col'd convs
        self._lane_frames = tuple(
            int(np.prod(ly.state_shape[:-1])) if ly.kind == "conv" else 1
            for ly in program.macro_stack)
        # per-tick input frame shape: the conv encoder consumes cfg.in_shape
        # images; FC/encoder-led programs consume their input-layer currents
        self._frame_shape = (tuple(program.cfg.in_shape)
                             if program.layers[0].kind == "conv"
                             else tuple(program.layers[0].state_shape))
        self.ticks = 0                    # engine ticks executed
        # pooled device-side event ledger (event backends only): per-layer
        # row-event counters as the executing kernel reports them
        self._event_backend = backend in ("ref_events", "pallas_events")
        self.device_row_events: Optional[list] = None
        self.device_dense_fallbacks: Optional[list] = None
        self.device_ticks = 0

    # -- request intake ------------------------------------------------------
    def submit(self, req: SNNRequest) -> None:
        if req.frames.shape[1:] != tuple(self._frame_shape):
            raise ValueError(
                f"request {req.rid}: frame shape {req.frames.shape[1:]} "
                f"does not match the program input {self._frame_shape}")
        budget = self._tick_budget(req)
        if self.max_safe_ticks is not None and budget > self.max_safe_ticks:
            from repro.analysis import RangeError
            raise RangeError(
                f"request {req.rid} streams {budget} ticks but the "
                f"readout's unclamped int32 accumulator is only proven "
                f"safe for {self.max_safe_ticks} frames; split the stream "
                "or cap max_ticks", where="readout")
        self.queue.put(req)

    @staticmethod
    def _tick_budget(req: SNNRequest) -> int:
        """Ticks this request may stream: its frame count, clipped by an
        explicit non-negative max_ticks."""
        if req.max_ticks is None:
            return len(req.frames)
        return min(len(req.frames), max(req.max_ticks, 0))

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.req is not None:
                continue
            # like the LM engine's finish-at-admit: a request with nothing
            # to stream (no frames, or max_ticks <= 0) never occupies a
            # slot or runs a spurious tick — keep draining the queue until
            # one actually needs ticks
            while not self.queue.empty():
                req = self.queue.get()
                if self._tick_budget(req) == 0:
                    req.logits = np.zeros(self._n_out[-1], np.float32)
                    if self.track_events:   # reports only when accounting
                        req.report = self._finalize_report(_Slot(
                            req=req, row_events=[np.zeros(n, np.int64)
                                                 for n in self._n_in]))
                    self.finished.append(req)
                    continue
                # admit-by-lane-copy: the fresh request's (zero) V tree
                # enters the slot's lane; the V_MEM lane is the KV-cache
                # analogue
                self.state = lane_scatter(self._fresh, self.state,
                                          self._batch_axes, i)
                slot.req = req
                slot.cursor = 0
                slot.ticks = 0
                slot.row_events = [np.zeros(n, np.int64)
                                   for n in self._n_in]
                break

    # -- per-slot event accounting ------------------------------------------
    def _account(self, rasters: list, active: list) -> None:
        """Fold this tick's macro-stack input rasters into the active
        slots' per-row event tallies. `_stack_input_rasters` lowers conv
        spike maps to their im2col patch rasters, so conv layers count
        events per (output position, patch row) — exactly as the macro
        issues them; lane i owns the i-th block of P contiguous frames."""
        rs = pipeline._stack_input_rasters(
            self.program, [np.asarray(r)[None] for r in rasters])
        for li, (r, p) in enumerate(zip(rs, self._lane_frames)):
            counts = r[0].astype(np.int64)        # (B * P_l, n_in_l)
            for i in active:
                self.slots[i].row_events[li] += \
                    counts[i * p:(i + 1) * p].sum(axis=0)

    def _account_device(self, out) -> None:
        """Pool this tick's executor-reported `EventStats` (fc stack in
        ``out.skips``, one per conv layer in ``out.conv_skips``) into the
        engine-lifetime device ledger. These are the counters the event
        executor measured while running — for `pallas_events`, on device —
        over ALL lanes, idle ones included (module docs)."""
        rows = [np.asarray(r, np.int64)
                for st in (out.conv_skips or []) for r in st.row_events]
        rows += [np.asarray(r, np.int64) for r in out.skips.row_events]
        fbs = [int(f) for st in (out.conv_skips or [])
               for f in st.dense_fallbacks]
        fbs += [int(f) for f in out.skips.dense_fallbacks]
        if self.device_row_events is None:
            self.device_row_events = rows
            self.device_dense_fallbacks = fbs if fbs else None
        else:
            self.device_row_events = [a + b for a, b in
                                      zip(self.device_row_events, rows)]
            if fbs:
                self.device_dense_fallbacks = [
                    a + b for a, b in zip(self.device_dense_fallbacks, fbs)]
        self.device_ticks += 1

    def device_event_stats(self):
        """The pooled device ledger as an `events.EventStats`: per-layer
        row-event counters summed over every tick served so far, frames =
        device_ticks * batch_slots lane-frames (exact for FC stacks; conv
        layers run ``lane_frames`` frames per lane per tick — use
        `device_skipped_row_fraction` for the pooled fraction there). On a
        fully-occupied engine these equal the summed per-slot raster
        tallies exactly — the serving-side closure tests assert it."""
        from repro.kernels.fused_snn_net.events import EventStats
        if self.device_row_events is None:
            raise ValueError("no device ledger: the engine has not ticked "
                             "on an event backend (ref_events/pallas_events)")
        return EventStats(
            row_events=tuple(self.device_row_events),
            frames=self.device_ticks * self.B,
            dense_fallbacks=(tuple(self.device_dense_fallbacks)
                             if self.device_dense_fallbacks is not None
                             else ()))

    def device_skipped_row_fraction(self) -> float:
        """Pooled skipped-row fraction of the device ledger, with each
        layer's frame count scaled by its lane-frames (conv layers run one
        frame per output position)."""
        if self.device_row_events is None:
            raise ValueError("no device ledger: the engine has not ticked "
                             "on an event backend (ref_events/pallas_events)")
        possible = sum(self.device_ticks * self.B * p * n
                       for p, n in zip(self._lane_frames, self._n_in))
        events = sum(int(r.sum()) for r in self.device_row_events)
        return 1.0 - events / possible if possible else 0.0

    def _finalize_report(self, slot: _Slot) -> SparsityReport:
        """The per-request SparsityReport: batch 1, one timestep per served
        tick — same geometry/accounting as `pipeline.sparsity_report` on an
        isolated run of the request's frames."""
        t = slot.ticks
        row_events = tuple(np.asarray(r, np.int64) for r in slot.row_events)
        return SparsityReport(
            n_in=self._n_in, n_out=self._n_out, neurons=self._neurons,
            events=tuple(int(r.sum()) for r in row_events),
            frames=t, timesteps=t, batch=1,
            layer_frames=tuple(t * p for p in self._lane_frames),
            row_events=row_events)

    # -- engine tick ---------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit + one batched stream_step. Returns #active
        slots remaining after evictions."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        frame = np.zeros((self.B, *self._frame_shape), np.float32)
        for i in active:
            slot = self.slots[i]
            frame[i] = slot.req.frames[slot.cursor]
        self.state, out = pipeline.stream_step(
            self.program, self.state, jnp.asarray(frame), self.backend,
            emit_rasters=self.track_events, **self.step_kw)
        self.ticks += 1
        if self.track_events and out.rasters is not None:
            self._account(out.rasters, active)
        if self._event_backend and out.skips is not None:
            self._account_device(out)
        logits = np.asarray(out.logits)
        v_out = np.asarray(out.v_out)
        for i in active:
            slot = self.slots[i]
            req = slot.req
            slot.cursor += 1
            slot.ticks += 1
            done = slot.cursor >= self._tick_budget(req)
            if (req.stop_threshold is not None
                    and float(np.max(np.abs(logits[i])))
                    >= req.stop_threshold):
                done = True                       # confident readout: stop
            if done:
                req.logits = logits[i].copy()
                req.v_out = v_out[i].copy()
                req.ticks = slot.ticks
                if self.track_events:
                    req.report = self._finalize_report(slot)
                self.finished.append(req)
                self.slots[i] = _Slot()
        return sum(1 for s in self.slots if s.req is not None)

    # run_until_drained (and its EngineUndrained contract) comes from
    # SlotEngine — one drain loop shared with the LM engine.

    # -- workload accounting -------------------------------------------------
    def aggregate_report(self) -> SparsityReport:
        """Pooled SparsityReport over every finished request — the
        engine-level skipped-work/EDP accounting input."""
        reps = [r.report for r in self.finished if r.report is not None]
        return merge_reports(reps)
