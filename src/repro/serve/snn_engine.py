"""Streaming SNN serving engine: continuous batching over persistent
membrane-potential slots.

The LM engine's per-slot state is a KV-cache lane; IMPULSE's per-slot state
is the membrane-potential tree — V_MEM fused next to the weights is exactly
the state that makes streaming serving natural on this architecture. This
engine mirrors `ServeEngine`:

  * a paged V-slot pool: ``pages`` pages of ``batch_slots`` lanes, each
    page owning one `pipeline.StreamState` tree. A fresh request is
    admitted into any free lane across pages (admit-by-lane-copy: its zero
    state is scattered into the lane along each leaf's structurally-
    determined batch axis — the same B-vs-B+1 probe the LM engine uses on
    its cache tree), and each engine tick dispatches only the occupied
    pages;
  * K-frame megasteps: every dispatch advances a page ``megastep`` frames
    via `pipeline.stream_megastep` — the next K frames of each lane's
    stream are pre-staged into one (K, B, *in_shape) host block, lanes
    whose stream runs out inside the block integrate zero current
    (active-mask contract), and requests that finish mid-block are
    finalized from the block's exact per-tick readout trajectory. Batch
    lanes never interact (every op is per-lane), so each request's output
    is bit-identical to serving it alone — at any K;
  * double-buffered upload (``double_buffer=True``): after dispatching
    tick t's block, tick t+1's block is staged host-side and shipped with
    `jax.device_put` while the device computes; the staged block is keyed
    by per-lane (request, cursor) metadata and rebuilt on any mismatch
    (early exit, admission, eviction), so speculation never changes
    results;
  * admission control: requests carry an ``arrival_tick`` on the engine's
    frame clock (``clock`` advances K per engine tick, idle ticks
    included) and are not admitted before it — a seeded Poisson arrival
    process is just a sorted submission with exponential gaps;
  * per-slot stop conditions: fixed tick budget (the frame sequence runs
    out) or readout-threshold early exit (|logit| confidence);
  * per-slot event accounting: input events per macro-stack layer row are
    accumulated from each block's rasters — credited only up to the tick
    the request actually served — and finalize into a per-request
    `pipeline.SparsityReport` exactly like the batch path's reports
    (tests close the loop against isolated runs).

Event-gated ticks come from the backend choice: ``pallas_sparse`` /
``int_ref(use_sparse=True)`` skip silent-tile work inside the tick,
``ref_events`` executes the spike-list upper bound on the host, and
``pallas_events`` executes it on device (VMEM compaction + gather-matvec).
The per-slot row-skip accounting is backend-independent (it reads the
rasters); the event backends additionally feed a pooled *device ledger*
(`device_event_stats`) — the counters the executing kernel itself reports,
over ALL lanes of every dispatched page. A vacated lane is re-seeded with
fresh zero state at evict time, so idle lanes are silent (zero current
into zero V emits no spikes at any depth) and the ledger's row-event
counters close against the summed per-request tallies on partially-
occupied engines too. The two residual gaps are ghost ticks (an early-exit
request's lane keeps integrating its remaining staged frames until the
block ends; the request's own accounting discards them, the device ledger
cannot) and LIF wrap-mode leak wraparound on very long idle stretches —
which is why per-request accounting stays raster-based.
"""
from __future__ import annotations

import weakref
from collections import deque
from dataclasses import dataclass, field
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pipeline
from repro.core.pipeline import SNNProgram, SparsityReport
from repro.serve.engine import SlotEngine, lane_scatter, probe_batch_axes


class ReportUnavailable(RuntimeError):
    """`aggregate_report` has nothing to aggregate: event tracking is
    disabled on this engine, or no request has finished yet. Named so
    operators don't mistake it for a report-geometry mismatch inside
    `merge_reports`."""


@dataclass
class SNNRequest:
    rid: int
    frames: np.ndarray                    # (T, *in_shape) input currents
    max_ticks: Optional[int] = None       # default: len(frames)
    stop_threshold: Optional[float] = None  # early exit when max|logit| >= thr
    arrival_tick: int = 0                 # earliest admission, engine clock
    # -- filled at finish ----------------------------------------------------
    logits: Optional[np.ndarray] = None
    v_out: Optional[np.ndarray] = None
    ticks: int = 0
    finish_clock: Optional[int] = None    # engine clock at the finish tick
    report: Optional[SparsityReport] = None

    @property
    def latency_ticks(self) -> Optional[int]:
        """Frame-clock request latency: queueing + service, arrival to
        finish (None until finished)."""
        if self.finish_clock is None:
            return None
        return self.finish_clock - self.arrival_tick


@dataclass
class _Slot:
    req: Optional[SNNRequest] = None
    cursor: int = 0                       # next frame index to present
    ticks: int = 0
    serial: int = -1                      # admission sequence number
    row_events: list = field(default_factory=list)


class _ArrivalQueue:
    """Submission-order FIFO with head peek: arrival-gated admission needs
    to inspect the head's ``arrival_tick`` without consuming it. Exposes
    the `queue.Queue` surface `SlotEngine.run_until_drained` relies on
    (``empty``/``qsize``) plus ``put``/``get``/``peek``."""

    def __init__(self):
        self._q: deque = deque()

    def put(self, item) -> None:
        self._q.append(item)

    def get(self):
        return self._q.popleft()

    def peek(self):
        return self._q[0]

    def empty(self) -> bool:
        return not self._q

    def qsize(self) -> int:
        return len(self._q)


def merge_reports(reports: list) -> SparsityReport:
    """Pool per-request reports (batch=1 each) into one workload report:
    events/row_events/frame counts add; the merged report's instruction
    counts equal the sum of the parts (counting is linear in events and
    frames), so engine-level EDP accounting stays exact."""
    if not reports:
        raise ValueError("merge_reports needs at least one report")
    head = reports[0]
    for r in reports[1:]:
        if (r.n_in, r.n_out, r.neurons) != (head.n_in, head.n_out,
                                            head.neurons):
            raise ValueError("cannot merge reports of different programs")
    return SparsityReport(
        n_in=head.n_in, n_out=head.n_out, neurons=head.neurons,
        events=tuple(sum(r.events[i] for r in reports)
                     for i in range(len(head.n_in))),
        frames=sum(r.frames for r in reports),
        timesteps=sum(r.timesteps for r in reports),
        batch=1,
        layer_frames=tuple(sum(r.frames_by_layer[i] for r in reports)
                           for i in range(len(head.n_in))),
        row_events=tuple(
            sum(np.asarray(r.row_events[i], np.int64) for r in reports)
            for i in range(len(head.n_in))))


_MEGASTEP_JIT = {}  # (id(program), backend, kw, rasters, mesh) -> (ref, fn)


def _jit_megastep(program, backend, step_kw, emit_rasters, mesh=None):
    """Jitted megastep core shared across engines over the same program.

    SNNProgram is frozen and holds device arrays (unhashable), so the
    cache is keyed by ``id`` with a weakref guard against id reuse (a
    `jax.sharding.Mesh` IS hashable, so ``mesh`` keys directly). The
    core returns ``MegastepOut``'s fields as a tuple (the dataclass is
    not a pytree); callers rebuild it.
    """
    key = (id(program), backend, tuple(sorted(step_kw.items())),
           emit_rasters, mesh)
    hit = _MEGASTEP_JIT.get(key)
    if hit is not None and hit[0]() is program:
        return hit[1]

    def _core(st, block, counts):
        st2, out = pipeline.stream_megastep(
            program, st, block, backend, active=counts,
            emit_rasters=emit_rasters, mesh=mesh, **step_kw)
        return st2, (out.v_out, out.logits, out.v_out_traj,
                     out.logits_traj, out.frames_consumed,
                     out.rasters, out.skips, out.conv_skips)

    fn = jax.jit(_core)
    _MEGASTEP_JIT[key] = (weakref.ref(program), fn)
    return fn


class SNNServeEngine(SlotEngine):
    """Continuous batching for streaming SNN inference (see module docs).

    ``backend`` is any `pipeline.STREAM_BACKENDS` entry; ``step_kw`` passes
    through to `stream_megastep` (block_b / interpret / gate_granularity /
    use_sparse / event_crossover). ``track_events=False`` disables raster
    emission and per-slot accounting — the pure-serving configuration in
    which inter-layer spikes never leave the kernel.

    ``pages`` × ``batch_slots`` is the lane pool; ``megastep`` is K, the
    frames advanced per dispatch; ``double_buffer`` stages the next block
    while the current one computes. The defaults (1 page, K=1) reproduce
    the classic tick-by-tick engine exactly.

    ``validate`` (default on) runs the static analyzer at engine build
    time: the kernel contracts of this exact (backend, K, step_kw)
    dispatch are verified before the first tick — the VMEM budget scales
    with K — and the program's `RangeReport` caps admission: a request
    whose tick budget, rounded up to the K-block horizon it will actually
    execute, exceeds the readout's proven ``max_safe_frames`` (the horizon
    past which the unclamped int32 accumulator can overflow) is rejected
    at `submit` with a named `RangeError` instead of silently serving
    garbage logits.

    ``mesh`` (a `jax.sharding.Mesh` with "data"/"model" axes) partitions
    the paged V-slot pool: each page's state tree is placed with its lane
    axis sharded over the data mesh axis (`dist.sharding.snn_state_specs`)
    and every megastep dispatch executes under shard_map — serving lanes
    over data shards, row-tiled fan-in over model shards — bit-identical
    to the single-device engine (every per-request output and both event
    ledgers). The float backend rejects a mesh (ValueError)."""

    def __init__(self, program: SNNProgram, *, batch_slots: int = 4,
                 backend: str = "int_ref", track_events: bool = True,
                 step_kw: Optional[dict] = None, validate: bool = True,
                 pages: int = 1, megastep: int = 1,
                 double_buffer: bool = False, mesh=None):
        if pages < 1:
            raise ValueError(f"pages must be >= 1, got {pages}")
        if megastep < 1:
            raise ValueError(f"megastep must be >= 1, got {megastep}")
        if mesh is not None and backend == "float":
            raise ValueError(
                "backend 'float' has no mesh execution: float reductions "
                "are not order-exact, so a sharded engine could not stay "
                "bit-identical to the single-device path")
        self.program = program
        self.backend = backend
        self.mesh = mesh
        self.B = batch_slots                  # lanes per page
        self.pages = pages
        self.K = megastep
        self.double_buffer = double_buffer
        self.track_events = track_events
        self.step_kw = dict(step_kw or {})
        self.max_safe_ticks: Optional[int] = None
        if validate:
            from repro.analysis import check_kernel_contracts, check_program
            check_kernel_contracts(
                program, backend, frames=megastep, streaming=True,
                emit_rasters=track_events,
                block_b=self.step_kw.get("block_b", 8),
                gate_granularity=self.step_kw.get("gate_granularity", 1),
                event_crossover=self.step_kw.get("event_crossover", 1.0),
                use_sparse=self.step_kw.get("use_sparse", False),
                mesh=mesh)
            self.max_safe_ticks = check_program(
                program, frames=1).max_safe_frames
        self.states = [pipeline.init_stream_state(program, batch_slots,
                                                  backend)
                       for _ in range(pages)]
        if mesh is not None:
            # place each page's pool on the mesh: lane axis over the data
            # shards (snn_state_specs degrades to replication when the
            # lane count does not divide), scalars replicated
            from repro.dist import sharding as dist_sharding
            self.states = [
                jax.device_put(st, dist_sharding.snn_state_specs(st, mesh))
                for st in self.states]
        self._fresh = pipeline.init_stream_state(program, 1, backend)
        # structurally-determined batch axis per state leaf (same B-vs-B+1
        # probe ServeEngine runs on its cache tree, shapes only — no
        # device allocation); leaves without a batch axis (the tick
        # counter) map to None and stay shared
        probe = jax.eval_shape(lambda: pipeline.init_stream_state(
            program, batch_slots + 1, backend))
        self._batch_axes = probe_batch_axes(self.states[0], probe)
        self.slots = [_Slot() for _ in range(pages * batch_slots)]
        self.queue = _ArrivalQueue()
        self.finished: list[SNNRequest] = []
        self._n_in, self._n_out, self._neurons = \
            pipeline._report_geometry(program)
        # frames each macro-stack layer runs per engine tick and per lane:
        # 1 for FC layers, H_out*W_out output positions for im2col'd convs
        self._lane_frames = tuple(
            int(np.prod(ly.state_shape[:-1])) if ly.kind == "conv" else 1
            for ly in program.macro_stack)
        # per-tick input frame shape: the conv encoder consumes cfg.in_shape
        # images; FC/encoder-led programs consume their input-layer currents
        self._frame_shape = (tuple(program.cfg.in_shape)
                             if program.layers[0].kind == "conv"
                             else tuple(program.layers[0].state_shape))
        self.ticks = 0                    # engine ticks executed (dispatches)
        self.clock = 0                    # frame clock: K per engine tick
        # jit the per-page megastep dispatch (the LM engine jits its
        # decode_step the same way): block/counts shapes are fixed per
        # engine config, so this compiles once. The event-list executors
        # fold their ledgers to host numpy inside the op wrapper and the
        # float backend's QAT ops are kept eager for bit-identity with
        # stream_step — those take the direct path. MegastepOut is a
        # plain dataclass, not a pytree, so the jitted core returns its
        # fields as a tuple. The compiled core is cached per (program,
        # backend, step_kw) so every engine over the same program — the
        # warmup drain in the benchmark, a restarted server — shares one
        # compile instead of retracing a fresh closure.
        self._dispatch = None
        if backend not in ("float", "ref_events", "pallas_events"):
            self._dispatch = _jit_megastep(program, backend, self.step_kw,
                                           track_events, mesh)
        self._admit_seq = 0
        self._staged: dict = {}           # page -> (meta, device block, counts)
        # pooled device-side event ledger (event backends only): per-layer
        # row-event counters as the executing kernel reports them
        self._event_backend = backend in ("ref_events", "pallas_events")
        self.device_row_events: Optional[list] = None
        self.device_dense_fallbacks: Optional[list] = None
        self.device_ticks = 0             # frame ticks dispatched, all pages

    @property
    def state(self) -> pipeline.StreamState:
        """Page 0's state tree (back-compat introspection handle for the
        classic single-page engine)."""
        return self.states[0]

    # -- request intake ------------------------------------------------------
    def submit(self, req: SNNRequest) -> None:
        """Enqueue ``req`` (an `SNNRequest` whose ``frames`` is a
        (T, *in_shape) current block) for arrival-gated FIFO admission.

        Raises ``ValueError`` when the request's frame shape does not
        match the program input, and `analysis.RangeError` when its tick
        budget — rounded up to the K-block horizon the lane will actually
        execute — exceeds the readout accumulator's proven
        ``max_safe_frames`` (validate=True engines only)."""
        if req.frames.shape[1:] != tuple(self._frame_shape):
            raise ValueError(
                f"request {req.rid}: frame shape {req.frames.shape[1:]} "
                f"does not match the program input {self._frame_shape}")
        budget = self._tick_budget(req)
        # the lane executes whole K-blocks: a request finishing mid-block
        # still integrates (masked, zero-current) ticks to the block edge,
        # so the proven-safe horizon must cover the K-rounded budget
        horizon = -(-budget // self.K) * self.K
        if self.max_safe_ticks is not None and horizon > self.max_safe_ticks:
            from repro.analysis import RangeError
            raise RangeError(
                f"request {req.rid} streams {budget} ticks "
                f"({horizon} at megastep K={self.K}) but the readout's "
                f"unclamped int32 accumulator is only proven safe for "
                f"{self.max_safe_ticks} frames; split the stream or cap "
                "max_ticks", where="readout")
        self.queue.put(req)

    @staticmethod
    def _tick_budget(req: SNNRequest) -> int:
        """Ticks this request may stream: its frame count, clipped by an
        explicit non-negative max_ticks."""
        if req.max_ticks is None:
            return len(req.frames)
        return min(len(req.frames), max(req.max_ticks, 0))

    def _admit(self) -> None:
        for i, slot in enumerate(self.slots):
            if slot.req is not None:
                continue
            # like the LM engine's finish-at-admit: a request with nothing
            # to stream (no frames, or max_ticks <= 0) never occupies a
            # slot or runs a spurious tick — keep draining the queue until
            # one actually needs ticks
            while not self.queue.empty():
                if self.queue.peek().arrival_tick > self.clock:
                    return    # FIFO: the head gates later submissions too
                req = self.queue.get()
                if self._tick_budget(req) == 0:
                    req.logits = np.zeros(self._n_out[-1], np.float32)
                    # shape-consistent readout V so degenerate requests
                    # finalize like every other finish (backend-native
                    # dtype: f32 float backend, int32 macro readout)
                    req.v_out = np.zeros(
                        self._n_out[-1],
                        np.float32 if self.backend == "float" else np.int32)
                    req.finish_clock = self.clock
                    if self.track_events:   # reports only when accounting
                        req.report = self._finalize_report(_Slot(
                            req=req, row_events=[np.zeros(n, np.int64)
                                                 for n in self._n_in]))
                    self.finished.append(req)
                    continue
                # admit-by-lane-copy: the fresh request's (zero) V tree
                # enters the slot's lane; the V_MEM lane is the KV-cache
                # analogue
                page, lane = divmod(i, self.B)
                self.states[page] = lane_scatter(
                    self._fresh, self.states[page], self._batch_axes, lane)
                slot.req = req
                slot.cursor = 0
                slot.ticks = 0
                slot.serial = self._admit_seq
                self._admit_seq += 1
                slot.row_events = [np.zeros(n, np.int64)
                                   for n in self._n_in]
                break

    # -- per-slot event accounting ------------------------------------------
    def _account(self, rasters: list, served: list) -> None:
        """Fold one block's macro-stack input rasters into the served
        slots' per-row event tallies. ``served`` is [(slot, lane, ticks)]
        — a request is credited only the ticks it actually served, so
        ghost ticks past a mid-block finish never enter its report.
        `_stack_input_rasters` lowers conv spike maps to their im2col
        patch rasters, so conv layers count events per (output position,
        patch row) — exactly as the macro issues them; lane l owns the
        l-th block of P contiguous frames."""
        rs = pipeline._stack_input_rasters(
            self.program, [np.asarray(r) for r in rasters])
        for li, (r, p) in enumerate(zip(rs, self._lane_frames)):
            counts = r.astype(np.int64)       # (K, B * P_l, n_in_l)
            for i, lane, n in served:
                self.slots[i].row_events[li] += \
                    counts[:n, lane * p:(lane + 1) * p].sum(axis=(0, 1))

    def _account_device(self, out) -> None:
        """Pool one dispatch's executor-reported `EventStats` (fc stack in
        ``out.skips``, one per conv layer in ``out.conv_skips``) into the
        engine-lifetime device ledger. These are the counters the event
        executor measured while running — for `pallas_events`, on device —
        over ALL lanes of the dispatched page, K frames each (module
        docs)."""
        rows = [np.asarray(r, np.int64)
                for st in (out.conv_skips or []) for r in st.row_events]
        rows += [np.asarray(r, np.int64) for r in out.skips.row_events]
        fbs = [int(f) for st in (out.conv_skips or [])
               for f in st.dense_fallbacks]
        fbs += [int(f) for f in out.skips.dense_fallbacks]
        if self.device_row_events is None:
            self.device_row_events = rows
            self.device_dense_fallbacks = fbs if fbs else None
        else:
            self.device_row_events = [a + b for a, b in
                                      zip(self.device_row_events, rows)]
            if fbs:
                self.device_dense_fallbacks = [
                    a + b for a, b in zip(self.device_dense_fallbacks, fbs)]
        self.device_ticks += self.K

    def device_event_stats(self):
        """The pooled device ledger as an `events.EventStats`: per-layer
        row-event counters summed over every dispatch so far, frames =
        device_ticks * batch_slots lane-frames (device_ticks accumulates
        K per dispatched page; exact for FC stacks — conv layers run
        ``lane_frames`` frames per lane per tick, use
        `device_skipped_row_fraction` for the pooled fraction there).
        Since vacated lanes are re-seeded with zero state, the row-event
        counters close exactly against the summed per-slot raster tallies
        at any occupancy — the serving-side closure tests assert it —
        modulo the ghost ticks of mid-block early exits (module docs)."""
        from repro.kernels.fused_snn_net.events import EventStats
        if self.device_row_events is None:
            raise ValueError("no device ledger: the engine has not ticked "
                             "on an event backend (ref_events/pallas_events)")
        return EventStats(
            row_events=tuple(self.device_row_events),
            frames=self.device_ticks * self.B,
            dense_fallbacks=(tuple(self.device_dense_fallbacks)
                             if self.device_dense_fallbacks is not None
                             else ()))

    def device_skipped_row_fraction(self) -> float:
        """Pooled skipped-row fraction of the device ledger, with each
        layer's frame count scaled by its lane-frames (conv layers run one
        frame per output position)."""
        if self.device_row_events is None:
            raise ValueError("no device ledger: the engine has not ticked "
                             "on an event backend (ref_events/pallas_events)")
        possible = sum(self.device_ticks * self.B * p * n
                       for p, n in zip(self._lane_frames, self._n_in))
        events = sum(int(r.sum()) for r in self.device_row_events)
        return 1.0 - events / possible if possible else 0.0

    def _finalize_report(self, slot: _Slot) -> SparsityReport:
        """The per-request SparsityReport: batch 1, one timestep per served
        tick — same geometry/accounting as `pipeline.sparsity_report` on an
        isolated run of the request's frames."""
        t = slot.ticks
        row_events = tuple(np.asarray(r, np.int64) for r in slot.row_events)
        return SparsityReport(
            n_in=self._n_in, n_out=self._n_out, neurons=self._neurons,
            events=tuple(int(r.sum()) for r in row_events),
            frames=t, timesteps=t, batch=1,
            layer_frames=tuple(t * p for p in self._lane_frames),
            row_events=row_events)

    # -- frame staging -------------------------------------------------------
    def _block_meta(self, page: int) -> tuple:
        """Identity of the block a page would dispatch right now: per
        occupied lane (admission serial, cursor, staged tick count). The
        key that validates a speculatively staged block."""
        meta = []
        for i in self.page_lanes(page):
            slot = self.slots[i]
            if slot.req is None:
                continue
            n = min(self._tick_budget(slot.req) - slot.cursor, self.K)
            meta.append((slot.serial, slot.cursor, n))
        return tuple(meta)

    def _build_block(self, page: int, at_next: bool = False):
        """Assemble one page's (K, B, *in_shape) frame block and per-lane
        active counts — from each lane's current cursor, or (``at_next``)
        from its predicted post-dispatch cursor for double-buffer
        speculation. Returns (meta, host block, counts); meta/block are
        None when no lane would be active."""
        block = np.zeros((self.K, self.B, *self._frame_shape), np.float32)
        counts = np.zeros(self.B, np.int32)
        meta, any_live = [], False
        for i in self.page_lanes(page):
            slot = self.slots[i]
            if slot.req is None:
                continue
            budget = self._tick_budget(slot.req)
            cursor = slot.cursor
            if at_next:
                cursor += min(budget - cursor, self.K)
                if cursor >= budget:
                    continue              # predicted finished by then
            n = min(budget - cursor, self.K)
            block[:n, i % self.B] = slot.req.frames[cursor:cursor + n]
            counts[i % self.B] = n
            meta.append((slot.serial, cursor, n))
            any_live = True
        if not any_live:
            return None, None, None
        return tuple(meta), block, counts

    def _stage_block(self, page: int):
        """The block a page dispatches this tick: the double-buffered
        upload when its metadata still matches (no early exit, admission,
        or eviction invalidated the speculation), else built fresh."""
        staged = self._staged.pop(page, None)
        if staged is not None and staged[0] == self._block_meta(page):
            return staged[1], staged[2]
        meta, block, counts = self._build_block(page)
        return jnp.asarray(block), counts

    def _stage_next(self, pages: list) -> None:
        """Double buffer: stage tick t+1's blocks (host assembly + device
        upload) while tick t's dispatches compute. Pure speculation —
        `_stage_block` re-validates against live metadata, so a mismatch
        costs one rebuild and never changes results."""
        for page in pages:
            meta, block, counts = self._build_block(page, at_next=True)
            if meta is not None:
                self._staged[page] = (meta, jax.device_put(block), counts)

    # -- engine tick ---------------------------------------------------------
    def step(self) -> int:
        """One engine tick: admit, then one K-frame megastep per occupied
        page. Returns #active slots remaining after evictions."""
        self._admit()
        by_page = self.active_by_page()
        if not by_page:
            if not self.queue.empty():
                # only future arrivals remain: idle ticks still advance
                # the frame clock so Poisson schedules reach their
                # arrival times under run_until_drained
                self.clock += self.K
            return 0
        outs = {}
        for page in sorted(by_page):
            block, counts = self._stage_block(page)
            if self._dispatch is not None:
                self.states[page], flat = self._dispatch(
                    self.states[page], block, counts)
                outs[page] = pipeline.MegastepOut(*flat)
            else:
                self.states[page], outs[page] = pipeline.stream_megastep(
                    self.program, self.states[page], block, self.backend,
                    active=counts, emit_rasters=self.track_events,
                    mesh=self.mesh, **self.step_kw)
        if self.double_buffer:
            self._stage_next(sorted(by_page))
        self.ticks += 1
        self.clock += self.K
        for page in sorted(by_page):
            self._retire_page(page, by_page[page], outs[page])
        return sum(1 for s in self.slots if s.req is not None)

    def _retire_page(self, page: int, lanes: list, out) -> None:
        """Account one page's megastep and finalize the requests that
        finished inside it — from the block's per-tick readout trajectory,
        at the exact tick a K=1 drain would have stopped on."""
        logits = np.asarray(out.logits_traj)       # (K, B, n_out)
        v_traj = np.asarray(out.v_out_traj)
        consumed = np.asarray(out.frames_consumed)
        served, fins = [], []
        for i in lanes:
            slot = self.slots[i]
            req = slot.req
            lane = i % self.B
            n = int(consumed[lane])
            fin = None
            for t in range(n):
                if (req.stop_threshold is not None
                        and float(np.max(np.abs(logits[t, lane])))
                        >= req.stop_threshold):
                    fin = t                        # confident readout: stop
                    break
                if slot.cursor + t + 1 >= self._tick_budget(req):
                    fin = t                        # budget exhausted
                    break
            credit = n if fin is None else fin + 1
            served.append((i, lane, credit))
            slot.cursor += credit
            slot.ticks += credit
            if fin is not None:
                fins.append((i, lane, fin))
        if self.track_events and out.rasters is not None:
            self._account(out.rasters, served)
        if self._event_backend and out.skips is not None:
            self._account_device(out)
        for i, lane, fin in fins:
            slot = self.slots[i]
            req = slot.req
            req.logits = logits[fin, lane].copy()
            req.v_out = v_traj[fin, lane].copy()
            req.ticks = slot.ticks
            req.finish_clock = self.clock - self.K + fin + 1
            if self.track_events:
                req.report = self._finalize_report(slot)
            self.finished.append(req)
            # idle lanes are silent: re-seed the vacated lane with fresh
            # zero state so deeper layers cannot keep leaking/firing from
            # carried V until re-admission
            self.states[page] = lane_scatter(
                self._fresh, self.states[page], self._batch_axes, lane)
            self.slots[i] = _Slot()

    # run_until_drained (and its EngineUndrained contract) comes from
    # SlotEngine — one drain loop shared with the LM engine.

    # -- workload accounting -------------------------------------------------
    def aggregate_report(self) -> SparsityReport:
        """Pooled SparsityReport over every finished request — the
        engine-level skipped-work/EDP accounting input. Raises
        `ReportUnavailable` when there is nothing to pool (event tracking
        off, or no request finished yet)."""
        if not self.track_events:
            raise ReportUnavailable(
                "event tracking is disabled (track_events=False): "
                "per-request SparsityReports were never accumulated; build "
                "the engine with track_events=True for accounting")
        reps = [r.report for r in self.finished if r.report is not None]
        if not reps:
            raise ReportUnavailable(
                "no finished requests yet: the aggregate report pools "
                "per-request reports, which exist only after a request "
                "finishes (run_until_drained / step)")
        return merge_reports(reps)
