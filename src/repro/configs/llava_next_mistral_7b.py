"""LLaVA-NeXT (1.6) Mistral-7B — VLM, anyres tiling.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

Backbone = Mistral-7B (32L, d=4096, GQA kv=8, d_ff=14336, vocab=32000).
Per the assignment the vision frontend (CLIP + anyres tiling + projector) is a
STUB: ``input_specs()`` provides precomputed patch embeddings of shape
(batch, n_patches, d_model) with n_patches = vision_patch_frac * seq_len; the
model concatenates them ahead of the text tokens.
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    rope_theta=1000000.0,
    frontend="vision_stub",
    vision_patch_frac=0.25,
    notes="vision frontend stubbed; long_500k skipped: full attention",
))
