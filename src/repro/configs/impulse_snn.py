"""The paper's own workloads: IMDB sentiment SNN and MNIST LeNet5-mod SNN.

These are not LM registry entries; they configure the core/ spiking stack.
  impulse-imdb : input 100 (GloVe-100d spike encoder) -> FC128 -> FC128 -> 1,
                 RMP neurons, 6b W / 11b V_MEM, 10 timesteps. 29.3K params.
  impulse-mnist: modified LeNet-5 with fan-in <= 128 (14 input channels, 3x3
                 kernels => 3*3*14 = 126 <= 128), FC layers < 128 neurons.
"""
from dataclasses import dataclass, field

from repro.configs.base import SpikingConfig


@dataclass(frozen=True)
class SNNModelConfig:
    arch_id: str
    layer_sizes: tuple            # FC sizes, input first
    conv_spec: tuple = ()         # ((out_ch, k, stride), ...) before FC stack
    in_shape: tuple = ()          # conv input (H, W, C)
    spiking: SpikingConfig = field(default_factory=SpikingConfig)
    timesteps: int = 10
    task: str = "binary"          # binary | multiclass


IMDB = SNNModelConfig(
    arch_id="impulse-imdb",
    layer_sizes=(100, 128, 128, 1),
    spiking=SpikingConfig(neuron="rmp", timesteps=10, threshold=1.0,
                          leak=0.0625, w_bits=6, v_bits=11),
    timesteps=10,
    task="binary",
)

# Modified LeNet-5: Conv1 is the spike encoder (kept off-macro, like the paper's
# input layer); Conv2,3 + FC1,2 are mapped on IMPULSE. Channel counts chosen so
# fan-in = 3*3*14 = 126 <= 128 and FC neurons < 128, per the paper.
MNIST = SNNModelConfig(
    arch_id="impulse-mnist",
    conv_spec=((14, 3, 1), (14, 3, 2), (14, 3, 2)),   # encoder + 2 macro convs
    in_shape=(28, 28, 1),
    layer_sizes=(686, 120, 84, 10),                   # 7*7*14 = 686 flatten
    spiking=SpikingConfig(neuron="rmp", timesteps=10, threshold=1.0,
                          leak=0.0625, w_bits=6, v_bits=11),
    timesteps=10,
    task="multiclass",
)

SNN_CONFIGS = {c.arch_id: c for c in (IMDB, MNIST)}


def get_snn_config(arch_id: str) -> SNNModelConfig:
    return SNN_CONFIGS[arch_id]
