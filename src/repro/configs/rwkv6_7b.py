"""RWKV6 (Finch) 7B — attention-free, data-dependent decay. [arXiv:2404.05892; hf]

The wkv recurrent state is the direct analogue of IMPULSE's membrane potential:
a per-channel accumulator updated in place with a (here: learned, data-dependent)
decay — exactly a LIF leak. The fused-state Pallas kernel (kernels/wkv6) keeps it
VMEM-resident across the sequence scan.
"""
from repro.configs.base import ModelConfig, RWKVConfig, register

CONFIG = register(ModelConfig(
    arch_id="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,                # d_model / head_size
    n_kv_heads=64,
    head_dim=64,               # rwkv6 head_size
    d_ff=14336,
    vocab_size=65536,
    rwkv=RWKVConfig(head_size=64),
    supports_long_context=True,
    notes="attn-free; long_500k runs (O(1) state per token)",
))
