"""Llama-4 Maverick 400B-A17B — MoE 128 routed experts, top-1, shared expert.
[hf:meta-llama/Llama-4-Maverick-17B-128E]

Config-literal note: the assignment line gives "48L d5120 40H kv8 d_ff=8192
vocab=202048, MoE 128e top-1". Taking MoE on *all* 48 layers yields ~776B
params, contradicting the 400B-A17B name; the published HF config interleaves
MoE every other layer (interleave_moe_layer_step=2) with dense-layer
d_ff=16384 and one shared expert, which reproduces ~400B total / ~17B active.
We implement the published interleaved layout (param_count() ≈ 4.0e11).
"""
from repro.configs.base import ModelConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,                  # dense (non-MoE) layers
    vocab_size=202048,
    rope_theta=500000.0,
    moe=MoEConfig(n_experts=128, top_k=1, n_shared_experts=1, d_ff=8192,
                  every=2, dense_d_ff=16384),
    notes="top-1 routing = event-driven expert sparsity; long_500k skipped (attention)",
))
