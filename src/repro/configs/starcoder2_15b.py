"""StarCoder2 15B — dense GQA (kv=4), RoPE. [arXiv:2402.19173; hf]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    head_dim=128,
    d_ff=24576,
    vocab_size=49152,
    rope_theta=100000.0,
    ffn_type="gelu",
    notes="long_500k skipped: pure full attention",
))
