"""Jamba v0.1 52B — hybrid Mamba+attention (1:7 interleave), MoE 16e top-2.
[arXiv:2403.19887; hf]

The SSM state of the 28/32 Mamba layers is the membrane-potential analogue;
the fused-state structure (IMPULSE's contribution) applies directly to the
selective-scan update. long_500k runs (hybrid, sub-quadratic in the Mamba
layers; the 4 attention layers use a sharded KV cache).
"""
from repro.configs.base import ModelConfig, MoEConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    arch_id="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    # attention on 1 of every 8 layers (offset 4), mamba elsewhere — 1:7
    attn_layer_period=8,
    attn_layer_offset=4,
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2, dt_rank=256),
    # MoE every other layer, 16 experts top-2 (expert ffn = d_ff)
    moe=MoEConfig(n_experts=16, top_k=2, n_shared_experts=0, d_ff=14336,
                  every=2, dense_d_ff=14336),
    supports_long_context=True,
    notes="hybrid; long_500k runs",
))
