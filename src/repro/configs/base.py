"""Config system: model / shape / parallelism / run configs and the registry.

Every assigned architecture gets a ``configs/<id>.py`` that builds a
:class:`ModelConfig` and registers it. Shapes are global (the four assigned
input-shape cells). A :class:`RunConfig` binds (model, shape, mesh, sharding).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0              # routed experts (0 = dense)
    top_k: int = 1
    n_shared_experts: int = 0
    d_ff: int = 0                   # per-expert hidden dim
    every: int = 1                  # MoE on layers where (idx % every == every-1)
    first_k_dense: int = 0          # leading dense layers (deepseek style)
    dense_d_ff: int = 0             # ffn dim of the dense layers interleaved w/ MoE


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = direct q projection (V2-Lite)
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba block (Jamba's SSM layers)."""
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 256


@dataclass(frozen=True)
class RWKVConfig:
    head_size: int = 64             # rwkv6 head size; n_heads = d_model // head_size


@dataclass(frozen=True)
class SpikingConfig:
    """IMPULSE integration: spiking FFN / paper SNN settings."""
    neuron: str = "rmp"             # if | lif | rmp
    timesteps: int = 10
    threshold: float = 1.0
    leak: float = 0.0625
    w_bits: int = 6                 # paper: 6-bit signed weights
    v_bits: int = 11                # paper: 11-bit signed membrane potential


@dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm | snn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # positional / norm
    rope_theta: float = 500000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # attention layout (hybrid archs)
    attn_layer_period: int = 1      # attention on layers where idx % period == attn_layer_offset
    attn_layer_offset: int = 0      # (period=1 -> all layers are attention)
    ffn_type: str = "swiglu"        # swiglu (3 mats) | gelu (2 mats)
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    rwkv: Optional[RWKVConfig] = None
    spiking: Optional[SpikingConfig] = None
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    # modality frontend stubs
    frontend: str = "none"          # none | audio_stub | vision_stub
    vision_patch_frac: float = 0.25  # fraction of seq that is image patches (vlm)
    # numerics
    dtype: str = "bfloat16"
    # capabilities
    supports_long_context: bool = False   # sub-quadratic path exists (SSM/linear)
    notes: str = ""

    # -- derived ------------------------------------------------------------
    def is_attention_layer(self, idx: int) -> bool:
        return idx % self.attn_layer_period == self.attn_layer_offset

    def is_moe_layer(self, idx: int) -> bool:
        if self.moe is None or self.moe.n_experts == 0:
            return False
        if idx < self.moe.first_k_dense:
            return False
        return idx % self.moe.every == self.moe.every - 1

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        n = self.vocab_size * self.d_model                       # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model                  # lm head
        for i in range(self.n_layers):
            n += self._block_params(i)
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                n += self._encoder_block_params()
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for i in range(self.n_layers):
            n += self._block_params(i, active_only=True)
        if self.is_encoder_decoder:
            for _ in range(self.n_encoder_layers):
                n += self._encoder_block_params()
        return n

    def _attn_params(self) -> int:
        d = self.d_model
        if self.mla is not None:
            m = self.mla
            qd = self.n_heads * (m.nope_head_dim + m.rope_head_dim)
            n = d * qd if m.q_lora_rank == 0 else d * m.q_lora_rank + m.q_lora_rank * qd
            n += d * (m.kv_lora_rank + m.rope_head_dim)          # kv down (+ shared rope key)
            n += m.kv_lora_rank * self.n_heads * (m.nope_head_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d                 # o proj
            return n
        return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d

    def _ffn_params(self, d_ff: int) -> int:
        mats = 3 if self.ffn_type == "swiglu" else 2             # swiglu | gelu
        return mats * self.d_model * d_ff

    def _ssm_params(self) -> int:
        if self.ssm is None:
            raise ValueError(f"{self.arch_id}: ssm layer kind requested "
                             "but cfg.ssm is unset")
        s, d = self.ssm, self.d_model
        d_in = s.expand * d
        n = 2 * d * d_in                                          # in_proj (x, z)
        n += d_in * s.d_conv                                      # conv1d
        n += d_in * (s.dt_rank + 2 * s.d_state)                   # x -> (dt, B, C)
        n += s.dt_rank * d_in                                     # dt proj
        n += d_in * s.d_state + d_in                              # A_log, D
        n += d_in * d                                             # out proj
        return n

    def _rwkv_params(self) -> int:
        d = self.d_model
        # time-mix: r,k,v,g,o projections + decay/first + lora token-shift (small)
        n = 5 * d * d + 2 * d + 6 * d * 32 * 2
        # channel-mix: k (d->ff), v (ff->d), receptance gate (d->d)
        n += 2 * d * self.d_ff + d * d
        return n

    def _block_params(self, idx: int, active_only: bool = False) -> int:
        n = 2 * self.d_model                                      # norms
        if self.rwkv is not None:
            return n + self._rwkv_params()
        if self.is_attention_layer(idx):
            n += self._attn_params()
            if self.is_encoder_decoder:
                n += 4 * self.d_model * self.d_model              # cross-attention
        else:
            n += self._ssm_params()
        if self.is_moe_layer(idx):
            m = self.moe
            k = (m.top_k if active_only else m.n_experts) + m.n_shared_experts
            n += k * self._ffn_params(m.d_ff)
            n += self.d_model * m.n_experts                       # router
        else:
            d_ff = self.d_ff
            if self.moe is not None and self.moe.dense_d_ff:
                d_ff = self.moe.dense_d_ff
            n += self._ffn_params(d_ff)
        return n

    def _encoder_block_params(self) -> int:
        d = self.d_model
        # MHA + (decoder adds cross-attn, counted in block for enc-dec decoders)
        return 2 * d + 4 * d * d + self._ffn_params(self.d_ff)


# ---------------------------------------------------------------------------
# Shapes (the assigned input-shape cells)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Parallelism / run config
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ParallelConfig:
    """How logical axes map onto the mesh, plus memory policies."""
    fsdp: bool = True               # shard weights over the data axis, gather on use
    seq_parallel: bool = True       # shard boundary activations' seq over model axis
    expert_parallel: bool = True    # shard experts over model axis
    remat: str = "block"            # none | block | full
    microbatches: int = 1           # gradient accumulation splits
    grad_compress: bool = False     # int8 + error feedback on cross-data reduction
    vocab_chunking: int = 0         # compute logits/loss in N seq chunks (0=off)
    scan_layers: bool = True        # lax.scan over homogeneous layer stacks
    unroll_time_scans: bool = False  # unroll chunked rwkv/mamba time scans
                                     # (dry-run cost accounting; see dryrun.py)
    attn_q_chunk: int = 0           # >0: flash-style blocked attention with
    attn_kv_block: int = 1024       #   this q-chunk size (§Perf hillclimb)
    moe_constraints: bool = False   # EP sharding constraints inside MoE dispatch
    moe_gather_dispatch: bool = False  # gather-only MoE dispatch (§Perf)
    state_constraints: bool = False  # shard SSM scan tensors (batch x model)


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    parallel: ParallelConfig = field(default_factory=ParallelConfig)
    optimizer: str = "adamw"        # sgd | adam | adamw | adafactor
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    if cfg.arch_id in _REGISTRY:
        raise ValueError(f"duplicate arch id {cfg.arch_id}")
    _REGISTRY[cfg.arch_id] = cfg
    return cfg


def get_config(arch_id: str) -> ModelConfig:
    _ensure_loaded()
    if arch_id not in _REGISTRY:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[arch_id]


def list_archs() -> list[str]:
    _ensure_loaded()
    return sorted(_REGISTRY)


_LOADED = False

ASSIGNED_ARCHS = [
    "rwkv6-7b", "llama3-8b", "starcoder2-15b", "llama3.2-1b", "phi3-medium-14b",
    "whisper-large-v3", "jamba-v0.1-52b", "llama4-maverick-400b-a17b",
    "deepseek-v2-lite-16b", "llava-next-mistral-7b",
]


def _ensure_loaded() -> None:
    """Import every config module once so registration side-effects run."""
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from repro.configs import (  # noqa: F401
        rwkv6_7b, llama3_8b, starcoder2_15b, llama3_2_1b, phi3_medium_14b,
        whisper_large_v3, jamba_v0_1_52b, llama4_maverick_400b_a17b,
        deepseek_v2_lite_16b, llava_next_mistral_7b, impulse_snn,
    )


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Shrink a config to smoke-test size, preserving the family structure
    (one full super-block period: the interleave pattern survives)."""
    import math
    period = cfg.attn_layer_period
    if cfg.moe is not None and cfg.moe.n_experts:
        period = math.lcm(period, cfg.moe.every)
    first_dense = cfg.moe.first_k_dense if cfg.moe is not None else 0
    kw: dict = dict(
        n_layers=max(period, 2) + first_dense,
        d_model=128,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads < cfg.n_heads else 4,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            n_experts=min(cfg.moe.n_experts, 4),
            top_k=min(cfg.moe.top_k, 2),
            d_ff=64 if cfg.moe.d_ff else 0,
            dense_d_ff=256 if cfg.moe.dense_d_ff else 0,
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(kv_lora_rank=32, q_lora_rank=0,
                              rope_head_dim=16, nope_head_dim=32, v_head_dim=32)
        kw["head_dim"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=8, d_conv=4, expand=2, dt_rank=16)
    if cfg.rwkv is not None:
        kw["rwkv"] = RWKVConfig(head_size=32)
        kw["n_heads"] = 128 // 32
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = 2
    return dataclasses.replace(cfg, arch_id=cfg.arch_id + "-smoke", **kw)
