"""Phi-3 Medium 14B — dense GQA (kv=10), RoPE, SwiGLU. [arXiv:2404.14219]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="phi3-medium-14b",
    family="dense",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=10,
    head_dim=128,
    d_ff=17920,
    vocab_size=100352,
    rope_theta=10000.0,
    notes="long_500k skipped: pure full attention",
))
