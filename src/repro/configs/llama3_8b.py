"""Llama-3 8B — dense GQA decoder, 128k vocab. [arXiv:2407.21783]"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="llama3-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=128256,
    rope_theta=500000.0,
    notes="long_500k skipped: pure full attention",
))
