"""Whisper large-v3 — encoder-decoder audio backbone. [arXiv:2212.04356]

Per the assignment, only the transformer BACKBONE is modeled; the conv/mel
frontend is a STUB — ``input_specs()`` supplies precomputed frame embeddings
of shape (batch, seq_len, d_model).

Shape semantics for enc-dec (documented in EXPERIMENTS.md):
  train_4k    — encoder over seq_len frames + teacher-forced decoder over seq_len tokens
  prefill_32k — encoder over seq_len frames + decoder prefill over seq_len//8 tokens
  decode_32k  — one decoder token: self-cache = seq_len, cross-cache = seq_len frames
  long_500k   — SKIP (full attention)
"""
from repro.configs.base import ModelConfig, register

CONFIG = register(ModelConfig(
    arch_id="whisper-large-v3",
    family="audio",
    n_layers=32,               # decoder layers
    n_encoder_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,             # MHA
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    is_encoder_decoder=True,
    frontend="audio_stub",
    ffn_type="gelu",
    tie_embeddings=True,
    rope_theta=0.0,            # sinusoidal absolute positions, no rope
    notes="enc-dec; frontend stubbed; long_500k skipped: full attention",
))
