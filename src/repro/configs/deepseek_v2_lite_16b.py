"""DeepSeek-V2-Lite 16B — MLA (kv_lora=512) + MoE 64 routed top-6 + 2 shared.
[arXiv:2405.04434; hf]

Assignment-header discrepancy note: the header says "MoE 64e top-6" while the
tail mentions "160 routed" (that is full V2, not Lite). We implement the
published V2-Lite: 27L, d=2048, 16 MLA heads, kv_lora_rank=512, rope_head=64,
nope_head=128, v_head=128, first layer dense (d_ff=10944), remaining 26 layers
MoE with 64 routed (top-6) + 2 shared experts, expert d_ff=1408.

MLA's compressed kv cache is the low-rank membrane analogue: decode reads a
(seq, 512+64) latent cache instead of per-head K/V.
"""
from repro.configs.base import ModelConfig, MLAConfig, MoEConfig, register

CONFIG = register(ModelConfig(
    arch_id="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=192,                # nope(128) + rope(64) query head dim
    d_ff=1408,
    vocab_size=102400,
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=0,
                  rope_head_dim=64, nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(n_experts=64, top_k=6, n_shared_experts=2, d_ff=1408,
                  every=1, first_k_dense=1, dense_d_ff=10944),
    notes="MLA compressed cache; long_500k skipped (full attention)",
))
