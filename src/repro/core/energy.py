"""Instruction-level energy / delay / EDP model, calibrated to the silicon.

Calibration sources (all from the paper):
  * Per-instruction efficiency at point D (0.85 V / 200 MHz), 1 op = one
    11-bit instruction-cycle: AccW2V 0.99, AccV2V 1.18, ResetV 1.02,
    SpikeCheck 1.22 TOPS/W  ->  E/cycle = 1 / (TOPS/W) pJ.
  * Cross-check (validated in tests): the Fig. 6 neuron-update energies are
    reproduced by summing the sequence cycles: IF = SpikeCheck+ResetV =
    0.820+0.980 = 1.80 pJ (paper 1.81), LIF = 2.65 (2.67), RMP = 1.67 (1.68).
  * Table I operating points: (0.7 V, 66.67 MHz, 0.072 mW, 0.91 TOPS/W),
    (0.85 V, 200 MHz, 0.201 mW, 0.99), (1.2 V, 500 MHz, 0.88 mW, 0.57).
  * Area 0.089 mm^2, 54.2 % memory area efficiency, 65 nm.

The EDP-vs-sparsity curve (Fig. 11b) falls out analytically: per timestep a
macro executes 2*(1-s)*128 AccW2V cycles plus the neuron-update sequence, so
EDP(s)/EDP(0) = ((2*(1-s)*128 + u) / (2*128 + u))^2 with u the update cycles —
97.3 % reduction at s = 0.85 for RMP (paper: ~97.4 %).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.core.isa import MACRO_IN, MACRO_OUT, InstrCount

PJ = 1e-12


@dataclass(frozen=True)
class OperatingPoint:
    name: str
    vdd: float
    freq_hz: float
    power_w: float                  # measured average power, AccW2V
    accw2v_tops_w: float            # measured efficiency at this point


POINT_A = OperatingPoint("A(0.7V)", 0.70, 66.67e6, 0.072e-3, 0.91)
POINT_D = OperatingPoint("D(0.85V)", 0.85, 200e6, 0.201e-3, 0.99)
POINT_G = OperatingPoint("G(1.2V)", 1.20, 500e6, 0.88e-3, 0.57)
OPERATING_POINTS = (POINT_A, POINT_D, POINT_G)

# Per-instruction TOPS/W at point D (1 op = 1 cycle = one 11-bit instruction).
TOPS_W_D = {
    "acc_w2v": 0.99,
    "acc_v2v": 1.18,
    "reset_v": 1.02,
    "spike_check": 1.22,
}

AREA_MM2 = 0.089
MEM_AREA_EFFICIENCY = 0.542
TECH_NM = 65


def instr_energy_j(instr: str, point: OperatingPoint = POINT_D) -> float:
    """Energy per executed cycle of one instruction type, in joules."""
    e_at_d = PJ / TOPS_W_D[instr]
    # scale by the AccW2V efficiency ratio (relative instruction costs are
    # circuit-topology constants; supply/frequency scales them together)
    return e_at_d * (POINT_D.accw2v_tops_w / point.accw2v_tops_w)


def sequence_energy_j(counts: InstrCount, point: OperatingPoint = POINT_D) -> float:
    names = ("acc_w2v", "acc_v2v", "spike_check", "reset_v")
    return float(sum(getattr(counts, n) * instr_energy_j(n, point) for n in names))


def sequence_delay_s(counts: InstrCount, point: OperatingPoint = POINT_D) -> float:
    return counts.total / point.freq_hz


def sequence_edp(counts: InstrCount, point: OperatingPoint = POINT_D) -> float:
    return sequence_energy_j(counts, point) * sequence_delay_s(counts, point)


# Fig. 6 instruction sequences, one cycle per listed instruction (the paper's
# "energy/update" accounting; a full 12-neuron odd+even set update is 2x this).
NEURON_SEQ_COUNTS = {
    "if": InstrCount(spike_check=1, reset_v=1),
    "lif": InstrCount(acc_v2v=1, spike_check=1, reset_v=1),
    "rmp": InstrCount(spike_check=1, acc_v2v=1),
}
NEURON_UPDATE_COUNTS = {k: InstrCount(*(2 * x for x in v))
                        for k, v in NEURON_SEQ_COUNTS.items()}


def neuron_update_energy_pj(neuron: str, point: OperatingPoint = POINT_D) -> float:
    """Fig. 6 'Energy/update' numbers (pJ)."""
    return sequence_energy_j(NEURON_SEQ_COUNTS[neuron], point) / PJ


def timestep_counts(sparsity: float, neuron: str = "rmp", n_in: int = MACRO_IN) -> InstrCount:
    """Instruction cycles for one macro-timestep at a given input sparsity
    (0 -> all 128 input rows spike; 1 -> none)."""
    events = (1.0 - sparsity) * n_in
    acc = int(round(2 * events))                   # odd + even cycle per event
    upd = NEURON_UPDATE_COUNTS[neuron]
    return InstrCount(acc_w2v=acc) + upd


def edp_per_neuron_per_timestep(sparsity: float, neuron: str = "rmp",
                                point: OperatingPoint = POINT_D) -> float:
    """Fig. 11b: measured EDP per-neuron per-timestep vs sparsity."""
    c = timestep_counts(sparsity, neuron)
    return sequence_edp(c, point) / MACRO_OUT


def edp_reduction(sparsity: float, neuron: str = "rmp",
                  point: OperatingPoint = POINT_D) -> float:
    """Fractional EDP reduction vs the zero-sparsity case (paper: 0.974 @ 0.85)."""
    return 1.0 - edp_per_neuron_per_timestep(sparsity, neuron, point) \
               / edp_per_neuron_per_timestep(0.0, neuron, point)


def measured_edp(counts: InstrCount, point: OperatingPoint = POINT_D) -> float:
    """EDP of a *measured* instruction tally (J*s): the event-driven
    counterpart of the analytic Fig. 11b curve. The counts come from the
    execution pipeline (rasters or a `pipeline.SparsityReport`), so the EDP
    reflects the sparsity the workload actually exhibited rather than a
    swept parameter."""
    return sequence_edp(counts, point)


def measured_edp_per_neuron_timestep(counts: InstrCount, macro_timesteps: int,
                                     point: OperatingPoint = POINT_D) -> float:
    """Normalize a measured tally to the Fig. 11b axis: average instruction
    cycles per macro-timestep (``macro_timesteps`` =
    `SparsityReport.macro_timesteps`; conv layers contribute one macro-
    timestep per (timestep, example, output position) frame — the im2col
    lowering re-uses the grid per position), then EDP per neuron — directly
    comparable to `edp_per_neuron_per_timestep(s)` at the measured
    sparsity. Fractional average counts are fine: the energy/delay sums are
    linear in the per-instruction counts."""
    if macro_timesteps <= 0:
        raise ValueError("macro_timesteps must be positive")
    avg = InstrCount(*(c / macro_timesteps for c in counts))
    return sequence_edp(avg, point) / MACRO_OUT


def measured_edp_reduction(executed: InstrCount, skipped: InstrCount,
                           point: OperatingPoint = POINT_D) -> float:
    """Fractional EDP reduction a measured workload realized through
    event-driven skipping, at row granularity: ``executed`` is the tally
    the pipeline counted (`SparsityReport.instruction_counts`), ``skipped``
    the silent-row AccW2V cycles it never issued
    (`SparsityReport.skipped_instruction_counts` /
    `isa.count_skipped_instructions_from_events`). Their sum is the dense
    zero-sparsity tally, so this is the measured counterpart of
    `edp_reduction(s)` — Fig. 11b from executed event counts rather than a
    swept parameter, and tracking *row* skips (what the silicon skips)
    rather than tile-gate statistics."""
    dense = executed + skipped
    if dense.total == 0:
        raise ValueError("empty instruction tally (executed + skipped == 0)")
    return 1.0 - sequence_edp(executed, point) / sequence_edp(dense, point)


def tops_per_watt(point: OperatingPoint) -> float:
    """Throughput/power for AccW2V (1 op/cycle), Table I row."""
    return point.accw2v_tops_w


def gops_per_mm2(point: OperatingPoint) -> float:
    """Performance/Area, Table I row: 1 op per cycle over the macro area."""
    return point.freq_hz / 1e9 / AREA_MM2


def snn_energy_j(counts: InstrCount, point: OperatingPoint = POINT_D) -> float:
    """Total energy for an instruction-count tally of a full SNN inference."""
    return sequence_energy_j(counts, point)


def energy_per_inference_j(counts: InstrCount, batch: int,
                           point: OperatingPoint = POINT_D) -> float:
    """Per-example energy of an executed workload tally (counts measured
    over ``batch`` examples by `pipeline.count_network_instructions` — for
    conv programs these come from execution of the im2col-lowered program,
    not the analytic pass alone)."""
    if batch <= 0:
        raise ValueError("batch must be positive")
    return sequence_energy_j(counts, point) / batch
