"""IMPULSE in-memory instruction set — functional (integer) semantics.

This layer defines the four macro instructions at *word level* (int32 math,
11-bit clamped), vectorizable over batch. It is the contract between:

  * macro.py  -- the bit-accurate column/bitline model (validated to match
                 this layer instruction-for-instruction), and
  * snn.py / kernels/fused_snn_step -- the training & TPU fast paths
                 (validated to match this layer end-to-end).

Macro geometry (the fabricated 65nm instance):
  W_MEM: 128 rows x 12 six-bit signed weights  (one row per input neuron)
  V_MEM: 32 rows x 6 twelve-bit slots; a neuron set (12 neurons) spans 2
         staggered rows (odd-parity slots + even-parity slots). 6 constant
         rows (threshold/reset/leak, odd+even each) leave 13 neuron sets.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.quant import clamp_v, spike_compare

MACRO_IN = 128          # input rows
MACRO_OUT = 12          # weights (output neurons) per row
V_ROWS = 32
V_SLOTS_PER_ROW = 6
N_CONST_ROWS = 6        # threshold_o/e, reset_o/e, leak_o/e
N_NEURON_SETS = (V_ROWS - N_CONST_ROWS) // 2    # 13


class InstrCount(NamedTuple):
    """Executed-cycle counts per instruction type (energy model input)."""
    acc_w2v: int = 0
    acc_v2v: int = 0
    spike_check: int = 0
    reset_v: int = 0

    def __add__(self, o: "InstrCount") -> "InstrCount":
        return InstrCount(*(a + b for a, b in zip(self, o)))

    @property
    def total(self) -> int:
        return sum(self)


@dataclass
class MacroState:
    """Logical state of one macro (word-level)."""
    wmem: jax.Array                       # (128, 12) int8 in [-31, 31]
    vmem: jax.Array                       # (N_SETS, 12) int32, 11-bit clamped
    threshold: jax.Array                  # (12,) int32 (stored negated on-chip)
    reset: jax.Array                      # (12,) int32
    leak: jax.Array                       # (12,) int32 (stored negated on-chip)
    spike_buf: jax.Array                  # (N_SETS, 12) bool
    clamp_mode: str = "saturate"


def make_state(wq: np.ndarray, threshold: int, reset: int = 0, leak: int = 0,
               clamp_mode: str = "saturate") -> MacroState:
    if wq.shape != (MACRO_IN, MACRO_OUT):
        raise ValueError(f"macro weight tile must be "
                         f"{(MACRO_IN, MACRO_OUT)}, got {wq.shape}")
    return MacroState(
        wmem=jnp.asarray(wq, jnp.int8),
        vmem=jnp.zeros((N_NEURON_SETS, MACRO_OUT), jnp.int32),
        threshold=jnp.full((MACRO_OUT,), threshold, jnp.int32),
        reset=jnp.full((MACRO_OUT,), reset, jnp.int32),
        leak=jnp.full((MACRO_OUT,), leak, jnp.int32),
        spike_buf=jnp.zeros((N_NEURON_SETS, MACRO_OUT), bool),
        clamp_mode=clamp_mode,
    )


# ---------------------------------------------------------------------------
# Instructions. ``cycle``: 0 = odd (even-indexed weight groups), 1 = even.
# Each call models ONE executed macro cycle.
# ---------------------------------------------------------------------------

def _parity_mask(cycle: int) -> np.ndarray:
    m = np.zeros(MACRO_OUT, bool)
    m[cycle::2] = True
    return m


def acc_w2v(st: MacroState, set_idx: int, in_row, cycle: int) -> MacroState:
    """V[set, parity] += W[in_row, parity]  (triple-row decode: RWLo/e + V RWL + WWL)."""
    mask = jnp.asarray(_parity_mask(cycle))
    w = st.wmem[in_row].astype(jnp.int32)
    v = st.vmem[set_idx]
    v = jnp.where(mask, clamp_v(v + w, st.clamp_mode), v)
    return replace(st, vmem=st.vmem.at[set_idx].set(v))


def acc_v2v(st: MacroState, set_idx: int, add: jax.Array, cycle: int,
            conditional: bool = False) -> MacroState:
    """V[set, parity] += add[parity]; optionally gated by the spike buffers
    (conditional write drivers), e.g. RMP soft reset."""
    mask = jnp.asarray(_parity_mask(cycle))
    if conditional:
        mask = mask & st.spike_buf[set_idx]
    v = st.vmem[set_idx]
    v = jnp.where(mask, clamp_v(v + add.astype(jnp.int32), st.clamp_mode), v)
    return replace(st, vmem=st.vmem.at[set_idx].set(v))


def spike_check(st: MacroState, set_idx: int, cycle: int) -> MacroState:
    """Compare V against threshold (adder-as-comparator; MSB carry-out).
    Latches spike buffers for the parity's neurons. Read-only on V. In
    ``wrap`` clamp mode the comparison itself wraps (quant.spike_compare):
    the silicon evaluates v + (-th) on the 11-bit adder."""
    mask = jnp.asarray(_parity_mask(cycle))
    fired = spike_compare(st.vmem[set_idx], st.threshold, st.clamp_mode)
    buf = jnp.where(mask, fired, st.spike_buf[set_idx])
    return replace(st, spike_buf=st.spike_buf.at[set_idx].set(buf))


def reset_v(st: MacroState, set_idx: int, cycle: int) -> MacroState:
    """Conditionally (per spike buffer) rewrite V from the reset row. The BLFA
    is bypassed; SINV -> CWD direct transfer."""
    mask = jnp.asarray(_parity_mask(cycle)) & st.spike_buf[set_idx]
    v = jnp.where(mask, st.reset, st.vmem[set_idx])
    return replace(st, vmem=st.vmem.at[set_idx].set(v))


# ---------------------------------------------------------------------------
# Neuron-update sequences (Fig. 6) and the per-timestep program.
# ---------------------------------------------------------------------------

def neuron_update(st: MacroState, set_idx: int, neuron: str) -> tuple[MacroState, jax.Array, InstrCount]:
    """End-of-timestep neuron update for both parities. Returns spikes (12,)."""
    cnt = InstrCount()
    if neuron == "lif":
        for c in (0, 1):
            st = acc_v2v(st, set_idx, -st.leak, c)
        cnt += InstrCount(acc_v2v=2)
    for c in (0, 1):
        st = spike_check(st, set_idx, c)
    cnt += InstrCount(spike_check=2)
    if neuron == "rmp":                            # soft reset: AccV2V(-th), gated
        for c in (0, 1):
            st = acc_v2v(st, set_idx, -st.threshold, c, conditional=True)
        cnt += InstrCount(acc_v2v=2)
    elif neuron in ("if", "lif"):
        for c in (0, 1):
            st = reset_v(st, set_idx, c)
        cnt += InstrCount(reset_v=2)
    else:
        raise ValueError(neuron)
    return st, st.spike_buf[set_idx], cnt


def timestep(st: MacroState, set_idx: int, in_spikes, neuron: str
             ) -> tuple[MacroState, jax.Array, InstrCount]:
    """One SNN timestep on one macro: event-driven AccW2V per spiking input
    row (odd+even cycles), then the neuron-update sequence.

    ``in_spikes``: (128,) bool host array — the *event list*; only spiking rows
    issue instructions (this is the sparsity → energy mechanism, Fig. 11).
    """
    in_spikes = np.asarray(in_spikes).astype(bool)
    rows = np.nonzero(in_spikes)[0]
    for r in rows:
        st = acc_w2v(st, set_idx, int(r), cycle=0)
        st = acc_w2v(st, set_idx, int(r), cycle=1)
    cnt = InstrCount(acc_w2v=2 * len(rows))
    st, spikes, c2 = neuron_update(st, set_idx, neuron)
    return st, spikes, cnt + c2


# ---------------------------------------------------------------------------
# Vectorized reference of the same semantics (jit-able; used as the oracle
# for snn.py / the Pallas kernel). Processes a whole layer tile at once.
# ---------------------------------------------------------------------------

def neuron_dynamics_int(v: jax.Array, *, neuron: str, threshold: jax.Array,
                        leak: jax.Array, reset: jax.Array,
                        clamp_mode: str = "saturate"
                        ) -> tuple[jax.Array, jax.Array]:
    """The post-accumulation half of a timestep: leak / SpikeCheck / reset on
    an already-accumulated (and clamped) V. Split out from
    `layer_timestep_int` so event-gated executors can skip the AccW2V matmul
    for all-silent inputs while still running the neuron update every
    timestep (LIF leaks and RMP can re-fire with zero input — the update
    sequence is unconditional on silicon too, Fig. 6)."""
    if neuron == "lif":
        v = clamp_v(v - leak, clamp_mode)
    s = spike_compare(v, threshold, clamp_mode)
    if neuron == "rmp":
        v = clamp_v(jnp.where(s, v - threshold, v), clamp_mode)
    else:
        v = jnp.where(s, reset, v)
    return v, s.astype(jnp.int32)


def layer_timestep_int(v: jax.Array, wq: jax.Array, in_spikes: jax.Array, *,
                       neuron: str, threshold: jax.Array, leak: jax.Array,
                       reset: jax.Array, clamp_mode: str = "saturate"
                       ) -> tuple[jax.Array, jax.Array]:
    """Batched integer timestep: v (..., n_out) int32, wq (n_in, n_out) int8,
    in_spikes (..., n_in) {0,1}. Mathematically == issuing `timestep` per macro
    tile (tested). Returns (v', out_spikes)."""
    acc = jnp.matmul(in_spikes.astype(jnp.int32), wq.astype(jnp.int32))
    v = clamp_v(v + acc, clamp_mode)
    return neuron_dynamics_int(v, neuron=neuron, threshold=threshold,
                               leak=leak, reset=reset, clamp_mode=clamp_mode)


def conv_layer_timestep_int(v: jax.Array, wq: jax.Array, in_spikes: jax.Array,
                            *, stride: int, neuron: str, threshold: jax.Array,
                            leak: jax.Array, reset: jax.Array,
                            clamp_mode: str = "saturate"
                            ) -> tuple[jax.Array, jax.Array]:
    """Batched integer conv timestep — the word-level semantics of one conv
    layer on the macro grid. v (B, H_out, W_out, c_out) int32; wq the HWIO
    int8 kernel (k, k, c_in, c_out); in_spikes (B, H, W, c_in) {0,1}.

    Lowered via im2col over the 128-row fan-in rule (mapping.im2col): every
    output position is an independent frame whose k*k*c_in patch vector
    drives `layer_timestep_int` on the packed (k*k*c_in, c_out) weight block
    — each position re-uses the same macro grid (mapping.conv_tiling), with
    its own V_MEM neuron set. Returns (v', out_spikes), both
    (B, H_out, W_out, c_out)."""
    from repro.core import mapping
    patches = mapping.im2col(in_spikes, wq.shape[0], stride)
    return layer_timestep_int(v, mapping.pack_conv_weights(wq), patches,
                              neuron=neuron, threshold=threshold, leak=leak,
                              reset=reset, clamp_mode=clamp_mode)


def count_layer_instructions_from_events(total_events: int, batch_t: int,
                                         n_in: int, n_out: int, neuron: str
                                         ) -> InstrCount:
    """Instruction cycles for a (n_in -> n_out) FC layer given only the
    aggregate event statistics: ``total_events`` input spikes over
    ``batch_t`` (timestep, example) frames. This is the raster-free entry
    point used by `pipeline.SparsityReport` (occupancy summaries carry the
    same information the counter needs); `count_layer_instructions`
    delegates here, so both paths agree by construction. Includes
    multi-macro tiling (mapping.py geometry: row tiles add AccV2V
    partial-sum reductions).
    """
    from repro.core import mapping
    tiles = mapping.fc_tiling(n_in, n_out)
    # AccW2V: each event hits every column tile, odd+even cycles
    n_acc_w = 2 * int(total_events) * tiles.col_tiles
    # partial-sum reduction: (row_tiles-1) AccV2V per set per parity per timestep
    n_red = 2 * (tiles.row_tiles - 1) * tiles.col_tiles * batch_t
    cnt = InstrCount(acc_w2v=n_acc_w, acc_v2v=n_red)
    # neuron update on the reduced set ("none" = accumulate-only readout layer)
    per_update = {"if": InstrCount(spike_check=2, reset_v=2),
                  "lif": InstrCount(acc_v2v=2, spike_check=2, reset_v=2),
                  "rmp": InstrCount(spike_check=2, acc_v2v=2),
                  "none": InstrCount()}[neuron]
    upd = InstrCount(*(x * tiles.col_tiles * batch_t for x in per_update))
    return cnt + upd


def count_skipped_instructions_from_events(total_events: int, batch_t: int,
                                           n_in: int, n_out: int
                                           ) -> InstrCount:
    """Instruction cycles event-driven execution *never issues* for a
    (n_in -> n_out) FC layer: every silent (frame, input-row) pair would
    have cost 2 AccW2V cycles per column tile on a dense scan. This is the
    row-granular skip model of Fig. 11b — the complement of
    `count_layer_instructions_from_events`, so

        executed + skipped == the dense tally at sparsity 0

    holds exactly (neuron-update and AccV2V-reduction cycles are
    unconditional and appear only on the executed side)."""
    from repro.core import mapping
    silent = batch_t * n_in - int(total_events)
    if silent < 0:
        raise ValueError(f"event count {total_events} exceeds the "
                         f"{batch_t * n_in} (frame, row) sites of a "
                         f"{n_in}->{n_out} layer over {batch_t} frames")
    tiles = mapping.fc_tiling(n_in, n_out)
    return InstrCount(acc_w2v=2 * silent * tiles.col_tiles)


def count_layer_instructions(spike_raster: np.ndarray, n_in: int, n_out: int,
                             neuron: str) -> InstrCount:
    """Instruction cycles to run a (n_in -> n_out) FC layer for a spike raster
    of shape (T, ..., n_in). See `count_layer_instructions_from_events`.
    """
    spikes_per_t = np.asarray(spike_raster).reshape(spike_raster.shape[0], -1, n_in)
    total_events = int(spikes_per_t.sum())
    batch_t = spikes_per_t.shape[0] * spikes_per_t.shape[1]
    return count_layer_instructions_from_events(total_events, batch_t,
                                                n_in, n_out, neuron)
