"""Compiled network-level SNN programs with pluggable execution backends.

IMPULSE's architectural claim is *fusion*: W_MEM and V_MEM share one array so
the membrane state never crosses a memory boundary. Before this module, that
fusion was only realized per layer, and the network loop around it was
re-implemented four times (float training, integer ISA, per-layer Pallas,
bit-level macro). `compile_network` lifts the network itself into a first-
class object — an `SNNProgram` describing the full stack (encoder -> spiking
FCs -> accumulate readout, thresholds/leaks/scales, multi-macro tiling) —
executed by a registry of backends that are tested to agree bit-for-bit:

  float    — QAT training semantics (surrogate gradients, fake-quant
             weights). For integer-domain programs it executes the *same*
             integer program in f32 (exact: all values < 2^24), which is the
             equivalence bridge between training and deployment.
  int_ref  — word-level ISA semantics (isa.layer_timestep_int scanned over
             the network), the functional contract of the silicon.
  pallas   — the network-level fused TPU kernel (kernels/fused_snn_net):
             every layer's V tile lives in VMEM scratch across the entire
             timestep loop and inter-layer spikes never touch HBM — the
             network-scale analogue of the macro's fused array.
  bitmacro — the bit-accurate column/bitline model (silicon oracle; small
             shapes, wrap arithmetic only, as on silicon).

Instruction counting is a *program-level pass* (`count_network_instructions`)
over the spike rasters, so every backend reports identical energy-model
inputs by construction.

See DESIGN.md §3 for the pipeline/backends diagram and the VMEM-residency
argument.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.impulse_snn import SNNModelConfig
from repro.core import isa, mapping
from repro.core.neuron import NeuronState, neuron_step
from repro.core.quant import (clamp_v, fake_quant_w, quantize_const,
                              quantize_w, spike_compare)

# ---------------------------------------------------------------------------
# Program representation
# ---------------------------------------------------------------------------

# Layer kinds:
#   encoder — off-macro neuron layer over raw input current (identity weight)
#   conv    — conv transform + neuron dynamics (float backend only)
#   fc      — spiking FC layer (on-macro)
#   readout — accumulate-only FC (prediction = final V_MEM)
LAYER_KINDS = ("encoder", "conv", "fc", "readout")


@dataclass(frozen=True)
class LayerSpec:
    kind: str
    n_in: int
    n_out: int
    w: Any = None                 # float weights | int8 wq (program.domain)
    threshold: Any = None         # float | int on the layer's fixed-point grid
    leak: Any = None
    scale: Any = None             # int domain: float <-> grid scale
    stride: int = 1               # conv only
    quantize: bool = True         # float domain: fake-quant this layer's w
    state_shape: tuple = ()       # per-example V shape (set at compile)

    @property
    def tiling(self) -> mapping.FCTiling:
        return mapping.fc_tiling(self.n_in, self.n_out)


@dataclass(frozen=True)
class SNNProgram:
    cfg: Optional[SNNModelConfig]
    domain: str                   # "float" (QAT training) | "int" (deployed)
    neuron: str                   # if | lif | rmp
    timesteps: int                # presentation steps per input frame
    layers: tuple                 # tuple[LayerSpec, ...]
    clamp_mode: str = "saturate"  # int domain V_MEM policy (see quant.clamp_v)
    quantize: bool = True         # float domain: QAT fake-quant on

    @property
    def fc_stack(self) -> tuple:
        """The on-macro part: spiking FCs + readout."""
        return tuple(l for l in self.layers if l.kind in ("fc", "readout"))

    @property
    def neuron_layers(self) -> tuple:
        """Layers with membrane dynamics that emit spikes."""
        return tuple(l for l in self.layers if l.kind != "readout")

    def logits(self, v_out: jax.Array) -> jax.Array:
        """Readout V -> float logits (undo the last layer's weight scale)."""
        if self.domain == "int":
            return v_out.astype(jnp.float32) * self.layers[-1].scale
        return v_out


@dataclass
class NetResult:
    """What one backend run produces. ``rasters[i]`` is the *input* spike
    raster of fc-stack layer i (so rasters[0] is the encoder output), each
    (T_total, B, n); ``v_final`` lists final V per layer, readout last."""
    v_out: jax.Array
    logits: jax.Array
    v_final: list
    rasters: Optional[list] = None
    aux: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def conv2d(x: jax.Array, w: jax.Array, stride: int) -> jax.Array:
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_state_shapes(cfg: SNNModelConfig, convs: list) -> list:
    x = jnp.zeros((1, *cfg.in_shape))
    shapes = []
    for c, (_, _, stride) in zip(convs, cfg.conv_spec):
        x = jax.eval_shape(lambda a, w, s=stride: conv2d(a, w, s), x, c["w"])
        shapes.append(tuple(x.shape[1:]))
        x = jnp.zeros(x.shape, x.dtype)
    return shapes


def compile_network(cfg: SNNModelConfig, params: dict, *, domain: str = "float",
                    clamp_mode: str = "saturate", quantize: bool = True
                    ) -> SNNProgram:
    """Lower (cfg, params) to an executable network program.

    ``domain="float"`` keeps the trainable parameterization (softplus'd
    thresholds/leaks, fake-quant weights) — differentiable, used for QAT.
    ``domain="int"`` quantizes every on-macro layer onto its 6b/11b grid
    (the deployed macro program); the encoder stays float (off-macro input
    layer, as in the paper).
    """
    th = jax.nn.softplus(params["threshold"]) + 1e-3
    lk = jax.nn.softplus(params["leak"]) * 0.1
    layers: list[LayerSpec] = []
    k = 0                                         # neuron-layer index into th/lk

    convs = params.get("convs", [])
    if convs:
        if domain == "int":
            raise NotImplementedError("conv stacks compile float-only (the "
                                      "int conv mapping is a later PR)")
        shapes = _conv_state_shapes(cfg, convs)
        c_in = cfg.in_shape[-1]
        for i, (c, shape) in enumerate(zip(convs, shapes)):
            kh, kw = c["w"].shape[:2]
            layers.append(LayerSpec(
                kind="conv", n_in=kh * kw * c_in,
                n_out=shape[-1], w=c["w"], threshold=th[k], leak=lk[k],
                stride=cfg.conv_spec[i][2], quantize=(i > 0),
                state_shape=shape))
            c_in = shape[-1]
            k += 1
    else:
        # word/current encoder: identity weight, neuron dynamics
        d_in = cfg.layer_sizes[0]
        layers.append(LayerSpec(kind="encoder", n_in=d_in, n_out=d_in,
                                threshold=th[k], leak=lk[k],
                                state_shape=(d_in,)))
        k += 1

    sizes = cfg.layer_sizes
    fc_ws = params["layers"]
    for j, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        is_readout = j == len(fc_ws) - 1
        w = fc_ws[j]["w"]
        if domain == "int":
            wq, scale = quantize_w(w)
            th_i = None if is_readout else jnp.int32(
                quantize_const(float(th[k]), scale))
            lk_i = None if is_readout else jnp.int32(
                quantize_const(float(lk[k]), scale))
            layers.append(LayerSpec(
                kind="readout" if is_readout else "fc", n_in=n_in, n_out=n_out,
                w=wq, threshold=th_i, leak=lk_i, scale=float(scale),
                state_shape=(n_out,)))
        else:
            layers.append(LayerSpec(
                kind="readout" if is_readout else "fc", n_in=n_in, n_out=n_out,
                w=w, threshold=None if is_readout else th[k],
                leak=None if is_readout else lk[k], state_shape=(n_out,)))
        if not is_readout:
            k += 1

    return SNNProgram(cfg=cfg, domain=domain, neuron=cfg.spiking.neuron,
                      timesteps=cfg.timesteps, layers=tuple(layers),
                      clamp_mode=clamp_mode, quantize=quantize)


def rate_coded_program(spiking_cfg, state_shape: tuple) -> SNNProgram:
    """Single-population program (used by models/spiking_ffn): one encoder
    layer integrating a constant current, thresholds/leaks taken verbatim
    (no softplus re-parameterization)."""
    layer = LayerSpec(kind="encoder", n_in=state_shape[-1],
                      n_out=state_shape[-1], threshold=spiking_cfg.threshold,
                      leak=spiking_cfg.leak, state_shape=state_shape)
    return SNNProgram(cfg=None, domain="float", neuron=spiking_cfg.neuron,
                      timesteps=spiking_cfg.timesteps, layers=(layer,),
                      quantize=False)


# ---------------------------------------------------------------------------
# Input presentation
# ---------------------------------------------------------------------------

def present_words(x_words: jax.Array, timesteps: int) -> jax.Array:
    """(B, n_words, d) -> (n_words * T, B, d): each word held T steps
    (membrane state persists across words — the sequential-memory claim)."""
    xs = jnp.repeat(x_words, timesteps, axis=1)
    return jnp.moveaxis(xs, 1, 0)


def present_static(x: jax.Array, timesteps: int) -> jax.Array:
    """(B, ...) -> (T, B, ...): direct encoding, same frame every step."""
    return jnp.broadcast_to(x[None], (timesteps, *x.shape))


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

BACKENDS: dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    def deco(fn: Callable) -> Callable:
        BACKENDS[name] = fn
        return fn
    return deco


def run_network(program: SNNProgram, xs: jax.Array, backend: str = "float",
                **kw) -> NetResult:
    """Execute a program on per-timestep input currents xs (T_total, B, ...).

    The float backend consumes xs directly. Integer backends share one float
    encoder pass (`encode`) — the off-macro input layer — then execute the
    on-macro fc stack in their own substrate.
    """
    if backend not in BACKENDS:
        raise KeyError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
    if backend != "float" and program.domain != "int":
        raise ValueError(f"backend {backend!r} needs an int-domain program "
                         "(compile_network(..., domain='int'))")
    return BACKENDS[backend](program, xs, **kw)


# ---------------------------------------------------------------------------
# float backend — the single temporal executor for training AND the f32
# rendering of integer programs (exact: every value is an integer < 2^24)
# ---------------------------------------------------------------------------

def _w_float(program: SNNProgram, spec: LayerSpec) -> jax.Array:
    if program.domain == "int":
        return spec.w.astype(jnp.float32)
    if program.quantize and spec.quantize:
        return fake_quant_w(spec.w)
    return spec.w


def _float_step(program: SNNProgram, vs: list, xt: jax.Array
                ) -> tuple[list, list]:
    """One network timestep. Returns (new vs, per-neuron-layer spikes)."""
    neuron = program.neuron
    int_dom = program.domain == "int"
    cur = xt
    vs_new, spikes = [], []
    for i, spec in enumerate(program.layers):
        if spec.kind == "readout":
            if cur.ndim > 2:
                cur = cur.reshape(cur.shape[0], -1)
            vs_new.append(vs[i] + cur @ _w_float(program, spec))
            continue
        if spec.kind == "conv":
            current = conv2d(cur, _w_float(program, spec), spec.stride)
        elif spec.kind == "fc":
            if cur.ndim > 2:
                cur = cur.reshape(cur.shape[0], -1)
            current = cur @ _w_float(program, spec)
        else:                                     # encoder: identity weight
            current = cur
        if int_dom and spec.kind == "fc":
            # f32 rendering of isa.layer_timestep_int (bit-exact)
            th = spec.threshold.astype(jnp.float32)
            v = clamp_v(vs[i] + current, program.clamp_mode)
            if neuron == "lif":
                v = clamp_v(v - spec.leak.astype(jnp.float32),
                            program.clamp_mode)
            s = spike_compare(v, th, program.clamp_mode).astype(jnp.float32)
            if neuron == "rmp":
                v = clamp_v(jnp.where(s > 0, v - th, v), program.clamp_mode)
            else:
                v = jnp.where(s > 0, 0.0, v)
        else:
            st, s = neuron_step(NeuronState(vs[i]), current, neuron=neuron,
                                threshold=spec.threshold, leak=spec.leak)
            v = st.v
        vs_new.append(v)
        spikes.append(s)
        cur = s
    return vs_new, spikes


def _init_vs(program: SNNProgram, batch: int) -> list:
    return [jnp.zeros((batch, *spec.state_shape)) for spec in program.layers]


@register_backend("float")
def run_float(program: SNNProgram, xs: jax.Array, *, return_trace: bool = False,
              collect_rasters: bool = False, collect_sums: bool = False,
              static_input: bool = False) -> NetResult:
    """Differentiable scan over the whole presentation. Aux always carries
    per-step mean spike rates; ``collect_rasters`` additionally stacks the
    full per-layer rasters, ``collect_sums`` carries per-layer spike-count
    sums (rate decoding without materializing rasters).

    ``static_input``: xs is a single (B, ...) frame presented every step
    (direct encoding); the scan closes over it instead of taking a
    timesteps-fold broadcast as a loop operand (which would materialize
    T copies of the activation on training hot paths)."""
    B = xs.shape[0] if static_input else xs.shape[1]
    n_neuron = len(program.neuron_layers)

    def step(carry, xt):
        vs, sums = carry
        vs, spikes = _float_step(program, vs, xt)
        rates = jnp.stack([s.mean() for s in spikes])
        if collect_sums:
            sums = [c + s for c, s in zip(sums, spikes)]
        trace = vs[-1][:, 0] if return_trace else jnp.zeros(B)
        out = (rates, trace, tuple(spikes) if collect_rasters else ())
        return (vs, sums), out

    sums0 = [jnp.zeros((B, *spec.state_shape))
             for spec in program.neuron_layers] if collect_sums else [0.0] * n_neuron
    carry0 = (_init_vs(program, B), sums0)
    if static_input:
        (vs, sums), (rates, trace, rasters) = jax.lax.scan(
            lambda c, _: step(c, xs), carry0, None, length=program.timesteps)
    else:
        (vs, sums), (rates, trace, rasters) = jax.lax.scan(step, carry0, xs)
    aux = {"spike_rates": rates, "v_trace": trace}
    if collect_sums:
        aux["spike_sums"] = sums
    v_out = vs[-1]
    return NetResult(v_out=v_out, logits=program.logits(v_out), v_final=vs,
                     rasters=list(rasters) if collect_rasters else None,
                     aux=aux)


# ---------------------------------------------------------------------------
# shared float encoder for the integer backends (off-macro input layer)
# ---------------------------------------------------------------------------

def encode(program: SNNProgram, xs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Run the encoder layer alone: (T_total, B, d) currents ->
    ((T_total, B, d) int8 spikes, final encoder V). Bitwise identical to the
    float backend's encoder layer (same ops on the same values)."""
    enc = program.layers[0]
    if enc.kind != "encoder":
        raise NotImplementedError(
            f"integer backends need an encoder-led stack, got {enc.kind!r}")

    def step(v, xt):
        st, s = neuron_step(NeuronState(v), xt, neuron=program.neuron,
                            threshold=enc.threshold, leak=enc.leak)
        return st.v, s.astype(jnp.int8)

    v_enc, spikes = jax.lax.scan(step, jnp.zeros(xs.shape[1:]), xs)
    return spikes, v_enc


def _assemble(program: SNNProgram, rasters: list, v_enc, v_stack: list
              ) -> NetResult:
    v_out = v_stack[-1]
    return NetResult(v_out=v_out, logits=program.logits(v_out),
                     v_final=[v_enc] + list(v_stack), rasters=rasters)


# ---------------------------------------------------------------------------
# int_ref backend — word-level ISA semantics scanned over the network
# ---------------------------------------------------------------------------

def _stack_kernel_args(program: SNNProgram) -> dict:
    """The fused_snn_net argument marshalling shared by int_ref and pallas —
    one place to extend when the stack grows per-layer parameters."""
    stack = program.fc_stack
    return dict(
        ws=[jnp.asarray(spec.w) for spec in stack],
        thresholds=tuple(int(spec.threshold) for spec in stack[:-1]),
        leaks=tuple(int(spec.leak) for spec in stack[:-1]),
        neuron=program.neuron, clamp_mode=program.clamp_mode)


def run_stack_from_raster(program: SNNProgram, spikes_enc: jax.Array, *,
                          use_pallas: bool = False, use_sparse: bool = False,
                          block_b: int = 8, interpret: bool = False,
                          emit_rasters: bool = True):
    """Execute only the on-macro fc stack on a supplied encoder spike raster
    (T_total, B, d) int8 — the public raster-in entry point that the
    int_ref/pallas backends and raster-driven benchmarks (synthetic
    sparsity sweeps) share. Returns (rasters, v_stack, skips) with
    ``rasters[0]`` the input raster itself, aligned with
    `count_network_instructions` / `sparsity_report` expectations."""
    from repro.kernels.fused_snn_net.ops import fused_snn_net
    kw = _stack_kernel_args(program)
    rasters, v_stack, skips = fused_snn_net(
        spikes_enc, kw.pop("ws"), use_pallas=use_pallas,
        use_sparse=use_sparse, block_b=block_b, interpret=interpret,
        emit_rasters=emit_rasters, **kw)
    full = [spikes_enc] + list(rasters) if emit_rasters else None
    return full, list(v_stack), skips


def _attach_skips(res: NetResult, skips, timesteps: int) -> NetResult:
    """Stash event-gating statistics on a result: raw per-(tile, layer)
    skipped-matmul counts plus the aggregate skipped-tile fraction (each of
    the n_tiles * n_layers gate sites fires once per timestep)."""
    if skips is None:
        return res
    skips = np.asarray(skips)
    res.aux["skip_counts"] = skips
    res.aux["skipped_tile_fraction"] = float(skips.sum()) / float(
        timesteps * skips.shape[0] * skips.shape[1])
    return res


@register_backend("int_ref")
def run_int_ref(program: SNNProgram, xs: jax.Array, *,
                use_sparse: bool = False) -> NetResult:
    """Word-level ISA semantics: the pure-jnp network reference (a scan of
    isa.layer_timestep_int over the stack) that is also the pallas kernel's
    non-TPU fallback — one implementation of the contract, two entry points.
    ``use_sparse`` runs the lax.cond event-gated variant (bit-identical)."""
    spikes_enc, v_enc = encode(program, xs)
    rasters, v_stack, skips = run_stack_from_raster(
        program, spikes_enc, use_pallas=False, use_sparse=use_sparse)
    res = _assemble(program, rasters, v_enc, v_stack)
    return _attach_skips(res, skips, xs.shape[0])


# ---------------------------------------------------------------------------
# pallas backends — the network-level fused kernel (dense and event-gated)
# ---------------------------------------------------------------------------

def _run_pallas(program: SNNProgram, xs: jax.Array, *, block_b: int,
                interpret: bool, emit_rasters: bool, use_sparse: bool
                ) -> NetResult:
    spikes_enc, v_enc = encode(program, xs)
    rasters, v_stack, skips = run_stack_from_raster(
        program, spikes_enc, use_pallas=True, use_sparse=use_sparse,
        block_b=block_b, interpret=interpret, emit_rasters=emit_rasters)
    res = _assemble(program, rasters, v_enc, v_stack)
    return _attach_skips(res, skips, xs.shape[0])


@register_backend("pallas")
def run_pallas(program: SNNProgram, xs: jax.Array, *, block_b: int = 8,
               interpret: bool = False, emit_rasters: bool = True) -> NetResult:
    return _run_pallas(program, xs, block_b=block_b, interpret=interpret,
                       emit_rasters=emit_rasters, use_sparse=False)


@register_backend("pallas_sparse")
def run_pallas_sparse(program: SNNProgram, xs: jax.Array, *, block_b: int = 8,
                      interpret: bool = False, emit_rasters: bool = True
                      ) -> NetResult:
    """Event-gated fused kernel: per (timestep, layer, batch-tile) the MXU
    matmul is predicated on tile occupancy (`@pl.when`), realizing the
    paper's event-driven AccW2V at tile granularity; the neuron update is
    unconditional, so results stay bit-identical to every dense backend.
    aux carries ``skip_counts`` ((B_tiles, n_layers) skipped matmuls) and
    ``skipped_tile_fraction``."""
    return _run_pallas(program, xs, block_b=block_b, interpret=interpret,
                       emit_rasters=emit_rasters, use_sparse=True)


# ---------------------------------------------------------------------------
# bitmacro backend — silicon oracle (numpy, bit-level, wrap arithmetic)
# ---------------------------------------------------------------------------

@register_backend("bitmacro")
def run_bitmacro(program: SNNProgram, xs: jax.Array) -> NetResult:
    """Execute the fc stack on the bit-accurate macro model. Constraints are
    the silicon's: fan-in <= 128 per layer (row_tiles == 1 — partial-sum
    reduction across macros is a word-level behaviour), batch <= 13 neuron
    sets, and two's-complement *wrap* arithmetic (saturation is a word-level
    deployment policy, not silicon; compile with clamp_mode='wrap' to
    compare bit-for-bit — see macro.py)."""
    from repro.core.macro import BitMacro
    if program.clamp_mode != "wrap":
        raise ValueError("bitmacro executes silicon wrap arithmetic; compile "
                         "the program with clamp_mode='wrap'")
    spikes_enc, v_enc = encode(program, xs)
    spikes_np = np.asarray(spikes_enc).astype(bool)             # (T, B, d)
    T_total, B = spikes_np.shape[:2]
    if B > isa.N_NEURON_SETS:
        raise ValueError(f"bitmacro backend maps batch onto neuron sets; "
                         f"B={B} > {isa.N_NEURON_SETS}")
    stack = program.fc_stack

    # one BitMacro per (layer, col_tile); batch element b uses neuron set b
    macros: list[list[BitMacro]] = []
    for spec in stack[:-1]:
        t = spec.tiling
        if t.row_tiles != 1:
            raise ValueError(f"bitmacro backend needs fan-in <= {isa.MACRO_IN} "
                             f"(layer {spec.n_in}x{spec.n_out})")
        wq_tiles = mapping.tile_weights(np.asarray(spec.w))     # (1, C, 128, 12)
        macros.append([
            BitMacro.from_weights(wq_tiles[0, c], threshold=int(spec.threshold),
                                  leak=int(spec.leak))
            for c in range(t.col_tiles)])

    rasters = [spikes_np.astype(np.int8)]
    layer_out = [np.zeros((T_total, B, spec.n_out), np.int8)
                 for spec in stack[:-1]]
    v_out = np.zeros((B, stack[-1].n_out), np.int64)
    wq_readout = np.asarray(stack[-1].w, np.int64)
    for t in range(T_total):
        for b in range(B):
            cur = spikes_np[t, b]
            for li, spec in enumerate(stack[:-1]):
                padded = np.zeros(isa.MACRO_IN, bool)
                padded[:spec.n_in] = cur[:spec.n_in]
                outs = [m.timestep(b, padded, program.neuron)
                        for m in macros[li]]
                cur = np.concatenate(outs)[:spec.n_out]
                layer_out[li][t, b] = cur.astype(np.int8)
            v_out[b] += cur.astype(np.int64) @ wq_readout
    rasters += layer_out
    # read V per layer: concatenate col tiles then trim padding
    v_final = []
    for li, spec in enumerate(stack[:-1]):
        v = np.stack([np.concatenate([m.read_v(b) for m in macros[li]])
                      for b in range(B)])[:, :spec.n_out]
        v_final.append(jnp.asarray(v.astype(np.int32)))
    rasters = [jnp.asarray(r) for r in rasters]
    v_stack = v_final + [jnp.asarray(v_out.astype(np.int32))]
    res = _assemble(program, rasters, v_enc, v_stack)
    res.aux["macro_counts"] = sum(
        (m.counts for tile in macros for m in tile), isa.InstrCount())
    return res


# ---------------------------------------------------------------------------
# program-level sparsity measurement + instruction counting (the energy-
# model inputs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SparsityReport:
    """Measured event statistics of one program execution — the bridge from
    spike rasters to the energy model. Per fc-stack layer i (whose *input*
    raster is the output of neuron layer i): total input events, per-
    timestep occupancy, and the macro-tiling geometry needed to turn events
    into instruction cycles. Built from full rasters (`sparsity_report`,
    exact, per-timestep resolution) or from the float backend's
    ``collect_sums`` aggregates (`sparsity_report_from_sums`, raster-free —
    the training-loop-friendly path). Both feed
    `count_network_instructions(program, report=...)` and
    `energy.measured_edp*`."""
    n_in: tuple                   # fan-in per fc-stack layer
    n_out: tuple
    neurons: tuple                # per-layer update kind ("rmp"... | "none")
    events: tuple                 # total input spike events per layer
    frames: int                   # (timestep, example) pairs = T_total * B
    timesteps: int
    batch: int
    occupancy_t: Optional[tuple] = None   # per layer: (T_total,) mean input
                                          # occupancy per timestep (rasters
                                          # only; None from sums)

    @property
    def layer_sparsity(self) -> tuple:
        """1 - (events / possible events), per fc-stack layer input."""
        return tuple(1.0 - e / (self.frames * n)
                     for e, n in zip(self.events, self.n_in))

    @property
    def overall_sparsity(self) -> float:
        """Event-weighted network input sparsity (all layers pooled)."""
        possible = sum(self.frames * n for n in self.n_in)
        return 1.0 - sum(self.events) / possible

    @property
    def silent_timestep_fraction(self) -> tuple:
        """Per layer: fraction of timesteps whose whole-batch input raster
        is silent — the whole-batch-granularity skip opportunity (the
        reference gate; per-batch-tile kernels skip at least this often)."""
        if self.occupancy_t is None:
            return tuple(None for _ in self.n_in)
        return tuple(float(np.mean(np.asarray(o) == 0.0))
                     for o in self.occupancy_t)

    @property
    def macro_timesteps(self) -> int:
        """Total macro-timesteps executed: every (timestep, example) frame
        runs each layer's col_tiles macros once — the normalizer that makes
        a measured InstrCount comparable to the paper's per-neuron
        per-timestep EDP curve (energy.measured_edp_per_neuron_timestep)."""
        return sum(self.frames * mapping.fc_tiling(ni, no).col_tiles
                   for ni, no in zip(self.n_in, self.n_out))

    def instruction_counts(self) -> isa.InstrCount:
        """Event statistics -> instruction cycles (identical to counting the
        rasters directly: both route through
        isa.count_layer_instructions_from_events)."""
        counts = isa.InstrCount()
        for ni, no, neuron, ev in zip(self.n_in, self.n_out, self.neurons,
                                      self.events):
            counts += isa.count_layer_instructions_from_events(
                ev, self.frames, ni, no, neuron)
        return counts


def _report_geometry(program: SNNProgram) -> tuple:
    stack = program.fc_stack
    return (tuple(l.n_in for l in stack), tuple(l.n_out for l in stack),
            tuple(program.neuron if l.kind == "fc" else "none"
                  for l in stack))


def sparsity_report(program: SNNProgram, rasters: list) -> SparsityReport:
    """Exact report from per-layer input rasters (`NetResult.rasters`):
    rasters[i] is (T_total, B, n_in_i) for fc-stack layer i."""
    if rasters is None:
        raise ValueError("sparsity_report needs spike rasters; run the "
                         "backend with emit_rasters=True (accounting mode), "
                         "or build the report from collect_sums aggregates")
    n_in, n_out, neurons = _report_geometry(program)
    rs = [np.asarray(r).reshape(r.shape[0], -1, ni)
          for r, ni in zip(rasters, n_in)]
    T, B = rs[0].shape[:2]
    return SparsityReport(
        n_in=n_in, n_out=n_out, neurons=neurons,
        events=tuple(int(r.sum()) for r in rs),
        frames=T * B, timesteps=T, batch=B,
        occupancy_t=tuple(r.mean(axis=(1, 2)) for r in rs))


def sparsity_report_from_sums(program: SNNProgram, spike_sums: list,
                              timesteps: int) -> SparsityReport:
    """Raster-free report from the float backend's ``collect_sums`` aux:
    spike_sums[i] is the (B, ...) per-neuron spike-count total of neuron
    layer i. The last len(fc_stack) neuron layers feed the fc stack, so
    their totals are exactly the per-layer input event counts — per-
    timestep occupancy is not recoverable from sums (occupancy_t=None)."""
    n_in, n_out, neurons = _report_geometry(program)
    sums = spike_sums[-len(program.fc_stack):]
    if len(sums) != len(n_in):
        raise ValueError(f"need one spike-sum per fc-stack layer input "
                         f"({len(n_in)}), got {len(spike_sums)}")
    B = int(np.asarray(sums[0]).shape[0])
    return SparsityReport(
        n_in=n_in, n_out=n_out, neurons=neurons,
        events=tuple(int(np.asarray(s).sum()) for s in sums),
        frames=timesteps * B, timesteps=timesteps, batch=B)


def count_network_instructions(program: SNNProgram, rasters: list = None, *,
                               report: Optional[SparsityReport] = None
                               ) -> isa.InstrCount:
    """Fold the per-layer event counts over the whole program. ``rasters[i]``
    is the input raster of fc-stack layer i; identical rasters (which all
    backends are tested to produce) give identical counts by construction.
    Alternatively pass a `SparsityReport` (``report=...``) — the raster-free
    accounting path; both routes share one counting implementation."""
    if report is not None:
        return report.instruction_counts()
    if rasters is None:
        raise ValueError("instruction counting needs spike rasters (run the "
                         "backend with emit_rasters=True, accounting mode) "
                         "or a SparsityReport")
    counts = isa.InstrCount()
    for spec, raster in zip(program.fc_stack, rasters):
        r = np.asarray(raster)
        counts += isa.count_layer_instructions(
            r, spec.n_in, spec.n_out,
            program.neuron if spec.kind == "fc" else "none")
    return counts
