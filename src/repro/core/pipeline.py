"""Compiled network-level SNN programs with pluggable execution backends.

IMPULSE's architectural claim is *fusion*: W_MEM and V_MEM share one array so
the membrane state never crosses a memory boundary. Before this module, that
fusion was only realized per layer, and the network loop around it was
re-implemented four times (float training, integer ISA, per-layer Pallas,
bit-level macro). `compile_network` lifts the network itself into a first-
class object — an `SNNProgram` describing the full stack (encoder -> on-
macro convs (im2col-lowered, mapping.py) -> spiking FCs -> accumulate
readout, thresholds/leaks/scales, multi-macro tiling) — executed by a
registry of backends that are tested to agree bit-for-bit:

  float    — QAT training semantics (surrogate gradients, fake-quant
             weights). For integer-domain programs it executes the *same*
             integer program in f32 (exact: all values < 2^24), which is the
             equivalence bridge between training and deployment.
  int_ref  — word-level ISA semantics (isa.layer_timestep_int scanned over
             the network), the functional contract of the silicon.
  pallas   — the network-level fused TPU kernel (kernels/fused_snn_net):
             every layer's V tile lives in VMEM scratch across the entire
             timestep loop and inter-layer spikes never touch HBM — the
             network-scale analogue of the macro's fused array.
  bitmacro — the bit-accurate column/bitline model (silicon oracle; wrap
             arithmetic only, as on silicon; fan-in > 128 layers split over
             row-tiled macros whose partial sums reduce with word-level
             AccV2V cycles, conv layers lower via im2col, and frames beyond
             13 neuron sets claim extra macro banks).

Instruction counting is a *program-level pass* (`count_network_instructions`)
over the spike rasters, so every backend reports identical energy-model
inputs by construction.

See DESIGN.md §3 for the pipeline/backends diagram and the VMEM-residency
argument.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.impulse_snn import SNNModelConfig
from repro.core import isa, mapping
from repro.core.neuron import NeuronState, neuron_step
from repro.core.quant import (clamp_v, fake_quant_w, quantize_neuron_const,
                              quantize_w, spike_compare)

# ---------------------------------------------------------------------------
# Program representation
# ---------------------------------------------------------------------------

# Layer kinds:
#   encoder — off-macro neuron layer over raw input current (identity weight)
#   conv    — conv transform + neuron dynamics. The FIRST conv of a stack is
#             the off-macro spike encoder (float weights, like the paper's
#             input layer); later convs are on-macro in the int domain
#             (scale set, int8 HWIO kernel, im2col-lowered — mapping.py)
#   fc      — spiking FC layer (on-macro)
#   readout — accumulate-only FC (prediction = final V_MEM)
LAYER_KINDS = ("encoder", "conv", "fc", "readout")


@dataclass(frozen=True)
class LayerSpec:
    kind: str
    n_in: int
    n_out: int
    w: Any = None                 # float weights | int8 wq (program.domain)
    threshold: Any = None         # float | int on the layer's fixed-point grid
    leak: Any = None
    scale: Any = None             # int domain: float <-> grid scale
    stride: int = 1               # conv only
    quantize: bool = True         # float domain: fake-quant this layer's w
    state_shape: tuple = ()       # per-example V shape (set at compile)

    @property
    def tiling(self) -> mapping.FCTiling:
        """This layer's macro-grid tiling (row/col tile counts for the
        n_in x n_out weight block — `mapping.fc_tiling`)."""
        return mapping.fc_tiling(self.n_in, self.n_out)


@dataclass(frozen=True)
class SNNProgram:
    cfg: Optional[SNNModelConfig]
    domain: str                   # "float" (QAT training) | "int" (deployed)
    neuron: str                   # if | lif | rmp
    timesteps: int                # presentation steps per input frame
    layers: tuple                 # tuple[LayerSpec, ...]
    clamp_mode: str = "saturate"  # int domain V_MEM policy (see quant.clamp_v)
    quantize: bool = True         # float domain: QAT fake-quant on

    @property
    def fc_stack(self) -> tuple:
        """The FC part of the on-macro stack: spiking FCs + readout."""
        return tuple(ly for ly in self.layers if ly.kind in ("fc", "readout"))

    @property
    def int_conv_stack(self) -> tuple:
        """On-macro conv layers (int domain only: quantized, scale set).
        The first conv of a stack is the off-macro encoder and never
        appears here."""
        return tuple(ly for ly in self.layers
                     if ly.kind == "conv" and ly.scale is not None)

    @property
    def macro_stack(self) -> tuple:
        """Everything that executes on macros: on-macro convs (im2col-
        lowered), spiking FCs, readout — the layers instruction counting
        and the integer backends iterate over."""
        return self.int_conv_stack + self.fc_stack

    @property
    def neuron_layers(self) -> tuple:
        """Layers with membrane dynamics that emit spikes."""
        return tuple(ly for ly in self.layers if ly.kind != "readout")

    def logits(self, v_out: jax.Array) -> jax.Array:
        """Readout V ``v_out`` (..., n_out) -> float logits of the same
        shape (undo the last layer's weight scale)."""
        if self.domain == "int":
            return v_out.astype(jnp.float32) * self.layers[-1].scale
        return v_out

    # -- streaming execution (DESIGN.md §3 "Streaming execution & serving")
    def init_state(self, batch: int, backend: str = "float") -> "StreamState":
        """Fresh per-layer membrane state for ``batch`` streams."""
        return init_stream_state(self, batch, backend)

    def step(self, state: "StreamState", frame: jax.Array,
             backend: str = "float", **kw
             ) -> "tuple[StreamState, StreamOut]":
        """Advance every stream one tick on a (B, ...) current frame."""
        return stream_step(self, state, frame, backend, **kw)

    def megastep(self, state: "StreamState", frames: jax.Array,
                 backend: str = "float", **kw
                 ) -> "tuple[StreamState, MegastepOut]":
        """Advance every stream of ``state`` K ticks on a (K, B, ...)
        ``frames`` block in one ``backend`` dispatch; ``kw`` passes
        through to `stream_megastep` (active / emit_rasters / mesh /
        kernel knobs)."""
        return stream_megastep(self, state, frames, backend, **kw)


@dataclass
class NetResult:
    """What one backend run produces. ``rasters[i]`` is the *input* spike
    raster of macro-stack layer i (so rasters[0] is the encoder output) —
    (T_total, B, n) flat for FC layers, (T_total, B, H, W, C) spike maps
    feeding conv layers; ``v_final`` lists final V per layer, readout
    last."""
    v_out: jax.Array
    logits: jax.Array
    v_final: list
    rasters: Optional[list] = None
    aux: dict = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Compilation
# ---------------------------------------------------------------------------

def conv2d(x: jax.Array, w: jax.Array, stride: int) -> jax.Array:
    """SAME-padded 2-D convolution of NHWC ``x`` with HWIO kernel ``w``
    at ``stride`` — the one conv primitive every domain lowers through."""
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_state_shapes(cfg: SNNModelConfig, convs: list) -> list:
    x = jnp.zeros((1, *cfg.in_shape))
    shapes = []
    for c, (_, _, stride) in zip(convs, cfg.conv_spec):
        x = jax.eval_shape(lambda a, w, s=stride: conv2d(a, w, s), x, c["w"])
        shapes.append(tuple(x.shape[1:]))
        x = jnp.zeros(x.shape, x.dtype)
    return shapes


def compile_network(cfg: SNNModelConfig, params: dict, *, domain: str = "float",
                    clamp_mode: str = "saturate", quantize: bool = True,
                    validate: bool = True) -> SNNProgram:
    """Lower (cfg, params) to an executable network program.

    ``domain="float"`` keeps the trainable parameterization (softplus'd
    thresholds/leaks, fake-quant weights) — differentiable, used for QAT.
    ``domain="int"`` quantizes every on-macro layer onto its 6b/11b grid
    (the deployed macro program); the encoder — the first FC *or conv*
    layer — stays float (off-macro input layer, as in the paper). On-macro
    convs keep their HWIO int8 kernel plus the im2col fan-in geometry
    (n_in = k*k*c_in — the 128-row rule, mapping.conv_tiling).

    Neuron constants quantize through `quant.quantize_neuron_const`, which
    folds them into the 11-bit V word under the program's clamp mode — a
    wrap-mode constant that rounds outside [V_MIN, V_MAX] wraps exactly as
    the datapath would read it, instead of clipping to a value no V op
    ever computes against.

    ``validate`` (default on) runs the static analyzer over the compiled
    program before returning it: `repro.analysis.check_program` proves the
    per-layer value ranges (no int32 accumulator overflow at the
    program's timestep horizon) and `check_kernel_contracts` verifies the
    fused-kernel dispatch geometry — a mis-configured program is rejected
    with a named `AnalysisError` at compile time, not mid-dispatch.
    """
    th = jax.nn.softplus(params["threshold"]) + 1e-3
    lk = jax.nn.softplus(params["leak"]) * 0.1
    layers: list[LayerSpec] = []
    k = 0                                         # neuron-layer index into th/lk

    convs = params.get("convs", [])
    if convs:
        shapes = _conv_state_shapes(cfg, convs)
        c_in = cfg.in_shape[-1]
        for i, (c, shape) in enumerate(zip(convs, shapes)):
            kh, kw = c["w"].shape[:2]
            if domain == "int" and i > 0:         # on-macro conv
                wq, scale = quantize_w(c["w"])
                layers.append(LayerSpec(
                    kind="conv", n_in=kh * kw * c_in, n_out=shape[-1],
                    w=wq,
                    threshold=jnp.int32(quantize_neuron_const(
                        float(th[k]), scale, clamp_mode)),
                    leak=jnp.int32(quantize_neuron_const(
                        float(lk[k]), scale, clamp_mode)),
                    scale=float(scale), stride=cfg.conv_spec[i][2],
                    quantize=False, state_shape=shape))
            else:                                 # float / encoder conv
                layers.append(LayerSpec(
                    kind="conv", n_in=kh * kw * c_in,
                    n_out=shape[-1], w=c["w"], threshold=th[k], leak=lk[k],
                    stride=cfg.conv_spec[i][2], quantize=(i > 0),
                    state_shape=shape))
            c_in = shape[-1]
            k += 1
    else:
        # word/current encoder: identity weight, neuron dynamics
        d_in = cfg.layer_sizes[0]
        layers.append(LayerSpec(kind="encoder", n_in=d_in, n_out=d_in,
                                threshold=th[k], leak=lk[k],
                                state_shape=(d_in,)))
        k += 1

    sizes = cfg.layer_sizes
    fc_ws = params["layers"]
    for j, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        is_readout = j == len(fc_ws) - 1
        w = fc_ws[j]["w"]
        if domain == "int":
            wq, scale = quantize_w(w)
            th_i = None if is_readout else jnp.int32(
                quantize_neuron_const(float(th[k]), scale, clamp_mode))
            lk_i = None if is_readout else jnp.int32(
                quantize_neuron_const(float(lk[k]), scale, clamp_mode))
            layers.append(LayerSpec(
                kind="readout" if is_readout else "fc", n_in=n_in, n_out=n_out,
                w=wq, threshold=th_i, leak=lk_i, scale=float(scale),
                state_shape=(n_out,)))
        else:
            layers.append(LayerSpec(
                kind="readout" if is_readout else "fc", n_in=n_in, n_out=n_out,
                w=w, threshold=None if is_readout else th[k],
                leak=None if is_readout else lk[k], state_shape=(n_out,)))
        if not is_readout:
            k += 1

    program = SNNProgram(cfg=cfg, domain=domain, neuron=cfg.spiking.neuron,
                         timesteps=cfg.timesteps, layers=tuple(layers),
                         clamp_mode=clamp_mode, quantize=quantize)
    if validate:
        # lazy import: analysis consumes programs, pipeline produces them —
        # the compile-time hook must not create an import cycle
        from repro.analysis import validate_program
        validate_program(program)
    return program


def rate_coded_program(spiking_cfg, state_shape: tuple) -> SNNProgram:
    """Single-population program (used by models/spiking_ffn): one encoder
    layer of per-example V shape ``state_shape`` integrating a constant
    current, thresholds/leaks taken verbatim from ``spiking_cfg`` (no
    softplus re-parameterization)."""
    layer = LayerSpec(kind="encoder", n_in=state_shape[-1],
                      n_out=state_shape[-1], threshold=spiking_cfg.threshold,
                      leak=spiking_cfg.leak, state_shape=state_shape)
    return SNNProgram(cfg=None, domain="float", neuron=spiking_cfg.neuron,
                      timesteps=spiking_cfg.timesteps, layers=(layer,),
                      quantize=False)


# ---------------------------------------------------------------------------
# Input presentation
# ---------------------------------------------------------------------------

def present_words(x_words: jax.Array, timesteps: int) -> jax.Array:
    """``x_words`` (B, n_words, d) -> (n_words * timesteps, B, d): each
    word held ``timesteps`` steps (membrane state persists across words —
    the sequential-memory claim)."""
    xs = jnp.repeat(x_words, timesteps, axis=1)
    return jnp.moveaxis(xs, 1, 0)


def present_static(x: jax.Array, timesteps: int) -> jax.Array:
    """``x`` (B, ...) -> (timesteps, B, ...): direct encoding, the same
    frame presented every step."""
    return jnp.broadcast_to(x[None], (timesteps, *x.shape))


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

BACKENDS: dict[str, Callable] = {}


def register_backend(name: str) -> Callable:
    """Decorator registering an execution backend under ``name`` in
    `BACKENDS` (the `run_network` dispatch table)."""
    def deco(fn: Callable) -> Callable:
        BACKENDS[name] = fn
        return fn
    return deco


def run_network(program: SNNProgram, xs: jax.Array, backend: str = "float",
                **kw) -> NetResult:
    """Execute a program on per-timestep input currents xs (T_total, B, ...).

    The float backend consumes xs directly. Integer backends share one float
    encoder pass (`encode`) — the off-macro input layer — then execute the
    on-macro fc stack in their own substrate.

    ``mesh`` (int backends only): a `jax.sharding.Mesh` with "data" and/or
    "model" axes — lanes partition over data, row-tiled fan-in over model
    with an exact integer-psum AccV2V reduction. Results are bit-identical
    to the single-device path (see DESIGN.md "Mesh execution"). The float
    backend's f32 reductions are not order-exact and the bitmacro oracle
    is host-side state; both reject a mesh with ValueError.
    """
    if backend not in BACKENDS:
        raise KeyError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
    if backend != "float" and program.domain != "int":
        raise ValueError(f"backend {backend!r} needs an int-domain program "
                         "(compile_network(..., domain='int'))")
    if backend in ("float", "bitmacro"):
        if kw.pop("mesh", None) is not None:
            raise ValueError(
                f"backend {backend!r} has no mesh execution: float "
                "reductions are not bitwise order-exact across shards and "
                "bitmacro state lives in host BitMacro objects; use an int "
                "device backend (int_ref/pallas/pallas_sparse/ref_events/"
                "pallas_events)")
    return BACKENDS[backend](program, xs, **kw)


# ---------------------------------------------------------------------------
# float backend — the single temporal executor for training AND the f32
# rendering of integer programs (exact: every value is an integer < 2^24)
# ---------------------------------------------------------------------------

def _w_float(program: SNNProgram, spec: LayerSpec) -> jax.Array:
    if program.domain == "int":
        return spec.w.astype(jnp.float32)
    if program.quantize and spec.quantize:
        return fake_quant_w(spec.w)
    return spec.w


def _float_step(program: SNNProgram, vs: list, xt: jax.Array
                ) -> tuple[list, list]:
    """One network timestep. Returns (new vs, per-neuron-layer spikes)."""
    neuron = program.neuron
    int_dom = program.domain == "int"
    cur = xt
    vs_new, spikes = [], []
    for i, spec in enumerate(program.layers):
        if spec.kind == "readout":
            if cur.ndim > 2:
                cur = cur.reshape(cur.shape[0], -1)
            vs_new.append(vs[i] + cur @ _w_float(program, spec))
            continue
        if spec.kind == "conv":
            current = conv2d(cur, _w_float(program, spec), spec.stride)
        elif spec.kind == "fc":
            if cur.ndim > 2:
                cur = cur.reshape(cur.shape[0], -1)
            current = cur @ _w_float(program, spec)
        else:                                     # encoder: identity weight
            current = cur
        if int_dom and spec.scale is not None:    # on-macro (fc or conv)
            # f32 rendering of isa.layer_timestep_int (bit-exact; for convs
            # conv2d == the im2col matmul per position, exactly, in int
            # arithmetic rendered in f32 — all values < 2^24)
            th = spec.threshold.astype(jnp.float32)
            v = clamp_v(vs[i] + current, program.clamp_mode)
            if neuron == "lif":
                v = clamp_v(v - spec.leak.astype(jnp.float32),
                            program.clamp_mode)
            s = spike_compare(v, th, program.clamp_mode).astype(jnp.float32)
            if neuron == "rmp":
                v = clamp_v(jnp.where(s > 0, v - th, v), program.clamp_mode)
            else:
                v = jnp.where(s > 0, 0.0, v)
        else:
            st, s = neuron_step(NeuronState(vs[i]), current, neuron=neuron,
                                threshold=spec.threshold, leak=spec.leak)
            v = st.v
        vs_new.append(v)
        spikes.append(s)
        cur = s
    return vs_new, spikes


def _init_vs(program: SNNProgram, batch: int) -> list:
    return [jnp.zeros((batch, *spec.state_shape)) for spec in program.layers]


@register_backend("float")
def run_float(program: SNNProgram, xs: jax.Array, *, return_trace: bool = False,
              collect_rasters: bool = False, collect_sums: bool = False,
              static_input: bool = False) -> NetResult:
    """Differentiable scan over the whole presentation. Aux always carries
    per-step mean spike rates; ``collect_rasters`` additionally stacks the
    full per-layer rasters, ``collect_sums`` carries per-layer spike-count
    sums (rate decoding without materializing rasters).

    ``static_input``: xs is a single (B, ...) frame presented every step
    (direct encoding); the scan closes over it instead of taking a
    timesteps-fold broadcast as a loop operand (which would materialize
    T copies of the activation on training hot paths)."""
    B = xs.shape[0] if static_input else xs.shape[1]
    n_neuron = len(program.neuron_layers)

    def step(carry, xt):
        vs, sums = carry
        vs, spikes = _float_step(program, vs, xt)
        rates = jnp.stack([s.mean() for s in spikes])
        if collect_sums:
            sums = [c + s for c, s in zip(sums, spikes)]
        trace = vs[-1][:, 0] if return_trace else jnp.zeros(B)
        out = (rates, trace, tuple(spikes) if collect_rasters else ())
        return (vs, sums), out

    sums0 = [jnp.zeros((B, *spec.state_shape))
             for spec in program.neuron_layers] if collect_sums else [0.0] * n_neuron
    carry0 = (_init_vs(program, B), sums0)
    if static_input:
        (vs, sums), (rates, trace, rasters) = jax.lax.scan(
            lambda c, _: step(c, xs), carry0, None, length=program.timesteps)
    else:
        (vs, sums), (rates, trace, rasters) = jax.lax.scan(step, carry0, xs)
    aux = {"spike_rates": rates, "v_trace": trace}
    if collect_sums:
        aux["spike_sums"] = sums
    v_out = vs[-1]
    return NetResult(v_out=v_out, logits=program.logits(v_out), v_final=vs,
                     rasters=list(rasters) if collect_rasters else None,
                     aux=aux)


# ---------------------------------------------------------------------------
# shared float encoder for the integer backends (off-macro input layer)
# ---------------------------------------------------------------------------

def _encoder_weight(program: SNNProgram, enc: LayerSpec):
    """The conv encoder's effective weight (fake-quant in QAT programs)."""
    return enc.w if not (program.quantize and enc.quantize) \
        else fake_quant_w(enc.w)


def encoder_step(program: SNNProgram, v_enc: jax.Array, frame: jax.Array
                 ) -> tuple[jax.Array, jax.Array]:
    """One tick of the off-macro encoder layer: carried membrane V plus a
    (B, ...) current frame -> (new V, (B, ...) int8 spikes). `encode` scans
    exactly this function, so frame-by-frame streaming reproduces the
    batch raster bit for bit."""
    enc = program.layers[0]
    if enc.kind == "encoder":
        current = frame
    elif enc.kind == "conv":
        current = conv2d(frame, _encoder_weight(program, enc), enc.stride)
    else:
        raise ValueError(
            f"integer backends need an encoder- or conv-led stack, but this "
            f"program's first layer is kind={enc.kind!r} "
            f"({enc.n_in}x{enc.n_out}); FC programs start with an 'encoder' "
            f"layer and conv programs with the conv spike encoder")
    st, s = neuron_step(NeuronState(v_enc), current, neuron=program.neuron,
                        threshold=enc.threshold, leak=enc.leak)
    return st.v, s.astype(jnp.int8)


def encode(program: SNNProgram, xs: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Run ``program``'s off-macro encoder layer alone on ``xs``:
    (T_total, B, ...) currents ->
    ((T_total, B, ...) int8 spikes, final encoder V). Bitwise identical to
    the float backend's encoder layer (same ops on the same values). For
    conv stacks the encoder is the first conv (float weights, spike maps
    out); for FC stacks it is the identity-weight input layer."""
    enc = program.layers[0]
    if enc.kind not in ("encoder", "conv"):
        # same error as the per-tick entry; raise eagerly, not inside scan
        encoder_step(program, None, None)
    v0 = jnp.zeros((xs.shape[1], *enc.state_shape)) if enc.kind == "conv" \
        else jnp.zeros(xs.shape[1:])
    v_enc, spikes = jax.lax.scan(
        lambda v, xt: encoder_step(program, v, xt), v0, xs)
    return spikes, v_enc


def _assemble(program: SNNProgram, rasters: list, v_enc, v_stack: list
              ) -> NetResult:
    v_out = v_stack[-1]
    return NetResult(v_out=v_out, logits=program.logits(v_out),
                     v_final=[v_enc] + list(v_stack), rasters=rasters)


# ---------------------------------------------------------------------------
# int_ref backend — word-level ISA semantics scanned over the network
# ---------------------------------------------------------------------------

def _stack_kernel_args(program: SNNProgram) -> dict:
    """The fused_snn_net argument marshalling shared by int_ref and pallas —
    one place to extend when the stack grows per-layer parameters."""
    stack = program.fc_stack
    return dict(
        ws=[jnp.asarray(spec.w) for spec in stack],
        thresholds=tuple(int(spec.threshold) for spec in stack[:-1]),
        leaks=tuple(int(spec.leak) for spec in stack[:-1]),
        neuron=program.neuron, clamp_mode=program.clamp_mode)


def _host_events_sharded(spikes, ws, *, mesh, v_init=None, **kw):
    """`ref_events` under a mesh: the host spike-list executor has no
    device placement, so lane (data-axis) partitioning is simulated —
    the batch splits into contiguous per-shard slices executed
    sequentially, rasters/V reassemble by concatenation, and the
    per-slice `EventStats` merge exactly (row events and frame counts
    are sums; lanes never interact). The model axis is a no-op for a
    host oracle — row-tile partials are a device concept — so this path
    validates lane partitioning only."""
    from repro.kernels.fused_snn_net.events import (EventStats,
                                                    fused_snn_net_events)
    from repro.kernels.fused_snn_net.ops import mesh_axis_extents
    n_data, _ = mesh_axis_extents(mesh)
    B = int(spikes.shape[1])
    bounds = [B * k // n_data for k in range(n_data + 1)]
    rs, vs, sts = [], [], []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if hi == lo:
            continue
        vi = ([np.asarray(v)[lo:hi] for v in v_init]
              if v_init is not None else None)
        r, v, st = fused_snn_net_events(spikes[:, lo:hi], ws, v_init=vi,
                                        **kw)
        rs.append(r)
        vs.append(v)
        sts.append(st)
    rasters = [jnp.concatenate([np.asarray(r[i]) for r in rs], axis=1)
               for i in range(len(rs[0]))]
    v_finals = [jnp.concatenate([np.asarray(v[i]) for v in vs], axis=0)
                for i in range(len(ws))]
    stats = EventStats(
        row_events=tuple(
            sum(np.asarray(st.row_events[i], np.int64) for st in sts)
            for i in range(len(ws))),
        frames=sum(st.frames for st in sts),
        dense_fallbacks=())
    return rasters, v_finals, stats


def _run_fc_stack(program: SNNProgram, spikes: jax.Array, *, use_pallas: bool,
                  use_sparse: bool, block_b: int, interpret: bool,
                  emit_rasters: bool, gate_granularity: int = 1,
                  use_events: bool = False, v_init: Optional[list] = None,
                  event_crossover: float = 1.0, mesh=None):
    kw = _stack_kernel_args(program)
    if mesh is not None:
        if use_events and not use_pallas:    # host spike-list executor
            return _host_events_sharded(
                spikes, kw.pop("ws"), mesh=mesh,
                emit_rasters=emit_rasters, v_init=v_init, **kw)
        from repro.kernels.fused_snn_net.ops import fused_snn_net_mesh
        return fused_snn_net_mesh(
            spikes, kw.pop("ws"), mesh=mesh, use_pallas=use_pallas,
            use_sparse=use_sparse, gate_granularity=gate_granularity,
            block_b=block_b, interpret=interpret,
            emit_rasters=emit_rasters, v_init=v_init,
            use_events=use_events, event_crossover=event_crossover, **kw)
    if use_events and use_pallas:        # device event-list kernel
        from repro.kernels.fused_snn_net.ops import fused_snn_net_device_events
        return fused_snn_net_device_events(
            spikes, kw.pop("ws"), block_b=block_b, interpret=interpret,
            emit_rasters=emit_rasters, v_init=v_init,
            event_crossover=event_crossover, **kw)
    if use_events:                       # host spike-list executor
        from repro.kernels.fused_snn_net.events import fused_snn_net_events
        return fused_snn_net_events(spikes, kw.pop("ws"),
                                    emit_rasters=emit_rasters,
                                    v_init=v_init, **kw)
    from repro.kernels.fused_snn_net.ops import fused_snn_net
    return fused_snn_net(
        spikes, kw.pop("ws"), use_pallas=use_pallas,
        use_sparse=use_sparse, gate_granularity=gate_granularity,
        block_b=block_b, interpret=interpret,
        emit_rasters=emit_rasters, v_init=v_init, **kw)


def run_stack_from_raster(program: SNNProgram, spikes_enc: jax.Array, *,
                          use_pallas: bool = False, use_sparse: bool = False,
                          block_b: int = 8, interpret: bool = False,
                          emit_rasters: bool = True,
                          gate_granularity: int = 1):
    """Execute only ``program``'s on-macro fc stack on a supplied encoder
    spike raster ``spikes_enc`` (T_total, B, d) int8 — the public
    raster-in entry point that raster-driven benchmarks (synthetic
    sparsity sweeps) share with the int_ref/pallas backends
    (``use_pallas`` / ``use_sparse`` / ``block_b`` / ``interpret`` /
    ``gate_granularity`` mirror `run_network`'s backend kwargs).
    Returns (rasters, v_stack, skips) with
    ``rasters[0]`` the input raster itself, aligned with
    `count_network_instructions` / `sparsity_report` expectations. Conv
    programs carry an on-macro conv front-end and route through
    `run_network` instead."""
    if program.int_conv_stack:
        raise ValueError("run_stack_from_raster executes the fc stack only; "
                         "this program has on-macro conv layers — execute it "
                         "through run_network (int_ref/pallas backends)")
    rasters, v_stack, skips = _run_fc_stack(
        program, spikes_enc, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, block_b=block_b,
        interpret=interpret, emit_rasters=emit_rasters)
    full = [spikes_enc] + list(rasters) if emit_rasters else None
    return full, list(v_stack), skips


def _conv_front_end(program: SNNProgram, spikes_enc: jax.Array, *,
                    use_pallas: bool, use_sparse: bool, block_b: int,
                    interpret: bool, gate_granularity: int = 1,
                    use_events: bool = False, v_init: Optional[list] = None,
                    event_crossover: float = 1.0, mesh=None):
    """Run the on-macro int conv layers on encoder spike maps. Each conv
    layer lowers onto the macro grid via im2col (mapping.py): its
    (T, B, H, W, C) input maps become a (T, B*P, k*k*C) patch raster —
    one frame per (example, output position), each claiming a V_MEM neuron
    set — executed by the same fused_snn_net machinery as the fc stack
    (readout=False), so the Pallas kernel, the jnp reference, event gating
    at any granularity, and the event-list executor all serve conv
    programs unchanged. Returns (maps, v_convs, conv_skips): per-layer
    output spike maps (T, B, H_out, W_out, C_out) int8, final V maps, and
    per-layer gate counts (None entries when dense; `events.EventStats`
    entries on the event-list path)."""
    from repro.kernels.fused_snn_net.events import fused_snn_net_events
    from repro.kernels.fused_snn_net.ops import (fused_snn_net,
                                                 fused_snn_net_device_events)
    maps, v_convs, conv_skips = [], [], []
    cur = spikes_enc
    for ci, spec in enumerate(program.int_conv_stack):
        t_total, batch = cur.shape[:2]
        patches = mapping.im2col_raster(cur, spec.w.shape[0], spec.stride)
        out_hw = mapping.conv_out_hw(cur.shape[2:4], spec.w.shape[0],
                                     spec.stride)
        kw = dict(thresholds=(int(spec.threshold),), leaks=(int(spec.leak),),
                  neuron=program.neuron, clamp_mode=program.clamp_mode,
                  readout=False, emit_rasters=True)
        vi = None
        if v_init is not None:
            # conv V state is a (B, H_out, W_out, C) map; the macro executes
            # one frame per (example, output position) — flatten to match
            vi = [jnp.asarray(v_init[ci]).reshape(-1, spec.n_out)]
        if mesh is not None and use_events and not use_pallas:
            # host spike-list executor under a mesh: the patch raster's
            # frame axis is (example, output position) — contiguous
            # per-shard slices are whole frames, so the lane-partition
            # argument applies unchanged
            rasters, v, skips = _host_events_sharded(
                patches.astype(jnp.int8),
                [np.asarray(mapping.pack_conv_weights(spec.w))],
                mesh=mesh, v_init=vi, **kw)
        elif mesh is not None:
            from repro.kernels.fused_snn_net.ops import fused_snn_net_mesh
            rasters, v, skips = fused_snn_net_mesh(
                patches.astype(jnp.int8),
                [jnp.asarray(mapping.pack_conv_weights(spec.w))],
                mesh=mesh, use_pallas=use_pallas, use_sparse=use_sparse,
                gate_granularity=gate_granularity, block_b=block_b,
                interpret=interpret, use_events=use_events,
                event_crossover=event_crossover, v_init=vi, **kw)
        elif use_events and use_pallas:  # device event-list kernel
            rasters, v, skips = fused_snn_net_device_events(
                patches.astype(jnp.int8),
                [jnp.asarray(mapping.pack_conv_weights(spec.w))],
                block_b=block_b, interpret=interpret,
                event_crossover=event_crossover, v_init=vi, **kw)
        elif use_events:                 # host spike-list executor
            rasters, v, skips = fused_snn_net_events(
                patches.astype(jnp.int8),
                [np.asarray(mapping.pack_conv_weights(spec.w))],
                v_init=vi, **kw)
            rasters = [jnp.asarray(r) for r in rasters]
        else:
            rasters, v, skips = fused_snn_net(
                patches.astype(jnp.int8),
                [jnp.asarray(mapping.pack_conv_weights(spec.w))],
                use_pallas=use_pallas, use_sparse=use_sparse,
                gate_granularity=gate_granularity, block_b=block_b,
                interpret=interpret, v_init=vi, **kw)
        cur = rasters[0].reshape(t_total, batch, *out_hw, spec.n_out)
        maps.append(cur)
        v_convs.append(jnp.asarray(v[0]).reshape(batch, *out_hw, spec.n_out))
        conv_skips.append(skips)
    return maps, v_convs, conv_skips


def _run_macro_stack(program: SNNProgram, xs: jax.Array, *, use_pallas: bool,
                     use_sparse: bool, block_b: int = 8,
                     interpret: bool = False, emit_rasters: bool = True,
                     gate_granularity: int = 1, use_events: bool = False,
                     event_crossover: float = 1.0, mesh=None
                     ) -> NetResult:
    """Shared int_ref/pallas/ref_events/pallas_events executor: float
    encoder pass, then the on-macro conv front-end (when present), then the
    fused fc stack. With ``mesh``, the conv and fc dispatches execute under
    shard_map (`kernels.fused_snn_net.ops.fused_snn_net_mesh`); the float
    encoder stays a single global pass (off-macro, elementwise per lane —
    there is nothing to reduce across shards)."""
    spikes_enc, v_enc = encode(program, xs)
    conv_maps, v_convs, conv_skips = _conv_front_end(
        program, spikes_enc, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, use_events=use_events,
        block_b=block_b, interpret=interpret,
        event_crossover=event_crossover, mesh=mesh)
    last = conv_maps[-1] if conv_maps else spikes_enc
    flat = last.reshape(*last.shape[:2], -1) if last.ndim > 3 else last
    rasters_fc, v_stack, skips = _run_fc_stack(
        program, flat, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, use_events=use_events,
        block_b=block_b, interpret=interpret, emit_rasters=emit_rasters,
        event_crossover=event_crossover, mesh=mesh)
    # rasters[i] = the input raster of macro-stack layer i: spike maps for
    # the conv part (the last conv's map doubles, flattened, as fc input)
    full = ([spikes_enc] + conv_maps + list(rasters_fc)
            if emit_rasters else None)
    res = _assemble(program, full, v_enc,
                    list(v_convs) + [jnp.asarray(v) for v in v_stack])
    if use_events:
        return _attach_event_stats(res, conv_skips, skips)
    res = _attach_skips(res, skips, xs.shape[0], gate_granularity)
    if use_sparse and conv_skips:
        res.aux["conv_skip_counts"] = [
            [np.asarray(b) for b in s] if isinstance(s, list)
            else np.asarray(s) for s in conv_skips]
    return res


def _site_count(s: np.ndarray) -> int:
    """Gate sites per timestep of one skip-count array: tiles x columns."""
    return s.shape[0] * s.shape[1]


def _attach_skips(res: NetResult, skips, timesteps: int,
                  granularity: int = 1) -> NetResult:
    """Stash event-gating statistics on a result: raw skipped-matmul counts
    plus the aggregate skipped-gate fraction (every gate site fires once
    per timestep). At granularity 1 sites are (tile, layer) pairs and the
    fraction keeps its historical name ``skipped_tile_fraction``; at finer
    granularities sites are (tile, layer, row-block) triples, counts come
    as a per-layer list, and the aggregate is ``skipped_block_fraction``."""
    if skips is None:
        return res
    if granularity == 1:
        skips = np.asarray(skips)
        res.aux["skip_counts"] = skips
        res.aux["skipped_tile_fraction"] = float(skips.sum()) / float(
            timesteps * _site_count(skips))
        return res
    skips = [np.asarray(s) for s in skips]
    res.aux["skip_counts"] = skips
    sites = sum(_site_count(s) for s in skips)
    res.aux["skipped_block_fraction"] = float(
        sum(int(s.sum()) for s in skips)) / float(timesteps * sites)
    return res


def _attach_event_stats(res: NetResult, conv_stats: list, fc_stats
                        ) -> NetResult:
    """Fold the per-layer `events.EventStats` of the conv front-end and the
    fc stack into result aux: per-row event counts, silent-row counts, and
    the overall skipped-row fraction — the event-list executor's skipped
    work is exactly its silent (frame, row) pairs."""
    row_events = [r for st in conv_stats for r in st.row_events]
    row_events += list(fc_stats.row_events)
    frames = [st.frames for st in conv_stats for _ in st.row_events]
    frames += [fc_stats.frames] * len(fc_stats.row_events)
    skipped = [f * len(r) - int(r.sum()) for f, r in zip(frames, row_events)]
    possible = sum(f * len(r) for f, r in zip(frames, row_events))
    res.aux["row_events"] = row_events
    res.aux["row_event_frames"] = frames
    res.aux["row_skip_counts"] = skipped
    res.aux["skipped_row_fraction"] = (sum(skipped) / possible
                                       if possible else 0.0)
    # device event-list kernel only: per-layer dense-crossover trip counts
    # (the host executor never falls back and reports empty tuples)
    fallbacks = [f for st in conv_stats for f in st.dense_fallbacks]
    fallbacks += list(fc_stats.dense_fallbacks)
    if fallbacks:
        res.aux["event_dense_fallbacks"] = fallbacks
    return res


@register_backend("int_ref")
def run_int_ref(program: SNNProgram, xs: jax.Array, *,
                use_sparse: bool = False, mesh=None) -> NetResult:
    """Word-level ISA semantics: the pure-jnp network reference (a scan of
    isa.layer_timestep_int over the fc stack, preceded by the im2col conv
    front-end — `_conv_front_end` -> fused_snn_net(readout=False), whose
    patch-raster execution is tested equal to isa.conv_layer_timestep_int)
    that is also the pallas kernel's non-TPU fallback — one implementation
    of the contract, two entry points. ``use_sparse`` runs the lax.cond
    event-gated variant (bit-identical). ``mesh`` executes the macro stack
    under shard_map, bit-identical to the single-device run (`run_network`
    docs)."""
    return _run_macro_stack(program, xs, use_pallas=False,
                            use_sparse=use_sparse, mesh=mesh)


# ---------------------------------------------------------------------------
# pallas backends — the network-level fused kernel (dense and event-gated)
# ---------------------------------------------------------------------------

def _run_pallas(program: SNNProgram, xs: jax.Array, *, block_b: int,
                interpret: bool, emit_rasters: bool, use_sparse: bool,
                gate_granularity: int = 1, mesh=None) -> NetResult:
    return _run_macro_stack(program, xs, use_pallas=True,
                            use_sparse=use_sparse, block_b=block_b,
                            gate_granularity=gate_granularity,
                            interpret=interpret, emit_rasters=emit_rasters,
                            mesh=mesh)


@register_backend("pallas")
def run_pallas(program: SNNProgram, xs: jax.Array, *, block_b: int = 8,
               interpret: bool = False, emit_rasters: bool = True,
               mesh=None) -> NetResult:
    """The fused multi-layer Pallas kernel (dense): all V tiles stay
    VMEM-resident across the timestep loop. ``block_b`` is the batch tile
    per grid step, ``interpret`` runs the kernel in interpret mode (CPU
    CI), ``mesh`` executes under shard_map — per-shard kernels on the
    data axis, the row-partial psum body on the model axis — bit-identical
    either way."""
    return _run_pallas(program, xs, block_b=block_b, interpret=interpret,
                       emit_rasters=emit_rasters, use_sparse=False,
                       mesh=mesh)


@register_backend("pallas_sparse")
def run_pallas_sparse(program: SNNProgram, xs: jax.Array, *, block_b: int = 8,
                      interpret: bool = False, emit_rasters: bool = True,
                      gate_granularity: int = 1, mesh=None) -> NetResult:
    """Event-gated fused kernel: per (timestep, layer, batch-tile) the MXU
    matmul is predicated on tile occupancy (`@pl.when`), realizing the
    paper's event-driven AccW2V; the neuron update is unconditional, so
    results stay bit-identical to every dense backend.

    ``gate_granularity`` is the sub-tile resolution knob: at 1 each layer's
    whole input tile is one gate (aux: ``skip_counts`` (B_tiles, n_layers)
    and ``skipped_tile_fraction``); at G in {2, 4, 8} each 128-lane
    macro-row tile splits into G independently predicated row blocks (aux:
    ``skip_counts`` as a per-layer list of (B_tiles, n_blocks) arrays and
    ``skipped_block_fraction``)."""
    return _run_pallas(program, xs, block_b=block_b, interpret=interpret,
                       emit_rasters=emit_rasters, use_sparse=True,
                       gate_granularity=gate_granularity, mesh=mesh)


@register_backend("ref_events")
def run_ref_events(program: SNNProgram, xs: jax.Array, *,
                   mesh=None) -> NetResult:
    """Spike-list compaction reference (`kernels/fused_snn_net/events`)
    executing ``program`` on ``xs`` (T_total, B, ...) currents:
    every (timestep, example) frame is compacted to (indices, count) and
    AccW2V becomes a gather-matvec over active rows only — work exactly
    proportional to events, the honest upper bound on skippable work (iid
    sparsity that defeats tile/block gates is fully exploited) and the
    word-level contract for per-row skip accounting. Bit-identical to all
    other backends; aux carries ``row_events`` (per-layer per-input-row
    event counts), ``row_skip_counts`` (silent (frame, row) pairs), and
    ``skipped_row_fraction``. ``mesh`` simulates lane partitioning on the
    host (contiguous per-data-shard slices run sequentially; counters
    merge by summation — the model axis is a documented no-op for this
    host executor)."""
    return _run_macro_stack(program, xs, use_pallas=False, use_sparse=False,
                            use_events=True, mesh=mesh)


@register_backend("pallas_events")
def run_pallas_events(program: SNNProgram, xs: jax.Array, *, block_b: int = 8,
                      interpret: bool = False, emit_rasters: bool = True,
                      event_crossover: float = 1.0, mesh=None) -> NetResult:
    """Device-side event-list execution (kernels/fused_snn_net kernel.py,
    ``events=True``): every (timestep, layer, example) frame is compacted
    *in VMEM* (cumsum position map = the fixed-capacity active-row index
    list) and AccW2V runs as a gather-matvec with a dynamic trip count —
    executed work proportional to events at every sparsity structure,
    closing the gap between the `pallas_sparse` block gates and the
    `ref_events` accounting upper bound. Frames whose tile event count
    exceeds ``event_crossover`` of capacity take the dense matmul fallback
    (bit-identical either way; default 1.0 never trips).

    Aux matches `ref_events` (``row_events`` / ``row_skip_counts`` /
    ``skipped_row_fraction`` — the kernel's counters are tested EQUAL to
    the host executor's `EventStats`) plus ``event_dense_fallbacks``, the
    per-layer dense-fallback trip counts."""
    return _run_macro_stack(program, xs, use_pallas=True, use_sparse=False,
                            use_events=True, block_b=block_b,
                            interpret=interpret, emit_rasters=emit_rasters,
                            event_crossover=event_crossover, mesh=mesh)


# ---------------------------------------------------------------------------
# streaming execution — the program-level step API
#
# IMPULSE's deployment mode is *streaming*: membrane potential is persistent
# per-neuron state fused next to the weights, so sequential inputs arrive
# frame by frame and V simply stays resident. `run_network` consumes a whole
# (T, B, ...) presentation in one call; `init_stream_state` / `stream_step`
# expose the same backends one tick at a time, carrying every layer's V as
# an explicit state tree. Because all on-macro arithmetic is integer (exact)
# and the float encoder executes the identical per-tick ops the batch scan
# executes, driving the batch raster frame-by-frame through `stream_step`
# reproduces `run_network` bit for bit — the contract tests/test_stream.py
# sweeps. serve/snn_engine.py builds continuous batching on top: slot lanes
# of one StreamState tree are the V_MEM analogue of LM KV-cache lanes.
# ---------------------------------------------------------------------------

STREAM_BACKENDS = ("float", "int_ref", "pallas", "pallas_sparse",
                   "ref_events", "pallas_events")


class StreamState(NamedTuple):
    """Carried membrane state of a streaming execution: one V leaf per
    program layer in `program.layers` order (encoder first, readout last),
    each (B, *state_shape). Dtypes are backend-native: all-f32 on the float
    backend, f32 encoder V + int32 macro-stack V on the integer backends.
    A NamedTuple — hence a pytree — so serving engines can tree-map lane
    copies over it when admitting/evicting requests."""
    vs: tuple
    t: int = 0           # ticks executed (bookkeeping; dynamics are
                         # time-invariant, so t never enters the math)


@dataclass
class StreamOut:
    """What one `stream_step` tick produces. ``rasters[i]`` is the input
    spike raster of macro-stack layer i for THIS tick, (B, n) flat /
    (B, H, W, C) maps with the T axis squeezed — stacking them over ticks
    rebuilds `NetResult.rasters` exactly (None when emit_rasters=False).
    ``skips``/``conv_skips`` carry the event-gating counts of this tick in
    the same layouts `run_network` aux uses: per-call skip-count arrays on
    the gated paths (summing over ticks equals the batch-run counts) or
    `events.EventStats` on the ref_events path."""
    v_out: Any
    logits: Any
    rasters: Optional[list] = None
    skips: Any = None
    conv_skips: Any = None


def _check_stream_backend(program: SNNProgram, backend: str) -> None:
    if backend not in STREAM_BACKENDS:
        raise KeyError(
            f"unknown streaming backend {backend!r}; have "
            f"{STREAM_BACKENDS} (bitmacro is a host-side verification "
            "oracle whose state lives in BitMacro objects, not a pytree — "
            "it has no streaming entry)")
    if backend != "float" and program.domain != "int":
        raise ValueError(f"backend {backend!r} needs an int-domain program "
                         "(compile_network(..., domain='int'))")


def init_stream_state(program: SNNProgram, batch: int,
                      backend: str = "float") -> StreamState:
    """Fresh (all-zero V) state for ``batch`` independent streams."""
    _check_stream_backend(program, backend)
    vs = []
    for i, spec in enumerate(program.layers):
        dtype = jnp.float32 if (backend == "float" or i == 0) else jnp.int32
        vs.append(jnp.zeros((batch, *spec.state_shape), dtype))
    return StreamState(vs=tuple(vs), t=0)


def stream_step(program: SNNProgram, state: StreamState, frame: jax.Array,
                backend: str = "float", *, emit_rasters: bool = True,
                use_sparse: bool = False, block_b: int = 8,
                interpret: bool = False, gate_granularity: int = 1,
                event_crossover: float = 1.0, mesh=None
                ) -> tuple[StreamState, StreamOut]:
    """Advance every stream one tick: (state, (B, ...) input currents) ->
    (new state, StreamOut). Batch lanes never interact — every op is
    per-lane — so a lane's trajectory is independent of what the other
    lanes serve, which is what makes continuous batching exact.

    Backend kwargs mirror `run_network`: ``use_sparse`` gates the int_ref
    tick, ``block_b``/``interpret`` configure the pallas kernel,
    ``gate_granularity`` refines the pallas_sparse gate. The integer
    backends reuse the fused kernels' one-timestep entry (``v_init``), so
    per-layer V tiles stay VMEM-resident within the tick and only cross
    the call boundary between ticks.

    ``mesh`` (a `jax.sharding.Mesh` with "data"/"model" axes) executes the
    macro-stack dispatches under shard_map, bit-identical to the
    single-device tick (see `run_network`); the float backend rejects a
    mesh (ValueError) because its reductions are not order-exact."""
    _check_stream_backend(program, backend)
    if backend == "float" and mesh is not None:
        raise ValueError(
            "backend 'float' has no mesh execution: float reductions are "
            "not order-exact, so a sharded run could not stay bit-identical")
    if backend == "float":
        vs, spikes = _float_step(program, list(state.vs), frame)
        v_out = vs[-1]
        return (StreamState(vs=tuple(vs), t=state.t + 1),
                StreamOut(v_out=v_out, logits=program.logits(v_out),
                          rasters=list(spikes) if emit_rasters else None))
    use_pallas = backend in ("pallas", "pallas_sparse", "pallas_events")
    use_events = backend in ("ref_events", "pallas_events")
    if backend == "pallas_sparse":
        use_sparse = True
    v_enc, spikes_enc = encoder_step(program, state.vs[0], frame)
    cur = spikes_enc[None]                       # (1, B, ...) one-frame raster
    n_convs = len(program.int_conv_stack)
    conv_maps, v_convs, conv_skips = _conv_front_end(
        program, cur, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, use_events=use_events,
        block_b=block_b, interpret=interpret,
        event_crossover=event_crossover,
        v_init=list(state.vs[1:1 + n_convs]) if n_convs else None,
        mesh=mesh)
    last = conv_maps[-1] if conv_maps else cur
    flat = last.reshape(*last.shape[:2], -1) if last.ndim > 3 else last
    rasters_fc, v_stack, skips = _run_fc_stack(
        program, flat, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, use_events=use_events,
        block_b=block_b, interpret=interpret, emit_rasters=emit_rasters,
        event_crossover=event_crossover,
        v_init=list(state.vs[1 + n_convs:]), mesh=mesh)
    new_vs = ((v_enc,) + tuple(v_convs)
              + tuple(jnp.asarray(v) for v in v_stack))
    rasters = None
    if emit_rasters:
        rasters = ([spikes_enc] + [m[0] for m in conv_maps]
                   + [jnp.asarray(r)[0] for r in rasters_fc])
    v_out = jnp.asarray(v_stack[-1])
    return (StreamState(vs=new_vs, t=state.t + 1),
            StreamOut(v_out=v_out, logits=program.logits(v_out),
                      rasters=rasters, skips=skips,
                      conv_skips=conv_skips if conv_skips else None))


@dataclass
class MegastepOut:
    """What one K-frame `stream_megastep` block produces — `StreamOut`'s
    block-granular sibling. ``rasters[i]`` keeps its K axis ((K, B, n) flat
    / (K, B, H, W, C) maps): concatenating blocks over a stream rebuilds
    `NetResult.rasters` exactly. ``v_out_traj``/``logits_traj`` are the
    per-tick readout trajectory *within* the block — what lets a server
    finalize a request that finishes mid-block (tick budget exhausted or
    confidence early-exit) with the exact values a tick-by-tick drain
    would have produced. ``frames_consumed`` is the per-lane count of real
    (non-masked) frames integrated, for exact accounting."""
    v_out: Any                    # (B, n_out) readout V after the block
    logits: Any                   # (B, n_out)
    v_out_traj: Any               # (K, B, n_out) per-tick readout V
    logits_traj: Any              # (K, B, n_out)
    frames_consumed: Any          # (B,) int32
    rasters: Optional[list] = None
    skips: Any = None
    conv_skips: Any = None


def stream_megastep(program: SNNProgram, state: StreamState,
                    frames: jax.Array, backend: str = "float", *,
                    active=None, emit_rasters: bool = True,
                    use_sparse: bool = False, block_b: int = 8,
                    interpret: bool = False, gate_granularity: int = 1,
                    event_crossover: float = 1.0, mesh=None
                    ) -> tuple[StreamState, MegastepOut]:
    """Advance every stream K ticks in ONE device dispatch: (state,
    (K, B, ...) pre-staged current block) -> (new state, MegastepOut).

    This is the serving-scale entry: where `stream_step` pays one host
    round-trip per frame, a megastep hands the fused kernels a K-frame
    raster and the per-layer V tiles stay VMEM-resident across the whole
    K loop (the `v_init` chunk-composition property: integer arithmetic
    is exact, so a K-frame call equals K chained one-frame calls bit for
    bit — the fused-V_MEM payoff the paper's streaming mode is built on).

    ``active`` (optional, (B,) ints) is the per-lane active-tick count:
    frames at tick t >= active[lane] are zeroed before integration, so
    evicted/short streams integrate zero current — exactly what a K=1
    engine presents to an idle lane — and ``frames_consumed`` reports
    min(active, K) per lane. The lane still *advances* K ticks (leak and
    reset run on zero current); a server that retires a lane mid-block
    discards the ghost ticks by re-seeding the lane from fresh state.

    ``v_out_traj``/``logits_traj`` expose the readout's per-tick values
    inside the block. On the integer backends the readout accumulator is
    unclamped int32, so the trajectory is recovered exactly as
    ``v_init + cumsum(raster @ w_readout)`` — int addition is associative,
    hence bit-identical to K single ticks (this forces the fc stack to
    emit rasters internally even when ``emit_rasters=False``).

    ``mesh`` (a `jax.sharding.Mesh` with "data"/"model" axes) executes the
    macro-stack dispatches under shard_map — serving lanes partition over
    the data axis, row-tiled fan-in over the model axis — bit-identical to
    the single-device block; the float backend rejects a mesh
    (ValueError)."""
    _check_stream_backend(program, backend)
    if backend == "float" and mesh is not None:
        raise ValueError(
            "backend 'float' has no mesh execution: float reductions are "
            "not order-exact, so a sharded run could not stay bit-identical")
    frames = jnp.asarray(frames)
    if frames.ndim < 3:
        raise ValueError(
            f"stream_megastep takes a (K, B, *in_shape) frame block, got "
            f"shape {frames.shape}")
    k, b = int(frames.shape[0]), int(frames.shape[1])
    if k < 1:
        raise ValueError("stream_megastep needs K >= 1 frames per block")
    if active is not None:
        act = jnp.asarray(active, jnp.int32)
        live = jnp.arange(k, dtype=jnp.int32)[:, None] < act[None, :]
        frames = jnp.where(
            live.reshape(k, b, *([1] * (frames.ndim - 2))), frames,
            jnp.zeros((), frames.dtype))
        consumed = jnp.minimum(act, k)
    else:
        consumed = jnp.full((b,), k, jnp.int32)
    if backend == "float":
        # eager K-loop, NOT lax.scan: the float (QAT) readout matmul can
        # drift a last ulp when XLA refuses the eager ops under scan, and
        # the contract here is bit-identity with K stream_step ticks
        vs, v_traj, spk = list(state.vs), [], []
        for t in range(k):
            vs, spikes = _float_step(program, vs, frames[t])
            v_traj.append(vs[-1])
            if emit_rasters:
                spk.append(spikes)
        v_traj = jnp.stack(v_traj)
        rasters = ([jnp.stack([s[i] for s in spk])
                    for i in range(len(spk[0]))] if emit_rasters else None)
        return (StreamState(vs=tuple(vs), t=state.t + k),
                MegastepOut(v_out=vs[-1], logits=program.logits(vs[-1]),
                            v_out_traj=v_traj,
                            logits_traj=program.logits(v_traj),
                            frames_consumed=consumed, rasters=rasters))
    use_pallas = backend in ("pallas", "pallas_sparse", "pallas_events")
    use_events = backend in ("ref_events", "pallas_events")
    if backend == "pallas_sparse":
        use_sparse = True
    # eager K-loop, not lax.scan: an un-jitted scan retraces per call,
    # which would put a compile on every serving dispatch; the eager ops
    # are exactly what `stream_step`/`encode` execute (bit-identical — the
    # encoder comparison in tests/test_stream.py pins eager == scanned)
    v_enc, spk_enc = state.vs[0], []
    for t in range(k):
        v_enc, s = encoder_step(program, v_enc, frames[t])
        spk_enc.append(s)
    spikes_enc = jnp.stack(spk_enc)
    n_convs = len(program.int_conv_stack)
    conv_maps, v_convs, conv_skips = _conv_front_end(
        program, spikes_enc, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, use_events=use_events,
        block_b=block_b, interpret=interpret,
        event_crossover=event_crossover,
        v_init=list(state.vs[1:1 + n_convs]) if n_convs else None,
        mesh=mesh)
    last = conv_maps[-1] if conv_maps else spikes_enc
    flat = last.reshape(*last.shape[:2], -1) if last.ndim > 3 else last
    rasters_fc, v_stack, skips = _run_fc_stack(
        program, flat, use_pallas=use_pallas, use_sparse=use_sparse,
        gate_granularity=gate_granularity, use_events=use_events,
        block_b=block_b, interpret=interpret, emit_rasters=True,
        event_crossover=event_crossover,
        v_init=list(state.vs[1 + n_convs:]), mesh=mesh)
    new_vs = ((v_enc,) + tuple(v_convs)
              + tuple(jnp.asarray(v) for v in v_stack))
    # exact per-tick readout trajectory (see docstring): the readout input
    # raster is the last spiking layer's output, or the stack input when
    # the stack is readout-only
    ro_in = (jnp.asarray(rasters_fc[-1]) if len(rasters_fc)
             else flat).astype(jnp.int32)
    w_ro = jnp.asarray(program.fc_stack[-1].w).astype(jnp.int32)
    v_traj = (jnp.asarray(state.vs[-1])[None]
              + jnp.cumsum(ro_in @ w_ro, axis=0))
    rasters = None
    if emit_rasters:
        rasters = ([spikes_enc] + list(conv_maps)
                   + [jnp.asarray(r) for r in rasters_fc])
    v_out = jnp.asarray(v_stack[-1])
    return (StreamState(vs=new_vs, t=state.t + k),
            MegastepOut(v_out=v_out, logits=program.logits(v_out),
                        v_out_traj=v_traj,
                        logits_traj=program.logits(v_traj),
                        frames_consumed=consumed, rasters=rasters,
                        skips=skips,
                        conv_skips=conv_skips if conv_skips else None))


def _bitmacro_layer(inp: np.ndarray, wq: np.ndarray, threshold: int,
                    leak: int, neuron: str):
    """Execute one spiking layer, (T, F, n_in) bool input frames ->
    ((T, F, n_out) int8 spikes, (F, n_out) final V, InstrCount), on a bank
    of bit-level macros — the distributed multi-macro architecture:

      * frames (batch elements, or (example, output position) pairs for
        im2col-lowered convs) map onto V_MEM neuron sets, 13 per macro
        grid; frame counts beyond 13 claim additional macro banks;
      * fan-in splits over ``row_tiles`` macros (mapping.tile_weights).
        Row tile 0 holds the persistent membrane state and the neuron
        constants; tiles >= 1 accumulate per-timestep partial sums that a
        word-level AccV2V (odd+even cycle per tile) reduces into tile 0
        before the neuron-update sequence runs there. Wrap arithmetic
        makes the split exact: mod-2^11 addition composes, so reduced
        per-tile partials equal the single-accumulate word semantics bit
        for bit (the reason saturate mode is word-level-only, macro.py).

    Executed cycles equal `isa.count_layer_instructions` on the input
    raster exactly: 2 AccW2V per event per col tile, 2(row_tiles-1) AccV2V
    reduction cycles per (frame, timestep, col tile), plus the per-neuron
    update sequence."""
    from repro.core.macro import BitMacro
    t_total, n_frames, n_in = inp.shape
    n_out = wq.shape[1]
    tiling = mapping.fc_tiling(n_in, n_out)
    wq_tiles = mapping.tile_weights(np.asarray(wq))
    n_banks = -(-n_frames // isa.N_NEURON_SETS)
    banks = [[[BitMacro.from_weights(wq_tiles[r, c], threshold=threshold,
                                     leak=leak)
               for c in range(tiling.col_tiles)]
              for r in range(tiling.row_tiles)]
             for _ in range(n_banks)]
    out = np.zeros((t_total, n_frames, n_out), np.int8)
    for t in range(t_total):
        for f in range(n_frames):
            bank, set_idx = divmod(f, isa.N_NEURON_SETS)
            grid = banks[bank]
            for row in np.nonzero(inp[t, f])[0]:        # event-driven AccW2V
                r, macro_row = divmod(int(row), isa.MACRO_IN)
                for c in range(tiling.col_tiles):
                    grid[r][c].acc_w2v(set_idx, macro_row, cycle=0)
                    grid[r][c].acc_w2v(set_idx, macro_row, cycle=1)
            for r in range(1, tiling.row_tiles):        # AccV2V reduction
                for c in range(tiling.col_tiles):
                    partial = grid[r][c].transfer_v(set_idx)
                    for cycle in (0, 1):
                        grid[0][c].acc_v2v(set_idx, partial, cycle)
            spikes = np.concatenate(
                [grid[0][c].neuron_update(set_idx, neuron)
                 for c in range(tiling.col_tiles)])
            out[t, f] = spikes[:n_out].astype(np.int8)
    v = np.stack([
        np.concatenate([banks[f // isa.N_NEURON_SETS][0][c]
                        .read_v(f % isa.N_NEURON_SETS)
                        for c in range(tiling.col_tiles)])
        for f in range(n_frames)])[:, :n_out]
    counts = sum((m.counts for bank in banks for row in bank for m in row),
                 isa.InstrCount())
    return out, v.astype(np.int32), counts


@register_backend("bitmacro")
def run_bitmacro(program: SNNProgram, xs: jax.Array) -> NetResult:
    """Execute ``program``'s on-macro stack on ``xs`` currents through the
    bit-accurate macro model (the silicon oracle).
    Layers with fan-in > 128 split over row-tiled macros
    whose partial sums reduce with word-level AccV2V cycles; conv layers
    lower via im2col onto the same grid (one neuron set per (example,
    output position)); frames beyond 13 neuron sets claim extra macro
    banks. The one remaining constraint is the silicon's two's-complement
    *wrap* arithmetic (saturation is a word-level deployment policy, not
    silicon — and the only mode in which split partial sums compose
    exactly; compile with clamp_mode='wrap', see macro.py)."""
    if program.clamp_mode != "wrap":
        raise ValueError("bitmacro executes silicon wrap arithmetic; compile "
                         "the program with clamp_mode='wrap'")
    spikes_enc, v_enc = encode(program, xs)
    cur = np.asarray(spikes_enc).astype(np.int8)       # (T, B, ...) spikes
    t_total, batch = cur.shape[:2]
    stack = program.macro_stack

    rasters = [jnp.asarray(cur)]
    v_stack: list = []
    total = isa.InstrCount()
    for spec in stack[:-1]:
        if spec.kind == "conv":
            patches = np.asarray(mapping.im2col_raster(
                cur, spec.w.shape[0], spec.stride))
            out_hw = mapping.conv_out_hw(cur.shape[2:4], spec.w.shape[0],
                                         spec.stride)
            inp = patches.astype(bool)
            wq = np.asarray(mapping.pack_conv_weights(spec.w))
        else:
            inp = cur.reshape(t_total, -1, spec.n_in).astype(bool)
            wq = np.asarray(spec.w)
        out, v, counts = _bitmacro_layer(inp, wq, int(spec.threshold),
                                         int(spec.leak), program.neuron)
        total += counts
        if spec.kind == "conv":
            cur = out.reshape(t_total, batch, *out_hw, spec.n_out)
            v = v.reshape(batch, *out_hw, spec.n_out)
        else:
            cur = out
        rasters.append(jnp.asarray(cur))
        v_stack.append(jnp.asarray(v))
    # readout: word-level wide accumulate (off the bit array, as deployed)
    flat = cur.reshape(t_total, batch, -1)
    wq_readout = np.asarray(stack[-1].w, np.int64)
    v_out = np.zeros((batch, stack[-1].n_out), np.int64)
    for t in range(t_total):
        v_out += flat[t].astype(np.int64) @ wq_readout
    v_stack.append(jnp.asarray(v_out.astype(np.int32)))
    res = _assemble(program, rasters, v_enc, v_stack)
    res.aux["macro_counts"] = total
    return res


# ---------------------------------------------------------------------------
# program-level sparsity measurement + instruction counting (the energy-
# model inputs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SparsityReport:
    """Measured event statistics of one program execution — the bridge from
    spike rasters to the energy model. Per fc-stack layer i (whose *input*
    raster is the output of neuron layer i): total input events, per-
    timestep occupancy, and the macro-tiling geometry needed to turn events
    into instruction cycles. Built from full rasters (`sparsity_report`,
    exact, per-timestep resolution) or from the float backend's
    ``collect_sums`` aggregates (`sparsity_report_from_sums`, raster-free —
    the training-loop-friendly path). Both feed
    `count_network_instructions(program, report=...)` and
    `energy.measured_edp*`."""
    n_in: tuple                   # fan-in per macro-stack layer
    n_out: tuple
    neurons: tuple                # per-layer update kind ("rmp"... | "none")
    events: tuple                 # total input spike events per layer
    frames: int                   # (timestep, example) pairs = T_total * B
    timesteps: int
    batch: int
    occupancy_t: Optional[tuple] = None   # per layer: (T_total,) mean input
                                          # occupancy per timestep (rasters
                                          # only; None from sums)
    layer_frames: Optional[tuple] = None  # per-layer frame counts; conv
                                          # layers run T*B*P frames (one per
                                          # output position). None = every
                                          # layer runs ``frames``
    row_events: Optional[tuple] = None    # per layer: (n_in,) int64 events
                                          # per input row over all frames —
                                          # the per-row event columns the
                                          # ref_events backend also reports

    @property
    def frames_by_layer(self) -> tuple:
        """Per-layer frame counts: ``layer_frames`` when set (conv layers
        run one frame per output position), else ``frames`` for every
        layer."""
        return (self.layer_frames if self.layer_frames is not None
                else tuple(self.frames for _ in self.n_in))

    @property
    def layer_sparsity(self) -> tuple:
        """1 - (events / possible events), per macro-stack layer input.
        A zero-frame execution (e.g. an empty serving request) has no gate
        sites; report sparsity 0 — no skip is claimed — rather than
        dividing by zero."""
        return tuple(1.0 - e / (f * n) if f * n else 0.0
                     for e, n, f in zip(self.events, self.n_in,
                                        self.frames_by_layer))

    @property
    def overall_sparsity(self) -> float:
        """Event-weighted network input sparsity (all layers pooled; 0.0
        for a zero-frame execution — see layer_sparsity)."""
        possible = sum(f * n for n, f in zip(self.n_in, self.frames_by_layer))
        return 1.0 - sum(self.events) / possible if possible else 0.0

    @property
    def silent_timestep_fraction(self) -> tuple:
        """Per layer: fraction of timesteps whose whole-batch input raster
        is silent — the whole-batch-granularity skip opportunity (the
        reference gate; per-batch-tile kernels skip at least this often)."""
        if self.occupancy_t is None:
            return tuple(None for _ in self.n_in)
        return tuple(float(np.mean(np.asarray(o) == 0.0))
                     for o in self.occupancy_t)

    @property
    def macro_timesteps(self) -> int:
        """Total macro-timesteps executed: every frame (a (timestep,
        example) pair, or (timestep, example, output position) for conv
        layers) runs its layer's col_tiles macro grids once — the
        normalizer that makes a measured InstrCount comparable to the
        paper's per-neuron per-timestep EDP curve
        (energy.measured_edp_per_neuron_timestep)."""
        return sum(f * mapping.fc_tiling(ni, no).col_tiles
                   for ni, no, f in zip(self.n_in, self.n_out,
                                        self.frames_by_layer))

    @property
    def row_skip_counts(self) -> tuple:
        """Per layer: silent (frame, input-row) pairs — the AccW2V gate
        sites an event-driven (row-granular) executor skips. This is the
        count `ref_events` measures during execution; here it falls out of
        the raster statistics, and the two are tested equal."""
        return tuple(f * n - e
                     for e, n, f in zip(self.events, self.n_in,
                                        self.frames_by_layer))

    @property
    def skipped_row_fraction(self) -> float:
        """Fraction of all (frame, row) gate sites that were silent —
        numerically ``overall_sparsity``, surfaced under the gating name
        so benchmark rows and the CI gate read as work skipped."""
        return self.overall_sparsity

    def block_event_counts(self, granularity: int) -> tuple:
        """Per layer: (n_blocks,) input-event totals per row block at the
        requested gate granularity — the same counted blocks
        `kernel.skip_layout` assigns skip columns to (128/G lanes each at
        G > 1; the whole input width at 1). A block the kernel's gate ever
        skipped for the full batch necessarily has zero events here, and
        each layer's blocks sum back to its total event count."""
        if self.row_events is None:
            raise ValueError("block_event_counts needs per-row event "
                             "columns; build the report from rasters or "
                             "collect_sums (row_events=None)")
        from repro.kernels.fused_snn_net.kernel import (GATE_GRANULARITIES,
                                                        LANE)
        if granularity not in GATE_GRANULARITIES:
            raise ValueError(f"gate granularity must be one of "
                             f"{GATE_GRANULARITIES}, got {granularity}")
        # per-layer block counts, NOT the joint skip_layout: the kernel
        # lays out skip columns per fused_snn_net call (each conv layer is
        # its own call), so the MAX_SKIP_COLS cap must not apply across
        # the whole macro stack here
        out = []
        for rows in self.row_events:
            rows = np.asarray(rows)
            bw = len(rows) if granularity == 1 else LANE // granularity
            nb = -(-len(rows) // bw)
            padded = np.zeros(nb * bw, rows.dtype)
            padded[:len(rows)] = rows
            out.append(padded.reshape(nb, bw).sum(axis=1))
        return tuple(out)

    def instruction_counts(self) -> isa.InstrCount:
        """Event statistics -> instruction cycles (identical to counting the
        rasters directly: both route through
        isa.count_layer_instructions_from_events)."""
        counts = isa.InstrCount()
        for ni, no, neuron, ev, f in zip(self.n_in, self.n_out, self.neurons,
                                         self.events, self.frames_by_layer):
            counts += isa.count_layer_instructions_from_events(
                ev, f, ni, no, neuron)
        return counts

    def skipped_instruction_counts(self) -> isa.InstrCount:
        """Instruction cycles event-driven execution never issued: the
        AccW2V cycles of every silent (frame, input-row) pair — the
        row-granular skip model behind the Fig. 11b curve (executed +
        skipped == the dense tally at sparsity 0)."""
        counts = isa.InstrCount()
        for ni, no, ev, f in zip(self.n_in, self.n_out, self.events,
                                 self.frames_by_layer):
            counts += isa.count_skipped_instructions_from_events(
                ev, f, ni, no)
        return counts


def _report_geometry(program: SNNProgram) -> tuple:
    stack = program.macro_stack
    return (tuple(ly.n_in for ly in stack), tuple(ly.n_out for ly in stack),
            tuple("none" if ly.kind == "readout" else program.neuron
                  for ly in stack))


def _stack_input_rasters(program: SNNProgram, rasters: list) -> list:
    """Normalize a raster list onto the macro stack: take the trailing
    len(macro_stack) entries (float-domain conv programs emit one raster
    per neuron layer, whose tail is exactly the macro-stack inputs), then
    lower conv-layer entries — (T, B, H, W, C) spike maps — to their
    (T, B*P, k*k*C) im2col patch rasters, the event stream the macro
    actually consumes. FC entries reshape to (T, frames, n_in)."""
    stack = program.macro_stack
    if len(rasters) < len(stack):
        raise ValueError(f"need one input raster per macro-stack layer "
                         f"({len(stack)}), got {len(rasters)}")
    out = []
    for spec, raster in zip(stack, rasters[-len(stack):]):
        r = np.asarray(raster)
        if spec.kind == "conv":
            r = np.asarray(mapping.im2col_raster(r, spec.w.shape[0],
                                                 spec.stride))
        out.append(r.reshape(r.shape[0], -1, spec.n_in))
    return out


def sparsity_report(program: SNNProgram, rasters: list) -> SparsityReport:
    """Exact report from per-layer input rasters (`NetResult.rasters`):
    rasters[i] is (T_total, B, n_in_i) for macro-stack layer i — or the
    (T_total, B, H, W, C) input spike map for a conv layer, which is
    lowered to its im2col patch raster here (events are counted per
    output position, as the macro issues them)."""
    if rasters is None:
        raise ValueError("sparsity_report needs spike rasters; run the "
                         "backend with emit_rasters=True (accounting mode), "
                         "or build the report from collect_sums aggregates")
    n_in, n_out, neurons = _report_geometry(program)
    rs = _stack_input_rasters(program, rasters)
    T = rs[0].shape[0]
    B = int(np.asarray(rasters[-1]).shape[1])     # fc rasters carry batch
    return SparsityReport(
        n_in=n_in, n_out=n_out, neurons=neurons,
        events=tuple(int(r.sum()) for r in rs),
        frames=T * B, timesteps=T, batch=B,
        occupancy_t=tuple(r.mean(axis=(1, 2)) for r in rs),
        layer_frames=tuple(T * r.shape[1] for r in rs),
        row_events=tuple(r.astype(np.int64).sum(axis=(0, 1)) for r in rs))


def sparsity_report_from_sums(program: SNNProgram, spike_sums: list,
                              timesteps: int) -> SparsityReport:
    """Raster-free report from the float backend's ``collect_sums`` aux:
    spike_sums[i] is the (B, ...) per-neuron spike-count total of neuron
    layer i. The last len(macro_stack) neuron layers feed the macro stack,
    so their totals are exactly the per-layer input event counts. Conv-fed
    layers see each input pixel once per covering patch; im2col is linear,
    so the patch event total is ``im2col(sum map).sum()`` — exact. Per-
    timestep occupancy is not recoverable from sums (occupancy_t=None)."""
    n_in, n_out, neurons = _report_geometry(program)
    stack = program.macro_stack
    sums = spike_sums[-len(stack):]
    if len(sums) != len(n_in):
        raise ValueError(f"need one spike-sum per macro-stack layer input "
                         f"({len(n_in)}), got {len(spike_sums)}")
    B = int(np.asarray(sums[0]).shape[0])
    events, layer_frames, row_events = [], [], []
    for spec, s in zip(stack, sums):
        s = np.asarray(s)
        if spec.kind == "conv":
            patches = np.asarray(mapping.im2col(s, spec.w.shape[0],
                                                spec.stride))
            # int64 element-wise cast before summing: the f32 counts are
            # integer-valued, but f32 *accumulation* loses exactness > 2^24
            rows = patches.astype(np.int64).reshape(-1, spec.n_in).sum(axis=0)
            layer_frames.append(timesteps * B
                                * patches.shape[1] * patches.shape[2])
        else:
            rows = s.astype(np.int64).reshape(-1, spec.n_in).sum(axis=0)
            layer_frames.append(timesteps * B)
        row_events.append(rows)
        events.append(int(rows.sum()))
    return SparsityReport(
        n_in=n_in, n_out=n_out, neurons=neurons, events=tuple(events),
        frames=timesteps * B, timesteps=timesteps, batch=B,
        layer_frames=tuple(layer_frames), row_events=tuple(row_events))


def count_network_instructions(program: SNNProgram, rasters: list = None, *,
                               report: Optional[SparsityReport] = None
                               ) -> isa.InstrCount:
    """Fold the per-layer event counts over the whole program. ``rasters[i]``
    is the input raster of macro-stack layer i (conv layers take their input
    spike maps, lowered to im2col patch rasters here); identical rasters
    (which all backends are tested to produce) give identical counts by
    construction. Row-tiled layers include the AccV2V partial-sum reduction
    term (isa.count_layer_instructions_from_events) that the bitmacro
    backend executes cycle-for-cycle. Alternatively pass a `SparsityReport`
    (``report=...``) — the raster-free accounting path; both routes share
    one counting implementation."""
    if report is not None:
        return report.instruction_counts()
    if rasters is None:
        raise ValueError("instruction counting needs spike rasters (run the "
                         "backend with emit_rasters=True, accounting mode) "
                         "or a SparsityReport")
    counts = isa.InstrCount()
    for spec, r in zip(program.macro_stack,
                       _stack_input_rasters(program, rasters)):
        counts += isa.count_layer_instructions(
            r, spec.n_in, spec.n_out,
            "none" if spec.kind == "readout" else program.neuron)
    return counts
