"""Neuron models (IF / LIF / RMP) with surrogate-gradient spikes.

Float domain is used for surrogate-gradient training (DIET-SNN style [3]);
the integer domain (macro-exact) lives in isa.py/macro.py. Both implement the
same three dynamics the macro supports through its instruction sequences:

  IF  : v += i;                 s = v >= th;  v = where(s, v_reset, v)
  LIF : v += i; v -= leak;      s = v >= th;  v = where(s, v_reset, v)
  RMP : v += i;                 s = v >= th;  v = v - th * s        (soft reset)

The macro's leak is *subtractive* (AccV2V with a negative leak row), so that is
the default; multiplicative leak (DIET-SNN training convention) is provided for
training parity studies.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEURON_TYPES = ("if", "lif", "rmp")


# ---------------------------------------------------------------------------
# Surrogate-gradient spike
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(2,))
def spike(v: jax.Array, threshold: jax.Array, width: float = 1.0) -> jax.Array:
    """Heaviside spike with triangular surrogate gradient of half-width ``width``."""
    return (v >= threshold).astype(v.dtype)


def _spike_fwd(v, threshold, width):
    return spike(v, threshold, width), (v, threshold)


def _spike_bwd(width, res, g):
    v, threshold = res
    x = (v - threshold) / width
    surr = jnp.maximum(0.0, 1.0 - jnp.abs(x)) / width       # triangle, area 1
    gv = g * surr
    gth = -gv
    # reduce the threshold cotangent over broadcast axes down to its shape
    th_shape = jnp.shape(threshold)
    extra = gth.ndim - len(th_shape)
    if extra > 0:
        gth = jnp.sum(gth, axis=tuple(range(extra)))
    for ax, n in enumerate(th_shape):
        if n == 1 and gth.shape[ax] != 1:
            gth = jnp.sum(gth, axis=ax, keepdims=True)
    return gv, gth.reshape(th_shape).astype(jnp.result_type(threshold))


spike.defvjp(_spike_fwd, _spike_bwd)


class NeuronState(NamedTuple):
    v: jax.Array          # membrane potential


def init_state(shape, dtype=jnp.float32) -> NeuronState:
    return NeuronState(v=jnp.zeros(shape, dtype))


def neuron_step(state: NeuronState, current: jax.Array, *, neuron: str,
                threshold, leak=0.0, v_reset=0.0, leak_mode: str = "subtractive",
                surrogate_width: float = 1.0) -> tuple[NeuronState, jax.Array]:
    """One timestep of membrane dynamics. Returns (new_state, spikes)."""
    if neuron not in NEURON_TYPES:
        raise ValueError(f"unknown neuron {neuron!r}")
    v = state.v + current
    if neuron == "lif":
        if leak_mode == "subtractive":
            v = v - leak
        elif leak_mode == "multiplicative":
            v = v * (1.0 - leak)
        else:
            raise ValueError(f"unknown leak_mode {leak_mode!r}")
    s = spike(v, threshold, surrogate_width)
    if neuron == "rmp":
        v = v - threshold * s                                # soft reset
    else:                                                    # if / lif: hard reset
        v = jnp.where(s > 0, jnp.asarray(v_reset, v.dtype), v)
    return NeuronState(v=v), s


def accumulate_only_step(state: NeuronState, current: jax.Array) -> NeuronState:
    """Output-layer variant: integrate, never fire (paper's sentiment readout:
    sign of the final V_MEM is the prediction, Fig. 10)."""
    return NeuronState(v=state.v + current)
