"""Bit-accurate model of the IMPULSE 10T-SRAM fused-array macro.

This is the *silicon oracle*: it models the 72 shared bitline columns, the
odd/even read-wordline interleave, the staggered V_MEM slot layout, the
bitline-logic full adders (BLFA) with their Carry-MUX modes (LSB / CF / CS /
MSB), and the conditional write drivers — at single-bit granularity. The
word-level ISA (isa.py) and the TPU fast paths are validated against it.

Layout (derived from the letter's constraints; see DESIGN.md §2):

  * W_MEM rows: 128 rows x 72 columns. Weight j (of 12) occupies columns
    [6j .. 6j+5], LSB first, 6-bit two's complement; even j on RWLo (odd
    cycle), odd j on RWLe (even cycle).
  * V_MEM slots: 12 physical columns each, at columns [6j .. 6j+11] (mod 72).
    Even-j slots live in one row, odd-j slots in the staggered partner row —
    so slots never collide within a row, and in every cycle all 72 column
    peripherals are busy (full utilization, Fig. 3).
  * Guard bit: slot bit position 5 is structurally '0'. It shares its column
    with the weight's sign bit (col 6j+5), letting the carry-skip (CS) block
    read Wsign unambiguously from the bitline OR and broadcast it to the six
    upper columns — that is the sign extension of the 6-bit weight into the
    11-bit V word, and it is why V_MEM is 11 (not 12) bits.
  * V encoding: value bits v[0..4] at slot bits 0..4, v[5..10] at slot bits
    6..11; 11-bit two's complement (slot bit 11 = sign).
  * BLFA: the bitlines give OR and AND of the two enabled rows; the adder
    needs only XOR = OR & ~AND and AND — so A and B need never be read
    individually.
  * Carry-MUX modes per column: LSB (cin=0), CF (carry forward: bypass the
    guard column in V+V ops), CS (carry skip + Wsign broadcast in W+V ops),
    MSB (chain end; comparator flag out).
  * Comparator: SpikeCheck adds V + (-th) (threshold row stores the negated
    threshold) and takes the MSB peripheral's chain output; functionally this
    is the complemented sign of the 11-bit sum, i.e. v >= th whenever v-th is
    in 11-bit range (the letter's "COUT from MSB" wording).
  * Arithmetic wraps mod 2^11 (ripple adder with discarded final carry);
    saturation is a word-level policy, not silicon (isa.py clamp_mode).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.isa import MACRO_IN, MACRO_OUT, N_NEURON_SETS, InstrCount

COLS = 72
SLOT_BITS = 12
GUARD = 5                    # structural-zero slot bit position
W_BITS = 6
V_VALUE_BITS = 11


# ---------------------------------------------------------------------------
# Encodings
# ---------------------------------------------------------------------------

def encode_w(w: int) -> np.ndarray:
    """6-bit two's complement, LSB first."""
    if not -32 <= w <= 31:
        raise ValueError(f"weight {w} exceeds the 6-bit two's-complement "
                         "range [-32, 31]")
    u = w & 0x3F
    return np.array([(u >> i) & 1 for i in range(W_BITS)], dtype=np.uint8)


def decode_w(bits: np.ndarray) -> int:
    u = int(sum(int(b) << i for i, b in enumerate(bits)))
    return u - 64 if u >= 32 else u


def encode_v(v: int) -> np.ndarray:
    """11-bit two's complement into a 12-bit slot with guard bit 5 == 0."""
    u = int(v) & 0x7FF
    bits = np.zeros(SLOT_BITS, dtype=np.uint8)
    for i in range(5):
        bits[i] = (u >> i) & 1
    for i in range(5, 11):
        bits[i + 1] = (u >> i) & 1
    return bits


def decode_v(bits: np.ndarray) -> int:
    if bits[GUARD] != 0:
        raise ValueError("guard bit violated: V slot carries a non-zero "
                         f"bit at guard position {GUARD}")
    u = sum(int(bits[i]) << i for i in range(5))
    u += sum(int(bits[i + 1]) << i for i in range(5, 11))
    return u - 2048 if u >= 1024 else u


def slot_columns(j: int) -> np.ndarray:
    """Physical columns of V slot j (staggered, wraps at 72)."""
    return (6 * j + np.arange(SLOT_BITS)) % COLS


# ---------------------------------------------------------------------------
# The bit-serial adder unit (12 columns, one slot)
# ---------------------------------------------------------------------------

def blfa_unit_add(a: np.ndarray, b: np.ndarray, guard_mode: str) -> tuple[np.ndarray, int, int]:
    """Ripple-carry add over one 12-column unit.

    a, b: (12,) slot bits. guard_mode: 'CS' (W+V: skip guard, b[>5] is the
    broadcast Wsign) or 'CF' (V+V: bypass guard). Returns (sum_bits with
    guard forced 0, msb_carry_out, sign_bit).

    Per column the bitlines sense OR(a,b) and AND(a,b); the BLFA forms
    XOR = OR & ~AND, SUM = XOR ^ cin, COUT = AND | (XOR & cin).
    """
    s = np.zeros(SLOT_BITS, dtype=np.uint8)
    cin = 0                                     # LSB mode
    for i in range(SLOT_BITS):
        if i == GUARD:
            # CS/CF: the Carry-MUX bypasses this peripheral's adder entirely
            s[i] = 0
            continue
        o, an = int(a[i] | b[i]), int(a[i] & b[i])
        x = o & (1 - an)                        # XOR from OR/AND only
        s[i] = x ^ cin
        cin = an | (x & cin)
    sign = int(s[SLOT_BITS - 1])
    return s, cin, sign                         # cin now = MSB carry-out


# ---------------------------------------------------------------------------
# Macro state (bit level)
# ---------------------------------------------------------------------------

@dataclass
class BitMacro:
    wbits: np.ndarray                           # (128, 72) uint8
    vbits: np.ndarray                           # (N_SETS, 2, 6, 12): set, parity row, slot-in-row, bit
    const: dict                                 # name -> (2, 6, 12) parity rows (threshold/reset/leak)
    spike_buf: np.ndarray                       # (N_SETS, 12) bool
    counts: InstrCount = field(default_factory=InstrCount)

    # -- construction -------------------------------------------------------
    @staticmethod
    def from_weights(wq: np.ndarray, threshold: int, reset: int = 0, leak: int = 0) -> "BitMacro":
        if wq.shape != (MACRO_IN, MACRO_OUT):
            raise ValueError(f"macro weight tile must be "
                             f"{(MACRO_IN, MACRO_OUT)}, got {wq.shape}")
        wbits = np.zeros((MACRO_IN, COLS), dtype=np.uint8)
        for r in range(MACRO_IN):
            for j in range(MACRO_OUT):
                wbits[r, 6 * j:6 * j + 6] = encode_w(int(wq[r, j]))
        vbits = np.zeros((N_NEURON_SETS, 2, 6, SLOT_BITS), dtype=np.uint8)
        for s in range(N_NEURON_SETS):
            for j in range(MACRO_OUT):
                vbits[s, j % 2, j // 2] = encode_v(0)
        const = {}
        for name, val in (("threshold_neg", -threshold), ("reset", reset), ("leak_neg", -leak)):
            rows = np.zeros((2, 6, SLOT_BITS), dtype=np.uint8)
            for j in range(MACRO_OUT):
                rows[j % 2, j // 2] = encode_v(val)
            const[name] = rows
        return BitMacro(wbits=wbits, vbits=vbits, const=const,
                        spike_buf=np.zeros((N_NEURON_SETS, MACRO_OUT), dtype=bool))

    # -- helpers -------------------------------------------------------------
    def _slot(self, set_idx: int, j: int) -> np.ndarray:
        return self.vbits[set_idx, j % 2, j // 2]

    def read_v(self, set_idx: int) -> np.ndarray:
        return np.array([decode_v(self._slot(set_idx, j)) for j in range(MACRO_OUT)])

    # -- instructions (one call = one cycle = one parity) --------------------
    def acc_w2v(self, set_idx: int, in_row: int, cycle: int) -> None:
        """Triple-row decode: W RWLo/e + V RWL + V WWL. Adds the 6 parity
        weights of `in_row` into the 6 same-parity V slots simultaneously."""
        for j in range(cycle, MACRO_OUT, 2):
            wslice = self.wbits[in_row, 6 * j:6 * j + 6]
            wsign = int(wslice[W_BITS - 1])
            b = np.zeros(SLOT_BITS, dtype=np.uint8)
            b[:5] = wslice[:5]
            b[GUARD] = wsign                     # shares the guard column; readable because guard==0
            b[GUARD + 1:] = wsign                # CS broadcast = sign extension
            a = self._slot(set_idx, j)
            s, _, _ = blfa_unit_add(a, b, guard_mode="CS")
            self.vbits[set_idx, j % 2, j // 2] = s
        self.counts += InstrCount(acc_w2v=1)

    def _vv_operand(self, name_or_set, set_idx: int, j: int) -> np.ndarray:
        if isinstance(name_or_set, str):
            return self.const[name_or_set][j % 2, j // 2]
        if isinstance(name_or_set, np.ndarray):   # another macro's V rows
            return name_or_set[j % 2, j // 2]
        return self.vbits[name_or_set, j % 2, j // 2]

    def acc_v2v(self, set_idx: int, src, cycle: int, conditional: bool = False) -> None:
        """V[set, parity] += src[parity]. ``src`` is a const-row name, a
        local set index, or a (2, 6, 12) bit array exported by another
        macro's `transfer_v` — the word-level AccV2V partial-sum reduction
        of the distributed multi-macro architecture (mapping.py)."""
        for j in range(cycle, MACRO_OUT, 2):
            if conditional and not self.spike_buf[set_idx, j]:
                continue                         # CWD leaves bitlines precharged
            a = self._slot(set_idx, j)
            b = self._vv_operand(src, set_idx, j)
            s, _, _ = blfa_unit_add(a, b, guard_mode="CF")
            self.vbits[set_idx, j % 2, j // 2] = s
        self.counts += InstrCount(acc_v2v=1)

    def transfer_v(self, set_idx: int) -> np.ndarray:
        """Export one neuron set's V rows for a cross-macro AccV2V and clear
        them to zero — the fan-in-split macro handing its partial sum to the
        reduction target. The executed cycles are counted on the *receiving*
        macro's `acc_v2v` (one macro-to-macro AccV2V instruction drives both
        arrays in the same cycle: this macro reads its bitlines while the
        target's BLFA adds; the CWD rewrites the reset pattern on the way
        out), matching the analytic reduction term of
        `isa.count_layer_instructions_from_events` exactly."""
        bits = self.vbits[set_idx].copy()
        self.vbits[set_idx] = 0                    # encode_v(0) is all-zero
        return bits

    def spike_check(self, set_idx: int, cycle: int) -> None:
        """Adder-as-comparator against the (negated) threshold row; latches
        the spike buffers. Read-only on V."""
        for j in range(cycle, MACRO_OUT, 2):
            a = self._slot(set_idx, j)
            b = self.const["threshold_neg"][j % 2, j // 2]
            _, _, sign = blfa_unit_add(a, b, guard_mode="CF")
            self.spike_buf[set_idx, j] = (sign == 0)   # v - th >= 0
        self.counts += InstrCount(spike_check=1)

    def reset_v(self, set_idx: int, cycle: int) -> None:
        """BLFA bypassed: SINV -> CWD direct; write gated by spike buffers."""
        for j in range(cycle, MACRO_OUT, 2):
            if self.spike_buf[set_idx, j]:
                self.vbits[set_idx, j % 2, j // 2] = self.const["reset"][j % 2, j // 2].copy()
        self.counts += InstrCount(reset_v=1)

    # -- neuron-update sequences (Fig. 6) ------------------------------------
    def neuron_update(self, set_idx: int, neuron: str) -> np.ndarray:
        if neuron == "lif":
            for c in (0, 1):
                self.acc_v2v(set_idx, "leak_neg", c)
        for c in (0, 1):
            self.spike_check(set_idx, c)
        if neuron == "rmp":
            for c in (0, 1):
                self.acc_v2v(set_idx, "threshold_neg", c, conditional=True)
        elif neuron in ("if", "lif"):
            for c in (0, 1):
                self.reset_v(set_idx, c)
        else:
            raise ValueError(neuron)
        return self.spike_buf[set_idx].copy()

    def timestep(self, set_idx: int, in_spikes: np.ndarray, neuron: str) -> np.ndarray:
        rows = np.nonzero(np.asarray(in_spikes).astype(bool))[0]
        for r in rows:
            self.acc_w2v(set_idx, int(r), cycle=0)
            self.acc_w2v(set_idx, int(r), cycle=1)
        return self.neuron_update(set_idx, neuron)


def physical_layout_check() -> bool:
    """Verify the staggered slot layout: within each parity row slots are
    column-disjoint and jointly cover all 72 columns; across W/V the weight
    columns are the low half of their slot."""
    for parity in (0, 1):
        cols: list[int] = []
        for j in range(parity, MACRO_OUT, 2):
            cols.extend(slot_columns(j).tolist())
        if sorted(cols) != list(range(COLS)):
            raise RuntimeError(
                f"staggered layout broken: parity-{parity} slots do not "
                f"tile the {COLS} columns ({sorted(cols)})")
    for j in range(MACRO_OUT):
        if list(slot_columns(j)[:6]) != list(range(6 * j, 6 * j + 6)):
            raise RuntimeError(
                f"slot {j}: weight columns are not the low half of the "
                "slot")
    return True
