"""Trainable SNN stack: the paper's IMDB sentiment net and MNIST LeNet5-mod.

Training follows DIET-SNN [3]: surrogate-gradient BPTT with trainable
per-layer threshold and leak, QAT to the macro's 6-bit weights. Inference has
two paths that are tested to agree:
  * float path (this file) — fake-quantized weights, float V;
  * macro path — true int8 weights + 11-bit V via isa.layer_timestep_int
    (and, transitively, the bit-accurate BitMacro), producing the spike
    rasters and instruction counts that drive the energy model.

Paper network (IMDB): GloVe-100d word -> encoder(100 IF/RMP neurons, spike
encoding) -> FC 100x128 -> FC 128x128 (both spiking, on-macro) -> FC 128x1
accumulate-only readout; each word presented `timesteps`(=10) steps, membrane
potentials persist across words (the sequential-memory claim, Fig. 1/10).
29,312 trainable weights (paper: 29.3K).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import isa
from repro.core.neuron import NeuronState, neuron_step, spike
from repro.core.quant import fake_quant_w, quantize_w, quantize_const, clamp_v


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_fc_snn(key: jax.Array, cfg: SNNModelConfig) -> dict:
    sizes = cfg.layer_sizes
    keys = jax.random.split(key, len(sizes) - 1)
    layers = []
    for k, (n_in, n_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (n_in, n_out), jnp.float32) * (2.0 / np.sqrt(n_in))
        layers.append({"w": w})
    n_spiking = len(sizes) - 2                    # last layer is accumulate-only
    return {
        "layers": layers,
        "threshold": jnp.full((n_spiking + 1,), cfg.spiking.threshold),  # [0] = encoder
        "leak": jnp.full((n_spiking + 1,), cfg.spiking.leak),
    }


def param_count(params: dict) -> int:
    return sum(int(np.prod(l["w"].shape)) for l in params["layers"])


# ---------------------------------------------------------------------------
# Temporal core (float / QAT path)
# ---------------------------------------------------------------------------

def _hidden_init(batch: int, cfg: SNNModelConfig):
    sizes = cfg.layer_sizes
    vs = [jnp.zeros((batch, sizes[0]))]                     # encoder V
    vs += [jnp.zeros((batch, n)) for n in sizes[1:-1]]      # spiking layers
    vs += [jnp.zeros((batch, sizes[-1]))]                   # output accumulator
    return vs


def _one_step(params, vs, x, cfg: SNNModelConfig, quantize: bool):
    """One SNN timestep. x: (B, n_in) analog input current. Returns new vs,
    per-layer spikes."""
    neuron = cfg.spiking.neuron
    th = jax.nn.softplus(params["threshold"]) + 1e-3        # keep positive
    lk = jax.nn.softplus(params["leak"]) * 0.1
    spikes = []
    # encoder: analog current -> spikes (the paper's "input layer")
    st, s = neuron_step(NeuronState(vs[0]), x, neuron=neuron,
                        threshold=th[0], leak=lk[0])
    vs_new = [st.v]
    spikes.append(s)
    cur = s
    # hidden spiking FC layers (on-macro)
    for i, layer in enumerate(params["layers"][:-1]):
        w = fake_quant_w(layer["w"]) if quantize else layer["w"]
        st, s = neuron_step(NeuronState(vs[i + 1]), cur @ w, neuron=neuron,
                            threshold=th[i + 1], leak=lk[i + 1])
        vs_new.append(st.v)
        spikes.append(s)
        cur = s
    # output layer: accumulate only (readout = final membrane potential)
    w = fake_quant_w(params["layers"][-1]["w"]) if quantize else params["layers"][-1]["w"]
    vs_new.append(vs[-1] + cur @ w)
    return vs_new, spikes


def sentiment_apply(params: dict, x_words: jax.Array, cfg: SNNModelConfig,
                    quantize: bool = True, return_trace: bool = False):
    """x_words: (B, n_words, d_in). Returns logits (B,) = final output V, plus
    aux dict (per-layer mean spike rates per timestep; optional V trace)."""
    B, n_words, d_in = x_words.shape
    T = cfg.timesteps

    def step(vs, xt):
        vs, spikes = _one_step(params, vs, xt, cfg, quantize)
        rates = jnp.stack([s.mean() for s in spikes])
        return vs, (rates, vs[-1][:, 0] if return_trace else jnp.zeros(B))

    # word w presented for T consecutive steps
    xs = jnp.repeat(x_words, T, axis=1)                     # (B, n_words*T, d)
    xs = jnp.moveaxis(xs, 1, 0)                             # (T_total, B, d)
    vs, (rates, trace) = jax.lax.scan(step, _hidden_init(B, cfg), xs)
    logits = vs[-1][:, 0]
    aux = {"spike_rates": rates, "v_trace": trace}
    return logits, aux


def sentiment_loss(params, x_words, labels, cfg: SNNModelConfig, quantize=True):
    logits, aux = sentiment_apply(params, x_words, cfg, quantize)
    # scale: output V grows with n_words*T; normalize for a stable BCE
    z = logits / (cfg.timesteps * x_words.shape[1]) * 8.0
    loss = jnp.mean(jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z))))
    acc = jnp.mean((logits > 0) == (labels > 0.5))
    return loss, {"accuracy": acc, **aux}


# ---------------------------------------------------------------------------
# Macro (integer) inference path — bit-exact with the ISA / silicon model
# ---------------------------------------------------------------------------

def quantize_params(params: dict, cfg: SNNModelConfig):
    """Float params -> per-layer (wq int8, scale, th_int, leak_int)."""
    th = np.asarray(jax.nn.softplus(params["threshold"]) + 1e-3)
    lk = np.asarray(jax.nn.softplus(params["leak"]) * 0.1)
    out = []
    for i, layer in enumerate(params["layers"]):
        wq, scale = quantize_w(layer["w"])
        is_out = i == len(params["layers"]) - 1
        th_i = None if is_out else int(quantize_const(float(th[i + 1]), scale))
        lk_i = None if is_out else int(quantize_const(float(lk[i + 1]), scale))
        out.append({"wq": wq, "scale": float(scale), "th": th_i, "leak": lk_i})
    return out, {"enc_th": float(th[0]), "enc_leak": float(lk[0])}


def sentiment_apply_int(params: dict, x_words: jax.Array, cfg: SNNModelConfig):
    """Integer-domain inference (the deployed macro program). Returns
    (logits_float, spike_rasters list[(T_total, B, n)], instruction counts)."""
    qlayers, enc = quantize_params(params, cfg)
    B, n_words, d_in = x_words.shape
    T = cfg.timesteps
    neuron = cfg.spiking.neuron

    xs = jnp.repeat(x_words, T, axis=1)
    xs = jnp.moveaxis(xs, 1, 0)                             # (T_total, B, d)

    def step(carry, xt):
        v_enc, v_hidden, v_out = carry
        # encoder in float (off-macro, like the paper's input layer)
        st, s = neuron_step(NeuronState(v_enc), xt, neuron=neuron,
                            threshold=enc["enc_th"], leak=enc["enc_leak"])
        v_enc = st.v
        cur = s.astype(jnp.int32)
        rasters = [cur]
        v_hidden_new = []
        for i, ql in enumerate(qlayers[:-1]):
            v, s_out = isa.layer_timestep_int(
                v_hidden[i], jnp.asarray(ql["wq"]), cur, neuron=neuron,
                threshold=jnp.int32(ql["th"]), leak=jnp.int32(ql["leak"]),
                reset=jnp.int32(0))
            v_hidden_new.append(v)
            cur = s_out
            rasters.append(cur)
        # output: accumulate int, no clamp to 11b growth issue -> use wide acc
        wq_out = jnp.asarray(qlayers[-1]["wq"], jnp.int32)
        v_out = v_out + cur @ wq_out
        return (v_enc, v_hidden_new, v_out), rasters

    v_hidden0 = [jnp.zeros((B, l["wq"].shape[1]), jnp.int32) for l in qlayers[:-1]]
    v_out0 = jnp.zeros((B, qlayers[-1]["wq"].shape[1]), jnp.int32)
    carry, rasters = jax.lax.scan(step, (jnp.zeros((B, d_in)), v_hidden0, v_out0), xs)
    logits = carry[2][:, 0].astype(jnp.float32) * qlayers[-1]["scale"]

    counts = isa.InstrCount()
    for i, ql in enumerate(qlayers):
        r = np.asarray(rasters[i])
        counts += isa.count_layer_instructions(
            r, r.shape[-1], ql["wq"].shape[1],
            neuron if i < len(qlayers) - 1 else "none")
    return logits, rasters, counts


# ---------------------------------------------------------------------------
# MNIST LeNet5-mod (conv spike encoder + on-macro convs/FCs)
# ---------------------------------------------------------------------------

def init_lenet_snn(key: jax.Array, cfg: SNNModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.conv_spec) + len(cfg.layer_sizes) - 1)
    convs = []
    c_in = cfg.in_shape[-1]
    for i, (c_out, k, stride) in enumerate(cfg.conv_spec):
        w = jax.random.normal(keys[i], (k, k, c_in, c_out)) * (2.0 / np.sqrt(k * k * c_in))
        convs.append({"w": w})          # stride lives in cfg (params stay float)
        c_in = c_out
    layers = []
    sizes = cfg.layer_sizes
    for j, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(keys[len(cfg.conv_spec) + j], (n_in, n_out)) * (2.0 / np.sqrt(n_in))
        layers.append({"w": w})
    n_spiking = len(cfg.conv_spec) + len(sizes) - 2
    return {"convs": convs, "layers": layers,
            "threshold": jnp.full((n_spiking,), cfg.spiking.threshold),
            "leak": jnp.full((n_spiking,), cfg.spiking.leak)}


def _conv(x, w, stride):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def lenet_apply(params: dict, images: jax.Array, cfg: SNNModelConfig,
                quantize: bool = True):
    """images: (B, H, W, C). Returns class logits (B, n_classes) = output V."""
    B = images.shape[0]
    neuron = cfg.spiking.neuron
    th = jax.nn.softplus(params["threshold"]) + 1e-3
    lk = jax.nn.softplus(params["leak"]) * 0.1

    def shapes():
        x = jnp.zeros((1, *cfg.in_shape))
        vs = []
        for c, (_, _, stride) in zip(params["convs"], cfg.conv_spec):
            x = _conv(x, c["w"], stride)
            vs.append(x.shape[1:])
        return vs

    conv_shapes = shapes()
    v_convs = [jnp.zeros((B, *s)) for s in conv_shapes]
    v_fcs = [jnp.zeros((B, n)) for n in cfg.layer_sizes[1:-1]]
    v_out = jnp.zeros((B, cfg.layer_sizes[-1]))

    def step(carry, _):
        v_convs, v_fcs, v_out = carry
        cur = images                                        # direct encoding
        v_convs_new, v_fcs_new = [], []
        k = 0
        for i, c in enumerate(params["convs"]):
            w = fake_quant_w(c["w"]) if (quantize and i > 0) else c["w"]
            stride = cfg.conv_spec[i][2]
            st, s = neuron_step(NeuronState(v_convs[i]), _conv(cur, w, stride),
                                neuron=neuron, threshold=th[k], leak=lk[k])
            v_convs_new.append(st.v)
            cur = s
            k += 1
        cur = cur.reshape(B, -1)
        for j, layer in enumerate(params["layers"][:-1]):
            w = fake_quant_w(layer["w"]) if quantize else layer["w"]
            st, s = neuron_step(NeuronState(v_fcs[j]), cur @ w,
                                neuron=neuron, threshold=th[k], leak=lk[k])
            v_fcs_new.append(st.v)
            cur = s
            k += 1
        w = fake_quant_w(params["layers"][-1]["w"]) if quantize else params["layers"][-1]["w"]
        v_out_new = v_out + cur @ w
        return (v_convs_new, v_fcs_new, v_out_new), None

    (v_convs, v_fcs, v_out), _ = jax.lax.scan(
        step, (v_convs, v_fcs, v_out), None, length=cfg.timesteps)
    return v_out


def lenet_loss(params, images, labels, cfg: SNNModelConfig, quantize=True):
    logits = lenet_apply(params, images, cfg, quantize)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"accuracy": acc}
