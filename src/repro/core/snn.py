"""Trainable SNN stack: the paper's IMDB sentiment net and MNIST LeNet5-mod.

Training follows DIET-SNN [3]: surrogate-gradient BPTT with trainable
per-layer threshold and leak, QAT to the macro's 6-bit weights. All temporal
execution routes through the network-level pipeline (core.pipeline): this
module only owns parameter init and the task-facing wrappers. Inference has
two program domains that are tested to agree:
  * float domain — fake-quantized weights, float V (QAT training semantics);
  * int domain   — true int8 weights + 11-bit V, executable on any of the
    int_ref / pallas / bitmacro backends, producing the spike rasters and
    instruction counts that drive the energy model.

Paper network (IMDB): GloVe-100d word -> encoder(100 IF/RMP neurons, spike
encoding) -> FC 100x128 -> FC 128x128 (both spiking, on-macro) -> FC 128x1
accumulate-only readout; each word presented `timesteps`(=10) steps, membrane
potentials persist across words (the sequential-memory claim, Fig. 1/10).
29,312 trainable weights (paper: 29.3K).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.impulse_snn import SNNModelConfig
from repro.core import pipeline


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def init_fc_snn(key: jax.Array, cfg: SNNModelConfig) -> dict:
    sizes = cfg.layer_sizes
    keys = jax.random.split(key, len(sizes) - 1)
    layers = []
    for k, (n_in, n_out) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (n_in, n_out), jnp.float32) * (2.0 / np.sqrt(n_in))
        layers.append({"w": w})
    n_spiking = len(sizes) - 2                    # last layer is accumulate-only
    return {
        "layers": layers,
        "threshold": jnp.full((n_spiking + 1,), cfg.spiking.threshold),  # [0] = encoder
        "leak": jnp.full((n_spiking + 1,), cfg.spiking.leak),
    }


def param_count(params: dict) -> int:
    return sum(int(np.prod(ly["w"].shape)) for ly in params["layers"])


# ---------------------------------------------------------------------------
# IMDB sentiment wrappers (float / QAT and deployed integer programs)
# ---------------------------------------------------------------------------

def sentiment_apply(params: dict, x_words: jax.Array, cfg: SNNModelConfig,
                    quantize: bool = True, return_trace: bool = False):
    """x_words: (B, n_words, d_in). Returns logits (B,) = final output V, plus
    aux dict (per-layer mean spike rates per timestep; optional V trace)."""
    program = pipeline.compile_network(cfg, params, domain="float",
                                       quantize=quantize)
    xs = pipeline.present_words(x_words, cfg.timesteps)
    res = pipeline.run_network(program, xs, "float", return_trace=return_trace)
    return res.logits[:, 0], res.aux


def sentiment_loss(params, x_words, labels, cfg: SNNModelConfig, quantize=True):
    logits, aux = sentiment_apply(params, x_words, cfg, quantize)
    # scale: output V grows with n_words*T; normalize for a stable BCE
    z = logits / (cfg.timesteps * x_words.shape[1]) * 8.0
    loss = jnp.mean(jnp.maximum(z, 0) - z * labels + jnp.log1p(jnp.exp(-jnp.abs(z))))
    acc = jnp.mean((logits > 0) == (labels > 0.5))
    return loss, {"accuracy": acc, **aux}


def sentiment_apply_int(params: dict, x_words: jax.Array, cfg: SNNModelConfig,
                        backend: str = "int_ref", **backend_kw):
    """Integer-domain inference (the deployed macro program) on any integer
    backend ("int_ref" | "pallas" | "bitmacro"). Returns (logits_float,
    spike_rasters list[(T_total, B, n)], instruction counts). In serving
    mode (pallas with emit_rasters=False) rasters and counts are None —
    event accounting needs the rasters."""
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_words(x_words, cfg.timesteps)
    res = pipeline.run_network(program, xs, backend, **backend_kw)
    counts = (pipeline.count_network_instructions(program, res.rasters)
              if res.rasters is not None else None)
    return res.logits[:, 0], res.rasters, counts


# ---------------------------------------------------------------------------
# MNIST LeNet5-mod (conv spike encoder + on-macro convs/FCs)
# ---------------------------------------------------------------------------

def init_lenet_snn(key: jax.Array, cfg: SNNModelConfig) -> dict:
    keys = jax.random.split(key, len(cfg.conv_spec) + len(cfg.layer_sizes) - 1)
    convs = []
    c_in = cfg.in_shape[-1]
    for i, (c_out, k, stride) in enumerate(cfg.conv_spec):
        w = jax.random.normal(keys[i], (k, k, c_in, c_out)) * (2.0 / np.sqrt(k * k * c_in))
        convs.append({"w": w})          # stride lives in cfg (params stay float)
        c_in = c_out
    layers = []
    sizes = cfg.layer_sizes
    for j, (n_in, n_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(keys[len(cfg.conv_spec) + j], (n_in, n_out)) * (2.0 / np.sqrt(n_in))
        layers.append({"w": w})
    n_spiking = len(cfg.conv_spec) + len(sizes) - 2
    return {"convs": convs, "layers": layers,
            "threshold": jnp.full((n_spiking,), cfg.spiking.threshold),
            "leak": jnp.full((n_spiking,), cfg.spiking.leak)}


def lenet_apply(params: dict, images: jax.Array, cfg: SNNModelConfig,
                quantize: bool = True):
    """images: (B, H, W, C). Returns class logits (B, n_classes) = output V.
    Direct encoding: the image is the input current every timestep; the first
    conv is the (unquantized) spike encoder."""
    program = pipeline.compile_network(cfg, params, domain="float",
                                       quantize=quantize)
    return pipeline.run_network(program, images, "float",
                                static_input=True).v_out


def lenet_apply_int(params: dict, images: jax.Array, cfg: SNNModelConfig,
                    backend: str = "int_ref", **backend_kw):
    """Integer-domain LeNet5-mod inference — the deployed conv program: the
    first conv stays the float spike encoder, later convs lower onto the
    macro grid via im2col (6b weights, 11b V), FCs ride the fused stack.
    Runs on any integer backend ("int_ref" | "pallas" | "pallas_sparse" |
    "bitmacro", the latter needing clamp_mode='wrap'). Returns
    (logits (B, n_classes), spike rasters, instruction counts) — rasters
    and counts None in serving mode (emit_rasters=False)."""
    program = pipeline.compile_network(cfg, params, domain="int",
                                       **{k: backend_kw.pop(k)
                                          for k in ("clamp_mode",)
                                          if k in backend_kw})
    xs = pipeline.present_static(images, cfg.timesteps)
    res = pipeline.run_network(program, xs, backend, **backend_kw)
    counts = (pipeline.count_network_instructions(program, res.rasters)
              if res.rasters is not None else None)
    return res.logits, res.rasters, counts


def lenet_loss(params, images, labels, cfg: SNNModelConfig, quantize=True):
    logits = lenet_apply(params, images, cfg, quantize)
    logp = jax.nn.log_softmax(logits)
    loss = -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=1))
    acc = jnp.mean(jnp.argmax(logits, -1) == labels)
    return loss, {"accuracy": acc}
