"""IMPULSE core: the paper's contribution as a composable JAX library.

  quant    -- 6-bit weight / 11-bit membrane fixed point (+ STE QAT)
  neuron   -- IF / LIF / RMP dynamics with surrogate gradients
  isa      -- the four in-memory instructions, word-level semantics
  macro    -- bit-accurate silicon model (columns, BLFA, carry modes)
  mapping  -- layer -> multi-macro tiling
  energy   -- calibrated instruction-level energy / EDP model
  snn      -- trainable spiking networks (IMDB sentiment, MNIST LeNet5-mod)
"""
from repro.core import energy, isa, macro, mapping, neuron, quant, snn  # noqa: F401
