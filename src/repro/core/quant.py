"""Fixed-point quantization matching the IMPULSE macro's number formats.

The macro stores:
  * weights  W_MEM : 6-bit signed two's complement  -> integer range [-32, 31]
    (we use the symmetric range [-31, 31] for QAT so that -w is representable)
  * membrane V_MEM : 11-bit signed two's complement -> integer range [-1024, 1023]
    (12 physical columns; one bit slot is sacrificed so Wsign reads correctly
    through the shared bitlines -- see macro.py)

W and V share one fixed-point grid: V accumulates raw W integers, so a single
per-layer scale converts between float and macro domains. Thresholds, leaks and
reset values are quantized on the same grid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

W_BITS = 6
V_BITS = 11
W_MAX = 2 ** (W_BITS - 1) - 1          # 31
W_MIN = -W_MAX                          # symmetric QAT range
V_MAX = 2 ** (V_BITS - 1) - 1          # 1023
V_MIN = -(2 ** (V_BITS - 1))           # -1024
V_SPAN = 2 ** V_BITS                   # wraparound span of the 11-bit word


def w_scale(w: jax.Array) -> jax.Array:
    """Per-tensor symmetric scale so that max|w| maps to W_MAX."""
    return jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / W_MAX


def quantize_w(w: jax.Array, scale: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """float weights -> (int weights in [-31,31] as int8, scale)."""
    scale = w_scale(w) if scale is None else scale
    wq = jnp.clip(jnp.round(w / scale), W_MIN, W_MAX).astype(jnp.int8)
    return wq, scale


def dequantize_w(wq: jax.Array, scale: jax.Array) -> jax.Array:
    return wq.astype(jnp.float32) * scale


@jax.custom_vjp
def fake_quant_w(w: jax.Array) -> jax.Array:
    """Quantize-dequantize with straight-through estimator (QAT)."""
    wq, scale = quantize_w(w)
    return dequantize_w(wq, scale)


def _fq_fwd(w):
    return fake_quant_w(w), None


def _fq_bwd(_, g):
    return (g,)                         # STE: pass gradient through


fake_quant_w.defvjp(_fq_fwd, _fq_bwd)


def clamp_v(v: jax.Array, mode: str = "saturate") -> jax.Array:
    """Constrain membrane potential to the 11-bit signed range.

    ``saturate`` clips (the deployment-safe mode); ``wrap`` reproduces raw
    two's-complement rollover of the 12-column ripple adder when the guard
    bit is violated (silicon behaviour without saturation logic).
    """
    if mode == "saturate":
        return jnp.clip(v, V_MIN, V_MAX)
    if mode == "wrap":
        # two's-complement wrap into [-1024, 1023]
        return ((v - V_MIN) % V_SPAN) + V_MIN
    raise ValueError(f"unknown clamp mode {mode!r}")


def spike_compare(v: jax.Array, threshold, mode: str = "saturate") -> jax.Array:
    """SpikeCheck comparison semantics per clamp mode.

    The silicon comparator evaluates sign(v + (-th)) through the SAME
    11-bit ripple adder that does every other V op (macro.py), so in
    ``wrap`` mode the *comparison itself* wraps when v - th leaves the
    11-bit range. ``saturate`` is the word-level deployment-safe policy:
    a true comparison.
    """
    if mode == "wrap":
        return clamp_v(v - threshold, "wrap") >= 0
    return v >= threshold


def clamp_v_np(v: np.ndarray, mode: str = "saturate") -> np.ndarray:
    """Numpy twin of `clamp_v` for host-side executors (the event-list
    backend runs data-dependent compaction that does not jit). Keeping the
    only two clamp implementations side by side in this module is what lets
    the repo lint forbid ad-hoc clamping everywhere else."""
    if mode == "saturate":
        return np.clip(v, V_MIN, V_MAX)
    if mode == "wrap":
        return ((v - V_MIN) % V_SPAN) + V_MIN
    raise ValueError(f"unknown clamp mode {mode!r}")


def spike_compare_np(v: np.ndarray, threshold, mode: str = "saturate") -> np.ndarray:
    """Numpy twin of `spike_compare` (see `clamp_v_np`)."""
    if mode == "wrap":
        return clamp_v_np(v - threshold, "wrap") >= 0
    return v >= threshold


def quantize_const(x: float, scale: jax.Array, lo: int = V_MIN, hi: int = V_MAX) -> jax.Array:
    """Quantize a scalar (threshold / leak / reset) onto the shared grid."""
    return jnp.clip(jnp.round(x / scale), lo, hi).astype(jnp.int32)


def quantize_neuron_const(x: float, scale, clamp_mode: str = "saturate") -> jax.Array:
    """Quantize a neuron constant (threshold / leak / reset) into the 11-bit
    V word its const row actually stores, honouring the program's clamp mode.

    ``saturate`` clips exactly like `quantize_const`. ``wrap`` folds the
    rounded value with the same two's-complement rollover the datapath
    applies: a constant that rounds outside [V_MIN, V_MAX] must wrap, not
    clip, or the compiled constant disagrees with what every V op computes
    against it — and the static analyzer's constant ranges would no longer
    match execution. All threshold/leak quantization routes through here so
    the guarantee `const in [V_MIN, V_MAX]` holds by construction.
    """
    q = jnp.round(x / scale).astype(jnp.int32)
    return clamp_v(q, clamp_mode).astype(jnp.int32)
