"""Layer -> multi-macro tiling (Fig. 3b) and the distributed-macro geometry.

A single macro serves fan-in <= 128 and 12 output neurons. Larger layers tile
onto a (row_tiles x col_tiles) macro grid; partial sums along the fan-in split
are reduced with AccV2V instructions (the paper's "distributed multi-macro
architecture"). Conv layers map via im2col with the paper's fan-in rule
(k*k*c_in <= 128 per macro row block, e.g. 3*3*14 = 126): `im2col` extracts
the (kh, kw, c_in)-ordered patch vector of every output position, so one conv
layer becomes an FC layer of fan-in k*k*c_in over B*H_out*W_out frames, each
frame claiming one neuron set of the macro grid (`pack_conv_weights` flattens
the HWIO kernel onto the matching W_MEM rows).

The same tile constants seed the Pallas BlockSpecs (kernels/fused_snn_step):
the TPU analogue pads 128x12 to the MXU-aligned 128x128 lane tile.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.isa import MACRO_IN, MACRO_OUT


@dataclass(frozen=True)
class FCTiling:
    n_in: int
    n_out: int
    row_tiles: int          # fan-in splits (partial-sum groups)
    col_tiles: int          # output-neuron splits
    @property
    def n_macros(self) -> int:
        return self.row_tiles * self.col_tiles


def fc_tiling(n_in: int, n_out: int) -> FCTiling:
    return FCTiling(n_in, n_out,
                    row_tiles=math.ceil(n_in / MACRO_IN),
                    col_tiles=math.ceil(n_out / MACRO_OUT))


@dataclass(frozen=True)
class ConvTiling:
    fan_in: int             # k*k*c_in
    n_out_ch: int
    out_positions: int      # H_out * W_out (each position re-uses the macro grid)
    fc: FCTiling

    @property
    def n_macros(self) -> int:
        return self.fc.n_macros


def conv_tiling(kernel: int, c_in: int, c_out: int, out_hw: tuple[int, int]) -> ConvTiling:
    fan_in = kernel * kernel * c_in
    return ConvTiling(fan_in=fan_in, n_out_ch=c_out,
                      out_positions=out_hw[0] * out_hw[1],
                      fc=fc_tiling(fan_in, c_out))


def tile_weights(w: np.ndarray) -> np.ndarray:
    """(n_in, n_out) int weights -> (row_tiles, col_tiles, 128, 12), zero padded."""
    n_in, n_out = w.shape
    t = fc_tiling(n_in, n_out)
    out = np.zeros((t.row_tiles, t.col_tiles, MACRO_IN, MACRO_OUT), dtype=w.dtype)
    for r in range(t.row_tiles):
        for c in range(t.col_tiles):
            blk = w[r * MACRO_IN:(r + 1) * MACRO_IN, c * MACRO_OUT:(c + 1) * MACRO_OUT]
            out[r, c, :blk.shape[0], :blk.shape[1]] = blk
    return out


def untile_outputs(v: np.ndarray, n_out: int) -> np.ndarray:
    """(col_tiles, 12) -> (n_out,) dropping padding."""
    return v.reshape(-1)[:n_out]


# ---------------------------------------------------------------------------
# Conv -> macro-grid lowering (im2col over the 128-row fan-in rule)
# ---------------------------------------------------------------------------

def same_pads(size: int, kernel: int, stride: int) -> tuple[int, int, int]:
    """XLA "SAME" geometry along one spatial axis: (out_size, pad_lo, pad_hi)."""
    out = -(-size // stride)                       # ceil(size / stride)
    total = max((out - 1) * stride + kernel - size, 0)
    lo = total // 2
    return out, lo, total - lo


def conv_out_hw(in_hw: tuple[int, int], kernel: int, stride: int) -> tuple[int, int]:
    """Output (H, W) of a SAME-padded conv."""
    return (same_pads(in_hw[0], kernel, stride)[0],
            same_pads(in_hw[1], kernel, stride)[0])


def im2col(x, kernel: int, stride: int):
    """(B, H, W, C) -> (B, H_out, W_out, k*k*C) SAME-padded patch extraction.

    Patch features are ordered (kh, kw, c) — exactly the row order
    `pack_conv_weights` flattens the HWIO kernel with — so
    ``im2col(x) @ pack_conv_weights(w) == conv2d(x, w)`` bit-for-bit in
    integer arithmetic (zero padding contributes zero rows). Traceable
    (pure jnp slicing with static shapes), exact for int-valued inputs.
    """
    x = jnp.asarray(x)
    _, h, w, _ = x.shape
    h_out, lo_h, hi_h = same_pads(h, kernel, stride)
    w_out, lo_w, hi_w = same_pads(w, kernel, stride)
    xp = jnp.pad(x, ((0, 0), (lo_h, hi_h), (lo_w, hi_w), (0, 0)))
    cols = [xp[:, di:di + (h_out - 1) * stride + 1:stride,
               dj:dj + (w_out - 1) * stride + 1:stride, :]
            for di in range(kernel) for dj in range(kernel)]
    return jnp.concatenate(cols, axis=-1)


def pack_conv_weights(w):
    """HWIO conv kernel (k, k, c_in, c_out) -> W_MEM layout (k*k*c_in, c_out):
    one macro row per patch feature, in `im2col` feature order."""
    return jnp.asarray(w).reshape(-1, w.shape[-1])


def im2col_raster(raster, kernel: int, stride: int):
    """Temporal form: (T, B, H, W, C) spike maps -> (T, B*P, k*k*C) patch
    raster, P = H_out*W_out — the conv layer's input raster in the shape the
    FC executors consume (one frame per (example, output position))."""
    t, b = raster.shape[:2]
    patches = im2col(jnp.reshape(raster, (t * b, *raster.shape[2:])),
                     kernel, stride)
    return jnp.reshape(patches, (t, -1, patches.shape[-1]))


# TPU-side tile constants: the macro's 128-row fan-in aligns exactly with the
# MXU's 128 lanes; output neurons pad 12 -> 128 sublanes per BlockSpec tile.
TPU_LANE = 128
TPU_SUBLANE_F32 = 8
