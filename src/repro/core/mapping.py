"""Layer -> multi-macro tiling (Fig. 3b) and the distributed-macro geometry.

A single macro serves fan-in <= 128 and 12 output neurons. Larger layers tile
onto a (row_tiles x col_tiles) macro grid; partial sums along the fan-in split
are reduced with AccV2V instructions (the paper's "distributed multi-macro
architecture"). Conv layers map via im2col with the paper's fan-in rule
(k*k*c_in <= 128 per macro row block, e.g. 3*3*14 = 126).

The same tile constants seed the Pallas BlockSpecs (kernels/fused_snn_step):
the TPU analogue pads 128x12 to the MXU-aligned 128x128 lane tile.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from repro.core.isa import MACRO_IN, MACRO_OUT


@dataclass(frozen=True)
class FCTiling:
    n_in: int
    n_out: int
    row_tiles: int          # fan-in splits (partial-sum groups)
    col_tiles: int          # output-neuron splits
    @property
    def n_macros(self) -> int:
        return self.row_tiles * self.col_tiles


def fc_tiling(n_in: int, n_out: int) -> FCTiling:
    return FCTiling(n_in, n_out,
                    row_tiles=math.ceil(n_in / MACRO_IN),
                    col_tiles=math.ceil(n_out / MACRO_OUT))


@dataclass(frozen=True)
class ConvTiling:
    fan_in: int             # k*k*c_in
    n_out_ch: int
    out_positions: int      # H_out * W_out (each position re-uses the macro grid)
    fc: FCTiling

    @property
    def n_macros(self) -> int:
        return self.fc.n_macros


def conv_tiling(kernel: int, c_in: int, c_out: int, out_hw: tuple[int, int]) -> ConvTiling:
    fan_in = kernel * kernel * c_in
    return ConvTiling(fan_in=fan_in, n_out_ch=c_out,
                      out_positions=out_hw[0] * out_hw[1],
                      fc=fc_tiling(fan_in, c_out))


def tile_weights(w: np.ndarray) -> np.ndarray:
    """(n_in, n_out) int weights -> (row_tiles, col_tiles, 128, 12), zero padded."""
    n_in, n_out = w.shape
    t = fc_tiling(n_in, n_out)
    out = np.zeros((t.row_tiles, t.col_tiles, MACRO_IN, MACRO_OUT), dtype=w.dtype)
    for r in range(t.row_tiles):
        for c in range(t.col_tiles):
            blk = w[r * MACRO_IN:(r + 1) * MACRO_IN, c * MACRO_OUT:(c + 1) * MACRO_OUT]
            out[r, c, :blk.shape[0], :blk.shape[1]] = blk
    return out


def untile_outputs(v: np.ndarray, n_out: int) -> np.ndarray:
    """(col_tiles, 12) -> (n_out,) dropping padding."""
    return v.reshape(-1)[:n_out]


# TPU-side tile constants: the macro's 128-row fan-in aligns exactly with the
# MXU's 128 lanes; output neurons pad 12 -> 128 sublanes per BlockSpec tile.
TPU_LANE = 128
TPU_SUBLANE_F32 = 8
