"""Serving launcher: batched requests through the continuous-batching engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --requests 8 --max-new 12
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config, reduced_config
from repro.models import lm
from repro.serve import Request, ServeEngine


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = reduced_config(get_config(args.arch))
    params = lm.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = ServeEngine(params, cfg, batch_slots=args.slots,
                         max_len=args.max_len)
    rng = np.random.default_rng(args.seed)
    t0 = time.perf_counter()
    for rid in range(args.requests):
        plen = int(rng.integers(4, 17))
        engine.submit(Request(rid=rid,
                              prompt=rng.integers(0, cfg.vocab_size, plen),
                              max_new_tokens=args.max_new))
    done = engine.run_until_drained()
    dt = time.perf_counter() - t0
    total_tokens = sum(len(r.out_tokens) for r in done)
    print(f"served {len(done)} requests, {total_tokens} tokens "
          f"in {dt:.2f}s ({total_tokens/dt:.1f} tok/s on CPU)")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {len(r.out_tokens)} tokens -> {r.out_tokens[:8]}...")
    return done


if __name__ == "__main__":
    main()
