"""Production mesh construction. TPU v5e pod targets:
  single pod : (16, 16)    = 256 chips, axes (data, model)
  multi-pod  : (2, 16, 16) = 512 chips, axes (pod, data, model)

Defined as functions (not module constants) so importing never touches jax
device state; the dry-run sets xla_force_host_platform_device_count FIRST.
"""
from __future__ import annotations

import jax

# TPU v5e hardware constants (roofline terms, EXPERIMENTS.md §Roofline)
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
HBM_BYTES = 16 * 2**30          # 16 GiB per chip
ICI_BW = 50e9                   # bytes/s per link (~)


def make_mesh(shape, axes):
    """jax.make_mesh across jax versions: axis_types (and AxisType) only
    exist from jax 0.5; older jax builds the same Auto-typed mesh without
    the kwarg."""
    if hasattr(jax.sharding, "AxisType"):
        return jax.make_mesh(shape, axes,
                             axis_types=(jax.sharding.AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n_devices: int = 0, model: int = 1):
    """Small CPU mesh for tests (n devices must already exist)."""
    n = n_devices or len(jax.devices())
    return make_mesh((n // model, model), ("data", "model"))
