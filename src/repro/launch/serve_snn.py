"""Streaming SNN serving launcher: word streams through the V_MEM-slot
continuous-batching engine (`serve.SNNServeEngine`).

    PYTHONPATH=src python -m repro.launch.serve_snn --requests 8 \
        --slots 4 --sparsity 0.85 --backend int_ref

Each request is a synthetic word stream for the IMDB-geometry network:
a seeded spike raster at the offered sparsity, scaled by the encoder
threshold so the off-macro encoder reproduces it exactly (the same trick
benchmarks/serve_snn.py uses — offered sparsity is then exact, not
approximate). The engine streams all requests through fixed decode slots
whose per-slot state is the membrane-potential tree, and reports
throughput (frames/s and words/s), the skipped-work fraction from the
pooled per-slot event accounting, and the measured-EDP figure it implies.

``--stop-threshold`` enables the readout-confidence early exit;
``--megastep K`` advances every lane K frames per device dispatch,
``--pages N`` grows the V-slot pool to N pages of ``--slots`` lanes,
``--double-buffer`` stages the next frame block while one computes, and
``--poisson-gap G`` draws seeded Poisson arrivals (mean gap G frame
ticks) for the admission-control path; ``--quick`` shrinks everything
for the CI serving smoke step.

``--mesh DATA,MODEL`` serves over a `jax.sharding.Mesh`: lanes partition
over the data axis, row-tiled macro fan-in over the model axis, and the
outputs stay bit-identical to the single-device drain (docs/serving.md
§Mesh). The devices must exist before jax initialises — on CPU launch
with ``XLA_FLAGS=--xla_force_host_platform_device_count=N``.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs.impulse_snn import get_snn_config
from repro.core import energy, pipeline, snn
from repro.serve import SNNRequest, SNNServeEngine


def encoder_exact_frames(program, raster: np.ndarray) -> np.ndarray:
    """Input currents that make the float encoder emit ``raster`` exactly:
    x = threshold * raster drives V to exactly threshold on event ticks
    (fires, resets/subtracts back to rest) and leaves it unchanged on
    silent ones — so the offered raster IS the encoder output raster."""
    th = float(np.asarray(program.layers[0].threshold))
    return raster.astype(np.float32) * th


def make_requests(program, n_requests: int, n_words: int, timesteps: int,
                  sparsity: float, seed: int, stop_threshold=None,
                  poisson_gap=None) -> list:
    """Seeded synthetic word-stream requests. ``poisson_gap`` (mean
    inter-arrival gap in frame ticks) stamps each request with a Poisson
    ``arrival_tick`` — seeded exponential gaps, sorted by construction —
    so the engine's admission control sees an offered-load process instead
    of a batch arrival."""
    rng = np.random.default_rng(seed)
    d = program.layers[0].n_in
    reqs = []
    arrival = 0.0
    for rid in range(n_requests):
        t_total = n_words * timesteps
        raster = (rng.random((t_total, d)) > sparsity).astype(np.int8)
        req = SNNRequest(
            rid=rid, frames=encoder_exact_frames(program, raster),
            stop_threshold=stop_threshold)
        if poisson_gap:
            arrival += rng.exponential(poisson_gap)
            req.arrival_tick = int(arrival)
        reqs.append(req)
    return reqs


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="impulse-imdb")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--words", type=int, default=6)
    ap.add_argument("--sparsity", type=float, default=0.85)
    ap.add_argument("--backend", default="int_ref",
                    choices=list(pipeline.STREAM_BACKENDS))
    ap.add_argument("--stop-threshold", type=float, default=None)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--megastep", type=int, default=1,
                    help="frames advanced per device dispatch (K)")
    ap.add_argument("--pages", type=int, default=1,
                    help="V-slot pool pages of --slots lanes each")
    ap.add_argument("--double-buffer", action="store_true",
                    help="stage the next frame block while this one computes")
    ap.add_argument("--poisson-gap", type=float, default=None,
                    help="mean inter-arrival gap in frame ticks (Poisson "
                         "admission; default: all requests arrive at once)")
    ap.add_argument("--mesh", default=None, metavar="DATA,MODEL",
                    help="serve on a (data, model) device mesh, e.g. 2,2 "
                         "(needs DATA*MODEL devices; on CPU set XLA_FLAGS="
                         "--xla_force_host_platform_device_count first)")
    ap.add_argument("--quick", action="store_true",
                    help="reduced sizes (CI serving smoke)")
    args = ap.parse_args(argv)

    mesh = None
    if args.mesh:
        from repro.launch.mesh import make_mesh
        n_data, n_model = (int(v) for v in args.mesh.split(","))
        need = n_data * n_model
        if len(jax.devices()) < need:
            raise SystemExit(
                f"--mesh {args.mesh} needs {need} devices but jax sees "
                f"{len(jax.devices())}; on CPU relaunch with XLA_FLAGS="
                f"--xla_force_host_platform_device_count={need}")
        mesh = make_mesh((n_data, n_model), ("data", "model"))

    cfg = get_snn_config(args.arch)
    if args.quick:
        args.requests, args.words, args.slots = 3, 2, 2
    params = snn.init_fc_snn(jax.random.PRNGKey(args.seed), cfg)
    program = pipeline.compile_network(cfg, params, domain="int")
    eng = SNNServeEngine(program, batch_slots=args.slots,
                         backend=args.backend,
                         step_kw=({"interpret": True}
                                  if args.backend.startswith("pallas")
                                  else {}),
                         pages=args.pages, megastep=args.megastep,
                         double_buffer=args.double_buffer, mesh=mesh)
    for req in make_requests(program, args.requests, args.words,
                             cfg.timesteps, args.sparsity, args.seed,
                             args.stop_threshold,
                             poisson_gap=args.poisson_gap):
        eng.submit(req)
    t0 = time.perf_counter()
    done = eng.run_until_drained()
    dt = time.perf_counter() - t0
    frames = sum(r.ticks for r in done)
    rep = eng.aggregate_report()
    print(f"served {len(done)} requests, {frames} frames in {dt:.2f}s "
          f"({frames / dt:.1f} frames/s, "
          f"{frames / cfg.timesteps / dt:.1f} words/s on CPU; "
          f"K={args.megastep}, {args.pages} page(s) x {args.slots} lanes"
          + (f", mesh data={args.mesh.split(',')[0]} "
             f"model={args.mesh.split(',')[1]}" if args.mesh else "") + ")")
    lats = [r.latency_ticks for r in done if r.latency_ticks is not None]
    if lats:
        print(f"latency (frame ticks, arrival->finish): "
              f"p50={np.percentile(lats, 50):.0f} "
              f"p99={np.percentile(lats, 99):.0f} "
              f"over clock {eng.clock}")
    print(f"offered sparsity {args.sparsity:.2f} -> skipped-row fraction "
          f"{rep.skipped_row_fraction:.3f}, instr={rep.instruction_counts().total}, "
          f"measured EDP {energy.measured_edp(rep.instruction_counts()):.3e} J*s")
    for r in sorted(done, key=lambda r: r.rid)[:4]:
        print(f"  req {r.rid}: {r.ticks} ticks, logits {np.round(r.logits, 3)}")
    return done


if __name__ == "__main__":
    main()
