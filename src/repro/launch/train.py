"""Training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b \
        --steps 50 --batch 8 --seq 256 --d-model 128 --reduced

On this CPU container you train REDUCED configs (the full configs are
dry-run-only); on a TPU pod the same entry point drives the full mesh — the
only difference is make_production_mesh vs the host mesh and --reduced.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from repro.configs.base import (ParallelConfig, RunConfig, ShapeConfig,
                                get_config, reduced_config)
from repro.data import ShardedLoader, lm_batch_fn
from repro.train import LoopConfig, init_train_state, make_train_step, train_loop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--optimizer", default="adamw")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    parallel = ParallelConfig(remat="block", fsdp=False, seq_parallel=False,
                              microbatches=args.microbatches,
                              grad_compress=args.grad_compress)
    run = RunConfig(model=cfg, shape=shape, parallel=parallel,
                    optimizer=args.optimizer, learning_rate=args.lr,
                    warmup_steps=max(args.steps // 10, 1), seed=args.seed)

    state, opt = init_train_state(jax.random.PRNGKey(args.seed), run,
                                  total_steps=args.steps)
    step_fn = jax.jit(make_train_step(run, opt), donate_argnums=(0,))
    loader = ShardedLoader(
        lambda s, sid, n: _to_batch(lm_batch_fn(cfg.vocab_size, args.batch,
                                                args.seq, args.seed)(s, sid, n)),
        num_shards=1)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=args.ckpt_every,
                          ckpt_dir=args.ckpt_dir, log_every=5)
    result = train_loop(step_fn, state, loader, loop_cfg,
                        on_metrics=lambda m: print(
                            f"step {m['step']:.0f} loss {m['loss']:.4f} "
                            f"gnorm {m['grad_norm']:.3f} {m['sec_per_step']:.2f}s"))
    print(f"done: {len(result.metrics_history)} logs, "
          f"resumed_from={result.resumed_from}, "
          f"stragglers={result.straggler_steps}")
    return result


def _to_batch(d):
    return {k: jnp.asarray(v) for k, v in d.items()}


if __name__ == "__main__":
    main()
