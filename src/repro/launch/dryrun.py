import os
# opt level 0: ~35x faster XLA:CPU compiles with verified-identical
# cost/memory analysis on a reference cell (EXPERIMENTS.md §Methodology);
# SPMD partitioning (the thing being proven) runs at every opt level.
os.environ["XLA_FLAGS"] = (os.environ.get("REPRO_EXTRA_XLA_FLAGS", "") +
                           " --xla_backend_optimization_level=0"
                           " --xla_force_host_platform_device_count=512").strip()

__doc__ = """Multi-pod dry-run: .lower().compile() every (architecture x
input-shape x mesh) cell and extract the roofline terms from the compiled
artifact.

    PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Per cell this writes artifacts/dryrun/<mesh>/<arch>__<shape>.json with:
  flops/device, bytes-accessed/device, per-collective byte totals,
  memory analysis (argument/output/temp bytes per device), roofline terms
  (compute/memory/collective seconds), MODEL_FLOPS and the useful-compute
  ratio. EXPERIMENTS.md §Dry-run/§Roofline are generated from these files.

NOTE: the XLA_FLAGS assignment above MUST stay the first statement — jax
locks the device count at first init. Smoke tests and benchmarks never import
this module, so they keep seeing 1 device.
"""

import argparse
import dataclasses
import json
import re
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ASSIGNED_ARCHS, ModelConfig, ParallelConfig,
                                RunConfig, SHAPES, ShapeConfig, get_config)
from repro.dist import sharding as shd
from repro.launch.mesh import (HBM_BW, HBM_BYTES, ICI_BW, PEAK_FLOPS_BF16,
                               make_production_mesh)
from repro.models import io_spec, lm
from repro.optim import make_optimizer
from repro.train.train_state import TrainState

ART_DIR = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"

# ---------------------------------------------------------------------------
# Per-cell parallel policy (the hillclimb edits THIS table; defaults first)
# ---------------------------------------------------------------------------

DEFAULT_TRAIN = dict(remat="block", fsdp=True, scan_layers=True,
                     vocab_chunking=4, microbatches=1)
DEFAULT_SERVE = dict(remat="none", fsdp=False, scan_layers=True,
                     vocab_chunking=1, microbatches=1)

OVERRIDES: dict[tuple[str, str], dict] = {
    # llama4-maverick: 400B params -> factored optimizer, more loss chunks
    ("llama4-maverick-400b-a17b", "train_4k"): dict(optimizer="adafactor",
                                                    vocab_chunking=8),
    ("starcoder2-15b", "train_4k"): dict(vocab_chunking=4),
}

# Hillclimb variants (§Perf): selected by --tag; each entry overrides the
# baseline ParallelConfig / optimizer for one (arch, shape). The iteration
# log lives in EXPERIMENTS.md §Perf.
HILLCLIMB: dict[tuple[str, str, str], dict] = {
    # --- jamba train_4k (worst memory blowup; paper-representative SSM) ---
    # p1: shard the SSM scan tensors + remat chunk bodies
    ("jamba-v0.1-52b", "train_4k", "p1"): dict(state_constraints=True),
    # p2: + gather-only dispatch on its 16-expert MoE + blocked attention
    ("jamba-v0.1-52b", "train_4k", "p2"): dict(state_constraints=True,
                                               moe_gather_dispatch=True,
                                               attn_q_chunk=1024),
    # p3: + microbatching to halve live activations
    ("jamba-v0.1-52b", "train_4k", "p3"): dict(state_constraints=True,
                                               moe_gather_dispatch=True,
                                               attn_q_chunk=1024,
                                               microbatches=2),
    # --- llama4 train_4k (most collective-bound) ---
    ("llama4-maverick-400b-a17b", "train_4k", "p1"): dict(
        optimizer="adafactor", vocab_chunking=8, moe_constraints=True),
    ("llama4-maverick-400b-a17b", "train_4k", "p2"): dict(
        optimizer="adafactor", vocab_chunking=8, moe_gather_dispatch=True),
    ("llama4-maverick-400b-a17b", "train_4k", "p3"): dict(
        optimizer="adafactor", vocab_chunking=8, moe_gather_dispatch=True,
        attn_q_chunk=1024, microbatches=2),
    # --- deepseek train_4k (worst roofline fraction) ---
    ("deepseek-v2-lite-16b", "train_4k", "p1"): dict(moe_constraints=True),
    ("deepseek-v2-lite-16b", "train_4k", "p2"): dict(moe_gather_dispatch=True),
    ("deepseek-v2-lite-16b", "train_4k", "p3"): dict(moe_gather_dispatch=True,
                                                     attn_q_chunk=1024,
                                                     microbatches=2),
    ("deepseek-v2-lite-16b", "train_4k", "p4"): dict(moe_gather_dispatch=True,
                                                     microbatches=4),
    ("llama4-maverick-400b-a17b", "train_4k", "p4"): dict(
        optimizer="adafactor", vocab_chunking=8, moe_gather_dispatch=True,
        microbatches=4),
    ("jamba-v0.1-52b", "train_4k", "p4"): dict(state_constraints=True,
                                               moe_gather_dispatch=True,
                                               microbatches=4),
    # --- rwkv long_500k (paper's fused-state serving path) ---
    # p1: 2D tensor parallelism for decode (weights sharded over data x model)
    ("rwkv6-7b", "long_500k", "p1"): dict(fsdp=True),
    # --- bonus: blocked attention on the worst prefill cells ---
    ("whisper-large-v3", "prefill_32k", "p1"): dict(attn_q_chunk=2048),
    ("llama3-8b", "prefill_32k", "p1"): dict(attn_q_chunk=2048),
    ("phi3-medium-14b", "prefill_32k", "p1"): dict(attn_q_chunk=2048),
}

# long_500k applicability (DESIGN.md §4): sub-quadratic archs only
LONG_OK = {"rwkv6-7b", "jamba-v0.1-52b"}


def cell_list(archs, shapes) -> list[tuple[str, str, str | None]]:
    cells = []
    for a in archs:
        for s in shapes:
            skip = None
            if s == "long_500k" and a not in LONG_OK:
                skip = "full-attention arch: 500k dense decode skipped per assignment"
            cells.append((a, s, skip))
    return cells


def make_run(arch: str, shape_name: str, tag: str = "") -> RunConfig:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    base = dict(DEFAULT_TRAIN if shape.kind == "train" else DEFAULT_SERVE)
    ov = dict(OVERRIDES.get((arch, shape_name), {}))
    if tag:
        ov.update(HILLCLIMB.get((arch, shape_name, tag), {}))
    optimizer = ov.pop("optimizer", "adamw")
    base.update(ov)
    return RunConfig(model=cfg, shape=shape, parallel=ParallelConfig(**base),
                     optimizer=optimizer)


# ---------------------------------------------------------------------------
# HLO collective parsing
# ---------------------------------------------------------------------------

_DTYPE_BYTES = {"pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2,
                "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
                "f64": 8, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\S+)\s+([\w\-]+)")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, int]:
    """Sum operand bytes per collective op kind from post-SPMD HLO."""
    # symbol table: instruction name -> result bytes
    sym: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if m:
            sym[m.group(1)] = _type_bytes(m.group(2))
    out: dict[str, int] = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        m = _DEF_RE.match(line)
        if not m:
            continue
        op = m.group(3)
        base = None
        for k in _COLL_OPS:
            if op == k or op == k + "-start":
                base = k
                break
        if base is None:
            continue
        # operand list: first (...) after the opcode
        rest = line[m.end():]
        paren = rest.find("(")
        if paren < 0:
            continue
        depth, j = 0, paren
        for j in range(paren, len(rest)):
            if rest[j] == "(":
                depth += 1
            elif rest[j] == ")":
                depth -= 1
                if depth == 0:
                    break
        operands = rest[paren + 1:j]
        bytes_ = 0
        for name in re.findall(r"%?([\w.\-]+)", operands):
            if name in sym:
                bytes_ += sym[name]
        if bytes_ == 0:                          # fallback: result size
            bytes_ = _type_bytes(m.group(2))
        out[base] += bytes_
    return out


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def build_train(run: RunConfig, mesh):
    cfg, parallel = run.model, run.parallel
    opt = make_optimizer(run.optimizer, run.learning_rate, run.weight_decay)
    from repro.train.train_state import make_train_step
    step_fn = make_train_step(run, opt)

    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    oshapes = jax.eval_shape(opt.init, pshapes)
    state_shapes = TrainState(pshapes, oshapes,
                              jax.ShapeDtypeStruct((), jnp.int32))
    pspecs = shd.param_specs(pshapes, mesh, parallel)
    ospecs = shd.param_specs(oshapes, mesh, parallel)
    state_specs = TrainState(pspecs, ospecs, shd.replicated(mesh))
    batch = io_spec.train_batch_spec(cfg, run.shape)
    bspecs = shd.batch_specs(batch, mesh, parallel)
    metric_specs = {"loss": shd.replicated(mesh), "grad_norm": shd.replicated(mesh),
                    "step": shd.replicated(mesh)}
    fn = jax.jit(step_fn,
                 in_shardings=(state_specs, bspecs),
                 out_shardings=(state_specs, metric_specs),
                 donate_argnums=(0,))
    return fn, (state_shapes, batch)


def build_prefill(run: RunConfig, mesh):
    cfg, parallel = run.model, run.parallel
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = shd.param_specs(pshapes, mesh, parallel)
    batch = io_spec.prefill_batch_spec(cfg, run.shape)
    bspecs = shd.batch_specs(batch, mesh, parallel)
    S = run.shape.seq_len

    def fn(params, b):
        return lm.prefill(params, b, cfg, S, parallel)

    cache_shapes = jax.eval_shape(
        lambda: lm.init_cache(cfg, run.shape.global_batch, S,
                              enc_len=(S if cfg.is_encoder_decoder else 0)))
    cspecs = shd.cache_specs(cache_shapes, mesh, parallel, cfg)
    out_specs = (shd.logits_spec(
        mesh, (run.shape.global_batch, cfg.vocab_size)), cspecs)
    jfn = jax.jit(fn, in_shardings=(pspecs, bspecs), out_shardings=out_specs)
    return jfn, (pshapes, batch)


def build_decode(run: RunConfig, mesh):
    cfg, parallel = run.model, run.parallel
    pshapes = jax.eval_shape(lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
    pspecs = shd.param_specs(pshapes, mesh, parallel)
    tokens, cache_shapes = io_spec.decode_spec(cfg, run.shape)
    cspecs = shd.cache_specs(cache_shapes, mesh, parallel, cfg)
    tspec = shd.batch_specs(tokens, mesh, parallel)

    def fn(params, t, cache):
        return lm.decode_step(params, t, cache, cfg, parallel)

    jfn = jax.jit(fn, in_shardings=(pspecs, tspec, cspecs),
                  out_shardings=(shd.logits_spec(
                      mesh, (run.shape.global_batch, cfg.vocab_size)), cspecs),
                  donate_argnums=(2,))
    return jfn, (pshapes, tokens, cache_shapes)


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    n = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n * shape.global_batch * shape.seq_len
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch                     # decode: per token


def _compile_cell(run: RunConfig, mesh):
    builders = {"train": build_train, "prefill": build_prefill,
                "decode": build_decode}
    build = builders[run.shape.kind]
    with mesh:
        with shd.activation_rules(mesh, run.parallel):
            fn, abstract_args = build(run, mesh)
            lowered = fn.lower(*abstract_args)
        compiled = lowered.compile()
        return compiled


def _measure(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):       # jax < 0.5: one dict per device
        ca = ca[0] if ca else {}
    coll = parse_collectives(compiled.as_text())
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "coll": coll}


def _reduced_run(run: RunConfig, n: int) -> RunConfig:
    """Depth-n variant (n super-blocks / encoder layers) with time scans
    unrolled, for the linear-in-depth cost extrapolation (XLA cost analysis
    counts while-loop bodies once; see EXPERIMENTS.md §Dry-run methodology).

    For attention-free rwkv every cost component is exactly linear in T at
    fixed wkv chunk, so the accounting compiles run at T<=4096 and scale by
    T/T' — this bounds the number of unrolled wkv chunk bodies at 64."""
    from repro.models.lm import n_prelude, super_period
    cfg = run.model
    kw: dict = {"n_layers": n_prelude(cfg) + super_period(cfg) * n}
    if cfg.is_encoder_decoder:
        kw["n_encoder_layers"] = n
    cfg2 = dataclasses.replace(cfg, **kw)
    # scan_layers=False: depth must change the HLO, not just a trip count
    par2 = dataclasses.replace(run.parallel, unroll_time_scans=True,
                               scan_layers=False)
    shape = run.shape
    if cfg.rwkv is not None and shape.kind != "decode" and shape.seq_len > 4096:
        shape = dataclasses.replace(shape, seq_len=4096)
    return dataclasses.replace(run, model=cfg2, parallel=par2, shape=shape)


def extrapolated_costs(run: RunConfig, mesh) -> dict:
    """costs(N) = v1 + (N-1) * (v2 - v1), measured at depth 1 and 2."""
    from repro.models.lm import n_super
    full_n = n_super(run.model)
    r1 = _reduced_run(run, 1)
    v1 = _measure(_compile_cell(r1, mesh))
    if full_n == 1:
        v = v1
    else:
        v2 = _measure(_compile_cell(_reduced_run(run, 2), mesh))
        scale = full_n - 1

        def ext(a, b):
            return a + scale * (b - a)

        coll = {k: max(0.0, ext(v1["coll"][k], v2["coll"][k]))
                for k in v1["coll"]}
        # clamp: extrapolation noise on micro-scale cells can go negative
        v = {"flops": max(ext(v1["flops"], v2["flops"]), 0.0),
             "bytes": max(ext(v1["bytes"], v2["bytes"]), 0.0), "coll": coll}
    mult = run.parallel.microbatches if run.parallel.microbatches > 1 else 1
    mult *= run.shape.seq_len / r1.shape.seq_len      # rwkv T-scaling (==1 else)
    if mult != 1:
        v = {"flops": v["flops"] * mult, "bytes": v["bytes"] * mult,
             "coll": {k: c * mult for k, c in v["coll"].items()}}
    return v


def run_cell(arch: str, shape_name: str, mesh_kind: str, tag: str = "") -> dict:
    run = make_run(arch, shape_name, tag)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = int(np.prod(list(mesh.shape.values())))
    t0 = time.time()
    compiled = _compile_cell(run, mesh)                      # the PROOF compile
    t_compile = time.time() - t0
    t_lower = 0.0
    ma = compiled.memory_analysis()
    raw = _measure(compiled)
    # roofline costs from depth-extrapolation (correct while-loop accounting)
    costs = extrapolated_costs(run, mesh)
    coll = costs["coll"]
    coll_bytes = float(sum(coll.values()))
    flops_dev = costs["flops"]
    bytes_dev = costs["bytes"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS_BF16,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_bytes / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    mf = model_flops(run.model, run.shape)
    hlo_global = flops_dev * n_chips
    mem = {
        "argument_bytes": int(ma.argument_size_in_bytes),
        "output_bytes": int(ma.output_size_in_bytes),
        "temp_bytes": int(ma.temp_size_in_bytes),
        "alias_bytes": int(ma.alias_size_in_bytes),
    }
    peak = mem["argument_bytes"] + mem["temp_bytes"] + mem["output_bytes"] \
        - mem["alias_bytes"]
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "chips": n_chips,
        "kind": run.shape.kind,
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_bytes,
        "collectives": coll,
        "raw_rolled_costs": raw,
        "memory": mem,
        "peak_bytes_per_device": int(peak),
        "fits_16GiB": bool(peak <= HBM_BYTES),
        "roofline_terms_s": terms,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_ratio": mf / hlo_global if hlo_global else 0.0,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "parallel": dataclasses.asdict(run.parallel),
        "optimizer": run.optimizer,
    }
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=str(ART_DIR))
    ap.add_argument("--tag", default="", help="suffix for artifact files (perf iterations)")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose artifact already exists")
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = []
    for mesh_kind in meshes:
        outdir = Path(args.out) / mesh_kind
        outdir.mkdir(parents=True, exist_ok=True)
        for arch, shape, skip in cell_list(archs, shapes):
            tag = f"__{args.tag}" if args.tag else ""
            fp = outdir / f"{arch}__{shape}{tag}.json"
            if args.resume and fp.exists():
                print(f"[skip] {mesh_kind} {arch} {shape}: artifact exists")
                continue
            if skip:
                fp.write_text(json.dumps(
                    {"arch": arch, "shape": shape, "mesh": mesh_kind,
                     "skipped": skip}, indent=1))
                print(f"[skip] {mesh_kind} {arch} {shape}: {skip}")
                continue
            try:
                res = run_cell(arch, shape, mesh_kind, args.tag)
                fp.write_text(json.dumps(res, indent=1))
                t = res["roofline_terms_s"]
                print(f"[ok]   {mesh_kind} {arch} {shape}: dominant={res['dominant']}"
                      f" compute={t['compute_s']:.3e}s memory={t['memory_s']:.3e}s"
                      f" coll={t['collective_s']:.3e}s peak={res['peak_bytes_per_device']/2**30:.2f}GiB"
                      f" fits={res['fits_16GiB']} (compile {res['compile_s']}s)")
            except Exception as e:  # noqa: BLE001 — a failing cell is a bug to fix
                failures.append((mesh_kind, arch, shape, repr(e)))
                print(f"[FAIL] {mesh_kind} {arch} {shape}: {e!r}"[:500])
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f[0], f[1], f[2], f[3][:200])
        sys.exit(1)
    print("\nall requested cells compiled.")


if __name__ == "__main__":
    main()
