"""Optimizers built from scratch (no optax): SGD(+momentum), Adam, AdamW,
Adafactor (factored second moment — required to fit the 400B llama4-maverick
optimizer state in 16 GiB/chip; see DESIGN.md §5).

API mirrors the (init, update) pair convention:
    opt = make_optimizer("adamw", lr=..., weight_decay=...)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., tuple[Any, Any]]   # (grads, state, params) -> (updates, state)


def apply_updates(params, updates):
    return jax.tree_util.tree_map(
        lambda p, u: (p + u.astype(p.dtype)), params, updates)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree_util.tree_map(lambda g: g * scale.astype(g.dtype), grads), norm


# ---------------------------------------------------------------------------

def sgd(lr: float | Callable, momentum: float = 0.9) -> Optimizer:
    def init(params):
        return {"mu": jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        mu = jax.tree_util.tree_map(
            lambda m, g: momentum * m + g.astype(jnp.float32), state["mu"], grads)
        updates = jax.tree_util.tree_map(lambda m: -lr_t * m, mu)
        return updates, {"mu": mu, "step": step}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay):
    def init(params):
        def z(p):
            return jnp.zeros_like(p, jnp.float32)
        return {"m": jax.tree_util.tree_map(z, params),
                "v": jax.tree_util.tree_map(z, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        m = jax.tree_util.tree_map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree_util.tree_map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(g.astype(jnp.float32)),
            state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(m_, v_, p):
            u = -lr_t * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay and p is not None and p.ndim >= 2:
                u = u - lr_t * weight_decay * p.astype(jnp.float32)
            return u

        if params is None:
            updates = jax.tree_util.tree_map(lambda m_, v_: upd(m_, v_, None), m, v)
        else:
            updates = jax.tree_util.tree_map(upd, m, v, params)
        return updates, {"m": m, "v": v, "step": step}

    return Optimizer(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=0.0)


def adamw(lr, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1) -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay=weight_decay)


def adafactor(lr, decay: float = 0.8, eps: float = 1e-30,
              clip_threshold: float = 1.0) -> Optimizer:
    """Factored second moment: for a (r, c) matrix keep row/col statistics
    (r + c floats instead of r*c). >=2D params are factored over the last two
    dims; smaller params keep a full accumulator."""

    def init(params):
        def z(p):
            if p.ndim >= 2:
                return {"row": jnp.zeros(p.shape[:-1], jnp.float32),
                        "col": jnp.zeros(p.shape[:-2] + p.shape[-1:], jnp.float32)}
            return {"full": jnp.zeros_like(p, jnp.float32)}
        return {"v": jax.tree_util.tree_map(z, params,
                                            is_leaf=lambda x: hasattr(x, "ndim")),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr(step) if callable(lr) else lr
        beta = 1.0 - step.astype(jnp.float32) ** (-decay)

        def upd(g, v):
            gf = g.astype(jnp.float32)
            g2 = jnp.square(gf) + eps
            if "full" in v:
                v_new = {"full": beta * v["full"] + (1 - beta) * g2}
                u = gf * jax.lax.rsqrt(v_new["full"] + eps)
            else:
                row = beta * v["row"] + (1 - beta) * jnp.mean(g2, axis=-1)
                col = beta * v["col"] + (1 - beta) * jnp.mean(g2, axis=-2)
                v_new = {"row": row, "col": col}
                r_factor = jax.lax.rsqrt(
                    row / jnp.maximum(jnp.mean(row, axis=-1, keepdims=True), eps) + eps)
                c_factor = jax.lax.rsqrt(col + eps)
                u = gf * r_factor[..., None] * c_factor[..., None, :]
            # update clipping (RMS <= clip_threshold)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + 1e-12)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            return -lr_t * u, v_new

        flat_g, tdef = jax.tree_util.tree_flatten(grads)
        flat_v = tdef.flatten_up_to(state["v"])
        out = [upd(g, v) for g, v in zip(flat_g, flat_v)]
        updates = tdef.unflatten([u for u, _ in out])
        v_state = tdef.unflatten([v for _, v in out])
        return updates, {"v": v_state, "step": step}

    return Optimizer(init, update)


def make_optimizer(name: str, lr, weight_decay: float = 0.1) -> Optimizer:
    if name == "sgd":
        return sgd(lr)
    if name == "adam":
        return adam(lr)
    if name == "adamw":
        return adamw(lr, weight_decay=weight_decay)
    if name == "adafactor":
        return adafactor(lr)
    raise ValueError(f"unknown optimizer {name!r}")
