from repro.optim.optimizers import (Optimizer, adafactor, adam, adamw,
                                    apply_updates, clip_by_global_norm,
                                    global_norm, make_optimizer, sgd)
from repro.optim.schedule import cosine_warmup
