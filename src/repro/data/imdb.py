"""Real-IMDB loader hook: if the aclImdb dump + GloVe vectors exist on disk
(env REPRO_IMDB_DIR / REPRO_GLOVE_PATH), build (B, n_words, 100) batches from
them; otherwise callers fall back to data.synthetic (the offline container
default — see DESIGN.md §8.2)."""
from __future__ import annotations

import os
from pathlib import Path

import numpy as np

IMDB_DIR = os.environ.get("REPRO_IMDB_DIR", "/data/aclImdb")
GLOVE_PATH = os.environ.get("REPRO_GLOVE_PATH", "/data/glove.6B.100d.txt")


def available() -> bool:
    return Path(IMDB_DIR).exists() and Path(GLOVE_PATH).exists()


def load_glove() -> dict[str, np.ndarray]:
    vecs = {}
    with open(GLOVE_PATH, encoding="utf-8") as f:
        for line in f:
            parts = line.rstrip().split(" ")
            vecs[parts[0]] = np.asarray(parts[1:], np.float32)
    return vecs


def load_reviews(split: str = "train", limit: int | None = None):
    out = []
    for label, sub in ((1.0, "pos"), (0.0, "neg")):
        d = Path(IMDB_DIR) / split / sub
        for i, p in enumerate(sorted(d.glob("*.txt"))):
            if limit and i >= limit // 2:
                break
            out.append((p.read_text(encoding="utf-8", errors="ignore"), label))
    return out


def vectorize(reviews, glove, n_words: int = 64):
    xs, ys = [], []
    for text, label in reviews:
        toks = [t.strip(".,!?<>/\"'()").lower() for t in text.split()]
        vs = [glove[t] for t in toks if t in glove][:n_words]
        if not vs:
            continue
        arr = np.zeros((n_words, 100), np.float32)
        arr[:len(vs)] = np.stack(vs)
        xs.append(arr)
        ys.append(label)
    return np.stack(xs), np.asarray(ys, np.float32)
