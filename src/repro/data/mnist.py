"""Real-MNIST loader hook (idx files under REPRO_MNIST_DIR); falls back to
data.synthetic.mnist_like_batch when absent."""
from __future__ import annotations

import gzip
import os
import struct
from pathlib import Path

import numpy as np

MNIST_DIR = os.environ.get("REPRO_MNIST_DIR", "/data/mnist")


def available() -> bool:
    return (Path(MNIST_DIR) / "train-images-idx3-ubyte.gz").exists()


def _read_idx(path: Path) -> np.ndarray:
    with gzip.open(path, "rb") as f:
        magic, = struct.unpack(">I", f.read(4))
        ndim = magic & 0xFF
        dims = struct.unpack(">" + "I" * ndim, f.read(4 * ndim))
        return np.frombuffer(f.read(), np.uint8).reshape(dims)


def load(split: str = "train"):
    pre = "train" if split == "train" else "t10k"
    imgs = _read_idx(Path(MNIST_DIR) / f"{pre}-images-idx3-ubyte.gz")
    labels = _read_idx(Path(MNIST_DIR) / f"{pre}-labels-idx1-ubyte.gz")
    x = imgs.astype(np.float32)[..., None] / 255.0
    return x, labels.astype(np.int32)
