"""Deterministic synthetic datasets, structure-matched to the paper's tasks.

The container has no network access, so IMDB+GloVe and MNIST are generated
(real-data loaders in imdb.py / mnist.py pick up on-disk copies when present).
The generators are built so the paper's *relative* claims are testable:

  * sentiment:  sequences of 100-d "word vectors" from a fixed random
    vocabulary; label = sign of the accumulated sentiment score with negation
    words flipping the polarity of a following window — so the task genuinely
    requires sequential state (an LSTM/SNN does well, a bag-of-words cannot
    capture negation).
  * mnist-like: 28x28 class-conditional stroke patterns with jitter + noise.
  * LM tokens:  a mixture of Zipfian unigrams and repeated n-gram motifs
    (so a real LM shows loss decrease quickly).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

GLOVE_DIM = 100
VOCAB = 2000
NEG_WORDS = 40        # first NEG_WORDS ids after the neutral block are negators


@dataclass
class SentimentDataset:
    vectors: np.ndarray       # (VOCAB, 100) word embeddings ("GloVe")
    polarity: np.ndarray      # (VOCAB,) per-word sentiment score
    is_negator: np.ndarray    # (VOCAB,) bool


def make_sentiment_vocab(seed: int = 0) -> SentimentDataset:
    rng = np.random.default_rng(seed)
    vectors = rng.standard_normal((VOCAB, GLOVE_DIM)).astype(np.float32) * 0.3
    polarity = np.zeros(VOCAB, np.float32)
    n_pol = VOCAB // 2
    polarity[:n_pol // 2] = rng.uniform(0.5, 1.5, n_pol // 2)       # positive
    polarity[n_pol // 2:n_pol] = -rng.uniform(0.5, 1.5, n_pol // 2)  # negative
    # give polar words a shared direction component so it's linearly decodable
    direction = rng.standard_normal(GLOVE_DIM).astype(np.float32)
    direction /= np.linalg.norm(direction)
    vectors += polarity[:, None] * direction[None, :] * 0.8
    is_negator = np.zeros(VOCAB, bool)
    is_negator[n_pol:n_pol + NEG_WORDS] = True
    neg_dir = rng.standard_normal(GLOVE_DIM).astype(np.float32)
    vectors[is_negator] += neg_dir / np.linalg.norm(neg_dir) * 1.2
    return SentimentDataset(vectors, polarity, is_negator)


def sentiment_batch(ds: SentimentDataset, batch: int, n_words: int,
                    seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Returns (x (B, n_words, 100), labels (B,) in {0,1})."""
    rng = np.random.default_rng(seed)
    ids = rng.integers(0, VOCAB, (batch, n_words))
    pol = ds.polarity[ids].copy()
    neg = ds.is_negator[ids]
    # a negator flips the polarity of the next 2 words (sequential semantics)
    for off in (1, 2):
        flip = np.zeros_like(neg)
        flip[:, off:] = neg[:, :-off]
        pol = np.where(flip, -pol, pol)
    score = pol.sum(axis=1) + rng.normal(0, 0.25, batch)
    labels = (score > 0).astype(np.float32)
    x = ds.vectors[ids]
    return x.astype(np.float32), labels


def mnist_like_batch(batch: int, seed: int) -> tuple[np.ndarray, np.ndarray]:
    """Class-conditional 28x28 patterns (10 classes). (B, 28, 28, 1), (B,)."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, batch)
    base = np.zeros((10, 28, 28), np.float32)
    proto_rng = np.random.default_rng(1234)
    for c in range(10):
        for _ in range(4):                       # 4 strokes per class
            x0, y0 = proto_rng.integers(4, 24, 2)
            dx, dy = proto_rng.integers(-3, 4, 2)
            for t in range(8):
                xx = np.clip(x0 + t * dx // 3, 0, 27)
                yy = np.clip(y0 + t * dy // 3, 0, 27)
                base[c, xx, yy] = 1.0
    imgs = base[labels]
    shift = rng.integers(-2, 3, (batch, 2))
    out = np.zeros_like(imgs)
    for i in range(batch):
        out[i] = np.roll(imgs[i], shift[i], axis=(0, 1))
    out += rng.normal(0, 0.15, out.shape).astype(np.float32)
    return out[..., None].astype(np.float32), labels.astype(np.int32)


def lm_token_batch(batch: int, seq: int, vocab: int, seed: int,
                   motif_len: int = 16) -> np.ndarray:
    """Zipfian tokens with injected repeated motifs; (B, seq+1) so that
    (inputs, targets) = (x[:, :-1], x[:, 1:])."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab + 1)
    p = 1.0 / ranks
    p /= p.sum()
    x = rng.choice(vocab, size=(batch, seq + 1), p=p)
    n_motifs = (seq + 1) // (4 * motif_len)
    motif = rng.integers(0, vocab, (8, motif_len))
    for b in range(batch):
        for _ in range(n_motifs):
            m = motif[rng.integers(0, 8)]
            pos = rng.integers(0, seq + 1 - motif_len)
            x[b, pos:pos + motif_len] = m
    return x.astype(np.int32)
