from repro.data.loader import ShardedLoader, lm_batch_fn
from repro.data.synthetic import (lm_token_batch, make_sentiment_vocab,
                                  mnist_like_batch, sentiment_batch)
