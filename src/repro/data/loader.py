"""Sharded, deterministic, restart-safe host data loader.

Determinism + elasticity contract (fault tolerance, DESIGN.md §5):
  * batch for global step s is a pure function of (seed, s) — restarts resume
    mid-stream by step index with no state files;
  * each data-parallel host generates only its shard (shard_id, num_shards),
    so the loader re-shards automatically when the mesh changes (elastic
    restart just passes the new shard count).
A background thread prefetches `prefetch` batches ahead.
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator



class ShardedLoader:
    def __init__(self, batch_fn: Callable[[int, int, int], dict], *,
                 shard_id: int = 0, num_shards: int = 1, start_step: int = 0,
                 prefetch: int = 2):
        """batch_fn(step, shard_id, num_shards) -> dict of np arrays (the
        local shard of the global batch)."""
        self.batch_fn = batch_fn
        self.shard_id = shard_id
        self.num_shards = num_shards
        self.step = start_step
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._worker, daemon=True)
        self._thread.start()

    def _worker(self):
        s = self.step
        while not self._stop.is_set():
            batch = self.batch_fn(s, self.shard_id, self.num_shards)
            while not self._stop.is_set():
                try:
                    self._q.put((s, batch), timeout=0.1)
                    break
                except queue.Full:
                    continue
            s += 1

    def __iter__(self) -> Iterator[tuple[int, dict]]:
        return self

    def __next__(self) -> tuple[int, dict]:
        return self._q.get()

    def close(self):
        self._stop.set()


def lm_batch_fn(vocab: int, global_batch: int, seq: int, seed: int = 0):
    """Deterministic LM batches sharded over the batch axis."""
    from repro.data.synthetic import lm_token_batch

    def fn(step: int, shard_id: int, num_shards: int) -> dict:
        if global_batch % num_shards != 0:
            raise ValueError(f"global_batch={global_batch} must shard "
                             f"evenly over {num_shards} hosts")
        local = global_batch // num_shards
        # derive an independent stream per (step, shard)
        x = lm_token_batch(local, seq, vocab,
                           seed=seed * 1_000_003 + step * 131 + shard_id)
        return {"tokens": x[:, :-1], "targets": x[:, 1:]}

    return fn
