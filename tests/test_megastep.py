"""Megastep contract: K frames per device dispatch is invisible.

Integer V_MEM arithmetic is exact, so advancing a stream K ticks in one
fused-kernel dispatch must be *bit-identical* to K chained single-tick
calls — rasters, readout trajectory, final state, and the skip counters
that feed the energy model. The sweeps here pin that at both layers:

  1. `stream_megastep` vs tick-by-tick `stream_step` on every streaming
     backend, every neuron/clamp combination, conv stacks, ragged chunk
     sizes (stream length not a multiple of K), and per-lane active
     masks (short/evicted lanes integrate zero current).
  2. The serving engine: a K-megastep drain over a paged V-slot pool
     (double-buffered or not) finishes every request bit-identically to
     the K=1 drain, and a seeded Poisson-arrival soak keeps the drain
     contract and per-request report closure under admission churn.

The drain-path bug round rides along: vacated lanes are re-seeded with
fresh zero state at evict (so device ledgers close at any occupancy),
zero-budget requests finish with a shape-consistent zero ``v_out``, and
``aggregate_report`` raises the named ``ReportUnavailable`` instead of a
generic merge error.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import pipeline, snn
from repro.serve import ReportUnavailable, SNNRequest, SNNServeEngine
from repro.serve.snn_engine import merge_reports

LENET_S = SNNModelConfig(
    arch_id="lenet-s",
    conv_spec=((4, 3, 1), (6, 3, 2)),
    in_shape=(8, 8, 1),
    layer_sizes=(4 * 4 * 6, 10, 3),
    spiking=SpikingConfig(neuron="rmp", timesteps=2, threshold=1.0,
                          leak=0.0625, w_bits=6, v_bits=11),
    timesteps=2, task="multiclass")

BACKEND_KW = [
    ("float", {}),
    ("int_ref", {}),
    ("int_ref", {"use_sparse": True}),
    ("pallas", {"interpret": True, "block_b": 4}),
    ("pallas_sparse", {"interpret": True, "block_b": 4,
                       "gate_granularity": 4}),
    ("ref_events", {}),
    ("pallas_events", {"interpret": True, "block_b": 4}),
]


def _case_id(b, k):
    gran = f"-g{k['gate_granularity']}" if "gate_granularity" in k else ""
    return f"{b}{gran}{'-sparse' if k.get('use_sparse') else ''}"


def _make(layer_sizes=(37, 50, 20, 3), neuron="rmp", n_words=3, batch=2,
          seed=0, clamp_mode="saturate", conv=None):
    cfg = SNNModelConfig(
        arch_id="test", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron=neuron, timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    rng = np.random.default_rng(seed + 7)
    if conv is not None:
        cfg = conv
        params = snn.init_lenet_snn(jax.random.PRNGKey(seed), cfg)
        program = pipeline.compile_network(cfg, params, domain="int",
                                           clamp_mode=clamp_mode)
        x = jnp.asarray(rng.standard_normal(
            (batch, *cfg.in_shape)).astype(np.float32)) * 2.0
        return cfg, program, pipeline.present_static(x, cfg.timesteps)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode=clamp_mode)
    x = jnp.asarray(rng.standard_normal(
        (batch, n_words, cfg.layer_sizes[0])).astype(np.float32))
    return cfg, program, pipeline.present_words(x, cfg.timesteps)


def _tickwise(program, xs, backend, **kw):
    """Reference: T single-tick stream_step calls. Returns per-tick
    v_out, logits, rasters and the final state."""
    state = program.init_state(xs.shape[1], backend)
    vs, ls, rs = [], [], []
    for t in range(xs.shape[0]):
        state, out = program.step(state, xs[t], backend, **kw)
        vs.append(np.asarray(out.v_out))
        ls.append(np.asarray(out.logits))
        rs.append([np.asarray(r) for r in out.rasters])
    return state, np.stack(vs), np.stack(ls), rs


def _megastep_chunks(program, xs, backend, chunks, **kw):
    """Drive xs through stream_megastep in the given chunk sizes."""
    state = program.init_state(xs.shape[1], backend)
    vs, ls, rs = [], [], []
    t = 0
    for k in chunks:
        state, out = program.megastep(state, xs[t:t + k], backend, **kw)
        assert out.v_out_traj.shape[0] == k
        vs.append(np.asarray(out.v_out_traj))
        ls.append(np.asarray(out.logits_traj))
        for tt in range(k):
            rs.append([np.asarray(r[tt]) for r in out.rasters])
        np.testing.assert_array_equal(np.asarray(out.frames_consumed),
                                      np.full(xs.shape[1], k))
        # the last trajectory entries ARE the single-step outputs
        np.testing.assert_array_equal(np.asarray(out.v_out), vs[-1][-1])
        np.testing.assert_array_equal(np.asarray(out.logits), ls[-1][-1])
        t += k
    return state, np.concatenate(vs), np.concatenate(ls), rs


def _assert_states_equal(a, b, tag):
    for i, (va, vb) in enumerate(zip(a.vs, b.vs)):
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=f"{tag} layer {i} V")


def _assert_megastep_matches(program, xs, backend, chunks, tag, **kw):
    ref_state, ref_v, ref_l, ref_r = _tickwise(program, xs, backend, **kw)
    got_state, got_v, got_l, got_r = _megastep_chunks(program, xs, backend,
                                                      chunks, **kw)
    np.testing.assert_array_equal(got_v, ref_v, err_msg=f"{tag} v_traj")
    np.testing.assert_array_equal(got_l, ref_l, err_msg=f"{tag} logits")
    for t, (ga, ra) in enumerate(zip(got_r, ref_r)):
        for li, (g, r) in enumerate(zip(ga, ra)):
            np.testing.assert_array_equal(
                g, r, err_msg=f"{tag} raster t={t} layer={li}")
    _assert_states_equal(got_state, ref_state, tag)


@pytest.mark.parametrize("backend,kw", BACKEND_KW,
                         ids=[_case_id(b, k) for b, k in BACKEND_KW])
def test_megastep_matches_single_tick_all_backends(backend, kw):
    """K-frame dispatch == K single-tick dispatches, bit for bit, on the
    full backend set — including a ragged final chunk (T=9 split 4+4+1,
    stream length not a multiple of K)."""
    _, program, xs = _make()
    for chunks in ([4, 4, 1], [1] * 9, [9]):
        _assert_megastep_matches(program, xs, backend, chunks,
                                 f"{backend}/{kw}/chunks={chunks}", **kw)


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_megastep_neuron_clamp_sweep(neuron, clamp_mode):
    """Neuron x clamp sweep (ragged shapes): the K-loop preserves the
    V_MEM update law under both overflow policies."""
    _, program, xs = _make(layer_sizes=(13, 11, 3), neuron=neuron,
                           clamp_mode=clamp_mode, seed=3)
    for backend, kw in [("int_ref", {"use_sparse": True}),
                        ("pallas_sparse", {"interpret": True,
                                           "block_b": 4})]:
        _assert_megastep_matches(program, xs, backend, [4, 5],
                                 f"{neuron}/{clamp_mode}/{backend}", **kw)


@pytest.mark.parametrize("backend,kw", [
    ("int_ref", {}),
    ("pallas", {"interpret": True, "block_b": 4}),
])
def test_megastep_conv_stack(backend, kw):
    """Conv front-end programs megastep bit-identically too — the (K, B,
    H, W, C) frame block threads through im2col unchanged."""
    _, program, xs = _make(conv=LENET_S, seed=5)
    xs = jnp.concatenate([xs, xs])        # two presentations, T=4
    _assert_megastep_matches(program, xs, backend, [3, 1], f"conv/{backend}",
                             **kw)


def test_megastep_active_mask_zero_fills_short_lanes():
    """Per-lane active counts: a lane active for only n < K ticks
    integrates zero current afterwards — exactly what a zero-padded
    stream of the same length produces — and frames_consumed reports n."""
    _, program, xs = _make(batch=3)
    k = 6
    active = np.array([4, 2, 6])
    state0 = program.init_state(3, "int_ref")
    state, out = program.megastep(state0, xs[:k], "int_ref",
                                  active=jnp.asarray(active))
    np.testing.assert_array_equal(np.asarray(out.frames_consumed), active)
    # reference: mask the block on the host, run tick by tick
    padded = np.asarray(xs[:k]).copy()
    for lane, n in enumerate(active):
        padded[n:, lane] = 0.0
    ref_state, ref_v, ref_l, _ = _tickwise(program, jnp.asarray(padded),
                                           "int_ref")
    np.testing.assert_array_equal(np.asarray(out.v_out_traj), ref_v)
    np.testing.assert_array_equal(np.asarray(out.logits_traj), ref_l)
    _assert_states_equal(state, ref_state, "active-mask")


def test_megastep_validates_frames_block():
    _, program, xs = _make()
    state = program.init_state(2, "int_ref")
    with pytest.raises(ValueError, match="megastep"):
        program.megastep(state, xs[0], "int_ref")      # missing K axis
    with pytest.raises(ValueError, match="megastep"):
        program.megastep(state, xs[:0], "int_ref")     # K=0 block


# ---------------------------------------------------------------------------
# serving engine: megastep/paged drains == K=1 drain, Poisson soak, and the
# drain-path bug round
# ---------------------------------------------------------------------------

def _program(seed=0):
    cfg = SNNModelConfig(
        arch_id="test", layer_sizes=(37, 50, 20, 3),
        spiking=SpikingConfig(neuron="rmp", timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    return cfg, pipeline.compile_network(cfg, params, domain="int")


def _word_request(cfg, rid, n_words, seed, **req_kw):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, n_words, cfg.layer_sizes[0])).astype(
        np.float32)
    frames = np.asarray(pipeline.present_words(
        jnp.asarray(x), cfg.timesteps))[:, 0]
    return SNNRequest(rid=rid, frames=frames, **req_kw)


def _drain(program, cfg, backend, kw, lengths, *, slots=2, seed=40,
           stop_rid=None, arrivals=None, **ekw):
    eng = SNNServeEngine(program, batch_slots=slots, backend=backend,
                         step_kw=kw, **ekw)
    for rid, nw in enumerate(lengths):
        req = _word_request(cfg, rid, nw, seed=seed + rid,
                            stop_threshold=(0.5 if rid == stop_rid
                                            else None))
        if arrivals is not None:
            req.arrival_tick = arrivals[rid]
        eng.submit(req)
    done = sorted(eng.run_until_drained(max_ticks=50_000),
                  key=lambda r: r.rid)
    assert len(done) == len(lengths)
    return eng, done


def _assert_drains_equal(ref, got, tag):
    for a, b in zip(ref, got):
        assert a.ticks == b.ticks, f"{tag} rid {a.rid} ticks"
        np.testing.assert_array_equal(np.asarray(a.logits),
                                      np.asarray(b.logits),
                                      err_msg=f"{tag} rid {a.rid} logits")
        np.testing.assert_array_equal(np.asarray(a.v_out),
                                      np.asarray(b.v_out),
                                      err_msg=f"{tag} rid {a.rid} v_out")
        for la, lb in zip(a.report.row_events, b.report.row_events):
            np.testing.assert_array_equal(
                np.asarray(la), np.asarray(lb),
                err_msg=f"{tag} rid {a.rid} row_events")
        assert a.report.instruction_counts() == b.report.instruction_counts()


@pytest.mark.parametrize("backend,kw", [
    ("int_ref", {"use_sparse": True}),
    ("pallas_sparse", {"interpret": True, "block_b": 2}),
    ("pallas_events", {"interpret": True, "block_b": 2}),
])
@pytest.mark.parametrize("megastep,pages,db", [
    (4, 2, True), (8, 1, False), (16, 3, True)])
def test_engine_megastep_drain_matches_k1(backend, kw, megastep, pages, db):
    """The bit-identity bar: a K-megastep drain over a paged pool (with
    or without double-buffered upload) finishes every request — ragged
    lengths, one early-exit request — identically to the K=1 drain:
    logits, V, ticks, per-request reports, and the merged report."""
    cfg, program = _program()
    lengths = [2, 4, 1, 3, 2, 1]
    ref_eng, ref = _drain(program, cfg, backend, kw, lengths, stop_rid=3)
    got_eng, got = _drain(program, cfg, backend, kw, lengths, stop_rid=3,
                          megastep=megastep, pages=pages, double_buffer=db)
    _assert_drains_equal(ref, got, f"{backend}/K={megastep}")
    a, b = ref_eng.aggregate_report(), got_eng.aggregate_report()
    assert a.events == b.events and a.frames == b.frames
    assert a.instruction_counts() == b.instruction_counts()


def test_engine_poisson_soak_drain_and_report_closure():
    """Offered-load churn: seeded Poisson arrivals over a paged pool keep
    the drain contract (all requests finish; idle ticks advance the
    frame clock until the head arrives) and per-request report closure —
    every finished request's report equals the batch path's report of
    its own frames, and latency >= service time."""
    cfg, program = _program(seed=2)
    rng = np.random.default_rng(9)
    lengths = [2, 1, 3, 2, 1, 2, 3, 1]
    arrivals = np.cumsum(rng.exponential(4.0, len(lengths))).astype(int)
    eng, done = _drain(program, cfg, "int_ref", {"use_sparse": True},
                       lengths, slots=2, arrivals=list(arrivals),
                       megastep=4, pages=2, double_buffer=True)
    assert eng.queue.empty() and not any(s.req for s in eng.slots)
    assert eng.clock >= int(arrivals[-1])  # idle ticks advanced the clock
    for rid, (r, nw) in enumerate(zip(done, lengths)):
        assert r.ticks == nw * cfg.timesteps
        assert r.latency_ticks >= r.ticks
        assert r.finish_clock >= r.arrival_tick + r.ticks
        rng_i = np.random.default_rng(40 + rid)
        x = jnp.asarray(rng_i.standard_normal(
            (1, nw, cfg.layer_sizes[0])).astype(np.float32))
        iso = pipeline.run_network(program,
                                   pipeline.present_words(x, cfg.timesteps),
                                   "int_ref")
        np.testing.assert_array_equal(r.v_out, np.asarray(iso.v_out)[0])
        ref = pipeline.sparsity_report(program, iso.rasters)
        assert r.report.events == ref.events
        assert r.report.instruction_counts() == ref.instruction_counts()
    merged = merge_reports([r.report for r in done])
    agg = eng.aggregate_report()
    assert agg.events == merged.events
    assert agg.instruction_counts() == merged.instruction_counts()


def test_engine_idle_lane_reset_restores_fresh_state():
    """The idle-lane fix: eviction scatters fresh zero state back into the
    vacated lane, so after a full drain every page's V tree equals the
    engine's fresh template — an idle lane dispatched in a later tick
    contributes zero events instead of replaying stale V."""
    cfg, program = _program()
    eng, _ = _drain(program, cfg, "int_ref", {"use_sparse": True},
                    [3, 1, 2], slots=2, megastep=2, pages=2)
    for page, state in enumerate(eng.states):
        for li, (v, f) in enumerate(zip(state.vs, eng._fresh.vs)):
            v = np.asarray(v)
            np.testing.assert_array_equal(
                v, np.broadcast_to(np.asarray(f), v.shape),
                err_msg=f"page {page} layer {li} not reset")


def test_zero_budget_request_finishes_with_zero_v_out():
    """Drain-path regression: a request admitted with nothing to stream
    (no frames, or max_ticks <= 0) finishes immediately with a
    *shape-consistent* zero v_out/logits — not None — and a stamped
    finish clock."""
    cfg, program = _program()
    eng = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    empty = SNNRequest(rid=0, frames=np.zeros((0, 37), np.float32))
    capped = _word_request(cfg, 1, 2, seed=3, max_ticks=0)
    eng.submit(empty)
    eng.submit(capped)
    done = sorted(eng.run_until_drained(), key=lambda r: r.rid)
    for r in done:
        assert r.ticks == 0 and r.finish_clock is not None
        assert r.v_out.shape == (cfg.layer_sizes[-1],)
        assert r.v_out.dtype == np.int32           # int domain
        np.testing.assert_array_equal(r.v_out, 0)
        np.testing.assert_array_equal(np.asarray(r.logits), 0)


def test_aggregate_report_named_errors():
    """Drain-path regression: aggregate_report raises the named
    ReportUnavailable — not a generic merge ValueError — both when event
    tracking is off and when nothing has finished yet."""
    cfg, program = _program()
    eng = SNNServeEngine(program, batch_slots=1, backend="int_ref",
                         track_events=False)
    eng.submit(_word_request(cfg, 0, 1, seed=5))
    eng.run_until_drained()
    with pytest.raises(ReportUnavailable, match="track_events"):
        eng.aggregate_report()
    eng2 = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    with pytest.raises(ReportUnavailable, match="finished"):
        eng2.aggregate_report()
