"""Mesh-sharded SNN execution: bit-identity with the single-device path.

The claim under test is the tentpole of the `repro.dist` wiring: because
every on-macro reduction is integer (the per-shard partial V is unclamped
int32, the cross-shard `psum` is the AccV2V reduction — exact under the
mod-2^11 word, with the single clamp applied *after* the reduction), a
`jax.sharding.Mesh` execution of `run_network` / `stream_megastep` /
`SNNServeEngine` is **bit-identical** to the single-device run — rasters,
per-layer V, readout V, logits, and the event-counter ledgers. Swept here
on 4 forced host devices (conftest sets
``--xla_force_host_platform_device_count=4``) over mesh shape x backend x
neuron x clamp mode x row-tiled shapes, at megastep K in {1, 8}, and
through a serving drain on a partitioned pool.

`dist.sharding._fit` unit tests ride along: a dropped axis warns with the
extents, and a *required* axis that cannot shard raises `ShardingError`
instead of silently replicating.
"""
import logging

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import pipeline, snn
from repro.dist import sharding
from repro.dist.sharding import ShardingError
from repro.launch.mesh import make_host_mesh
from repro.serve import SNNRequest, SNNServeEngine
from repro.serve.snn_engine import merge_reports

needs4 = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="mesh suite needs >= 4 devices "
           "(--xla_force_host_platform_device_count=4)")

#: (n_data, n_model) mesh shapes over 4 devices: pure data-parallel, pure
#: model-parallel (row tiles), and the mixed square
MESH_SHAPES = ((4, 1), (1, 4), (2, 2))


def _make(layer_sizes=(300, 150, 20, 3), neuron="rmp", n_words=3, batch=4,
          seed=0, clamp="saturate"):
    """A row-tiled program (fan-in 300 > LANE=128 splits over macros) and
    a (T, B, d) presentation."""
    cfg = SNNModelConfig(
        arch_id="test", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron=neuron, timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 7)
    x = jnp.asarray(rng.standard_normal(
        (batch, n_words, layer_sizes[0])).astype(np.float32))
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode=clamp)
    return program, pipeline.present_words(x, cfg.timesteps)


def _make_conv(seed=0):
    """A conv-front-end program: the mesh dispatch must also cover the
    im2col patch-raster calls."""
    cfg = SNNModelConfig(
        arch_id="lenet-s", conv_spec=((4, 3, 1), (6, 3, 2)),
        in_shape=(8, 8, 1), layer_sizes=(4 * 4 * 6, 10, 3),
        spiking=SpikingConfig(neuron="rmp", timesteps=2, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=2, task="multiclass")
    params = snn.init_lenet_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 3)
    x = jnp.asarray(rng.standard_normal((4, 8, 8, 1)).astype(np.float32))
    program = pipeline.compile_network(cfg, params, domain="int")
    return program, pipeline.present_static(x, cfg.timesteps)


def _assert_results_equal(ref, got, tag, *, events=False):
    """Every observable of a NetResult, bit for bit."""
    for i, (a, b) in enumerate(zip(ref.rasters, got.rasters)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag} raster {i}")
    for i, (a, b) in enumerate(zip(ref.v_final, got.v_final)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag} V {i}")
    np.testing.assert_array_equal(np.asarray(ref.v_out),
                                  np.asarray(got.v_out),
                                  err_msg=f"{tag} v_out")
    np.testing.assert_array_equal(np.asarray(ref.logits),
                                  np.asarray(got.logits),
                                  err_msg=f"{tag} logits")
    if events:
        for i, (a, b) in enumerate(zip(ref.aux["row_events"],
                                       got.aux["row_events"])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{tag} row_events {i}")
        assert ref.aux["row_event_frames"] == got.aux["row_event_frames"]


BACKEND_KW = [
    ("int_ref", {}),
    ("pallas", {"interpret": True, "block_b": 4}),
    ("pallas_sparse", {"interpret": True, "block_b": 4}),
    ("pallas_sparse", {"interpret": True, "block_b": 4,
                       "gate_granularity": 4}),
    ("ref_events", {}),
    ("pallas_events", {"interpret": True, "block_b": 4}),
]


def _case_id(b, k):
    return b + (f"-g{k['gate_granularity']}" if "gate_granularity" in k
                else "")


# ---------------------------------------------------------------------------
# run_network bit-identity
# ---------------------------------------------------------------------------

@needs4
@pytest.mark.parametrize("backend,kw", BACKEND_KW,
                         ids=[_case_id(b, k) for b, k in BACKEND_KW])
@pytest.mark.parametrize("shape", MESH_SHAPES,
                         ids=[f"d{d}m{m}" for d, m in MESH_SHAPES])
def test_mesh_matches_single_device(shape, backend, kw):
    """Every int backend, every mesh shape, one row-tiled program: the
    mesh run equals the single-device run bit for bit."""
    program, xs = _make()
    mesh = make_host_mesh(4, model=shape[1])
    ref = pipeline.run_network(program, xs, backend, **kw)
    got = pipeline.run_network(program, xs, backend, mesh=mesh, **kw)
    _assert_results_equal(ref, got, f"{shape}/{backend}",
                          events=backend in ("ref_events", "pallas_events"))


@needs4
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
@pytest.mark.parametrize("clamp", ["saturate", "wrap"])
def test_mesh_neuron_clamp_sweep(neuron, clamp):
    """Neuron x clamp on ragged, non-dividing shapes (B=3 does not divide
    data=2; widths are not multiples of model=2): padding and the
    post-psum clamp stay exact in both word policies."""
    program, xs = _make(layer_sizes=(37, 51, 19, 3), neuron=neuron,
                        batch=3, clamp=clamp, seed=5)
    mesh = make_host_mesh(4, model=2)
    for backend, kw in (("int_ref", {}),
                        ("pallas", {"interpret": True, "block_b": 4})):
        ref = pipeline.run_network(program, xs, backend, **kw)
        got = pipeline.run_network(program, xs, backend, mesh=mesh, **kw)
        _assert_results_equal(ref, got, f"{neuron}/{clamp}/{backend}")


@needs4
@pytest.mark.parametrize("backend,kw",
                         [("int_ref", {}),
                          ("pallas", {"interpret": True, "block_b": 4}),
                          ("ref_events", {})],
                         ids=["int_ref", "pallas", "ref_events"])
def test_mesh_conv_front_end(backend, kw):
    """Conv programs: the im2col patch-raster dispatches execute under the
    mesh too (patch frames partition as whole (example, position) frames)."""
    program, xs = _make_conv()
    mesh = make_host_mesh(4, model=2)
    ref = pipeline.run_network(program, xs, backend, **kw)
    got = pipeline.run_network(program, xs, backend, mesh=mesh, **kw)
    _assert_results_equal(ref, got, f"conv/{backend}",
                          events=backend == "ref_events")


@needs4
def test_float_and_bitmacro_reject_mesh():
    """Non-mesh backends fail loudly instead of silently ignoring the
    mesh: float reductions are not order-exact, bitmacro state is host-
    side."""
    program, xs = _make()
    mesh = make_host_mesh(4)
    with pytest.raises(ValueError, match="no mesh execution"):
        pipeline.run_network(program, xs, "float", mesh=mesh)
    with pytest.raises(ValueError, match="no mesh execution"):
        pipeline.stream_megastep(
            program, pipeline.init_stream_state(program, 4), xs[:2],
            "float", mesh=mesh)
    with pytest.raises(ValueError):
        SNNServeEngine(program, backend="float", mesh=mesh)


# ---------------------------------------------------------------------------
# streaming megasteps on a mesh
# ---------------------------------------------------------------------------

@needs4
@pytest.mark.parametrize("k", [1, 8])
@pytest.mark.parametrize("backend,kw",
                         [("int_ref", {}),
                          ("pallas", {"interpret": True, "block_b": 4}),
                          ("pallas_events", {"interpret": True,
                                             "block_b": 4})],
                         ids=["int_ref", "pallas", "pallas_events"])
def test_mesh_megastep_stream(k, backend, kw):
    """Driving a presentation through K-frame megastep blocks on a (2, 2)
    mesh reproduces the meshless drive exactly: carried state, per-tick
    readout trajectories, and frames_consumed."""
    program, xs = _make(n_words=4)             # T_total = 12
    mesh = make_host_mesh(4, model=2)
    st_a = st_b = pipeline.init_stream_state(program, 4, backend)
    for lo in range(0, xs.shape[0], k):
        block = xs[lo:lo + k]
        if block.shape[0] < k:                 # ragged tail: mask it
            pad = jnp.zeros((k - block.shape[0], *block.shape[1:]),
                            block.dtype)
            active = np.full(4, block.shape[0], np.int32)
            block = jnp.concatenate([block, pad])
        else:
            active = None
        st_a, out_a = pipeline.stream_megastep(program, st_a, block,
                                               backend, active=active, **kw)
        st_b, out_b = pipeline.stream_megastep(program, st_b, block,
                                               backend, active=active,
                                               mesh=mesh, **kw)
        np.testing.assert_array_equal(np.asarray(out_a.v_out_traj),
                                      np.asarray(out_b.v_out_traj))
        np.testing.assert_array_equal(np.asarray(out_a.logits_traj),
                                      np.asarray(out_b.logits_traj))
        np.testing.assert_array_equal(np.asarray(out_a.frames_consumed),
                                      np.asarray(out_b.frames_consumed))
    for i, (a, b) in enumerate(zip(st_a.vs, st_b.vs)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"carried V {i}")


# ---------------------------------------------------------------------------
# serving on a partitioned pool
# ---------------------------------------------------------------------------

def _requests(n=7, t=9, d=300, seed=11):
    rng = np.random.default_rng(seed)
    out = []
    for r in range(n):
        out.append(SNNRequest(
            rid=r, frames=rng.standard_normal((t, d)).astype(np.float32)))
    return out


def _drain(program, mesh, backend, kw, megastep=4, pages=2):
    eng = SNNServeEngine(program, batch_slots=4, backend=backend,
                         step_kw=kw, pages=pages, megastep=megastep,
                         mesh=mesh)
    for r in _requests():
        eng.submit(r)
    eng.run_until_drained()
    return eng


@needs4
@pytest.mark.parametrize("backend,kw",
                         [("int_ref", {}),
                          ("ref_events", {}),
                          ("pallas_events", {"interpret": True,
                                             "block_b": 4})],
                         ids=["int_ref", "ref_events", "pallas_events"])
def test_mesh_serving_drain(backend, kw):
    """A full drain on a mesh-partitioned paged pool (2 pages x 4 lanes,
    lanes sharded over data=2, rows over model=2, K=4 megasteps) serves
    every request bit-identically to the single-device engine, and the
    event accounting closes: per-request reports, the merged aggregate,
    and — on the event backends — the device ledger."""
    program, _ = _make()
    mesh = make_host_mesh(4, model=2)
    a = _drain(program, None, backend, kw)
    b = _drain(program, mesh, backend, kw)
    assert len(a.finished) == len(b.finished) == 7
    for ra, rb in zip(sorted(a.finished, key=lambda r: r.rid),
                      sorted(b.finished, key=lambda r: r.rid)):
        np.testing.assert_array_equal(ra.logits, rb.logits,
                                      err_msg=f"rid {ra.rid} logits")
        np.testing.assert_array_equal(ra.v_out, rb.v_out,
                                      err_msg=f"rid {ra.rid} v_out")
        assert (ra.ticks, ra.finish_clock) == (rb.ticks, rb.finish_clock)
        for i, (x, y) in enumerate(zip(ra.report.row_events,
                                       rb.report.row_events)):
            np.testing.assert_array_equal(
                np.asarray(x), np.asarray(y),
                err_msg=f"rid {ra.rid} row_events {i}")
    # aggregate closure: merging the mesh engine's per-request reports
    # equals merging the single-device engine's
    agg_a = a.aggregate_report()
    agg_b = merge_reports([r.report for r in b.finished])
    assert agg_a.events == agg_b.events
    assert agg_a.frames == agg_b.frames
    for x, y in zip(agg_a.row_events, agg_b.row_events):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    if backend in ("ref_events", "pallas_events"):
        da, db = a.device_event_stats(), b.device_event_stats()
        assert da.frames == db.frames
        for x, y in zip(da.row_events, db.row_events):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert (a.device_skipped_row_fraction()
                == b.device_skipped_row_fraction())


# ---------------------------------------------------------------------------
# dist.sharding._fit: warning + ShardingError (satellite fix)
# ---------------------------------------------------------------------------

@needs4
def test_fit_divisibility_drop_warns(caplog):
    """A proposal whose dimension does not divide the mesh extent degrades
    to replication AND warns with the axis and extents — never silently."""
    mesh = make_host_mesh(4, model=2)          # data=2, model=2
    with caplog.at_level(logging.WARNING, logger="repro.dist.sharding"):
        spec = sharding._fit(("data",), (5,), mesh)
    assert spec == P(None)
    rendered = [r.getMessage() for r in caplog.records]
    assert any("dropping axis 'data'" in m for m in rendered)
    assert any("size 5 does not divide mesh extent 2" in m
               for m in rendered)


@needs4
def test_fit_required_axis_raises():
    """The same drop on an explicitly *required* axis raises ShardingError
    (with the extents) instead of degrading."""
    mesh = make_host_mesh(4, model=2)
    with pytest.raises(ShardingError, match="does not divide mesh extent"):
        sharding._fit(("data",), (5,), mesh, required=("data",))
    # a missing mesh axis is equally fatal when required
    with pytest.raises(ShardingError, match="missing from mesh"):
        sharding._fit(("banks",), (4,), mesh, required=("banks",))
    # ...but silently replicates when not required (generic-rule contract)
    assert sharding._fit(("banks",), (4,), mesh) == P(None)


@needs4
def test_fit_size_one_extent_is_honoured(caplog):
    """A size-1 mesh axis counts as honoured (sharding over it IS
    replication): no warning, no error, even when required."""
    mesh = make_host_mesh(4, model=1)          # data=4, model=1
    with caplog.at_level(logging.WARNING, logger="repro.dist.sharding"):
        spec = sharding._fit(("model",), (5,), mesh, required=("model",))
    assert spec == P(None)
    assert not caplog.records


@needs4
def test_logical_spec_snn_axes():
    """The SNN logical axes resolve onto the mesh: lanes/banks -> data,
    macro_row_tile -> model; an unknown *required* name raises."""
    mesh = make_host_mesh(4, model=2)
    assert sharding.logical_spec(mesh, ("lane", None), (8, 16)) \
        == P("data", None)
    assert sharding.logical_spec(mesh, ("macro_row_tile", None), (6, 16),
                                 required=("macro_row_tile",)) \
        == P("model", None)
    assert sharding.logical_spec(mesh, ("bank",), (2,)) == P("data")
    with pytest.raises(ShardingError, match="resolves to no mesh axis"):
        sharding.logical_spec(mesh, ("lane",), (8,), required=("lanez",))


@needs4
def test_snn_state_specs_places_lanes():
    """Streaming-state placement: every array leaf's lane axis shards over
    data; the scalar tick counter replicates."""
    program, _ = _make()
    mesh = make_host_mesh(4, model=2)
    st = pipeline.init_stream_state(program, 4, "int_ref")
    specs = sharding.snn_state_specs(st, mesh)
    for s in specs.vs:
        assert s.spec == P("data", None)
    assert specs.t.spec == P()
