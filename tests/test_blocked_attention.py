"""Blocked (flash-style) attention == naive _sdpa, causal and bidirectional,
GQA and MHA, ragged chunk layouts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import _sdpa, blocked_attention


def _qkv(B, T, S, H, KV, D, seed=0):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((B, T, H, D)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.standard_normal((B, S, KV, D)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.standard_normal((B, S, KV, D)) * 0.5, jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("cfg", [
    # B, T, H, KV, D, q_chunk, kv_block
    (2, 128, 4, 2, 16, 32, 32),
    (1, 256, 4, 4, 8, 64, 128),
    (2, 64, 8, 2, 16, 64, 16),
])
def test_blocked_matches_sdpa(causal, cfg):
    B, T, H, KV, D, qc, kb = cfg
    q, k, v = _qkv(B, T, T, H, KV, D, seed=sum(cfg))
    ref = _sdpa(q, k, v, causal=causal, q_pos=jnp.arange(T)[None])
    out = blocked_attention(q, k, v, causal=causal, q_chunk=qc, kv_block=kb)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


def test_blocked_unrolled_identical():
    q, k, v = _qkv(1, 128, 128, 4, 2, 16, seed=7)
    a = blocked_attention(q, k, v, causal=True, q_chunk=32, kv_block=32,
                          unroll=False)
    b = blocked_attention(q, k, v, causal=True, q_chunk=32, kv_block=32,
                          unroll=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_blocked_grads_finite():
    q, k, v = _qkv(1, 64, 64, 4, 4, 8, seed=3)

    def f(q, k, v):
        return jnp.sum(blocked_attention(q, k, v, causal=True,
                                         q_chunk=32, kv_block=16) ** 2)

    gq, gk, gv = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    for g in (gq, gk, gv):
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.abs(g).sum()) > 0
