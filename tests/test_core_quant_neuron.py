"""Unit + property tests: quantization grid and neuron dynamics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import quant
from repro.core.neuron import init_state, neuron_step, spike


# ---------------------------------------------------------------------------
# quant
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_range():
    w = jnp.linspace(-2.0, 2.0, 101)
    wq, scale = quant.quantize_w(w)
    assert wq.dtype == jnp.int8
    assert int(wq.max()) == quant.W_MAX and int(wq.min()) == quant.W_MIN
    err = jnp.abs(quant.dequantize_w(wq, scale) - w)
    assert float(err.max()) <= float(scale) / 2 + 1e-6


@given(st.integers(min_value=quant.W_MIN, max_value=quant.W_MAX))
@settings(max_examples=30, deadline=None)
def test_quant_int_identity(k):
    """Integers already on the grid survive quantization exactly."""
    w = jnp.array([float(k), float(quant.W_MAX)])  # pin the scale
    wq, _ = quant.quantize_w(w)
    assert int(wq[0]) == k


def test_fake_quant_ste_gradient():
    w = jnp.array([0.3, -0.7, 1.2])
    g = jax.grad(lambda w: jnp.sum(quant.fake_quant_w(w) * jnp.array([1.0, 2.0, 3.0])))(w)
    np.testing.assert_allclose(np.asarray(g), [1.0, 2.0, 3.0])  # straight-through


@given(st.integers(min_value=-5000, max_value=5000))
@settings(max_examples=50, deadline=None)
def test_clamp_modes(v):
    sat = int(quant.clamp_v(jnp.int32(v), "saturate"))
    wrap = int(quant.clamp_v(jnp.int32(v), "wrap"))
    assert quant.V_MIN <= sat <= quant.V_MAX
    assert quant.V_MIN <= wrap <= quant.V_MAX
    assert (wrap - v) % 2048 == 0                     # two's complement rollover
    if quant.V_MIN <= v <= quant.V_MAX:
        assert sat == v == wrap


# ---------------------------------------------------------------------------
# neurons
# ---------------------------------------------------------------------------

def _run(neuron, currents, th=1.0, leak=0.25, **kw):
    st_ = init_state(())
    vs, ss = [], []
    for c in currents:
        st_, s = neuron_step(st_, jnp.float32(c), neuron=neuron, threshold=th,
                             leak=leak, **kw)
        vs.append(float(st_.v)); ss.append(float(s))
    return vs, ss


def test_if_dynamics():
    vs, ss = _run("if", [0.4, 0.4, 0.4])
    assert ss == [0.0, 0.0, 1.0]
    assert vs[:2] == [pytest.approx(0.4), pytest.approx(0.8)]
    assert vs[2] == 0.0                               # hard reset


def test_lif_subtractive_leak():
    vs, ss = _run("lif", [0.5, 0.0], th=10.0, leak=0.25)
    assert vs[0] == pytest.approx(0.25)               # 0.5 - leak
    assert vs[1] == pytest.approx(0.0)                # 0.25 - 0.25


def test_rmp_soft_reset():
    vs, ss = _run("rmp", [1.7], th=1.0)
    assert ss == [1.0]
    assert vs[0] == pytest.approx(0.7)                # v - th, residual kept


def test_rmp_never_fires_below_threshold_property():
    @given(st.lists(st.floats(-0.2, 0.0999), min_size=1, max_size=10))
    @settings(max_examples=25, deadline=None)
    def inner(cs):
        _, ss = _run("rmp", cs, th=1.0)
        assert all(s == 0.0 for s in ss)
    inner()


def test_surrogate_gradient_triangle():
    g = jax.grad(lambda v: spike(v, 1.0, 1.0))(jnp.float32(0.9))
    assert float(g) == pytest.approx(0.9)             # 1 - |0.9-1| = 0.9
    g0 = jax.grad(lambda v: spike(v, 1.0, 1.0))(jnp.float32(3.0))
    assert float(g0) == 0.0


def test_threshold_gradient_flows():
    th = jnp.float32(1.0)
    g = jax.grad(lambda t: jnp.sum(spike(jnp.array([0.9, 1.05]), t, 1.0)))(th)
    assert np.isfinite(float(g)) and float(g) != 0.0
