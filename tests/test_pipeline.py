"""Backend-equivalence contract of the network pipeline.

One SNNProgram, four execution substrates — float (f32 rendering of the
integer program), int_ref (word-level ISA), pallas (network-level fused
kernel, interpret mode), bitmacro (bit-level silicon oracle) — must produce
bit-identical spike rasters, final V, and identical program-level
InstrCounts. The sweep covers every neuron model, both V_MEM clamp policies,
odd shapes (non-multiples of the 128-lane / 12-neuron tiles), fan-in > 128
layers (row-tiled macros with the AccV2V partial-sum reduction on the
silicon oracle), and LeNet5-mod conv stacks (im2col-lowered int convs).

The bitmacro backend joins only in ``wrap`` mode: the silicon's ripple adder
wraps mod 2^11 (saturation is a word-level deployment policy, macro.py), and
saturating at word level does not commute with the macro's event-by-event
accumulation order — which is also why the row-tiled partial-sum reduction
is exact there: mod-2^11 addition composes across the fan-in split.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import IMDB, MNIST, SNNModelConfig
from repro.core import pipeline, snn

# (layer_sizes, n_words, batch) — odd widths exercise the padding paths
SHAPES = [
    ((100, 128, 128, 1), 2, 2),     # the IMDB geometry
    ((37, 50, 20, 3), 3, 2),        # ragged everything
    ((130, 140, 12, 1), 2, 1),      # >128 fan-in (row-tiled on silicon)
]

# spatially reduced LeNet5-mod stack (same structure as configs.MNIST:
# conv spike encoder -> on-macro convs -> FCs -> readout) so the bit-level
# oracle joins the conv sweep at tractable cost
LENET_S = SNNModelConfig(
    arch_id="lenet-s",
    conv_spec=((4, 3, 1), (6, 3, 2)),
    in_shape=(8, 8, 1),
    layer_sizes=(4 * 4 * 6, 10, 3),
    spiking=SpikingConfig(neuron="rmp", timesteps=2, threshold=1.0,
                          leak=0.0625, w_bits=6, v_bits=11),
    timesteps=2, task="multiclass")


def _make(layer_sizes, neuron, n_words, batch, seed=0):
    cfg = SNNModelConfig(
        arch_id="test", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron=neuron, timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 7)
    x = jnp.asarray(rng.standard_normal(
        (batch, n_words, layer_sizes[0])).astype(np.float32))
    return cfg, params, x


def _make_conv(cfg, neuron, batch, seed=0, scale=2.0):
    cfg = dataclasses.replace(
        cfg, spiking=dataclasses.replace(cfg.spiking, neuron=neuron))
    params = snn.init_lenet_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 7)
    x = jnp.asarray(rng.standard_normal(
        (batch, *cfg.in_shape)).astype(np.float32)) * scale
    return cfg, params, x


def _run_all(cfg, params, x, clamp_mode, with_bitmacro=True):
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode=clamp_mode)
    if cfg.conv_spec:
        xs = pipeline.present_static(x, cfg.timesteps)
    else:
        xs = pipeline.present_words(x, cfg.timesteps)
    results = {
        "float": pipeline.run_network(program, xs, "float",
                                      collect_rasters=True),
        "int_ref": pipeline.run_network(program, xs, "int_ref"),
        "pallas": pipeline.run_network(program, xs, "pallas", interpret=True,
                                       block_b=4),
        "pallas_sparse": pipeline.run_network(program, xs, "pallas_sparse",
                                              interpret=True, block_b=4),
        "pallas_sparse_rb4": pipeline.run_network(
            program, xs, "pallas_sparse", interpret=True, block_b=4,
            gate_granularity=4),
        "ref_events": pipeline.run_network(program, xs, "ref_events"),
        "pallas_events": pipeline.run_network(program, xs, "pallas_events",
                                              interpret=True, block_b=4),
    }
    if clamp_mode == "wrap" and with_bitmacro:
        results["bitmacro"] = pipeline.run_network(program, xs, "bitmacro")
    return program, results


def _assert_equivalent(program, results, tag=""):
    ref = results.pop("int_ref")
    counts_ref = pipeline.count_network_instructions(program, ref.rasters)
    assert counts_ref.total > 0
    for name, res in results.items():
        assert len(res.rasters) == len(ref.rasters), (name, tag)
        for li, (a, b) in enumerate(zip(res.rasters, ref.rasters)):
            np.testing.assert_array_equal(
                np.asarray(a).astype(np.int8),
                np.asarray(b).astype(np.int8),
                err_msg=f"{name} raster {li} ({tag})")
        # final V: encoder V is float everywhere; stack V must be bit-equal
        for li, (a, b) in enumerate(zip(res.v_final[1:], ref.v_final[1:])):
            np.testing.assert_array_equal(
                np.asarray(a).astype(np.int64),
                np.asarray(b).astype(np.int64),
                err_msg=f"{name} V {li} ({tag})")
        counts = pipeline.count_network_instructions(program, res.rasters)
        assert counts == counts_ref, (name, tag, counts, counts_ref)
    return counts_ref


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("shape", SHAPES,
                         ids=["imdb", "ragged", "rowtile130"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_backend_equivalence(neuron, shape, clamp_mode):
    layer_sizes, n_words, batch = shape
    cfg, params, x = _make(layer_sizes, neuron, n_words, batch)
    program, results = _run_all(cfg, params, x, clamp_mode)
    if clamp_mode == "wrap":        # row-tiled shapes join via AccV2V now
        assert "bitmacro" in results
    _assert_equivalent(program, results, f"{neuron}/{clamp_mode}")


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_conv_backend_equivalence(neuron, clamp_mode):
    """The conv acceptance sweep on a reduced LeNet5-mod stack: im2col-
    lowered int convs, all four substrates (bitmacro joins in wrap mode),
    bit-identical per timestep."""
    cfg, params, x = _make_conv(LENET_S, neuron, batch=2)
    program, results = _run_all(cfg, params, x, clamp_mode)
    assert len(program.int_conv_stack) == 1       # conv0 is the encoder
    assert len(program.macro_stack) == 1 + len(program.fc_stack)
    if clamp_mode == "wrap":
        assert "bitmacro" in results
    _assert_equivalent(program, results, f"conv/{neuron}/{clamp_mode}")


def test_mnist_lenet5_mod_int_all_backends():
    """The acceptance contract on the paper's own conv network: the MNIST
    LeNet5-mod config (fan-in 3*3*14 = 126, two on-macro convs, row-tiled
    686-wide FC) compiles in the int domain and runs bit-identical on every
    backend, including the bit-level oracle with its AccV2V reduction on
    the 686 -> 120 layer (6 row tiles)."""
    cfg = dataclasses.replace(MNIST, timesteps=2,
                              spiking=dataclasses.replace(MNIST.spiking,
                                                          timesteps=2))
    cfg, params, x = _make_conv(cfg, "rmp", batch=1, seed=2)
    program, results = _run_all(cfg, params, x, "wrap")
    assert set(results) == {"float", "int_ref", "pallas", "pallas_sparse",
                            "pallas_sparse_rb4", "ref_events",
                            "pallas_events", "bitmacro"}
    assert [ly.tiling.row_tiles for ly in program.fc_stack] == [6, 1, 1]
    assert [ly.n_in for ly in program.int_conv_stack] == [126, 126]
    counts = _assert_equivalent(program, results, "mnist-lenet5-mod")
    assert counts.acc_v2v > 0                     # reduction term executed


def test_imdb_all_backends_bit_identical():
    """The acceptance contract on the paper's own network: all backends,
    one program, identical rasters / V / InstrCounts (wrap = raw silicon)."""
    cfg = dataclasses.replace(IMDB, timesteps=3,
                              spiking=dataclasses.replace(IMDB.spiking,
                                                          timesteps=3))
    params = snn.init_fc_snn(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 3, 100)).astype(np.float32))
    program, results = _run_all(cfg, params, x, "wrap")
    assert set(results) == {"float", "int_ref", "pallas", "pallas_sparse",
                            "pallas_sparse_rb4", "ref_events",
                            "pallas_events", "bitmacro"}
    ref = results["int_ref"]
    counts = {n: pipeline.count_network_instructions(program, r.rasters)
              for n, r in results.items()}
    for name, res in results.items():
        for a, b in zip(res.rasters, ref.rasters):
            np.testing.assert_array_equal(np.asarray(a).astype(np.int8),
                                          np.asarray(b), err_msg=name)
        for a, b in zip(res.v_final[1:], ref.v_final[1:]):
            np.testing.assert_array_equal(np.asarray(a).astype(np.int64),
                                          np.asarray(b).astype(np.int64),
                                          err_msg=name)
        np.testing.assert_allclose(np.asarray(res.logits),
                                   np.asarray(ref.logits), err_msg=name)
        assert counts[name] == counts["int_ref"]


def test_wrappers_route_through_pipeline():
    """snn.sentiment_apply_int on the pallas backend == int_ref backend."""
    cfg = dataclasses.replace(IMDB, timesteps=2,
                              spiking=dataclasses.replace(IMDB.spiking,
                                                          timesteps=2))
    params = snn.init_fc_snn(jax.random.PRNGKey(2), cfg)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((3, 2, 100)).astype(np.float32))
    l_ref, r_ref, c_ref = snn.sentiment_apply_int(params, x, cfg)
    l_pal, r_pal, c_pal = snn.sentiment_apply_int(params, x, cfg,
                                                  backend="pallas",
                                                  interpret=True)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pal))
    for a, b in zip(r_ref, r_pal):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert c_ref == c_pal


def test_lenet_wrappers_route_through_pipeline():
    """snn.lenet_apply_int on the pallas backend == int_ref backend — the
    LeNet-class deploy-end-to-end wrapper."""
    cfg, params, x = _make_conv(LENET_S, "rmp", batch=2, seed=3)
    l_ref, r_ref, c_ref = snn.lenet_apply_int(params, x, cfg)
    l_pal, r_pal, c_pal = snn.lenet_apply_int(params, x, cfg,
                                              backend="pallas",
                                              interpret=True)
    np.testing.assert_array_equal(np.asarray(l_ref), np.asarray(l_pal))
    for a, b in zip(r_ref, r_pal):
        np.testing.assert_array_equal(np.asarray(a).astype(np.int8),
                                      np.asarray(b).astype(np.int8))
    assert c_ref == c_pal and c_ref.total > 0
    assert l_ref.shape == (2, cfg.layer_sizes[-1])
    # serving mode: conv front-end still chains, no raster outputs
    l_srv, r_srv, c_srv = snn.lenet_apply_int(params, x, cfg,
                                              backend="pallas",
                                              interpret=True,
                                              emit_rasters=False)
    assert r_srv is None and c_srv is None
    np.testing.assert_array_equal(np.asarray(l_srv), np.asarray(l_ref))


def test_serving_mode_skips_rasters():
    """emit_rasters=False returns the same final V with no raster outputs
    (the inter-layer-spikes-never-touch-HBM serving configuration)."""
    cfg, params, x = _make((64, 40, 24, 2), "rmp", 2, 3, seed=4)
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_words(x, cfg.timesteps)
    full = pipeline.run_network(program, xs, "pallas", interpret=True)
    serve = pipeline.run_network(program, xs, "pallas", interpret=True,
                                 emit_rasters=False)
    assert serve.rasters is None
    np.testing.assert_array_equal(np.asarray(serve.v_out),
                                  np.asarray(full.v_out))


@pytest.mark.parametrize("neuron,layer_sizes", [
    ("if", (100, 128, 128, 1)),
    ("lif", (100, 128, 128, 1)),
    ("rmp", (100, 128, 128, 1)),
    ("rmp", (130, 140, 12, 1)),     # row-tiled: AccV2V reduction cycles
])
def test_instruction_counts_match_bitmacro_counts(neuron, layer_sizes):
    """Cross-check the two instruction-counting paths on wrap-mode programs:
    the program-level raster pass (count_network_instructions) vs the
    cycle-by-cycle tally the bit-level macro model keeps while executing
    (aux['macro_counts']) — including the AccV2V partial-sum reduction term
    on fan-in-split layers. The bitmacro executes only the spiking layers
    (the readout accumulate is word-level), so the raster pass restricted
    to spiking layers must equal the silicon tally exactly."""
    from repro.core import isa
    cfg, params, x = _make(layer_sizes, neuron, 2, 3, seed=11)
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode="wrap")
    xs = pipeline.present_words(x, cfg.timesteps)
    res = pipeline.run_network(program, xs, "bitmacro")
    spiking = [ly for ly in program.fc_stack if ly.kind == "fc"]
    counts = isa.InstrCount()
    for spec, raster in zip(spiking, res.rasters):
        counts += isa.count_layer_instructions(
            np.asarray(raster), spec.n_in, spec.n_out, program.neuron)
    assert counts == res.aux["macro_counts"], (counts,
                                               res.aux["macro_counts"])
    # and the network-level pass = spiking tally + the readout layer
    total = pipeline.count_network_instructions(program, res.rasters)
    readout = program.fc_stack[-1]
    counts += isa.count_layer_instructions(
        np.asarray(res.rasters[-1]), readout.n_in, readout.n_out, "none")
    assert total == counts


@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_bitmacro_accv2v_reduction_golden(neuron):
    """The multi-macro golden test: a fan-in-split layer (200 -> 20, two row
    tiles x two col tiles) executed on the bit-level macro bank — partial
    sums reduced across macros with AccV2V — equals the single-accumulate
    word-level semantics (isa.layer_timestep_int, one virtual 200-row
    macro) bit for bit, and the executed cycle tally equals the analytic
    `isa.count_layer_instructions` pass (its row_tiles-1 AccV2V reduction
    term) exactly."""
    from repro.core import isa
    from repro.core.pipeline import _bitmacro_layer
    rng = np.random.default_rng(5)
    n_in, n_out, T, F = 200, 20, 4, 3
    wq = rng.integers(-31, 32, (n_in, n_out)).astype(np.int8)
    inp = (rng.random((T, F, n_in)) < 0.3)
    th, leak = 60, 2
    out, v, counts = _bitmacro_layer(inp, wq, th, leak, neuron)

    v_ref = jnp.zeros((F, n_out), jnp.int32)
    for t in range(T):
        v_ref, s_ref = isa.layer_timestep_int(
            v_ref, jnp.asarray(wq), jnp.asarray(inp[t], jnp.int32),
            neuron=neuron, threshold=jnp.int32(th), leak=jnp.int32(leak),
            reset=jnp.int32(0), clamp_mode="wrap")
        np.testing.assert_array_equal(out[t], np.asarray(s_ref, np.int8),
                                      err_msg=f"t={t}")
    np.testing.assert_array_equal(v, np.asarray(v_ref))

    analytic = isa.count_layer_instructions(inp.astype(np.int8),
                                            n_in, n_out, neuron)
    assert counts == analytic, (counts, analytic)
    # the reduction term itself: 2 cycles * (row_tiles-1) * col_tiles * T*F
    base = {"rmp": 2, "lif": 2, "if": 0}[neuron] * 2 * T * F
    assert counts.acc_v2v == base + 2 * 1 * 2 * T * F


def test_sparsity_report_counting_paths_agree():
    """Raster counting == report counting == collect_sums counting; the
    report's occupancy stats reconstruct the raster's."""
    cfg, params, x = _make((37, 50, 20, 3), "rmp", 3, 2, seed=5)
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_words(x, cfg.timesteps)
    res = pipeline.run_network(program, xs, "int_ref")
    rep = pipeline.sparsity_report(program, res.rasters)
    c_raster = pipeline.count_network_instructions(program, res.rasters)
    assert pipeline.count_network_instructions(program, report=rep) == c_raster
    # raster-free path: float backend spike-count sums
    resf = pipeline.run_network(program, xs, "float", collect_sums=True)
    rep_sums = pipeline.sparsity_report_from_sums(
        program, resf.aux["spike_sums"], xs.shape[0])
    assert rep_sums.events == rep.events
    assert rep_sums.occupancy_t is None
    assert pipeline.count_network_instructions(program,
                                               report=rep_sums) == c_raster
    # occupancy stats reconstruct the rasters'
    T, B = xs.shape[:2]
    assert rep.frames == T * B and rep.timesteps == T and rep.batch == B
    for r, occ, s, n in zip(res.rasters, rep.occupancy_t,
                            rep.layer_sparsity, rep.n_in):
        r = np.asarray(r)
        np.testing.assert_allclose(occ, r.mean(axis=(1, 2)))
        assert s == pytest.approx(1.0 - r.mean())
    assert 0.0 <= rep.overall_sparsity <= 1.0
    assert rep.macro_timesteps > 0


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.85])
def test_measured_edp_matches_analytic_on_single_macro(sparsity):
    """The measured-EDP normalization closes the loop with the analytic
    Fig. 11b model: a single full macro (128 -> 12 rmp layer) at exactly
    (1-s)*128 events per frame must land on the analytic curve point."""
    from repro.core import energy
    T, B = 10, 4
    events_per_frame = round((1.0 - sparsity) * 128)
    rep = pipeline.SparsityReport(
        n_in=(128,), n_out=(12,), neurons=("rmp",),
        events=(events_per_frame * T * B,), frames=T * B,
        timesteps=T, batch=B)
    medp = energy.measured_edp_per_neuron_timestep(
        rep.instruction_counts(), rep.macro_timesteps)
    analytic = energy.edp_per_neuron_per_timestep(sparsity, "rmp")
    assert medp == pytest.approx(analytic, rel=1e-9)
    assert energy.measured_edp(rep.instruction_counts()) > 0
    with pytest.raises(ValueError):
        energy.measured_edp_per_neuron_timestep(rep.instruction_counts(), 0)


def test_conv_counting_paths_agree():
    """Conv programs: raster counting == report counting == collect_sums
    counting (patch events via im2col linearity), with per-layer frame
    counts (T*B*P for convs) feeding the same instruction counter the
    executors are checked against."""
    cfg, params, x = _make_conv(LENET_S, "rmp", batch=2, seed=9)
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_static(x, cfg.timesteps)
    res = pipeline.run_network(program, xs, "int_ref")
    rep = pipeline.sparsity_report(program, res.rasters)
    c_raster = pipeline.count_network_instructions(program, res.rasters)
    assert pipeline.count_network_instructions(program, report=rep) == c_raster
    # conv layers run one frame per (timestep, example, output position)
    T, B = xs.shape[:2]
    conv = program.int_conv_stack[0]
    p = conv.state_shape[0] * conv.state_shape[1]
    assert rep.frames_by_layer[0] == T * B * p
    assert rep.frames_by_layer[-1] == T * B
    assert rep.macro_timesteps > 0 and 0.0 <= rep.overall_sparsity <= 1.0
    # raster-free path: float backend spike-count sums (maps for convs)
    resf = pipeline.run_network(program, xs, "float", collect_sums=True)
    rep_sums = pipeline.sparsity_report_from_sums(
        program, resf.aux["spike_sums"], T)
    assert rep_sums.events == rep.events
    assert rep_sums.layer_frames == rep.layer_frames
    assert pipeline.count_network_instructions(program,
                                               report=rep_sums) == c_raster


def test_sparsity_report_error_paths():
    cfg, params, _ = _make((37, 50, 20, 3), "rmp", 2, 2)
    program = pipeline.compile_network(cfg, params, domain="int")
    with pytest.raises(ValueError):
        pipeline.sparsity_report(program, None)
    with pytest.raises(ValueError):
        pipeline.count_network_instructions(program)
    with pytest.raises(ValueError):
        pipeline.sparsity_report_from_sums(program, [np.zeros((2, 50))], 3)
    with pytest.raises(ValueError):
        pipeline.count_network_instructions(program, [np.zeros((3, 2, 50))])


def test_error_paths_name_the_config():
    """The -O-safe ValueError convention on the former NotImplementedError
    sites: a stack led by neither an encoder nor a conv names the offending
    layer kind; the fc-only raster entry point rejects conv programs."""
    cfg, params, x = _make((37, 50, 20, 3), "rmp", 2, 2)
    program = pipeline.compile_network(cfg, params, domain="int")
    headless = dataclasses.replace(program, layers=program.layers[1:])
    with pytest.raises(ValueError, match="kind='fc'"):
        pipeline.encode(headless, jnp.zeros((2, 2, 37)))
    ccfg, cparams, cx = _make_conv(LENET_S, "rmp", batch=1)
    cprogram = pipeline.compile_network(ccfg, cparams, domain="int")
    with pytest.raises(ValueError, match="conv"):
        pipeline.run_stack_from_raster(
            cprogram, jnp.zeros((2, 1, 8, 8, 4), jnp.int8))
    # conv stacks now COMPILE in the int domain (the former
    # NotImplementedError at the compile gate) and execute end to end
    assert cprogram.domain == "int" and len(cprogram.int_conv_stack) == 1


def test_fused_net_readout_flag_validation():
    from repro.kernels.fused_snn_net.ops import fused_snn_net
    spikes = jnp.zeros((2, 2, 16), jnp.int8)
    ws = [jnp.zeros((16, 8), jnp.int8)]
    with pytest.raises(ValueError, match="threshold"):
        fused_snn_net(spikes, ws, thresholds=(), leaks=(), readout=False,
                      use_pallas=False)
    # readout=False: one threshold per layer, all layers spiking
    rasters, vs, _ = fused_snn_net(spikes, ws, thresholds=(5,), leaks=(0,),
                                   readout=False, use_pallas=False)
    assert len(rasters) == 1 and len(vs) == 1


def test_rate_coded_program_matches_manual_loop():
    """The spiking_ffn path: pipeline rate decoding == a hand-rolled
    neuron_step loop (guards the refactor of models/spiking_ffn)."""
    from repro.core.neuron import NeuronState, neuron_step
    sp = SpikingConfig(neuron="lif", timesteps=6, threshold=0.4, leak=0.05)
    rng = np.random.default_rng(0)
    current = jnp.asarray(rng.standard_normal((2, 5, 16)).astype(np.float32))
    program = pipeline.rate_coded_program(sp, current.shape[1:])
    res = pipeline.run_network(program, current, "float", collect_sums=True,
                               static_input=True)
    # the materialized-presentation form must agree with the closed-over form
    res2 = pipeline.run_network(program, pipeline.present_static(current, 6),
                                "float", collect_sums=True)
    np.testing.assert_allclose(np.asarray(res.aux["spike_sums"][0]),
                               np.asarray(res2.aux["spike_sums"][0]))
    st, count = NeuronState(jnp.zeros_like(current)), jnp.zeros_like(current)
    for _ in range(6):
        st, s = neuron_step(st, current, neuron="lif", threshold=0.4,
                            leak=0.05)
        count = count + s
    np.testing.assert_allclose(np.asarray(res.aux["spike_sums"][0]),
                               np.asarray(count))
