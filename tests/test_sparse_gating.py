"""Bit-identity contract of the event-gated execution path.

The gate (ops.fused_snn_net use_sparse=True) may skip AccW2V matmuls for
all-silent tiles but must never change a single output bit relative to the
dense word-level reference — across neuron models, clamp modes, and input
structures engineered to hit the edge cases: fully silent timesteps (gate
fires), fully dense timesteps (gate never fires), and silence appearing
only *downstream* (RMP re-firing keeps deep layers busy while the input
gate skips). Skip counters are also pinned exactly where the structure
makes them deterministic.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_snn_net.ops import fused_snn_net

WS_SHAPES = [(40, 24), (24, 16), (16, 3)]
THS, LKS = (9, 5), (1, 1)


def _ws(seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(-31, 32, s).astype(np.int8))
            for s in WS_SHAPES]


def _raster(structure: str, T=9, B=5, N=40, seed=1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if structure == "all_silent":
        return np.zeros((T, B, N), np.int8)
    if structure == "all_dense":
        return np.ones((T, B, N), np.int8)
    if structure == "bursty":                   # silent timesteps interleaved
        frames = (rng.random((T, B, N)) < 0.4).astype(np.int8)
        frames[::3] = 0
        return frames
    if structure == "sparse_iid":
        return (rng.random((T, B, N)) < 0.05).astype(np.int8)
    raise ValueError(structure)


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
@pytest.mark.parametrize("structure",
                         ["all_silent", "all_dense", "bursty", "sparse_iid"])
def test_gated_paths_bit_identical(structure, neuron, clamp_mode):
    spikes = jnp.asarray(_raster(structure))
    ws = _ws()
    kw = dict(thresholds=THS, leaks=LKS, neuron=neuron, clamp_mode=clamp_mode)
    r_ref, v_ref, sk_ref = fused_snn_net(spikes, ws, use_pallas=False, **kw)
    assert sk_ref is None
    runs = {
        "ref_sparse": fused_snn_net(spikes, ws, use_pallas=False,
                                    use_sparse=True, **kw),
        "pallas_sparse": fused_snn_net(spikes, ws, interpret=True, block_b=2,
                                       use_sparse=True, **kw),
    }
    T, n_tiles = spikes.shape[0], (spikes.shape[1] + 1) // 2
    for name, (r, v, sk) in runs.items():
        for li, (a, b) in enumerate(zip(r, r_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} raster {li}")
        for li, (a, b) in enumerate(zip(v, v_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} V {li}")
        sk = np.asarray(sk)
        assert sk.shape[1] == len(ws)
        assert (sk >= 0).all() and (sk <= T).all()
    # deterministic gate counts where the structure pins them
    sk_r = np.asarray(runs["ref_sparse"][2])      # (1, n_layers) silent steps
    sk_p = np.asarray(runs["pallas_sparse"][2])   # (n_tiles, n_layers)
    silent_in = int((np.asarray(spikes).reshape(T, -1).sum(axis=1) == 0).sum())
    assert sk_r[0, 0] == silent_in
    # per-tile gating skips at least whenever the whole frame is silent
    # (individual tiles of a non-silent frame can also be silent)
    assert sk_p[:, 0].sum() >= silent_in * n_tiles
    if structure == "all_dense":
        assert sk_r[0, 0] == 0 and sk_p.sum(axis=0)[0] == 0
    if structure == "all_silent":
        # IF propagates total silence end to end; LIF/RMP dynamics may
        # still fire deep layers, which the gate must NOT suppress
        if neuron == "if":
            assert sk_r.sum() == T * len(ws)
            assert sk_p.sum() == T * len(ws) * n_tiles


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("granularity", [2, 4, 8])
@pytest.mark.parametrize("structure", ["bursty", "sparse_iid"])
def test_row_block_gating_bit_identical(structure, granularity, clamp_mode):
    """Sub-tile (row-block) gating must stay bit-identical to dense for
    every granularity: partial sums accumulate unclamped and the 11-bit
    clamp applies once after the last block — the wrap rows would expose
    any intermediate clamp (saturation does not commute with the split)."""
    spikes = jnp.asarray(_raster(structure))
    ws = _ws()
    kw = dict(thresholds=THS, leaks=LKS, neuron="rmp", clamp_mode=clamp_mode)
    r_ref, v_ref, _ = fused_snn_net(spikes, ws, use_pallas=False, **kw)
    runs = {
        "ref": fused_snn_net(spikes, ws, use_pallas=False, use_sparse=True,
                             gate_granularity=granularity, **kw),
        "pallas": fused_snn_net(spikes, ws, interpret=True, block_b=2,
                                use_sparse=True,
                                gate_granularity=granularity, **kw),
    }
    for name, (r, v, sk) in runs.items():
        for li, (a, b) in enumerate(zip(r + v, r_ref + v_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} G={granularity} "
                                                  f"out {li}")
        # per-layer block columns: ceil(n_in / (128/G)) counted blocks
        assert isinstance(sk, list) and len(sk) == len(ws)
        bw = 128 // granularity
        for (n_in, _), s in zip(WS_SHAPES, sk):
            assert np.asarray(s).shape[1] == -(-n_in // bw)
    # finer granularity can only skip more MXU work per lane: total skipped
    # lanes (blocks x width) is monotone vs the whole-tile gate
    _, _, sk1 = fused_snn_net(spikes, ws, use_pallas=False, use_sparse=True,
                              **kw)
    lanes_g = sum(np.asarray(s).sum() * (128 // granularity)
                  for s in runs["ref"][2])
    lanes_1 = sum(int(n) * int(c) for (n, _), c in
                  zip(WS_SHAPES, np.asarray(sk1)[0]))
    assert lanes_g >= lanes_1


def test_row_block_skip_counts_match_raster():
    """Kernel gate decisions are exact: for every (layer, block, batch
    tile), the skip count equals the number of timesteps whose logical
    lanes in that block are silent for the whole tile — computed here
    independently from the raster (including the padded-lane tail of the
    40-wide input)."""
    T, B, block_b, G = 9, 4, 2, 4
    rng = np.random.default_rng(8)
    spikes = (rng.random((T, B, 40)) < 0.06).astype(np.int8)
    ws = _ws()
    kw = dict(thresholds=THS, leaks=LKS, neuron="if", clamp_mode="saturate")
    r_dense, _, _ = fused_snn_net(jnp.asarray(spikes), ws, use_pallas=False,
                                  **kw)
    _, _, sk = fused_snn_net(jnp.asarray(spikes), ws, interpret=True,
                             block_b=block_b, use_sparse=True,
                             gate_granularity=G, **kw)
    inputs = [spikes] + [np.asarray(r) for r in r_dense[:-1]]
    bw = 128 // G
    for li, (inp, s) in enumerate(zip(inputs, sk)):
        s = np.asarray(s)
        n_in = inp.shape[2]
        assert s.shape == (B // block_b, -(-n_in // bw))
        for tile in range(B // block_b):
            rows = inp[:, tile * block_b:(tile + 1) * block_b, :]
            for g in range(s.shape[1]):
                blk = rows[:, :, g * bw:min((g + 1) * bw, n_in)]
                expect = int((blk.reshape(T, -1).sum(axis=1) == 0).sum())
                assert s[tile, g] == expect, (li, tile, g)


def test_skip_layout_contract():
    """The skip output is sized from the stack, not a fixed 128 lanes: the
    former SKIP_LANES cap silently truncated counts past 128 layers."""
    from repro.kernels.fused_snn_net.kernel import (MAX_SKIP_COLS,
                                                    skip_layout)
    n_cols, offsets, lanes = skip_layout((40, 24, 16), 1)
    assert n_cols == (1, 1, 1) and offsets == (0, 1, 2) and lanes == 128
    n_cols, offsets, lanes = skip_layout((130, 24, 16), 8)
    assert n_cols == (9, 2, 1) and offsets == (0, 9, 11)
    # past the cap: a named error instead of silent truncation
    many = tuple(128 for _ in range(MAX_SKIP_COLS + 1))
    with pytest.raises(ValueError, match="MAX_SKIP_COLS"):
        skip_layout(many, 1)
    with pytest.raises(ValueError, match="granularity"):
        skip_layout((40,), 3)
    # lane padding covers layouts past one 128-lane tile
    wide = tuple(128 for _ in range(130))
    assert skip_layout(wide, 1)[2] == 256


def test_gate_granularity_validation():
    spikes = jnp.zeros((2, 2, 40), jnp.int8)
    ws = _ws()
    kw = dict(thresholds=THS, leaks=LKS)
    with pytest.raises(ValueError, match="use_sparse"):
        fused_snn_net(spikes, ws, gate_granularity=4, **kw)
    with pytest.raises(ValueError, match="granularity"):
        fused_snn_net(spikes, ws, use_sparse=True, gate_granularity=5,
                      use_pallas=False, **kw)


def test_chain_misalignment_raises_not_asserts():
    """The stack contract survives ``python -O``: misaligned chains and
    empty stacks raise ValueError (previously an assert)."""
    spikes = jnp.zeros((2, 2, 40), jnp.int8)
    ws = _ws()
    with pytest.raises(ValueError, match="misaligned"):
        fused_snn_net(spikes, [ws[0], ws[0]], thresholds=THS, leaks=LKS)
    with pytest.raises(ValueError, match="non-empty"):
        fused_snn_net(spikes, [], thresholds=(), leaks=())
    with pytest.raises(ValueError, match="2-D"):
        fused_snn_net(spikes, [jnp.zeros((40,), jnp.int8)],
                      thresholds=(), leaks=())
    # dense and sparse reject the same way on the non-pallas path too
    with pytest.raises(ValueError, match="misaligned"):
        fused_snn_net(spikes, [ws[0], ws[0]], thresholds=THS, leaks=LKS,
                      use_pallas=False)
