"""Bit-identity contract of the event-gated execution path.

The gate (ops.fused_snn_net use_sparse=True) may skip AccW2V matmuls for
all-silent tiles but must never change a single output bit relative to the
dense word-level reference — across neuron models, clamp modes, and input
structures engineered to hit the edge cases: fully silent timesteps (gate
fires), fully dense timesteps (gate never fires), and silence appearing
only *downstream* (RMP re-firing keeps deep layers busy while the input
gate skips). Skip counters are also pinned exactly where the structure
makes them deterministic.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_snn_net.ops import fused_snn_net

WS_SHAPES = [(40, 24), (24, 16), (16, 3)]
THS, LKS = (9, 5), (1, 1)


def _ws(seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(-31, 32, s).astype(np.int8))
            for s in WS_SHAPES]


def _raster(structure: str, T=9, B=5, N=40, seed=1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    if structure == "all_silent":
        return np.zeros((T, B, N), np.int8)
    if structure == "all_dense":
        return np.ones((T, B, N), np.int8)
    if structure == "bursty":                   # silent timesteps interleaved
        frames = (rng.random((T, B, N)) < 0.4).astype(np.int8)
        frames[::3] = 0
        return frames
    if structure == "sparse_iid":
        return (rng.random((T, B, N)) < 0.05).astype(np.int8)
    raise ValueError(structure)


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
@pytest.mark.parametrize("structure",
                         ["all_silent", "all_dense", "bursty", "sparse_iid"])
def test_gated_paths_bit_identical(structure, neuron, clamp_mode):
    spikes = jnp.asarray(_raster(structure))
    ws = _ws()
    kw = dict(thresholds=THS, leaks=LKS, neuron=neuron, clamp_mode=clamp_mode)
    r_ref, v_ref, sk_ref = fused_snn_net(spikes, ws, use_pallas=False, **kw)
    assert sk_ref is None
    runs = {
        "ref_sparse": fused_snn_net(spikes, ws, use_pallas=False,
                                    use_sparse=True, **kw),
        "pallas_sparse": fused_snn_net(spikes, ws, interpret=True, block_b=2,
                                       use_sparse=True, **kw),
    }
    T, n_tiles = spikes.shape[0], (spikes.shape[1] + 1) // 2
    for name, (r, v, sk) in runs.items():
        for li, (a, b) in enumerate(zip(r, r_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} raster {li}")
        for li, (a, b) in enumerate(zip(v, v_ref)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{name} V {li}")
        sk = np.asarray(sk)
        assert sk.shape[1] == len(ws)
        assert (sk >= 0).all() and (sk <= T).all()
    # deterministic gate counts where the structure pins them
    sk_r = np.asarray(runs["ref_sparse"][2])      # (1, n_layers) silent steps
    sk_p = np.asarray(runs["pallas_sparse"][2])   # (n_tiles, n_layers)
    silent_in = int((np.asarray(spikes).reshape(T, -1).sum(axis=1) == 0).sum())
    assert sk_r[0, 0] == silent_in
    # per-tile gating skips at least whenever the whole frame is silent
    # (individual tiles of a non-silent frame can also be silent)
    assert sk_p[:, 0].sum() >= silent_in * n_tiles
    if structure == "all_dense":
        assert sk_r[0, 0] == 0 and sk_p.sum(axis=0)[0] == 0
    if structure == "all_silent":
        # IF propagates total silence end to end; LIF/RMP dynamics may
        # still fire deep layers, which the gate must NOT suppress
        if neuron == "if":
            assert sk_r.sum() == T * len(ws)
            assert sk_p.sum() == T * len(ws) * n_tiles


def test_chain_misalignment_raises_not_asserts():
    """The stack contract survives ``python -O``: misaligned chains and
    empty stacks raise ValueError (previously an assert)."""
    spikes = jnp.zeros((2, 2, 40), jnp.int8)
    ws = _ws()
    with pytest.raises(ValueError, match="misaligned"):
        fused_snn_net(spikes, [ws[0], ws[0]], thresholds=THS, leaks=LKS)
    with pytest.raises(ValueError, match="non-empty"):
        fused_snn_net(spikes, [], thresholds=(), leaks=())
    with pytest.raises(ValueError, match="2-D"):
        fused_snn_net(spikes, [jnp.zeros((40,), jnp.int8)],
                      thresholds=(), leaks=())
    # dense and sparse reject the same way on the non-pallas path too
    with pytest.raises(ValueError, match="misaligned"):
        fused_snn_net(spikes, [ws[0], ws[0]], thresholds=THS, leaks=LKS,
                      use_pallas=False)
