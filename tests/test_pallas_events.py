"""Device-side event-list execution (`pallas_events`) contracts.

The kernel compacts every (timestep, layer, example) frame in VMEM and
executes AccW2V as a gather-matvec over active rows — the claims pinned
here are exactly the ones that make that path trustworthy:

  * bit-identity with the dense word-level reference across neuron models,
    both clamp modes, odd/padded/wide shapes, and the dense-crossover
    fallback (property-tested);
  * counter equality: the kernel's per-row event counters equal the host
    `ref_events` executor's `EventStats` EXACTLY — the accounting contract
    that lets `SparsityReport` -> `energy.measured_edp_reduction` report
    the *executed* row-skip EDP;
  * compaction edge cases: all-silent frames (zero gather iterations, zero
    counters, fraction 1.0), all-dense frames tripping the crossover
    fallback (counted, still bit-identical), padded lanes beyond n_in,
    B=1 streaming and v_init chunk composition;
  * serving: on a fully-occupied engine the pooled device ledger closes
    against the summed per-slot raster reports.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import energy, pipeline, snn
from repro.kernels.fused_snn_net.events import fused_snn_net_events
from repro.kernels.fused_snn_net.ops import (fused_snn_net,
                                             fused_snn_net_device_events)
from repro.serve import SNNRequest, SNNServeEngine
from repro.serve.snn_engine import merge_reports

# padded-lane everything: 40/24/16 pad to 128 lanes, 130 spans two macro row
# tiles; T/B stay fixed so the pallas interpret jit cache is shared
WS_SHAPES = [(40, 24), (24, 16), (16, 3)]
WS_SHAPES_WIDE = [(130, 24), (24, 3)]
T, B, BLOCK_B = 6, 4, 2


def _ws(shapes, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(-31, 32, s).astype(np.int8))
            for s in shapes]


def _run_pair(spikes, ws, *, neuron, clamp_mode, event_crossover=1.0):
    """(device-events run, dense reference run, host EventStats)."""
    n_spiking = len(ws) - 1
    kw = dict(thresholds=tuple([9, 5][:n_spiking]),
              leaks=tuple([1, 1][:n_spiking]),
              neuron=neuron, clamp_mode=clamp_mode)
    ev = fused_snn_net_device_events(jnp.asarray(spikes), ws,
                                     block_b=BLOCK_B, interpret=True,
                                     event_crossover=event_crossover, **kw)
    ref = fused_snn_net(jnp.asarray(spikes), ws, use_pallas=False, **kw)
    _, _, host_stats = fused_snn_net_events(np.asarray(spikes),
                                            [np.asarray(w) for w in ws], **kw)
    return ev, ref, host_stats


def _assert_identical(ev, ref, tag=""):
    r_ev, v_ev, _ = ev
    r_ref, v_ref, _ = ref
    for li, (a, b) in enumerate(zip(r_ev, r_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag} raster {li}")
    for li, (a, b) in enumerate(zip(v_ev, v_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag} V {li}")


def _assert_counters_equal(stats, host_stats, tag=""):
    assert stats.frames == host_stats.frames, tag
    for li, (a, b) in enumerate(zip(stats.row_events,
                                    host_stats.row_events)):
        assert len(a) == len(b), (tag, li)          # logical rows only
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                      err_msg=f"{tag} row_events {li}")
    assert stats.skipped_rows == host_stats.skipped_rows, tag
    assert stats.skipped_row_fraction == pytest.approx(
        host_stats.skipped_row_fraction), tag


@settings(max_examples=8, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from(["if", "lif", "rmp"]),
       st.sampled_from(["saturate", "wrap"]),
       st.floats(min_value=0.02, max_value=0.6))
def test_device_events_bit_identity_and_counter_closure(seed, neuron,
                                                        clamp_mode, density):
    """Property: for random stacks (narrow and >128-fan-in wide) and random
    densities the device event path is bit-identical to the dense word
    reference AND its counters equal the host spike-list executor's."""
    rng = np.random.default_rng(seed)
    shapes = WS_SHAPES_WIDE if rng.integers(0, 2) else WS_SHAPES
    ws = _ws(shapes, seed=seed + 1)
    spikes = (rng.random((T, B, shapes[0][0])) < density).astype(np.int8)
    ev, ref, host = _run_pair(spikes, ws, neuron=neuron,
                              clamp_mode=clamp_mode)
    tag = f"{neuron}/{clamp_mode}/{density:.2f}"
    _assert_identical(ev, ref, tag)
    _assert_counters_equal(ev[2], host, tag)
    assert ev[2].dense_fallbacks == (0,) * len(ws)   # 1.0 never trips


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
def test_all_silent_frames(clamp_mode):
    """A silent presentation issues zero gather work: every counter is zero
    and the skipped-row fraction is exactly 1.0 (still bit-identical —
    LIF/RMP dynamics run unconditionally on zero input)."""
    ws = _ws(WS_SHAPES, seed=3)
    spikes = np.zeros((T, B, WS_SHAPES[0][0]), np.int8)
    ev, ref, host = _run_pair(spikes, ws, neuron="lif",
                              clamp_mode=clamp_mode)
    _assert_identical(ev, ref, f"silent/{clamp_mode}")
    _assert_counters_equal(ev[2], host, f"silent/{clamp_mode}")
    assert ev[2].events == (0,) * len(ws)
    assert ev[2].skipped_row_fraction == 1.0


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
def test_dense_fallback_crossover(clamp_mode):
    """An all-ones input frame exceeds any crossover < 1: the first layer
    must take the dense fallback on every (timestep, tile), and results
    stay bit-identical with counters unchanged (the counters are
    path-independent)."""
    ws = _ws(WS_SHAPES, seed=4)
    spikes = np.ones((T, B, WS_SHAPES[0][0]), np.int8)
    ev, ref, host = _run_pair(spikes, ws, neuron="rmp",
                              clamp_mode=clamp_mode, event_crossover=0.5)
    _assert_identical(ev, ref, f"fallback/{clamp_mode}")
    _assert_counters_equal(ev[2], host, f"fallback/{clamp_mode}")
    n_tiles = B // BLOCK_B
    assert ev[2].dense_fallbacks[0] == T * n_tiles   # every frame fell back
    # crossover 0.0 forces the dense path everywhere — the degenerate
    # configuration that proves the fallback alone reproduces the kernel
    ev0, ref0, host0 = _run_pair(spikes, ws, neuron="rmp",
                                 clamp_mode=clamp_mode, event_crossover=0.0)
    _assert_identical(ev0, ref0, f"alwaysdense/{clamp_mode}")
    _assert_counters_equal(ev0[2], host0, f"alwaysdense/{clamp_mode}")
    assert ev0[2].dense_fallbacks == (T * n_tiles,) * len(ws)


def test_padded_lanes_beyond_n_in():
    """Odd widths leave padded VMEM lanes past n_in: junk there must not
    burn gather iterations or leak into the counters — row counters come
    back at the LOGICAL width with totals matching the raster sums."""
    ws = _ws(WS_SHAPES_WIDE, seed=5)
    rng = np.random.default_rng(6)
    spikes = (rng.random((T, B, WS_SHAPES_WIDE[0][0])) < 0.4).astype(np.int8)
    ev, ref, host = _run_pair(spikes, ws, neuron="rmp", clamp_mode="wrap")
    _assert_identical(ev, ref, "wide")
    _assert_counters_equal(ev[2], host, "wide")
    stats = ev[2]
    assert [len(r) for r in stats.row_events] == [130, 24]
    np.testing.assert_array_equal(
        np.asarray(stats.row_events[0]),
        spikes.astype(np.int64).sum(axis=(0, 1)))


def test_b1_streaming_and_chunk_composition():
    """B=1 (a single padded batch lane) and v_init chunk threading: two
    half-presentations that carry V compose bit-identically with one full
    call, counters included (row counts add over chunks)."""
    ws = _ws(WS_SHAPES, seed=7)
    rng = np.random.default_rng(8)
    spikes = (rng.random((T, 1, WS_SHAPES[0][0])) < 0.3).astype(np.int8)
    kw = dict(thresholds=(9, 5), leaks=(1, 1), neuron="rmp",
              clamp_mode="saturate")
    full = fused_snn_net_device_events(jnp.asarray(spikes), ws,
                                       block_b=1, interpret=True, **kw)
    ref = fused_snn_net(jnp.asarray(spikes), ws, use_pallas=False, **kw)
    _assert_identical(full, ref, "b1")
    h = T // 2
    first = fused_snn_net_device_events(jnp.asarray(spikes[:h]), ws,
                                        block_b=1, interpret=True, **kw)
    second = fused_snn_net_device_events(jnp.asarray(spikes[h:]), ws,
                                         block_b=1, interpret=True,
                                         v_init=first[1], **kw)
    for a, b in zip(second[1], full[1]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b, c in zip(first[2].row_events, second[2].row_events,
                       full[2].row_events):
        np.testing.assert_array_equal(np.asarray(a) + np.asarray(b),
                                      np.asarray(c))


def _program(seed=5, layer_sizes=(37, 50, 20, 3)):
    cfg = SNNModelConfig(
        arch_id="dev-ev", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron="rmp", timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 2)
    x = jnp.asarray(rng.standard_normal((2, 3, layer_sizes[0]))
                    .astype(np.float32))
    return cfg, pipeline.compile_network(cfg, params, domain="int"), \
        pipeline.present_words(x, cfg.timesteps)


def test_backend_aux_flows_into_measured_edp():
    """The registered backend's aux equals the ref_events aux AND the
    raster-derived SparsityReport columns — so the executed row-skip
    statistics flow into `energy.measured_edp_reduction` unchanged."""
    _, program, xs = _program()
    ev = pipeline.run_network(program, xs, "pallas_events", interpret=True,
                              block_b=4)
    host = pipeline.run_network(program, xs, "ref_events")
    for a, b in zip(ev.aux["row_events"], host.aux["row_events"]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ev.aux["row_skip_counts"] == host.aux["row_skip_counts"]
    assert ev.aux["skipped_row_fraction"] == pytest.approx(
        host.aux["skipped_row_fraction"])
    assert ev.aux["event_dense_fallbacks"] == [0] * len(program.macro_stack)
    rep = pipeline.sparsity_report(program, ev.rasters)
    for a, b in zip(ev.aux["row_events"], rep.row_events):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tuple(ev.aux["row_skip_counts"]) == rep.row_skip_counts
    red = energy.measured_edp_reduction(rep.instruction_counts(),
                                        rep.skipped_instruction_counts())
    assert 0.0 < red < 1.0


def test_engine_device_ledger_closes_when_fully_occupied():
    """Serving closure: with every lane serving every tick (n_requests ==
    slots, equal lengths, no early stop) the pooled device ledger equals
    the merged per-slot raster reports exactly."""
    cfg, program, _ = _program(seed=9)
    eng = SNNServeEngine(program, batch_slots=2, backend="pallas_events",
                         step_kw={"interpret": True, "block_b": 2})
    rng = np.random.default_rng(11)
    for rid in range(2):
        x = rng.standard_normal((1, 2, 37)).astype(np.float32)
        frames = np.asarray(pipeline.present_words(
            jnp.asarray(x), cfg.timesteps))[:, 0]
        eng.submit(SNNRequest(rid=rid, frames=frames))
    done = eng.run_until_drained()
    assert len(done) == 2
    ledger = eng.device_event_stats()
    merged = merge_reports([r.report for r in done])
    assert ledger.frames == merged.frames
    for a, b in zip(ledger.row_events, merged.row_events):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert eng.device_skipped_row_fraction() == pytest.approx(
        merged.skipped_row_fraction)
    assert ledger.dense_fallbacks == (0,) * len(program.macro_stack)
    # an engine that never ticked an event backend has no ledger
    eng2 = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    with pytest.raises(ValueError, match="device ledger"):
        eng2.device_event_stats()


@pytest.mark.parametrize("megastep,words", [(1, [3, 1]), (4, [4, 8])])
def test_engine_device_ledger_closes_partially_occupied(megastep, words):
    """Serving closure at *any* occupancy: unequal-length requests on a
    pool with spare lanes leave lanes idle for most ticks — the idle-lane
    fix scatters fresh zero state into vacated lanes at evict, so idle
    lanes contribute zero events and the pooled device ledger's
    row_events still equal the merged per-slot raster reports exactly.
    (Ledger *frames* count every dispatched lane by definition, so only
    the event columns are compared.) Before the fix, a vacated lane
    replayed its stale V_MEM and leaked phantom events into the ledger.
    The K=4 budgets are K-aligned: a request finishing *mid-block* fires
    ghost events on the block's remaining zero-input ticks (subtract-
    reset can leave residual V >= threshold) until the post-dispatch
    evict resets the lane — exact closure is guaranteed at block
    boundaries (DESIGN.md documents the caveat)."""
    cfg, program, _ = _program(seed=9)
    eng = SNNServeEngine(program, batch_slots=3, backend="pallas_events",
                         step_kw={"interpret": True, "block_b": 3},
                         megastep=megastep)
    rng = np.random.default_rng(11)
    for rid, n_words in enumerate(words):       # 2 requests on 3 lanes
        x = rng.standard_normal((1, n_words, 37)).astype(np.float32)
        frames = np.asarray(pipeline.present_words(
            jnp.asarray(x), cfg.timesteps))[:, 0]
        eng.submit(SNNRequest(rid=rid, frames=frames))
    done = eng.run_until_drained()
    assert len(done) == 2
    ledger = eng.device_event_stats()
    merged = merge_reports([r.report for r in done])
    assert ledger.frames > merged.frames        # idle lanes tick too
    for a, b in zip(ledger.row_events, merged.row_events):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ledger.dense_fallbacks == (0,) * len(program.macro_stack)
