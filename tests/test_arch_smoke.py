"""Per-architecture smoke tests: a REDUCED config of each family runs one
forward/train step and one prefill+decode step on CPU — shapes asserted, no
NaNs. The FULL configs are exercised only via the dry-run."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ASSIGNED_ARCHS, ParallelConfig, ShapeConfig,
                                get_config, reduced_config)
from repro.models import io_spec, lm

SMOKE_SHAPE = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="train")
PARALLEL = ParallelConfig(remat="none", scan_layers=True)


def _params_and_batch(arch):
    cfg = reduced_config(get_config(arch))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = io_spec.materialize(io_spec.train_batch_spec(cfg, SMOKE_SHAPE))
    return cfg, params, batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_loss_no_nan(arch):
    cfg, params, batch = _params_and_batch(arch)
    loss, aux = jax.jit(
        lambda p, b: lm.loss_fn(p, b, cfg, PARALLEL))(params, batch)
    assert np.isfinite(float(loss)), (arch, float(loss))
    assert float(loss) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step_grads_finite(arch):
    cfg, params, batch = _params_and_batch(arch)
    para = dataclasses.replace(PARALLEL, remat="block")
    (loss, _), grads = jax.jit(jax.value_and_grad(
        lambda p, b: lm.loss_fn(p, b, cfg, para), has_aux=True))(params, batch)
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves, arch
    total = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32)))) for g in leaves)
    assert np.isfinite(total) and total > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_prefill_decode_consistency(arch):
    """prefill(prompt) then one decode step: shapes + finiteness; for the
    non-encoder archs, decoding the next token after a 1-shorter prefill must
    match the full-prefill logits (cache correctness)."""
    cfg = reduced_config(get_config(arch))
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    shape = ShapeConfig("smoke", seq_len=32, global_batch=2, kind="prefill")
    batch = io_spec.materialize(io_spec.prefill_batch_spec(cfg, shape))
    max_len = 48

    logits, cache = jax.jit(
        lambda p, b: lm.prefill(p, b, cfg, max_len, PARALLEL))(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))

    next_tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
    logits2, cache = jax.jit(
        lambda p, t, c: lm.decode_step(p, t, c, cfg, PARALLEL))(params, next_tok, cache)
    assert logits2.shape == (2, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    prompt_len = batch["tokens"].shape[1]
    if "patches" in batch:
        prompt_len += batch["patches"].shape[1]
    assert int(cache["len"][0]) == prompt_len + 1


def test_decode_matches_prefill_dense():
    """Teacher-forcing equivalence on the dense family: prefill over t tokens
    == prefill over t-1 then decode token t. fp32 params so the check tests
    the math, not bf16 rounding."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(2), cfg, dtype=jnp.float32)
    toks = jnp.asarray(np.random.default_rng(0).integers(0, 64, (1, 9)), jnp.int32)
    full, _ = lm.prefill(params, {"tokens": toks}, cfg, 16, PARALLEL)
    part, cache = lm.prefill(params, {"tokens": toks[:, :-1]}, cfg, 16, PARALLEL)
    dec, _ = lm.decode_step(params, toks[:, -1:], cache, cfg, PARALLEL)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-2, atol=2e-2)


def test_decode_matches_prefill_rwkv():
    cfg = reduced_config(get_config("rwkv6-7b"))
    params = lm.init_params(jax.random.PRNGKey(3), cfg, dtype=jnp.float32)
    toks = jnp.asarray(np.random.default_rng(1).integers(0, 64, (1, 9)), jnp.int32)
    full, _ = lm.prefill(params, {"tokens": toks}, cfg, 16, PARALLEL)
    part, cache = lm.prefill(params, {"tokens": toks[:, :-1]}, cfg, 16, PARALLEL)
    dec, _ = lm.decode_step(params, toks[:, -1:], cache, cfg, PARALLEL)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_moe_active_flops_scale():
    """MoE active-params accounting: llama4's active count ~17B vs 400B total."""
    cfg = get_config("llama4-maverick-400b-a17b")
    assert 3.8e11 < cfg.param_count() < 4.2e11
    assert 1.5e10 < cfg.active_param_count() < 1.9e10
