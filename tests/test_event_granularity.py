"""Event-accounting contracts across gating granularities.

Three independently produced statistics must agree on what "skippable
work" is:

  * `ref_events` (spike-list compaction executor) measures per-row event
    counts *during* execution — work proportional to events;
  * `pipeline.SparsityReport` derives the same per-row columns from the
    rasters (or collect_sums aggregates) after the fact;
  * the row-block kernel's skip counters record which (layer, block,
    batch-tile, timestep) gate sites were silent.

The property tests pin: ref_events row counts == report row counts; each
layer's block event columns sum back to its total events for every
granularity (padded-lane shapes included); a block the kernel skipped for
the full batch at every timestep has zero events; and the row-granular
skipped-instruction tally closes with the executed tally to the dense
zero-sparsity count, which is what lets `energy.measured_edp_reduction`
land exactly on the analytic Fig. 11b curve.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import energy, isa, pipeline, snn
from repro.kernels.fused_snn_net.events import fused_snn_net_events
from repro.kernels.fused_snn_net.ops import fused_snn_net

# padded-lane everything: 40/24/16 pad to 128 lanes, 130 row-tiles past one
# macro; T/B stay fixed so the pallas interpret jit cache is shared
WS_SHAPES = [(40, 24), (24, 16), (16, 3)]
WS_SHAPES_WIDE = [(130, 24), (24, 3)]
T, B, BLOCK_B = 6, 4, 2


def _ws(shapes, seed=0):
    rng = np.random.default_rng(seed)
    return [jnp.asarray(rng.integers(-31, 32, s).astype(np.int8))
            for s in shapes]


def _layer_inputs(spikes, rasters):
    """Input raster of every layer: the encoder raster, then each spiking
    layer's output (the readout consumes the last spiking raster)."""
    return [np.asarray(spikes)] + [np.asarray(r) for r in rasters[:-1]] \
        + [np.asarray(rasters[-1])]


@settings(max_examples=12, deadline=None)
@given(st.integers(0, 10_000), st.sampled_from([1, 2, 4, 8]),
       st.sampled_from(["if", "lif", "rmp"]),
       st.floats(min_value=0.02, max_value=0.6))
def test_row_and_block_event_columns_agree(seed, granularity, neuron,
                                           density):
    rng = np.random.default_rng(seed)
    wide = bool(rng.integers(0, 2))
    shapes = WS_SHAPES_WIDE if wide else WS_SHAPES
    ws = _ws(shapes, seed=seed + 1)
    n_spiking = len(ws) - 1
    ths = tuple([9, 5][:n_spiking])
    lks = tuple([1, 1][:n_spiking])
    spikes = (rng.random((T, B, shapes[0][0])) < density).astype(np.int8)
    kw = dict(thresholds=ths, leaks=lks, neuron=neuron,
              clamp_mode="saturate")
    rasters, vs, stats = fused_snn_net_events(spikes, ws, **kw)
    # bit-identity with the dense word-level reference
    r_ref, v_ref, _ = fused_snn_net(jnp.asarray(spikes), ws,
                                    use_pallas=False, **kw)
    for a, b in zip(list(rasters) + list(vs), list(r_ref) + list(v_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # per-row events measured during event-driven execution == counted
    # from the rasters after the fact
    inputs = _layer_inputs(spikes, r_ref)
    for li, (inp, rows) in enumerate(zip(inputs, stats.row_events)):
        np.testing.assert_array_equal(
            np.asarray(rows), inp.astype(np.int64).sum(axis=(0, 1)),
            err_msg=f"layer {li}")
    assert stats.frames == T * B
    # block columns sum-match the row columns at every granularity
    from repro.kernels.fused_snn_net.kernel import LANE, skip_layout
    n_blocks, _, _ = skip_layout(tuple(s[0] for s in shapes), granularity)
    for rows, nb, (n_in, _) in zip(stats.row_events, n_blocks, shapes):
        bw = n_in if granularity == 1 else LANE // granularity
        padded = np.zeros(nb * bw, np.int64)
        padded[:n_in] = rows
        blocks = padded.reshape(nb, bw).sum(axis=1)
        assert int(blocks.sum()) == int(np.asarray(rows).sum())
        # a block the kernel may skip every (tile, timestep) has no events
        if granularity > 1:
            _, _, sk = fused_snn_net(
                jnp.asarray(spikes), ws, interpret=True, block_b=BLOCK_B,
                use_sparse=True, gate_granularity=granularity, **kw)
            for s, rows2, (n_in2, _) in zip(sk, stats.row_events, shapes):
                s = np.asarray(s)
                bw2 = LANE // granularity
                for g in range(s.shape[1]):
                    if s[:, g].sum() == T * (B // BLOCK_B):   # always silent
                        assert rows2[g * bw2:(g + 1) * bw2].sum() == 0
            break                      # one kernel run per example is enough


@pytest.mark.parametrize("sparsity", [0.0, 0.5, 0.85])
def test_measured_edp_reduction_matches_fig11b(sparsity):
    """executed + skipped == dense closes the row-skip model: on a single
    full macro at exactly (1-s)*128 events/frame the measured reduction is
    the analytic Fig. 11b point."""
    events_per_frame = round((1.0 - sparsity) * 128)
    rep = pipeline.SparsityReport(
        n_in=(128,), n_out=(12,), neurons=("rmp",),
        events=(events_per_frame * T * B,), frames=T * B,
        timesteps=T, batch=B)
    executed = rep.instruction_counts()
    skipped = rep.skipped_instruction_counts()
    dense = isa.InstrCount(*(a + b for a, b in zip(executed, skipped)))
    assert dense == pipeline.SparsityReport(
        n_in=(128,), n_out=(12,), neurons=("rmp",),
        events=(128 * T * B,), frames=T * B, timesteps=T,
        batch=B).instruction_counts()
    red = energy.measured_edp_reduction(executed, skipped)
    assert red == pytest.approx(energy.edp_reduction(sparsity), rel=1e-9)


def test_skipped_instruction_counts_error_paths():
    with pytest.raises(ValueError, match="exceeds"):
        isa.count_skipped_instructions_from_events(10_000, 2, 16, 4)
    with pytest.raises(ValueError, match="empty"):
        energy.measured_edp_reduction(isa.InstrCount(), isa.InstrCount())
    rep = pipeline.SparsityReport(n_in=(128,), n_out=(12,),
                                  neurons=("rmp",), events=(0,), frames=4,
                                  timesteps=2, batch=2)
    with pytest.raises(ValueError, match="row_events"):
        rep.block_event_counts(4)


def _program(seed=5):
    cfg = SNNModelConfig(
        arch_id="ev", layer_sizes=(37, 50, 20, 3),
        spiking=SpikingConfig(neuron="rmp", timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 2)
    x = jnp.asarray(rng.standard_normal((2, 3, 37)).astype(np.float32))
    program = pipeline.compile_network(cfg, params, domain="int")
    return program, pipeline.present_words(x, cfg.timesteps)


def test_ref_events_backend_contract():
    """The registered backend: bit-identical results, and its measured
    per-row skip statistics equal the SparsityReport columns (which the
    raster-free collect_sums path reproduces too)."""
    program, xs = _program()
    ref = pipeline.run_network(program, xs, "int_ref")
    ev = pipeline.run_network(program, xs, "ref_events")
    for a, b in zip(ev.rasters, ref.rasters):
        np.testing.assert_array_equal(np.asarray(a).astype(np.int8),
                                      np.asarray(b).astype(np.int8))
    for a, b in zip(ev.v_final[1:], ref.v_final[1:]):
        np.testing.assert_array_equal(np.asarray(a).astype(np.int64),
                                      np.asarray(b).astype(np.int64))
    rep = pipeline.sparsity_report(program, ref.rasters)
    assert rep.row_events is not None
    for a, b in zip(ev.aux["row_events"], rep.row_events):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tuple(ev.aux["row_skip_counts"]) == rep.row_skip_counts
    assert ev.aux["skipped_row_fraction"] == pytest.approx(
        rep.skipped_row_fraction)
    assert rep.skipped_row_fraction == pytest.approx(rep.overall_sparsity)
    assert tuple(ev.aux["row_event_frames"]) == rep.frames_by_layer
    # sums path carries the same row columns
    resf = pipeline.run_network(program, xs, "float", collect_sums=True)
    rep_sums = pipeline.sparsity_report_from_sums(
        program, resf.aux["spike_sums"], xs.shape[0])
    for a, b in zip(rep_sums.row_events, rep.row_events):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # block columns at every granularity sum back to the event totals
    for g in (1, 2, 4, 8):
        blocks = rep.block_event_counts(g)
        assert tuple(int(b.sum()) for b in blocks) == rep.events


def test_ref_events_backend_conv_program():
    """Conv programs run the event-list executor on their im2col patch
    rasters: per-row columns cover k*k*c_in patch rows and frame counts
    follow the (timestep, example, position) lowering."""
    cfg = SNNModelConfig(
        arch_id="lenet-ev", conv_spec=((4, 3, 1), (6, 3, 2)),
        in_shape=(8, 8, 1), layer_sizes=(4 * 4 * 6, 10, 3),
        spiking=SpikingConfig(neuron="rmp", timesteps=2, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=2, task="multiclass")
    params = snn.init_lenet_snn(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(4)
    x = jnp.asarray(rng.standard_normal((2, *cfg.in_shape))
                    .astype(np.float32)) * 2.0
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_static(x, cfg.timesteps)
    ref = pipeline.run_network(program, xs, "int_ref")
    ev = pipeline.run_network(program, xs, "ref_events")
    for a, b in zip(ev.rasters, ref.rasters):
        np.testing.assert_array_equal(np.asarray(a).astype(np.int8),
                                      np.asarray(b).astype(np.int8))
    rep = pipeline.sparsity_report(program, ref.rasters)
    for a, b in zip(ev.aux["row_events"], rep.row_events):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert tuple(ev.aux["row_event_frames"]) == rep.frames_by_layer
    assert tuple(ev.aux["row_skip_counts"]) == rep.row_skip_counts
    conv = program.int_conv_stack[0]
    assert len(ev.aux["row_events"][0]) == conv.n_in     # k*k*c_in rows
