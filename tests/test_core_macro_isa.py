"""The silicon-chain tests: bit-accurate macro == word-level ISA == vectorized
reference, plus layout and comparator properties."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa, macro
from repro.core.quant import V_MAX, V_MIN


def test_physical_layout():
    assert macro.physical_layout_check()


@given(st.integers(min_value=-32, max_value=31))
@settings(max_examples=64, deadline=None)
def test_w_encoding_roundtrip(w):
    assert macro.decode_w(macro.encode_w(w)) == w


@given(st.integers(min_value=V_MIN, max_value=V_MAX))
@settings(max_examples=64, deadline=None)
def test_v_encoding_roundtrip(v):
    bits = macro.encode_v(v)
    assert bits[macro.GUARD] == 0
    assert macro.decode_v(bits) == v


@given(st.integers(min_value=V_MIN, max_value=V_MAX),
       st.integers(min_value=-31, max_value=31))
@settings(max_examples=200, deadline=None)
def test_blfa_w_plus_v_add(v, w):
    """Bit-serial W+V add (CS mode, sign extension via Wsign broadcast)
    == integer add mod 2^11."""
    a = macro.encode_v(v)
    wbits = macro.encode_w(w)
    b = np.zeros(12, np.uint8)
    b[:5] = wbits[:5]
    b[5] = wbits[5]
    b[6:] = wbits[5]
    s, _, _ = macro.blfa_unit_add(a, b, guard_mode="CS")
    expect = ((v + w) - V_MIN) % 2048 + V_MIN
    assert macro.decode_v(s) == expect


@given(st.integers(min_value=V_MIN, max_value=V_MAX),
       st.integers(min_value=V_MIN, max_value=V_MAX))
@settings(max_examples=200, deadline=None)
def test_blfa_v_plus_v_add(v, u):
    """Bit-serial V+V add (CF mode through the guard column) == int add."""
    s, _, _ = macro.blfa_unit_add(macro.encode_v(v), macro.encode_v(u), guard_mode="CF")
    expect = ((v + u) - V_MIN) % 2048 + V_MIN
    assert macro.decode_v(s) == expect


@given(st.integers(min_value=V_MIN // 2, max_value=V_MAX // 2),
       st.integers(min_value=0, max_value=V_MAX // 2))
@settings(max_examples=200, deadline=None)
def test_comparator(v, th):
    """SpikeCheck's adder-as-comparator == (v >= th) in the no-overflow regime."""
    _, _, sign = macro.blfa_unit_add(macro.encode_v(v), macro.encode_v(-th), guard_mode="CF")
    assert (sign == 0) == (v >= th)


# ---------------------------------------------------------------------------
# Full instruction-level equivalence: BitMacro vs word-level ISA
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_bitmacro_matches_isa_timesteps(neuron):
    rng = np.random.default_rng(0)
    wq = rng.integers(-31, 32, size=(isa.MACRO_IN, isa.MACRO_OUT)).astype(np.int8)
    th, leak = 40, 3
    bm = macro.BitMacro.from_weights(wq, threshold=th, leak=leak)
    st_ = isa.make_state(wq, threshold=th, leak=leak, clamp_mode="wrap")

    total = isa.InstrCount()
    for t in range(4):
        spikes_in = rng.random(isa.MACRO_IN) < 0.15          # ~85% sparsity
        out_bits = bm.timestep(0, spikes_in, neuron)
        st_, out_isa, cnt = isa.timestep(st_, 0, spikes_in, neuron)
        total += cnt
        np.testing.assert_array_equal(out_bits, np.asarray(out_isa))
        np.testing.assert_array_equal(bm.read_v(0), np.asarray(st_.vmem[0]))
    assert bm.counts == total                                # same cycle count


def test_isa_matches_vectorized_reference():
    """Word-level instruction program == the jit-able batched reference."""
    rng = np.random.default_rng(1)
    wq = rng.integers(-20, 21, size=(isa.MACRO_IN, isa.MACRO_OUT)).astype(np.int8)
    th, leak = 60, 2
    for neuron in ("if", "lif", "rmp"):
        st_ = isa.make_state(wq, threshold=th, leak=leak)
        v_ref = jnp.zeros((isa.MACRO_OUT,), jnp.int32)
        for t in range(5):
            spikes_in = (rng.random(isa.MACRO_IN) < 0.2).astype(np.int8)
            st_, s_isa, _ = isa.timestep(st_, 0, spikes_in, neuron)
            v_ref, s_ref = isa.layer_timestep_int(
                v_ref, jnp.asarray(wq), jnp.asarray(spikes_in), neuron=neuron,
                threshold=jnp.int32(th), leak=jnp.int32(leak), reset=jnp.int32(0))
            np.testing.assert_array_equal(np.asarray(st_.vmem[0]), np.asarray(v_ref))
            np.testing.assert_array_equal(np.asarray(s_isa).astype(np.int32),
                                          np.asarray(s_ref))


def test_sparsity_drives_instruction_count():
    """The event-driven property: AccW2V cycles == 2 * (#input spikes)."""
    rng = np.random.default_rng(2)
    wq = rng.integers(-31, 32, size=(isa.MACRO_IN, isa.MACRO_OUT)).astype(np.int8)
    st_ = isa.make_state(wq, threshold=1000)
    spikes_in = rng.random(isa.MACRO_IN) < 0.3
    _, _, cnt = isa.timestep(st_, 0, spikes_in, "rmp")
    assert cnt.acc_w2v == 2 * int(spikes_in.sum())
    assert cnt.spike_check == 2 and cnt.acc_v2v == 2
