"""End-to-end behaviour tests for the paper's system: train the IMDB SNN
briefly, check the QAT->int-macro deployment parity, sparsity accounting,
and the energy-model integration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.impulse_snn import IMDB, MNIST
from repro.core import energy, snn
from repro.core.isa import InstrCount
from repro.data import make_sentiment_vocab, mnist_like_batch, sentiment_batch
from repro.optim import adamw, apply_updates


@pytest.fixture(scope="module")
def trained_sentiment():
    cfg = dataclasses.replace(
        IMDB, spiking=dataclasses.replace(IMDB.spiking, threshold=0.5))
    ds = make_sentiment_vocab(0)
    params = snn.init_fc_snn(jax.random.PRNGKey(0), cfg)
    opt = adamw(lambda s: 5e-3, weight_decay=0.0)
    ost = opt.init(params)

    @jax.jit
    def step(p, o, x, y):
        (loss, aux), g = jax.value_and_grad(snn.sentiment_loss, has_aux=True)(
            p, x, y, cfg)
        u, o = opt.update(g, o, p)
        return apply_updates(p, u), o, loss

    for s in range(60):
        xb, yb = sentiment_batch(ds, 64, 10, seed=s)
        params, ost, _ = step(params, ost, jnp.asarray(xb), jnp.asarray(yb))
    xb, yb = sentiment_batch(ds, 256, 10, seed=12_345)
    return cfg, params, jnp.asarray(xb), jnp.asarray(yb)


def test_snn_learns_above_chance(trained_sentiment):
    cfg, params, x, y = trained_sentiment
    logits, _ = snn.sentiment_apply(params, x, cfg)
    acc = float(jnp.mean((logits > 0) == (y > 0.5)))
    assert acc > 0.62, acc                          # well above chance after 60 steps


def test_int_macro_deployment_parity(trained_sentiment):
    """The deployed 6b/11b integer path must agree with the QAT float path
    on the vast majority of predictions (the QAT contract)."""
    cfg, params, x, y = trained_sentiment
    logits_f, _ = snn.sentiment_apply(params, x, cfg)
    logits_i, rasters, counts = snn.sentiment_apply_int(params, x, cfg)
    agree = float(jnp.mean((logits_i > 0) == (logits_f > 0)))
    assert agree > 0.9, agree


def test_sparsity_and_instruction_accounting(trained_sentiment):
    cfg, params, x, y = trained_sentiment
    _, rasters, counts = snn.sentiment_apply_int(params, x, cfg)
    # event-driven accounting: AccW2V cycles == 2 * spikes * col_tiles summed
    from repro.core import mapping
    expect = 0
    sizes = cfg.layer_sizes
    for i, r in enumerate(rasters):
        t = mapping.fc_tiling(sizes[i], sizes[i + 1])
        expect += 2 * int(np.asarray(r).sum()) * t.col_tiles
    assert counts.acc_w2v == expect
    # energy strictly positive & monotone with extra instructions
    e1 = energy.snn_energy_j(counts)
    e2 = energy.snn_energy_j(counts + InstrCount(acc_w2v=100))
    assert 0 < e1 < e2


def test_lenet_snn_forward_and_grads():
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), MNIST)
    x, y = mnist_like_batch(4, seed=0)
    logits = snn.lenet_apply(params, jnp.asarray(x), MNIST)
    assert logits.shape == (4, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))
    (loss, aux), g = jax.value_and_grad(snn.lenet_loss, has_aux=True)(
        params, jnp.asarray(x), jnp.asarray(y), MNIST)
    gn = sum(float(jnp.abs(t).sum()) for t in jax.tree_util.tree_leaves(g))
    assert np.isfinite(gn) and gn > 0


def test_membrane_persists_across_words(trained_sentiment):
    """The paper's sequential-memory mechanism: permuting word order changes
    the output (a bag-of-words readout would not)."""
    cfg, params, x, y = trained_sentiment
    logits1, _ = snn.sentiment_apply(params, x[:32], cfg)
    perm = x[:32, ::-1]                              # reverse word order
    logits2, _ = snn.sentiment_apply(params, perm, cfg)
    assert float(jnp.max(jnp.abs(logits1 - logits2))) > 1e-3
