"""The static-verification contract (repro.analysis, DESIGN.md §"Static
verification").

Three claims under test:

  * soundness — sampled executions across backends never leave the
    per-layer intervals the range pass proved (the analyzer may be loose,
    never wrong);
  * rejection — every adversarial mis-configuration (overflow horizon,
    skip-column overflow, crossover out of range, VMEM-exceeding dispatch)
    is refused with a *named* error identifying the offending layer or
    contract, before any kernel is built;
  * the lint rules fire on the patterns they claim to ban, nowhere else,
    and the CI gate (`tools/check_invariants.py`) fails on a deliberately
    broken tree.
"""
import dataclasses
import importlib.util
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import (ContractError, Interval, RangeError, V_DOMAIN,
                            check_kernel_contracts, check_program,
                            clamp_interval, lint_source, validate_program,
                            wrap_is_exact)
from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import pipeline, snn
from repro.core.pipeline import LayerSpec, SNNProgram
from repro.core.quant import V_MAX, V_MIN, V_SPAN


# ---------------------------------------------------------------------------
# interval lattice
# ---------------------------------------------------------------------------

def test_interval_algebra():
    a, b = Interval(-3, 5), Interval(2, 10)
    assert a + b == Interval(-1, 15)
    assert a - b == Interval(-13, 3)
    assert a.hull(b) == Interval(-3, 10)
    assert a.intersect(b) == Interval(2, 5)
    assert Interval(6, 10).intersect(Interval(0, 5)) is None
    assert a.shift(4) == Interval(1, 9)
    assert a.contains(Interval(0, 5)) and not a.contains(b)
    assert a.contains_value(0) and not a.contains_value(6)
    assert Interval.point(7) == Interval(7, 7)
    with pytest.raises(ValueError):
        Interval(3, 2)


def test_clamp_interval_saturate():
    assert clamp_interval(Interval(-5000, 5000), "saturate") == V_DOMAIN
    assert clamp_interval(Interval(0, 100), "saturate") == Interval(0, 100)
    assert clamp_interval(Interval(900, 5000), "saturate") == \
        Interval(900, V_MAX)


def test_clamp_interval_wrap_exact_window():
    # a whole interval inside one wrap window translates exactly
    iv = Interval(V_MAX + 1, V_MAX + 10)
    assert wrap_is_exact(iv)
    assert clamp_interval(iv, "wrap") == Interval(V_MIN, V_MIN + 9)
    # in-domain interval is untouched
    assert clamp_interval(Interval(-10, 10), "wrap") == Interval(-10, 10)


def test_clamp_interval_wrap_widens_across_windows():
    iv = Interval(V_MAX - 1, V_MAX + 1)       # straddles the wrap seam
    assert not wrap_is_exact(iv)
    assert clamp_interval(iv, "wrap") == V_DOMAIN


def test_wrap_interval_matches_scalar_wrap():
    for lo, hi in [(-3000, -2900), (2040, 2060), (0, 5), (1020, 1030)]:
        iv = clamp_interval(Interval(lo, hi), "wrap")
        for v in range(lo, hi + 1):
            w = ((v - V_MIN) % V_SPAN) + V_MIN
            assert iv.contains_value(w), (lo, hi, v, w, iv)


# ---------------------------------------------------------------------------
# soundness: executions stay inside the proven intervals
# ---------------------------------------------------------------------------

def _program(layer_sizes, neuron, clamp_mode, seed, timesteps=3):
    cfg = SNNModelConfig(
        arch_id="ana-test", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron=neuron, timesteps=timesteps,
                              threshold=1.0, leak=0.0625,
                              w_bits=6, v_bits=11),
        timesteps=timesteps)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    return cfg, pipeline.compile_network(cfg, params, domain="int",
                                         clamp_mode=clamp_mode)


@given(st.sampled_from([("if", "saturate"), ("if", "wrap"),
                        ("lif", "saturate"), ("lif", "wrap"),
                        ("rmp", "saturate"), ("rmp", "wrap")]),
       st.integers(min_value=0, max_value=2 ** 16),
       st.sampled_from([5, 23]))
@settings(max_examples=12, deadline=None)
def test_execution_never_leaves_proven_intervals(neuron_mode, seed, n_hidden):
    neuron, clamp_mode = neuron_mode
    """Property: for every backend, every final membrane value lies inside
    the invariant interval the range pass proved for its layer, and the
    readout total inside the frame-horizon bound."""
    cfg, program = _program((17, n_hidden, 9, 2), neuron, clamp_mode, seed)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((2, 2, 17)).astype(np.float32)) * 3
    xs = pipeline.present_words(x, cfg.timesteps)
    report = check_program(program, frames=int(xs.shape[0]))

    runs = {
        "int_ref": pipeline.run_network(program, xs, "int_ref"),
        "pallas": pipeline.run_network(program, xs, "pallas",
                                       interpret=True, block_b=4),
        "ref_events": pipeline.run_network(program, xs, "ref_events"),
    }
    for backend, res in runs.items():
        # v_final[0] is the off-macro float encoder; the rest is the
        # macro stack in report order (spiking FCs then readout)
        assert len(res.v_final) - 1 == len(report.layers)
        for layer, v in zip(report.layers, res.v_final[1:]):
            vals = np.asarray(v).astype(np.int64)
            lo, hi = int(vals.min()), int(vals.max())
            assert layer.v_post.contains(Interval(lo, hi)), \
                (backend, layer.name, (lo, hi), layer.v_post)


@given(st.integers(min_value=0, max_value=2 ** 16))
@settings(max_examples=6, deadline=None)
def test_wrap_report_is_sound_and_flagged(seed):
    """Wrap-mode reports stay inside the V word and mark any clamp
    transfer that had to widen (wrap_exact=False is allowed, escaping the
    word is not)."""
    _, program = _program((17, 12, 5, 2), "rmp", "wrap", seed)
    report = check_program(program)
    for layer in report.layers:
        if layer.kind != "readout":
            assert V_DOMAIN.contains(layer.v_post), layer


# ---------------------------------------------------------------------------
# rejection: adversarial mis-configurations, each refused by name
# ---------------------------------------------------------------------------

def test_readout_overflow_horizon_rejected():
    """A frame horizon past max_safe_frames is a proven int32 overflow:
    named RangeError on the readout, and the reported bound is sharp."""
    _, program = _program((17, 12, 5, 2), "rmp", "saturate", seed=0)
    report = check_program(program)
    safe = report.max_safe_frames
    assert safe is not None and safe > 0
    check_program(program, frames=safe)                  # exactly safe: ok
    with pytest.raises(RangeError) as ei:
        check_program(program, frames=safe + 1)
    assert "readout" in str(ei.value)
    assert ei.value.where.startswith("readout")


def test_compile_time_validation_default_on():
    """`compile_network` refuses a program whose own presentation horizon
    already overflows the readout — unless validation is explicitly off."""
    cfg = SNNModelConfig(
        arch_id="ana-overflow", layer_sizes=(17, 12, 2),
        spiking=SpikingConfig(neuron="if", timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(0), cfg)
    bad = dataclasses.replace(cfg, timesteps=2 ** 40)
    with pytest.raises(RangeError):
        pipeline.compile_network(bad, params, domain="int")
    program = pipeline.compile_network(bad, params, domain="int",
                                       validate=False)
    assert program.timesteps == 2 ** 40                  # opt-out compiles


def test_megastep_staged_frame_block_counted_and_bounded():
    """A K-frame megastep pre-stages a (K, B, *in_shape) float32 frame
    block next to the kernel operands: the per-K VMEM slope must include
    it exactly, and a huge K is refused by name."""
    from repro.analysis.kernel_contracts import _pad_lane
    _, program = _program((17, 12, 5, 2), "if", "saturate", seed=0)
    in_elems = int(np.prod(program.layers[0].state_shape))
    r1 = check_kernel_contracts(program, "pallas", frames=1, streaming=True,
                                emit_rasters=False)
    r2 = check_kernel_contracts(program, "pallas", frames=2, streaming=True,
                                emit_rasters=False)
    per_k = r2.vmem_bytes - r1.vmem_bytes
    # int8 spike block (padded fan-in) + staged float32 frames, per lane
    assert per_k == r1.block_b * (_pad_lane(17) + in_elems * 4)
    with pytest.raises(ContractError, match="vmem_budget"):
        check_kernel_contracts(program, "pallas", frames=10 ** 6,
                               streaming=True)


def test_saturate_overflow_fanin_rejected_wrap_composes():
    """A fan-in so large the unclamped accumulator can pass int32 is
    rejected in saturate mode (clamping an overflowed value clips the
    wrong number) but accepted in wrap mode (2^11 divides 2^32: silicon
    wrap composes through the rollover)."""
    layers = (
        LayerSpec(kind="fc", n_in=10 ** 8, n_out=4, w=None,
                  threshold=100, leak=0),
        LayerSpec(kind="readout", n_in=4, n_out=2, w=None),
    )

    def prog(mode):
        return SNNProgram(cfg=None, domain="int", neuron="if", timesteps=2,
                          layers=layers, clamp_mode=mode)

    with pytest.raises(RangeError) as ei:
        check_program(prog("saturate"))
    assert "fc[0]" in str(ei.value) and "saturate" in str(ei.value)
    report = check_program(prog("wrap"))                 # wrap: proven safe
    assert not report.layers[0].wrap_exact
    assert V_DOMAIN.contains(report.layers[0].v_post)


def test_oversized_constant_rejected():
    layers = (
        LayerSpec(kind="fc", n_in=8, n_out=4, w=None,
                  threshold=V_MAX + 1, leak=0),
        LayerSpec(kind="readout", n_in=4, n_out=2, w=None),
    )
    program = SNNProgram(cfg=None, domain="int", neuron="if", timesteps=2,
                         layers=layers)
    with pytest.raises(RangeError) as ei:
        check_program(program)
    assert "threshold" in str(ei.value)
    assert "quantize_neuron_const" in str(ei.value)


def test_skip_layout_overflow_rejected():
    """A stack whose gate-site column map exceeds MAX_SKIP_COLS at fine
    granularity is refused for the gated backend before dispatch — and
    only for it (129 layers x 128/16 sites = 1032 > 1024)."""
    _, program = _program((128,) * 129 + (4,), "if", "saturate", seed=0)
    check_kernel_contracts(program, "pallas")            # dense: fine
    check_kernel_contracts(program, "pallas_sparse",     # coarse: fits
                           gate_granularity=1)
    with pytest.raises(ContractError) as ei:
        check_kernel_contracts(program, "pallas_sparse",
                               gate_granularity=8)
    assert "skip_layout" in str(ei.value)
    assert "MAX_SKIP_COLS" in str(ei.value)


def test_event_crossover_out_of_range_rejected():
    _, program = _program((17, 12, 2), "if", "saturate", seed=0)
    for bad in (-0.2, 1.5):
        with pytest.raises(ContractError) as ei:
            check_kernel_contracts(program, "pallas_events",
                                   event_crossover=bad)
        assert "event_crossover" in str(ei.value)
    # the dispatch wrapper itself refuses too (defense in depth at ops)
    x = jnp.zeros((1, 1, 17), jnp.float32)
    xs = pipeline.present_words(x, 3)
    with pytest.raises(ValueError, match="event_crossover"):
        pipeline.run_network(program, xs, "pallas_events", interpret=True,
                             block_b=2, event_crossover=1.5)


def test_vmem_exceeding_dispatch_rejected():
    """A (frames, block_b) pair whose resident working set cannot fit the
    per-core VMEM budget is refused before any kernel is built."""
    _, program = _program((128, 128, 2), "if", "saturate", seed=0)
    check_kernel_contracts(program, "pallas", frames=4, block_b=8)
    with pytest.raises(ContractError) as ei:
        check_kernel_contracts(program, "pallas", frames=200_000,
                               block_b=64)
    assert "vmem_budget" in str(ei.value)


def test_backend_and_mode_contracts():
    _, program = _program((17, 12, 2), "if", "saturate", seed=0)
    with pytest.raises(ContractError):
        check_kernel_contracts(program, "no_such_backend")
    with pytest.raises(ContractError) as ei:
        check_kernel_contracts(program, "bitmacro")      # needs wrap
    assert "wrap" in str(ei.value)
    with pytest.raises(ContractError) as ei:
        check_kernel_contracts(program, "pallas", gate_granularity=2)
    assert "gate_granularity" in str(ei.value)
    with pytest.raises(ContractError):
        check_kernel_contracts(program, "pallas", block_b=0)


def test_validate_program_bundles_all_passes():
    from repro.analysis import HOST_BACKENDS, TRACE_BACKENDS
    _, program = _program((17, 12, 2), "if", "saturate", seed=0)
    ranges, contracts, traces = validate_program(program)
    assert ranges.max_safe_frames is not None
    assert set(contracts) == {"pallas"}
    assert contracts["pallas"].vmem_bytes > 0
    # trace pass default-on for int programs: every registered int backend
    assert set(traces) == set(TRACE_BACKENDS) | set(HOST_BACKENDS)
    for b in TRACE_BACKENDS:
        assert traces[b].surfaces, b
        assert {s.surface for s in traces[b].surfaces} == {
            "batch", "step", "megastep", "mesh"}
        assert traces[b].cost is not None and traces[b].cost.macs > 0
    for b in HOST_BACKENDS:
        # host executors have no jaxpr; bitmacro additionally requires
        # wrap mode, so on this saturate program its contract refuses it
        assert traces[b].checks[0].prop in ("host_backend",
                                            "contract_skip")
    # and off by request / for float programs
    r2 = validate_program(program, trace=False)
    assert r2[2] == {}


# ---------------------------------------------------------------------------
# serving: admission control against the proven horizon
# ---------------------------------------------------------------------------

def test_engine_validates_and_caps_admission():
    from repro.serve.snn_engine import SNNRequest, SNNServeEngine
    _, program = _program((17, 12, 5, 2), "rmp", "saturate", seed=0)
    eng = SNNServeEngine(program, backend="int_ref", batch_slots=2)
    assert eng.max_safe_ticks == check_program(program).max_safe_frames
    frames = np.zeros((3, 17), dtype=np.int8)
    eng.submit(SNNRequest(rid="ok", frames=frames))      # within budget
    eng.max_safe_ticks = 2                               # force a tiny cap
    with pytest.raises(RangeError, match="proven safe"):
        eng.submit(SNNRequest(rid="too-long", frames=frames))


def test_engine_rejects_contract_violation_at_build():
    from repro.serve.snn_engine import SNNServeEngine
    _, program = _program((17, 12, 2), "if", "saturate", seed=0)
    with pytest.raises(ContractError, match="event_crossover"):
        SNNServeEngine(program, backend="pallas_events",
                       step_kw={"interpret": True, "block_b": 2,
                                "event_crossover": 7.0})
    eng = SNNServeEngine(program, backend="pallas_events",
                         step_kw={"interpret": True, "block_b": 2,
                                  "event_crossover": 7.0}, validate=False)
    assert eng.max_safe_ticks is None                    # opt-out builds


# ---------------------------------------------------------------------------
# repo lint
# ---------------------------------------------------------------------------

def _rules(src, path="src/repro/models/x.py"):
    return [v.rule for v in lint_source(src, path)]


def test_lint_bare_assert():
    assert _rules("def f(x):\n    assert x > 0\n") == ["ANA001"]
    assert _rules("def f(x):\n    assert x > 0  # noqa: ANA001\n") == []


def test_lint_adhoc_clamp():
    assert _rules("import numpy as np\nv = np.clip(v, V_MIN, V_MAX)\n") == \
        ["ANA002"]
    assert _rules("v = jnp.clip(v, -1024, 1023)\n") == ["ANA002"]
    assert _rules("w = (v - V_MIN) % V_SPAN\n") == ["ANA002"]
    # the quant module is the one home allowed to clamp to the V word
    assert _rules("import numpy as np\nv = np.clip(v, V_MIN, V_MAX)\n",
                  path="src/repro/core/quant.py") == []
    # clipping to other bounds is not a V-word clamp
    assert _rules("v = np.clip(v, 0.0, 1.0)\n") == []


def test_lint_unseeded_randomness():
    assert _rules("import numpy as np\nx = np.random.rand(3)\n") == \
        ["ANA003"]
    assert _rules("r = np.random.default_rng()\n") == ["ANA003"]
    assert _rules("r = np.random.default_rng(0)\n") == []
    assert _rules("r = np.random.default_rng(seed)\n") == []


def test_lint_float_cast_in_int_domain():
    kern = "src/repro/kernels/fused_snn_net/ops.py"
    # every cast spelling is caught inside the int-domain scope
    assert _rules("y = x.astype(jnp.float32)\n", path=kern) == ["ANA005"]
    assert _rules('y = x.astype("float32")\n', path=kern) == ["ANA005"]
    assert _rules("y = x.astype(float)\n", path=kern) == ["ANA005"]
    assert _rules("y = jnp.zeros(4, dtype=np.bfloat16)\n", path=kern) == \
        ["ANA005"]
    assert _rules("y = x.astype(jnp.float32)\n",
                  path="src/repro/core/isa.py") == ["ANA005"]
    # int casts, float *annotations*, and out-of-scope modules are fine
    assert _rules("y = x.astype(jnp.int32)\n", path=kern) == []
    assert _rules("def f(x: float) -> float:\n    return x\n",
                  path=kern) == []
    assert _rules("y = x.astype(jnp.float32)\n",
                  path="src/repro/core/quant.py") == []
    assert _rules("y = x.astype(jnp.float32)  # noqa: ANA005\n",
                  path=kern) == []


def test_library_tree_is_lint_clean():
    from repro.analysis import lint_paths
    root = pathlib.Path(__file__).parent.parent / "src" / "repro"
    assert lint_paths([root]) == []


# ---------------------------------------------------------------------------
# the CI gate itself
# ---------------------------------------------------------------------------

def _load_check_invariants():
    path = (pathlib.Path(__file__).parent.parent / "tools" /
            "check_invariants.py")
    spec = importlib.util.spec_from_file_location("check_invariants", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_check_invariants_gate(tmp_path, capsys):
    """The CLI passes on a clean tree and fails (exit 1, violation named)
    on a deliberately broken one."""
    mod = _load_check_invariants()
    clean, broken = tmp_path / "clean", tmp_path / "broken"
    clean.mkdir()
    broken.mkdir()
    (clean / "ok.py").write_text("def f(x):\n    return x\n")
    (broken / "bad.py").write_text(
        "def f(x):\n    assert x > 0\n    return x % 2048\n")

    mod.LINT_ROOT = clean
    mod.main(["--lint-only"])                            # no SystemExit
    mod.LINT_ROOT = broken
    with pytest.raises(SystemExit) as ei:
        mod.main(["--lint-only"])
    assert ei.value.code == 1
    assert "ANA001" in capsys.readouterr().out
