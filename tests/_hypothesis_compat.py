"""Minimal stand-in for the `hypothesis` API surface these tests use.

The container image does not ship hypothesis and installing packages is not
an option, so conftest.py registers this module as `hypothesis` when the
real library is absent. It is NOT a property-based testing engine: each
@given test runs `max_examples` deterministic examples — strategy boundary
values first (where most of the macro's two's-complement edge cases live),
then seeded pseudo-random draws. With the real hypothesis installed this
module is never imported.
"""
from __future__ import annotations

import functools
import inspect
import itertools
import random
from dataclasses import dataclass
from typing import Any, Callable


@dataclass(frozen=True)
class _Strategy:
    boundary: tuple            # always-tested edge examples
    draw: Callable[[random.Random], Any]


def integers(min_value: int, max_value: int) -> _Strategy:
    edge = {min_value, max_value, 0, -1, 1}
    edge = tuple(v for v in sorted(edge) if min_value <= v <= max_value)
    return _Strategy(edge, lambda r: r.randint(min_value, max_value))


def floats(min_value: float, max_value: float, **_: Any) -> _Strategy:
    return _Strategy((min_value, max_value),
                     lambda r: r.uniform(min_value, max_value))


def sampled_from(options) -> _Strategy:
    options = tuple(options)
    return _Strategy(options[:2], lambda r: r.choice(options))


def lists(elements: _Strategy, min_size: int = 0, max_size: int = 10) -> _Strategy:
    def draw(r: random.Random):
        n = r.randint(min_size, max_size)
        return [elements.draw(r) for _ in range(n)]
    boundary = tuple([list(elements.boundary[:1]) * max(min_size, 1)][:1])
    return _Strategy(boundary, draw)


class strategies:                       # mirrors `from hypothesis import strategies as st`
    integers = staticmethod(integers)
    floats = staticmethod(floats)
    sampled_from = staticmethod(sampled_from)
    lists = staticmethod(lists)


def settings(max_examples: int = 100, **_: Any):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco


def given(*strats: _Strategy):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            max_examples = getattr(wrapper, "_max_examples", 100)
            # all-boundary cross product first, then seeded random draws
            combos = list(itertools.product(*(s.boundary for s in strats)))
            rng = random.Random(1234567 + len(strats))
            while len(combos) < max_examples:
                combos.append(tuple(s.draw(rng) for s in strats))
            for combo in combos[:max(max_examples, len(combos))]:
                fn(*args, *combo, **kwargs)
        # pytest must not see the strategy params as fixtures
        del wrapper.__wrapped__
        wrapper.__signature__ = inspect.Signature()
        return wrapper
    return deco
