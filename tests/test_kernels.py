"""Per-kernel validation: shape/dtype sweeps against the pure-jnp oracles,
all Pallas kernels in interpret mode (CPU container; TPU is the target)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.fused_snn_step.ops import fused_snn_layer
from repro.kernels.fused_snn_step.ref import fused_snn_layer_ref
from repro.kernels.wkv6.ops import wkv6, wkv6_decode_step
from repro.kernels.wkv6.ref import wkv6_sequential


# ---------------------------------------------------------------------------
# fused_snn_step
# ---------------------------------------------------------------------------

SNN_SHAPES = [
    # T, B, N_in, N_out  (macro-ish, ragged, large)
    (10, 4, 100, 12),
    (10, 4, 128, 128),
    (7, 3, 130, 20),
    (4, 16, 256, 140),
]


@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
@pytest.mark.parametrize("shape", SNN_SHAPES)
def test_fused_snn_kernel_matches_ref(neuron, shape):
    T, B, Nin, Nout = shape
    rng = np.random.default_rng(hash((neuron, shape)) % 2**32)
    spikes = jnp.asarray((rng.random((T, B, Nin)) < 0.2).astype(np.int8))
    wq = jnp.asarray(rng.integers(-31, 32, (Nin, Nout)).astype(np.int8))
    kw = dict(threshold=60, leak=2, reset=0, neuron=neuron)
    out_k, v_k = fused_snn_layer(spikes, wq, interpret=True, **kw)
    out_r, v_r = fused_snn_layer_ref(spikes, wq, **kw)
    np.testing.assert_array_equal(np.asarray(out_k), np.asarray(out_r))
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
def test_fused_snn_clamp_modes(clamp_mode):
    rng = np.random.default_rng(7)
    spikes = jnp.asarray((rng.random((6, 2, 128)) < 0.9).astype(np.int8))  # dense -> overflow
    wq = jnp.asarray(rng.integers(-31, 32, (128, 12)).astype(np.int8))
    kw = dict(threshold=1000, neuron="if", clamp_mode=clamp_mode)
    out_k, v_k = fused_snn_layer(spikes, wq, interpret=True, **kw)
    out_r, v_r = fused_snn_layer_ref(spikes, wq, **kw)
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))
    assert int(jnp.max(jnp.abs(v_k))) <= 1024


def test_fused_snn_dtype_bool_input():
    rng = np.random.default_rng(3)
    spikes = jnp.asarray(rng.random((5, 2, 64)) < 0.3)       # bool
    wq = jnp.asarray(rng.integers(-31, 32, (64, 24)).astype(np.int8))
    out_k, v_k = fused_snn_layer(spikes, wq, threshold=40, neuron="rmp",
                                 interpret=True)
    out_r, v_r = fused_snn_layer_ref(spikes.astype(jnp.int8), wq,
                                     threshold=40, neuron="rmp")
    np.testing.assert_array_equal(np.asarray(v_k), np.asarray(v_r))


# ---------------------------------------------------------------------------
# wkv6
# ---------------------------------------------------------------------------

def _wkv_inputs(B, T, H, K, V, seed=0, w_lo=0.6):
    rng = np.random.default_rng(seed)
    r = rng.standard_normal((B, T, H, K)).astype(np.float32) * 0.5
    k = rng.standard_normal((B, T, H, K)).astype(np.float32) * 0.5
    v = rng.standard_normal((B, T, H, V)).astype(np.float32) * 0.5
    w = rng.uniform(w_lo, 0.999, (B, T, H, K)).astype(np.float32)
    u = rng.standard_normal((H, K)).astype(np.float32) * 0.3
    return map(jnp.asarray, (r, k, v, w, u))


def _to_bh(x, B, H):
    return jnp.moveaxis(x, 2, 1).reshape(B * H, x.shape[1], x.shape[-1])


WKV_SHAPES = [
    # B, T, H, K, V
    (2, 64, 2, 64, 64),
    (1, 128, 3, 64, 64),
    (2, 100, 2, 32, 32),     # ragged T (padding path)
    (1, 192, 1, 16, 64),     # K != V
]


@pytest.mark.parametrize("shape", WKV_SHAPES)
def test_wkv6_chunked_matches_sequential(shape):
    B, T, H, K, V = shape
    r, k, v, w, u = _wkv_inputs(B, T, H, K, V, seed=sum(shape))
    ub = jnp.broadcast_to(u[None], (B, H, K)).reshape(B * H, K)
    y_seq, s_seq = wkv6_sequential(_to_bh(r, B, H), _to_bh(k, B, H),
                                   _to_bh(v, B, H), _to_bh(w, B, H), ub)
    y_ops, s_ops = wkv6(r, k, v, w, u, use_pallas=False)
    y_ops_bh = _to_bh(y_ops, B, H)
    np.testing.assert_allclose(np.asarray(y_ops_bh), np.asarray(y_seq),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_ops.reshape(B * H, K, V)),
                               np.asarray(s_seq), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("shape", WKV_SHAPES[:2])
def test_wkv6_pallas_matches_chunked(shape):
    B, T, H, K, V = shape
    r, k, v, w, u = _wkv_inputs(B, T, H, K, V, seed=13 + sum(shape))
    y_p, s_p = wkv6(r, k, v, w, u, use_pallas=True, interpret=True)
    y_c, s_c = wkv6(r, k, v, w, u, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_p), np.asarray(y_c), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_c), rtol=1e-5, atol=1e-5)


def test_wkv6_initial_state_continuation():
    """Splitting a sequence must equal running it whole (serving handoff)."""
    B, T, H, K, V = 1, 128, 2, 32, 32
    r, k, v, w, u = _wkv_inputs(B, T, H, K, V, seed=5)
    y_full, s_full = wkv6(r, k, v, w, u, use_pallas=False)
    half = T // 2
    y1, s1 = wkv6(r[:, :half], k[:, :half], v[:, :half], w[:, :half], u,
                  use_pallas=False)
    y2, s2 = wkv6(r[:, half:], k[:, half:], v[:, half:], w[:, half:], u,
                  s0=s1, use_pallas=False)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=3e-4, atol=3e-4)


def test_wkv6_decode_step_matches_sequential():
    B, H, K, V = 2, 2, 32, 32
    r, k, v, w, u = _wkv_inputs(B, 8, H, K, V, seed=9)
    s = jnp.zeros((B, H, K, V))
    ys = []
    for t in range(8):
        y, s = wkv6_decode_step(r[:, t].swapaxes(1, 1), k[:, t], v[:, t],
                                w[:, t], u, s)
        ys.append(y)
    y_dec = jnp.stack(ys, axis=1)                            # (B, T, H, V)
    y_full, s_full = wkv6(r, k, v, w, u, use_pallas=False)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s), np.asarray(s_full),
                               rtol=2e-4, atol=2e-4)
