"""Distribution tests that need >1 device: run as subprocesses with
xla_force_host_platform_device_count set before jax imports.

Covers: sharding rules divisibility, int8-wire compressed all-reduce with
error feedback, GPipe pipeline parallelism, and a sharded end-to-end train
step on an 8-device host mesh."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path


SRC = str(Path(__file__).resolve().parents[1] / "src")


def run_subprocess(body: str, devices: int = 8) -> dict:
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
        import json
        import jax, jax.numpy as jnp
        import numpy as np
        if not hasattr(jax.sharding, "AxisType"):   # jax < 0.5 compat shim
            class _AxisType:
                Auto = None
            jax.sharding.AxisType = _AxisType
            _mm = jax.make_mesh
            jax.make_mesh = lambda *a, axis_types=None, **k: _mm(*a, **k)
        {textwrap.indent(textwrap.dedent(body), '        ').strip()}
        print("RESULT:" + json.dumps(result))
    """)
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for line in out.stdout.splitlines():
        if line.startswith("RESULT:"):
            return json.loads(line[len("RESULT:"):])
    raise AssertionError(f"no RESULT in stdout: {out.stdout[-2000:]}")


def test_sharding_rules_divisibility():
    """_fit drops non-dividing axes (whisper 20 heads on 16-way model)."""
    res = run_subprocess("""
        from jax.sharding import PartitionSpec as P
        from repro.dist.sharding import _fit
        mesh = jax.make_mesh((2, 4), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        ok = _fit(("data", "model"), (8, 12), mesh)          # both divide
        dropped = _fit(("data", "model"), (8, 10), mesh)     # 10 % 4 != 0
        both = _fit((("data", "model"), None), (16, 3), mesh)
        result = {"ok": str(ok), "dropped": str(dropped), "both": str(both)}
    """, devices=8)
    assert res["ok"] == "PartitionSpec('data', 'model')"
    assert res["dropped"] == "PartitionSpec('data', None)"
    assert "'data', 'model'" in res["both"] or "('data', 'model')" in res["both"]


def test_compressed_allreduce_error_feedback():
    """int8-wire mean-reduce == fp32 mean within quant error; error feedback
    makes the BIAS vanish across steps (sum of deq errors -> 0)."""
    res = run_subprocess("""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P
        from repro.dist.compress import compressed_psum_mean
        mesh = jax.make_mesh((8,), ("data",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        g_global = jnp.asarray(np.random.default_rng(0).standard_normal((8, 64)),
                               jnp.float32)

        def step(g, r):
            out, r2 = compressed_psum_mean({"w": g[0]}, {"w": r[0]}, "data")
            return out["w"][None], r2["w"][None]

        f = shard_map(step, mesh=mesh, in_specs=(P("data"), P("data")),
                      out_specs=(P("data"), P("data")), check_rep=False)
        r = jnp.zeros((8, 64), jnp.float32)
        true_mean = g_global.mean(0)
        errs, acc = [], jnp.zeros((8, 64))
        for _ in range(6):
            out, r = f(g_global, r)
            errs.append(float(jnp.abs(out[0] - true_mean).max()))
            acc = acc + out
        # with error feedback the time-average converges to the true mean
        avg_err = float(jnp.abs(acc[0]/6 - true_mean).max())
        result = {"first_err": errs[0], "avg_err": avg_err}
    """, devices=8)
    assert res["first_err"] < 0.05            # one-step quant error is small
    assert res["avg_err"] < res["first_err"]  # feedback kills the bias


def test_pipeline_parallel_gpipe():
    """4-stage pipeline over 4 devices == sequential composition."""
    res = run_subprocess("""
        from repro.dist.pipeline import make_pipeline_fn
        mesh = jax.make_mesh((4,), ("pipe",),
                             axis_types=(jax.sharding.AxisType.Auto,))
        rng = np.random.default_rng(0)
        Ws = jnp.asarray(rng.standard_normal((4, 16, 16)) * 0.3, jnp.float32)

        def stage(w, x):
            return jnp.tanh(x @ w)

        pipe = make_pipeline_fn(stage, mesh, "pipe", n_micro=6)
        xs = jnp.asarray(rng.standard_normal((6, 2, 16)), jnp.float32)
        out = pipe(Ws, xs)
        ref = xs
        for s in range(4):
            ref = jnp.tanh(ref @ Ws[s])
        result = {"max_err": float(jnp.abs(out - ref).max())}
    """, devices=4)
    assert res["max_err"] < 1e-5


def test_sharded_train_step_8dev():
    """End-to-end: reduced llama3.2 train step on a (4 data x 2 model) host
    mesh with the production sharding rules; loss finite, grads sharded."""
    res = run_subprocess("""
        import dataclasses
        from repro.configs.base import (ParallelConfig, RunConfig, ShapeConfig,
                                        get_config, reduced_config)
        from repro.dist import sharding as shd
        from repro.models import io_spec, lm
        from repro.optim import make_optimizer
        from repro.train.train_state import TrainState, make_train_step

        cfg = reduced_config(get_config("llama3.2-1b"))
        shape = ShapeConfig("t", 64, 8, "train")
        parallel = ParallelConfig(remat="block", fsdp=True, seq_parallel=True,
                                  vocab_chunking=2)
        run = RunConfig(model=cfg, shape=shape, parallel=parallel,
                        optimizer="adamw", learning_rate=1e-3, warmup_steps=1)
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
        opt = make_optimizer("adamw", 1e-3, 0.1)
        with mesh:
            params = lm.init_params(jax.random.PRNGKey(0), cfg)
            pspecs = shd.param_specs(params, mesh, parallel)
            params = jax.tree_util.tree_map(jax.device_put, params, pspecs)
            ostate = opt.init(params)
            state = TrainState(params, ostate, jnp.zeros((), jnp.int32))
            batch = io_spec.materialize(io_spec.train_batch_spec(cfg, shape))
            bspecs = shd.batch_specs(batch, mesh, parallel)
            batch = jax.tree_util.tree_map(jax.device_put, batch, bspecs)
            step_fn = jax.jit(make_train_step(run, opt))
            with shd.activation_rules(mesh, parallel):
                state2, metrics = step_fn(state, batch)
            loss1 = float(metrics["loss"])
            state3, metrics2 = step_fn(state2, batch)
        w = jax.tree_util.tree_leaves(state3.params)[0]
        result = {"loss1": loss1, "loss2": float(metrics2["loss"]),
                  "finite": bool(np.isfinite(loss1)),
                  "n_shards": len(w.sharding.device_set)}
    """, devices=8)
    assert res["finite"]
    assert res["loss2"] <= res["loss1"] + 0.5
    assert res["n_shards"] == 8
