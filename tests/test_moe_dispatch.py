"""MoE dispatch equivalence: gather-only dispatch == scatter dispatch, and
both match a dense (no-capacity) reference when capacity is generous."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MoEConfig, get_config, reduced_config
from repro.models.layers import init_moe, moe_ffn


def _setup(seed=0, E=8, k=2, B=2, T=32, d=64, f=32):
    import dataclasses
    cfg = dataclasses.replace(
        reduced_config(get_config("deepseek-v2-lite-16b")),
        d_model=d,
        moe=MoEConfig(n_experts=E, top_k=k, n_shared_experts=0, d_ff=f))
    key = jax.random.PRNGKey(seed)
    p = init_moe(key, cfg, dtype=jnp.float32)
    x = jax.random.normal(jax.random.fold_in(key, 1), (B, T, d)) * 0.5
    return cfg, p, x


def _dense_ref(x, p, cfg):
    """No-capacity dense reference: every token through its top-k experts."""
    m = cfg.moe
    B, T, d = x.shape
    xf = x.reshape(-1, d)
    logits = xf @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    gates, eidx = jax.lax.top_k(probs, m.top_k)
    gates = gates / gates.sum(-1, keepdims=True)
    h = jax.nn.silu(jnp.einsum("nd,edf->nef", xf, p["experts"]["gate"])) \
        * jnp.einsum("nd,edf->nef", xf, p["experts"]["up"])
    ye = jnp.einsum("nef,efd->ned", h, p["experts"]["down"])  # all experts
    onehot = jax.nn.one_hot(eidx, m.n_experts)                # (N, k, E)
    w = (onehot * gates[..., None]).sum(1)                    # (N, E)
    return jnp.einsum("ne,ned->nd", w, ye).reshape(B, T, d)


@pytest.mark.parametrize("gather", [False, True])
def test_dispatch_matches_dense_reference(gather):
    cfg, p, x = _setup()
    out, _ = moe_ffn(x, p, cfg, capacity_factor=8.0,        # generous: no drops
                     gather_dispatch=gather)
    ref = _dense_ref(x, p, cfg)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-4)


def test_gather_equals_scatter_with_drops():
    cfg, p, x = _setup(seed=3)
    a, _ = moe_ffn(x, p, cfg, capacity_factor=1.0, gather_dispatch=False)
    b, _ = moe_ffn(x, p, cfg, capacity_factor=1.0, gather_dispatch=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_dispatch_grads_finite():
    cfg, p, x = _setup(seed=5)

    def loss(p, gather):
        out, lb = moe_ffn(x, p, cfg, gather_dispatch=gather)
        return jnp.sum(out ** 2) + lb

    for gather in (False, True):
        g = jax.grad(loss)(p, gather)
        total = sum(float(jnp.abs(t).sum()) for t in jax.tree_util.tree_leaves(g))
        assert np.isfinite(total) and total > 0
