"""Streaming execution contract: the step API and the SNN serving engine.

Two claims are swept here:

  1. *Stream == batch, bit for bit.* Driving a presentation frame-by-frame
     through `stream_step` (per-layer V carried as an explicit state tree)
     reproduces `run_network` exactly — rasters, final V, logits, and the
     event-gating skip counters — on every streaming backend, every neuron
     model, both clamp modes, odd shapes, and conv stacks. This is the
     paper's fused-V_MEM property restated at the API boundary: membrane
     state is *state*, not a per-call temporary.

  2. *Slots are invisible.* The continuous-batching SNN engine serves each
     request bit-identically to running it alone (batch lanes never
     interact), and its per-slot event accounting finalizes into
     SparsityReports equal to the ones the batch path derives from full
     rasters — so serving-time skip accounting feeds the energy model with
     no drift.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import energy, pipeline, snn
from repro.serve import SNNRequest, SNNServeEngine
from repro.serve.engine import EngineUndrained
from repro.serve.snn_engine import merge_reports

LENET_S = SNNModelConfig(
    arch_id="lenet-s",
    conv_spec=((4, 3, 1), (6, 3, 2)),
    in_shape=(8, 8, 1),
    layer_sizes=(4 * 4 * 6, 10, 3),
    spiking=SpikingConfig(neuron="rmp", timesteps=2, threshold=1.0,
                          leak=0.0625, w_bits=6, v_bits=11),
    timesteps=2, task="multiclass")


def _make(layer_sizes, neuron, n_words, batch, seed=0):
    cfg = SNNModelConfig(
        arch_id="test", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron=neuron, timesteps=3, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=3)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 7)
    x = jnp.asarray(rng.standard_normal(
        (batch, n_words, layer_sizes[0])).astype(np.float32))
    return cfg, params, x


def _stream(program, xs, backend, **kw):
    """Run xs through stream_step tick by tick; returns (state, stacked
    rasters, accumulated skips, last StreamOut)."""
    state = program.init_state(xs.shape[1], backend)
    frames_r, skips = [], None
    out = None
    for t in range(xs.shape[0]):
        state, out = program.step(state, xs[t], backend, **kw)
        frames_r.append(out.rasters)
        # event backends return EventStats (a NamedTuple: `+` would
        # concatenate, not add) — their counters are checked elsewhere
        if out.skips is not None and backend not in ("ref_events",
                                                     "pallas_events"):
            if skips is None:
                skips = out.skips
            elif isinstance(skips, list):
                skips = [a + b for a, b in zip(skips, out.skips)]
            else:
                skips = skips + out.skips
    rasters = [np.stack([np.asarray(fr[i]) for fr in frames_r])
               for i in range(len(frames_r[0]))]
    return state, rasters, skips, out


def _assert_stream_matches_batch(program, xs, backend, tag, **kw):
    run_kw = dict(kw)
    if backend == "float":
        run_kw = {"collect_rasters": True}
    res = pipeline.run_network(program, xs, backend, **run_kw)
    state, rasters, skips, out = _stream(program, xs, backend, **kw)
    assert state.t == xs.shape[0]
    for i, (a, b) in enumerate(zip(state.vs, res.v_final)):
        np.testing.assert_array_equal(
            np.asarray(a, np.float64), np.asarray(b, np.float64),
            err_msg=f"{tag} V {i}")
    ref = res.rasters[-len(rasters):]      # float emits per-neuron-layer
    for i, (a, b) in enumerate(zip(rasters, ref)):
        np.testing.assert_array_equal(
            a.astype(np.int8), np.asarray(b).astype(np.int8),
            err_msg=f"{tag} raster {i}")
    np.testing.assert_array_equal(np.asarray(out.v_out, np.int64)
                                  if backend != "float" else out.v_out,
                                  np.asarray(res.v_out, np.int64)
                                  if backend != "float" else res.v_out,
                                  err_msg=f"{tag} v_out")
    np.testing.assert_allclose(np.asarray(out.logits),
                               np.asarray(res.logits), err_msg=f"{tag} logits")
    return res, skips


BACKEND_KW = [
    ("float", {}),
    ("int_ref", {}),
    ("int_ref", {"use_sparse": True}),
    ("pallas", {"interpret": True, "block_b": 4}),
    ("pallas_sparse", {"interpret": True, "block_b": 4}),
    ("pallas_sparse", {"interpret": True, "block_b": 4,
                       "gate_granularity": 4}),
    ("ref_events", {}),
    ("pallas_events", {"interpret": True, "block_b": 4}),
]


def _case_id(b, k):
    gran = f"-g{k['gate_granularity']}" if "gate_granularity" in k else ""
    return f"{b}{gran}{'-sparse' if k.get('use_sparse') else ''}"


@pytest.mark.parametrize("backend,kw", BACKEND_KW,
                         ids=[_case_id(b, k) for b, k in BACKEND_KW])
def test_stream_matches_batch_all_backends(backend, kw):
    """The full backend set on one program: frame-by-frame streaming is
    bit-identical to the batch raster run, skip counters included."""
    cfg, params, x = _make((37, 50, 20, 3), "rmp", 3, 2)
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_words(x, cfg.timesteps)
    res, skips = _assert_stream_matches_batch(program, xs, backend,
                                              f"{backend}/{kw}", **kw)
    if skips is not None:                  # summed per-tick gate counters
        ref = res.aux["skip_counts"]
        if isinstance(ref, list):
            for a, b in zip(skips, ref):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        else:
            np.testing.assert_array_equal(np.asarray(skips), np.asarray(ref))


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_stream_neuron_clamp_sweep(neuron, clamp_mode):
    """Neuron x clamp sweep on ragged shapes, int_ref + event-gated pallas."""
    cfg, params, x = _make((37, 50, 20, 3), neuron, 2, 2, seed=3)
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode=clamp_mode)
    xs = pipeline.present_words(x, cfg.timesteps)
    for backend, kw in [("int_ref", {}),
                        ("pallas_sparse", {"interpret": True, "block_b": 4})]:
        _assert_stream_matches_batch(program, xs, backend,
                                     f"{neuron}/{clamp_mode}/{backend}", **kw)


@pytest.mark.parametrize("backend,kw", [
    ("int_ref", {}),
    ("pallas", {"interpret": True, "block_b": 4}),
    ("ref_events", {}),
    ("pallas_events", {"interpret": True, "block_b": 4}),
])
def test_stream_conv_stack(backend, kw):
    """Conv programs stream too: the im2col front-end threads per-conv V
    maps through the state tree."""
    cfg = LENET_S
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(7)
    x = jnp.asarray(rng.standard_normal(
        (2, *cfg.in_shape)).astype(np.float32)) * 2.0
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode="wrap")
    xs = pipeline.present_static(x, cfg.timesteps)
    _assert_stream_matches_batch(program, xs, backend, f"conv/{backend}",
                                 **kw)


def test_stream_float_domain_program():
    """The QAT (float-domain) program streams on the float backend with the
    same state-tree contract. True-float accumulation is NOT bit-stable
    between the scanned batch loop and eager per-tick ops (XLA fuses them
    differently; last-ulp drift), so this checks to f32 tolerance — the
    bit-identity guarantee belongs to the integer domain, where the float
    backend is an exact integer rendering and IS swept bit-exact above."""
    cfg, params, x = _make((20, 16, 8, 2), "lif", 2, 3, seed=5)
    program = pipeline.compile_network(cfg, params, domain="float")
    xs = pipeline.present_words(x, cfg.timesteps)
    res = pipeline.run_network(program, xs, "float", collect_rasters=True)
    state, rasters, _, out = _stream(program, xs, "float")
    for i, (a, b) in enumerate(zip(state.vs, res.v_final)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, err_msg=f"float-domain V {i}")
    for i, (a, b) in enumerate(zip(rasters, res.rasters)):
        np.testing.assert_allclose(a, np.asarray(b),
                                   err_msg=f"float-domain raster {i}")
    np.testing.assert_allclose(np.asarray(out.logits),
                               np.asarray(res.logits), atol=1e-5)


def test_stream_serving_mode_no_rasters():
    """emit_rasters=False: same state trajectory and outputs, no raster
    emission (the serving configuration)."""
    cfg, params, x = _make((37, 50, 20, 3), "rmp", 2, 2, seed=9)
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_words(x, cfg.timesteps)
    st_a = program.init_state(2, "int_ref")
    st_b = program.init_state(2, "int_ref")
    for t in range(xs.shape[0]):
        st_a, out_a = program.step(st_a, xs[t], "int_ref")
        st_b, out_b = program.step(st_b, xs[t], "int_ref",
                                   emit_rasters=False)
        assert out_b.rasters is None
    for a, b in zip(st_a.vs, st_b.vs):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(out_a.v_out),
                                  np.asarray(out_b.v_out))


def test_stream_state_validation():
    cfg, params, _ = _make((37, 50, 20, 3), "rmp", 2, 2)
    program = pipeline.compile_network(cfg, params, domain="int")
    with pytest.raises(KeyError, match="bitmacro"):
        program.init_state(2, "bitmacro")
    fprogram = pipeline.compile_network(cfg, params, domain="float")
    with pytest.raises(ValueError, match="int-domain"):
        fprogram.init_state(2, "int_ref")
    state = program.init_state(2, "int_ref")
    assert len(state.vs) == len(program.layers) and state.t == 0


# ---------------------------------------------------------------------------
# SNN serving engine
# ---------------------------------------------------------------------------

def _imdb_like_program(seed=0, layer_sizes=(37, 50, 20, 3), neuron="rmp"):
    cfg, params, _ = _make(layer_sizes, neuron, 2, 2, seed=seed)
    return cfg, pipeline.compile_network(cfg, params, domain="int")


def _word_request(cfg, rid, n_words, seed):
    rng = np.random.default_rng(seed)
    x = rng.standard_normal((1, n_words, cfg.layer_sizes[0])).astype(
        np.float32)
    frames = np.asarray(pipeline.present_words(
        jnp.asarray(x), cfg.timesteps))[:, 0]
    return SNNRequest(rid=rid, frames=frames), jnp.asarray(x)


@pytest.mark.parametrize("backend,kw", [
    ("int_ref", {}),
    ("pallas_sparse", {"interpret": True, "block_b": 4}),
    ("pallas_events", {"interpret": True, "block_b": 4}),
])
def test_snn_engine_staggered_equals_isolated(backend, kw):
    """Staggered admits/evictions (5 requests of different lengths through
    2 slots): every request's v_out/logits equal an isolated batch run of
    its own frames, and its per-slot SparsityReport equals the report the
    batch path builds from full rasters."""
    cfg, program = _imdb_like_program()
    eng = SNNServeEngine(program, batch_slots=2, backend=backend,
                         step_kw=kw)
    reqs = [_word_request(cfg, rid, nw, seed=40 + rid)
            for rid, nw in enumerate([2, 4, 1, 3, 2])]
    for r, _ in reqs:
        eng.submit(r)
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == list(range(5))
    for rid, (_, x) in enumerate(reqs):
        r = next(d for d in done if d.rid == rid)
        xs = pipeline.present_words(x, cfg.timesteps)
        iso = pipeline.run_network(program, xs, "int_ref")
        np.testing.assert_array_equal(r.v_out, np.asarray(iso.v_out)[0],
                                      err_msg=f"rid {rid}")
        np.testing.assert_allclose(r.logits, np.asarray(iso.logits)[0],
                                   err_msg=f"rid {rid}")
        ref = pipeline.sparsity_report(program, iso.rasters)
        assert r.report.events == ref.events
        assert r.report.layer_frames == ref.layer_frames
        for a, b in zip(r.report.row_events, ref.row_events):
            np.testing.assert_array_equal(a, b)
        assert r.report.instruction_counts() == ref.instruction_counts()


def test_snn_engine_accounting_closes_energy_loop():
    """Per-slot skip accounting -> merged SparsityReport -> measured EDP:
    executed + skipped instruction cycles close against the dense tally,
    and the merged report equals the sum of its parts."""
    cfg, program = _imdb_like_program(seed=2)
    eng = SNNServeEngine(program, batch_slots=2, backend="int_ref",
                         step_kw={"use_sparse": True})
    for rid in range(4):
        eng.submit(_word_request(cfg, rid, 2, seed=60 + rid)[0])
    done = eng.run_until_drained()
    agg = eng.aggregate_report()
    assert agg.instruction_counts().total == sum(
        r.report.instruction_counts().total for r in done)
    assert agg.frames == sum(r.report.frames for r in done)
    # executed + skipped == dense (the Fig. 11b closure, serving-side)
    from repro.core import isa
    dense = isa.InstrCount()
    for ni, no, neuron, f in zip(agg.n_in, agg.n_out, agg.neurons,
                                 agg.frames_by_layer):
        dense += isa.count_layer_instructions_from_events(f * ni, f, ni, no,
                                                          neuron)
    both = agg.instruction_counts() + agg.skipped_instruction_counts()
    assert both.acc_w2v == dense.acc_w2v
    assert energy.measured_edp(agg.instruction_counts()) > 0
    assert 0.0 < agg.skipped_row_fraction < 1.0
    with pytest.raises(ValueError):
        merge_reports([])


def test_snn_engine_early_exit_and_tick_budget():
    """Per-slot stop conditions: a confident readout (stop_threshold) exits
    before the frame budget; max_ticks truncates the stream; both record
    the ticks actually served."""
    cfg, program = _imdb_like_program(seed=4)
    req_full, x = _word_request(cfg, 0, 4, seed=11)
    t_total = len(req_full.frames)
    # threshold early exit: pick a threshold below the final |logit| so the
    # exit must trigger at or before the end — then check it used the
    # *first* tick whose logit cleared it
    xs = pipeline.present_words(x, cfg.timesteps)
    state = program.init_state(1, "int_ref")
    traj = []
    for t in range(t_total):
        state, out = program.step(state, xs[t], "int_ref")
        traj.append(float(np.max(np.abs(np.asarray(out.logits)))))
    thr = max(traj) * 0.5
    first = next(t for t, v in enumerate(traj) if v >= thr) + 1
    req = SNNRequest(rid=0, frames=np.asarray(req_full.frames),
                     stop_threshold=thr)
    eng = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    eng.submit(req)
    done = eng.run_until_drained()
    assert done[0].ticks == first
    # fixed tick budget
    req2 = SNNRequest(rid=1, frames=np.asarray(req_full.frames), max_ticks=3)
    eng2 = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    eng2.submit(req2)
    assert eng2.run_until_drained()[0].ticks == 3


def test_snn_engine_undrained_raises():
    """The tick cap never silently drops work — same contract as the LM
    engine: EngineUndrained carries the partial finished list, and the
    engine can keep draining afterwards."""
    cfg, program = _imdb_like_program(seed=6)
    eng = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    for rid in range(3):
        eng.submit(_word_request(cfg, rid, 2, seed=80 + rid)[0])
    with pytest.raises(EngineUndrained) as ei:
        eng.run_until_drained(max_ticks=7)       # 3 reqs x 6 ticks > 7
    assert ei.value.pending >= 1
    partial = len(ei.value.finished)
    assert partial < 3
    done = eng.run_until_drained()               # resumable: finish the rest
    assert sorted(r.rid for r in done) == [0, 1, 2]
    # the exception snapshot does not alias the live finished list
    assert len(ei.value.finished) == partial


def test_snn_engine_rejects_wrong_frame_shape():
    cfg, program = _imdb_like_program(seed=8)
    eng = SNNServeEngine(program, batch_slots=1)
    with pytest.raises(ValueError, match="frame shape"):
        eng.submit(SNNRequest(rid=0, frames=np.zeros((4, 5), np.float32)))


def test_snn_engine_conv_program():
    """Conv programs serve through the engine too: image frames in, the
    per-slot accounting counts conv events per (output position, patch
    row) — and still closes against the batch-path report."""
    cfg = LENET_S
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), cfg)
    program = pipeline.compile_network(cfg, params, domain="int")
    rng = np.random.default_rng(1)
    eng = SNNServeEngine(program, batch_slots=2, backend="int_ref")
    xs_all = []
    for rid in range(3):
        x = rng.standard_normal((1, *cfg.in_shape)).astype(np.float32) * 2.0
        frames = np.asarray(pipeline.present_static(
            jnp.asarray(x), cfg.timesteps))[:, 0]
        eng.submit(SNNRequest(rid=rid, frames=frames))
        xs_all.append(x)
    done = eng.run_until_drained()
    assert len(done) == 3
    for r in done:
        xs = pipeline.present_static(jnp.asarray(xs_all[r.rid]),
                                     cfg.timesteps)
        iso = pipeline.run_network(program, xs, "int_ref")
        np.testing.assert_array_equal(r.v_out, np.asarray(iso.v_out)[0],
                                      err_msg=f"rid {r.rid}")
        ref = pipeline.sparsity_report(program, iso.rasters)
        assert r.report.events == ref.events
        assert r.report.layer_frames == ref.layer_frames


def test_snn_engine_empty_and_zero_budget_requests():
    """Degenerate requests finish at admit without occupying a slot or
    running a tick, and their zero-frame reports stay well-defined
    (no division by zero in the sparsity fractions or aggregation)."""
    cfg, program = _imdb_like_program(seed=10)
    eng = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    eng.submit(SNNRequest(rid=0, frames=np.zeros((0, 37), np.float32)))
    eng.submit(SNNRequest(rid=1, frames=np.zeros((4, 37), np.float32),
                          max_ticks=0))
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1]
    for r in done:
        assert r.ticks == 0                      # no spurious tick ran
        assert r.report.frames == 0
        assert r.report.skipped_row_fraction == 0.0
        assert r.report.overall_sparsity == 0.0
        assert r.report.layer_sparsity == (0.0,) * len(r.report.n_in)
    assert eng.ticks == 0                        # the engine never stepped
    assert eng.aggregate_report().skipped_row_fraction == 0.0
    # and a zero-budget request queued behind real work does not stall it
    eng2 = SNNServeEngine(program, batch_slots=1, backend="int_ref")
    real, _ = _word_request(cfg, 2, 1, seed=90)
    eng2.submit(SNNRequest(rid=3, frames=np.zeros((0, 37), np.float32)))
    eng2.submit(real)
    done2 = eng2.run_until_drained()
    assert sorted(r.rid for r in done2) == [2, 3]
    assert next(r for r in done2 if r.rid == 2).ticks == cfg.timesteps
