"""The jaxpr trace-verification contract (repro.analysis.trace_check,
DESIGN.md §7.5).

Two claims under test:

  * rejection — each of four deliberately broken kernels (a float
    round-trip, a duplicated clamp, an out-of-bounds slice, a clamp
    smuggled ahead of the cross-shard psum) is refused by a *named*
    `TraceError` identifying the violated property and the offending
    primitive, straight from its jaxpr;
  * acceptance — every real backend x neuron x clamp-mode dispatch (and
    the mesh tick under an abstract axis env) traces clean across all
    surfaces, and the static cost model closes exactly against the ISA
    instruction counts.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis import (TRACE_BACKENDS, TraceError, TraceExpectation,
                            check_closed_jaxpr, check_cost_closure,
                            check_trace)
from repro.configs.base import SpikingConfig
from repro.configs.impulse_snn import SNNModelConfig
from repro.core import pipeline, quant, snn


def _program(layer_sizes, neuron, clamp_mode, timesteps=3, seed=0):
    cfg = SNNModelConfig(
        arch_id="trace-test", layer_sizes=layer_sizes,
        spiking=SpikingConfig(neuron=neuron, timesteps=timesteps,
                              threshold=1.0, leak=0.0625,
                              w_bits=6, v_bits=11),
        timesteps=timesteps)
    params = snn.init_fc_snn(jax.random.PRNGKey(seed), cfg)
    return pipeline.compile_network(cfg, params, domain="int",
                                    clamp_mode=clamp_mode)


# ---------------------------------------------------------------------------
# rejection: injected defects, each refused by name
# ---------------------------------------------------------------------------

_X = jnp.zeros((4, 16), jnp.int32)
_W = jnp.zeros((16, 8), jnp.int32)


def test_float_roundtrip_rejected():
    """An f32 cast inside an int dispatch silently loses bit-identity past
    2**24 — the dtype pass names the float aval."""
    def bad(x, w):
        acc = (x.astype(jnp.float32) @ w.astype(jnp.float32))
        return quant.clamp_v(acc.astype(jnp.int32), "saturate")

    jx = jax.make_jaxpr(bad)(_X, _W)
    with pytest.raises(TraceError, match="dtype: float"):
        check_closed_jaxpr(jx, TraceExpectation(
            where="bad:float", neuron="if", n_spiking=1))


def test_duplicated_clamp_rejected():
    """Two stacked V-word clamps change wrap semantics and hide range
    bugs — the clamp pass counts heads against the ISA contract."""
    def bad(x, w):
        v = quant.clamp_v(jnp.dot(x, w, preferred_element_type=jnp.int32),
                          "saturate")
        return quant.clamp_v(v, "saturate")

    jx = jax.make_jaxpr(bad)(_X, _W)
    with pytest.raises(TraceError, match="clamp: 2 V-word clamp"):
        check_closed_jaxpr(jx, TraceExpectation(
            where="bad:double", neuron="if", n_spiking=1))


def test_oob_slice_rejected():
    """A gather/slice whose interval provably escapes its operand is a
    silent wrong-weight read on hardware — the bounds pass names it."""
    def bad(v):
        seg = jax.lax.dynamic_slice(
            v, (jnp.asarray(120, jnp.int32),), (16,))
        return quant.clamp_v(seg, "saturate")

    jx = jax.make_jaxpr(bad)(jnp.zeros((128,), jnp.int32))
    with pytest.raises(TraceError, match="bounds"):
        check_closed_jaxpr(jx, TraceExpectation(
            where="bad:oob", neuron="if", n_spiking=1))


def test_clamp_before_psum_rejected():
    """Clamping the row-tile partial before the cross-shard psum breaks
    the AccV2V exactness argument (clamp does not distribute over the
    sum) — the dominance pass names the psum."""
    def bad(x, w):
        part = quant.clamp_v(
            jnp.dot(x, w, preferred_element_type=jnp.int32), "saturate")
        return jax.lax.psum(part, "model")

    jx = jax.make_jaxpr(bad, axis_env=[("model", 2)])(_X, _W)
    with pytest.raises(TraceError, match="upstream of the cross-shard psum"):
        check_closed_jaxpr(jx, TraceExpectation(
            where="bad:psum", neuron="if", n_spiking=1,
            mesh_axes=(("model", 2),)))


def test_unknown_backend_and_float_domain_rejected():
    program = _program((9, 7, 2), "if", "saturate")
    with pytest.raises(TraceError, match="no int-domain trace"):
        check_trace(program, "no_such_backend")


# ---------------------------------------------------------------------------
# acceptance: real dispatches trace clean across the whole grid
# ---------------------------------------------------------------------------

@given(st.sampled_from([("if", "saturate"), ("lif", "wrap"),
                        ("rmp", "saturate"), ("rmp", "wrap")]),
       st.sampled_from(TRACE_BACKENDS))
@settings(max_examples=8, deadline=None)
def test_clean_dispatches_verify_on_every_surface(neuron_mode, backend):
    neuron, clamp_mode = neuron_mode
    """Property: every registered int backend's real dispatch verifies on
    all four surfaces (batch/step/megastep/mesh) for every neuron x
    clamp_mode, with a positive MAC count from the cost model."""
    program = _program((9, 7, 5, 2), neuron, clamp_mode)
    report = check_trace(program, backend, block_b=4,
                         mesh={"data": 2, "model": 2})
    assert {s.surface for s in report.surfaces} == \
        {"batch", "step", "megastep", "mesh"}
    assert all(s.clamps >= 0 for s in report.surfaces)
    assert report.cost is not None and report.cost.macs > 0
    props = {c.prop for c in report.checks}
    assert {"dtype", "clamp_count", "clamp_dominance", "bounds"} <= props


def test_cost_closure_exact_on_conv_program():
    """The static dense-instruction count (trace geometry + SAME-padding
    events) equals the executed pipeline count exactly, conv included."""
    cfg = SNNModelConfig(
        arch_id="trace-lenet", conv_spec=((4, 3, 1), (6, 3, 2)),
        in_shape=(10, 10, 1), layer_sizes=(5 * 5 * 6, 16, 4),
        spiking=SpikingConfig(neuron="if", timesteps=2, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=2, task="multiclass")
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), cfg)
    program = pipeline.compile_network(cfg, params, domain="int",
                                       clamp_mode="saturate")
    check_cost_closure(program, batch=2)


def test_cost_closure_exact_on_fc_program():
    program = _program((17, 12, 5, 2), "rmp", "saturate")
    check_cost_closure(program, batch=4)
