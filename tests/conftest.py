"""Test-session setup: make `src/` importable and gate optional deps.

The tier-1 command runs with PYTHONPATH=src (also set via pytest.ini
``pythonpath``); the sys.path insert below keeps direct `pytest tests/...`
invocations working from any cwd. The hypothesis fallback keeps the
property tests runnable in the hermetic container (no pip installs).
"""
import sys
from pathlib import Path

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", Path(__file__).parent / "_hypothesis_compat.py")
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod     # register first: dataclasses resolve
    _spec.loader.exec_module(_mod)       # __module__ via sys.modules at exec
