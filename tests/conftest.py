"""Test-session setup: make `src/` importable and gate optional deps.

The tier-1 command runs with PYTHONPATH=src (also set via pytest.ini
``pythonpath``); the sys.path insert below keeps direct `pytest tests/...`
invocations working from any cwd. The hypothesis fallback keeps the
property tests runnable in the hermetic container (no pip installs).

The XLA_FLAGS guard forces 4 simulated host devices for the whole test
session (jax reads the flag at first backend init, so it must be set
before any test imports jax): the mesh equivalence suite
(test_mesh_snn.py) needs a 4-way mesh, and running the *entire* tier-1
suite under forced multi-device is itself part of the contract — every
single-device path must be oblivious to how many devices exist. An
explicit user-set XLA_FLAGS is respected.
"""
import os
import sys
from pathlib import Path

if "XLA_FLAGS" not in os.environ and "jax" not in sys.modules:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

SRC = str(Path(__file__).resolve().parents[1] / "src")
if SRC not in sys.path:
    sys.path.insert(0, SRC)

try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    import importlib.util
    _spec = importlib.util.spec_from_file_location(
        "hypothesis", Path(__file__).parent / "_hypothesis_compat.py")
    _mod = importlib.util.module_from_spec(_spec)
    sys.modules["hypothesis"] = _mod     # register first: dataclasses resolve
    _spec.loader.exec_module(_mod)       # __module__ via sys.modules at exec
