"""Energy-model calibration tests: the model must reproduce the paper's
measured numbers (Fig. 6, Fig. 11b, Table I) within rounding."""
import numpy as np
import pytest

from repro.core import energy
from repro.core.isa import InstrCount


def test_fig6_neuron_update_energies():
    """Fig. 6: IF 1.81 pJ, LIF 2.67 pJ, RMP 1.68 pJ at point D."""
    assert energy.neuron_update_energy_pj("if") == pytest.approx(1.81, abs=0.02)
    assert energy.neuron_update_energy_pj("lif") == pytest.approx(2.67, abs=0.03)
    assert energy.neuron_update_energy_pj("rmp") == pytest.approx(1.68, abs=0.02)


def test_fig11b_edp_reduction_at_85_sparsity():
    """~97.4% EDP reduction at 85% sparsity (RMP, point D)."""
    red = energy.edp_reduction(0.85)
    assert red == pytest.approx(0.974, abs=0.004)


def test_edp_monotone_in_sparsity():
    xs = np.linspace(0, 1, 21)
    edps = [energy.edp_per_neuron_per_timestep(s) for s in xs]
    assert all(a >= b for a, b in zip(edps, edps[1:]))


def test_table1_performance_area():
    """GOPS/mm^2 at the three Table I supply points: 0.75 / 2.24 / 5.61."""
    assert energy.gops_per_mm2(energy.POINT_A) == pytest.approx(0.75, abs=0.01)
    assert energy.gops_per_mm2(energy.POINT_D) == pytest.approx(2.24, abs=0.02)
    assert energy.gops_per_mm2(energy.POINT_G) == pytest.approx(5.61, abs=0.02)


def test_table1_tops_w():
    assert energy.tops_per_watt(energy.POINT_D) == pytest.approx(0.99)
    assert energy.tops_per_watt(energy.POINT_A) == pytest.approx(0.91)
    assert energy.tops_per_watt(energy.POINT_G) == pytest.approx(0.57)


def test_power_consistency():
    """Measured power ~= freq * energy/cycle for AccW2V at each point."""
    for pt in energy.OPERATING_POINTS:
        e = energy.instr_energy_j("acc_w2v", pt)
        derived_power = e * pt.freq_hz
        # within 2x (the measured average power includes idle periphery)
        assert derived_power == pytest.approx(pt.power_w, rel=1.0)


def test_sequence_energy_additive():
    a = InstrCount(acc_w2v=10)
    b = InstrCount(spike_check=4)
    assert energy.sequence_energy_j(a + b) == pytest.approx(
        energy.sequence_energy_j(a) + energy.sequence_energy_j(b))
