"""Config-system invariants (hypothesis property tests + registry checks)."""
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs.base import (ASSIGNED_ARCHS, SHAPES, get_config, list_archs,
                                reduced_config)
from repro.models.lm import layer_kind, n_prelude, n_super, super_period


def test_all_assigned_archs_registered():
    assert set(ASSIGNED_ARCHS) <= set(list_archs())
    assert len(ASSIGNED_ARCHS) == 10


def test_shapes_are_the_assignment():
    assert set(SHAPES) == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["long_500k"].seq_len == 524288


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_structural_invariants(arch):
    cfg = get_config(arch)
    assert cfg.n_heads % cfg.n_kv_heads == 0
    assert (cfg.n_layers - n_prelude(cfg)) % super_period(cfg) == 0
    assert n_super(cfg) * super_period(cfg) + n_prelude(cfg) == cfg.n_layers
    # every layer classifies
    for i in range(cfg.n_layers):
        mixer, f = layer_kind(cfg, i)
        assert mixer in ("attn", "ssm", "rwkv")
        assert f in ("dense", "moe", "spiking", "none")
    # reduced config preserves the family interleave
    r = reduced_config(cfg)
    kinds_full = [layer_kind(cfg, n_prelude(cfg) + j)[0]
                  for j in range(super_period(cfg))]
    kinds_red = [layer_kind(r, n_prelude(r) + j)[0]
                 for j in range(super_period(r))]
    assert kinds_full == kinds_red


@pytest.mark.parametrize("arch,total_b,active_b", [
    ("llama3-8b", 8.0, 8.0),
    ("jamba-v0.1-52b", 51.6, 12.1),
    ("llama4-maverick-400b-a17b", 400.7, 17.2),
    ("deepseek-v2-lite-16b", 15.7, 2.7),
])
def test_param_counts_match_published(arch, total_b, active_b):
    cfg = get_config(arch)
    assert cfg.param_count() / 1e9 == pytest.approx(total_b, rel=0.02)
    assert cfg.active_param_count() / 1e9 == pytest.approx(active_b, rel=0.03)


def test_jamba_interleave_is_1_to_7():
    cfg = get_config("jamba-v0.1-52b")
    kinds = [layer_kind(cfg, i)[0] for i in range(cfg.n_layers)]
    assert kinds.count("attn") == 4 and kinds.count("ssm") == 28


def test_long_context_flags():
    for a in ASSIGNED_ARCHS:
        cfg = get_config(a)
        assert cfg.supports_long_context == (a in ("rwkv6-7b", "jamba-v0.1-52b"))


@given(st.sampled_from(ASSIGNED_ARCHS), st.integers(0, 200))
@settings(max_examples=40, deadline=None)
def test_moe_layer_never_in_prelude(arch, idx):
    cfg = get_config(arch)
    if idx >= cfg.n_layers:
        return
    if cfg.moe is not None and idx < cfg.moe.first_k_dense:
        assert not cfg.is_moe_layer(idx)
