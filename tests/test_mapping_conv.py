"""Conv -> macro-grid lowering properties (the im2col contract).

The int conv path rests on one identity: SAME-padded conv2d over {0,1}
spike maps equals the im2col patch matrix times the row-packed HWIO kernel,
*exactly*, in integer arithmetic — zero padding contributes zero rows, and
the (kh, kw, c) patch-feature order matches `pack_conv_weights`. Property
tests sweep random kernel/stride/channel geometries and check the identity
at three levels: raw accumulation, the full word-level conv layer-timestep
(`isa.conv_layer_timestep_int` vs a conv2d-built rendering) under BOTH
V_MEM clamp policies (the wrap mode is where partial-sum order would show),
and the temporal raster form the pipeline feeds the executors.
"""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import isa, mapping
from repro.core.pipeline import conv2d
from repro.core.quant import clamp_v, spike_compare


def _geometry(seed, kernel, stride, c_in, c_out, h, w):
    rng = np.random.default_rng(seed)
    x = (rng.random((2, h, w, c_in)) < 0.35).astype(np.int32)
    wq = rng.integers(-31, 32, (kernel, kernel, c_in, c_out)).astype(np.int32)
    return x, wq


@given(st.integers(min_value=1, max_value=4),     # kernel
       st.integers(min_value=1, max_value=3),     # stride
       st.integers(min_value=1, max_value=3),     # c_in
       st.integers(min_value=1, max_value=5),     # c_out
       st.integers(min_value=3, max_value=9),     # h
       st.integers(min_value=3, max_value=9))     # w
@settings(max_examples=48, deadline=None)
def test_im2col_matmul_equals_conv2d(kernel, stride, c_in, c_out, h, w):
    x, wq = _geometry(kernel + 7 * stride + h, kernel, stride, c_in, c_out,
                      h, w)
    ref = np.asarray(conv2d(jnp.asarray(x, jnp.float32),
                            jnp.asarray(wq, jnp.float32), stride))
    patches = np.asarray(mapping.im2col(x, kernel, stride))
    got = patches @ np.asarray(mapping.pack_conv_weights(wq))
    assert patches.shape[-1] == kernel * kernel * c_in
    np.testing.assert_array_equal(got.astype(np.int64),
                                  ref.astype(np.int64))
    # geometry helper agrees with the patch shape
    assert patches.shape[1:3] == mapping.conv_out_hw((h, w), kernel, stride)


@pytest.mark.parametrize("clamp_mode", ["saturate", "wrap"])
@pytest.mark.parametrize("neuron", ["if", "lif", "rmp"])
def test_conv_layer_timestep_int_matches_conv2d_rendering(neuron, clamp_mode):
    """The word-level conv timestep (im2col lowering) == the direct conv2d
    rendering of the same integer dynamics, over several timesteps of
    persistent V — including the 11-bit wrap regime (weights scaled up so V
    actually leaves [-1024, 1023])."""
    rng = np.random.default_rng(3)
    kernel, stride, c_in, c_out, h = 3, 2, 2, 5, 7
    wq = jnp.asarray(rng.integers(-31, 32, (kernel, kernel, c_in, c_out)),
                     jnp.int32) * 4               # force wrap events
    th, leak = jnp.int32(90), jnp.int32(3)
    h_out, w_out = mapping.conv_out_hw((h, h), kernel, stride)
    v = jnp.zeros((2, h_out, w_out, c_out), jnp.int32)
    v_ref = v
    for t in range(4):
        x = jnp.asarray((rng.random((2, h, h, c_in)) < 0.4), jnp.int32)
        v, s = isa.conv_layer_timestep_int(
            v, wq, x, stride=stride, neuron=neuron, threshold=th, leak=leak,
            reset=jnp.int32(0), clamp_mode=clamp_mode)
        # direct rendering: conv2d accumulate, then the shared dynamics
        acc = conv2d(x.astype(jnp.float32),
                     wq.astype(jnp.float32), stride).astype(jnp.int32)
        v_ref = clamp_v(v_ref + acc, clamp_mode)
        if neuron == "lif":
            v_ref = clamp_v(v_ref - leak, clamp_mode)
        s_ref = spike_compare(v_ref, th, clamp_mode)
        if neuron == "rmp":
            v_ref = clamp_v(jnp.where(s_ref, v_ref - th, v_ref), clamp_mode)
        else:
            v_ref = jnp.where(s_ref, 0, v_ref)
        np.testing.assert_array_equal(np.asarray(s),
                                      np.asarray(s_ref.astype(jnp.int32)),
                                      err_msg=f"spikes t={t}")
        np.testing.assert_array_equal(np.asarray(v), np.asarray(v_ref),
                                      err_msg=f"V t={t}")
    assert int(np.asarray(v).min()) >= -1024 and int(np.asarray(v).max()) <= 1023


@given(st.integers(min_value=1, max_value=3),
       st.integers(min_value=1, max_value=3))
@settings(max_examples=12, deadline=None)
def test_im2col_raster_matches_per_frame(kernel, stride):
    """(T, B, H, W, C) raster form == im2col applied frame by frame."""
    rng = np.random.default_rng(kernel * 11 + stride)
    raster = (rng.random((3, 2, 6, 6, 2)) < 0.3).astype(np.int8)
    got = np.asarray(mapping.im2col_raster(raster, kernel, stride))
    h_out, w_out = mapping.conv_out_hw((6, 6), kernel, stride)
    assert got.shape == (3, 2 * h_out * w_out, kernel * kernel * 2)
    for t in range(3):
        per_frame = np.asarray(mapping.im2col(raster[t], kernel, stride))
        np.testing.assert_array_equal(
            got[t], per_frame.reshape(-1, kernel * kernel * 2))


def test_same_pads_matches_xla():
    """Asymmetric-padding cases (even kernels, stride > size alignment)."""
    for size, kernel, stride in [(5, 2, 2), (7, 4, 3), (4, 3, 2), (3, 1, 1)]:
        out, lo, hi = mapping.same_pads(size, kernel, stride)
        x = jnp.ones((1, size, size, 1), jnp.float32)
        w = jnp.ones((kernel, kernel, 1, 1), jnp.float32)
        ref = conv2d(x, w, stride)
        assert ref.shape[1] == out, (size, kernel, stride)
        assert lo + hi == max((out - 1) * stride + kernel - size, 0)
