"""SpikingFFN (IMPULSE layer inside the LM stack): shapes, grads, rates,
and end-to-end trainability of a spiking-FFN transformer."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import (ParallelConfig, ShapeConfig, SpikingConfig,
                                get_config, reduced_config)
from repro.models import io_spec, lm
from repro.models.spiking_ffn import init_spiking_ffn, spiking_ffn


def _cfg():
    base = reduced_config(get_config("llama3.2-1b"))
    return dataclasses.replace(
        base, spiking=SpikingConfig(neuron="rmp", timesteps=6, threshold=0.5))


def test_spiking_ffn_forward_rate_and_grads():
    cfg = _cfg()
    p = init_spiking_ffn(jax.random.PRNGKey(0), cfg.d_model, cfg.d_ff,
                         dtype=jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model)) * 0.5

    def f(p):
        out, rate = spiking_ffn(x, p, cfg)
        return jnp.sum(out ** 2), rate

    (val, rate), g = jax.value_and_grad(f, has_aux=True)(p)
    assert 0.0 <= float(rate) <= 1.0
    total = sum(float(jnp.abs(t).sum()) for t in jax.tree_util.tree_leaves(g))
    assert np.isfinite(total) and total > 0     # surrogate grads flow


def test_spiking_lm_trains():
    cfg = _cfg()
    shape = ShapeConfig("t", 32, 2, "train")
    par = ParallelConfig(remat="none", fsdp=False, seq_parallel=False)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = io_spec.materialize(io_spec.train_batch_spec(cfg, shape))
    (loss, aux), grads = jax.jit(jax.value_and_grad(
        lambda p, b: lm.loss_fn(p, b, cfg, par), has_aux=True))(params, batch)
    assert np.isfinite(float(loss))
    assert float(aux["aux"]) > 0                # spikes fired somewhere
    gn = sum(float(jnp.abs(t.astype(jnp.float32)).sum())
             for t in jax.tree_util.tree_leaves(grads))
    assert np.isfinite(gn) and gn > 0
