"""The CI benchmark regression gate: parsing and gating policy.

The gate's contract: skipped-work fractions may not drop (one-sided,
absolute tolerance), instruction counts and calibrated energy numbers may
not drift (two-sided, relative tolerance), wall-clock and workload stats
never fail a run, and losing a baseline row is itself a failure.
"""
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "tools"))

import bench_gate  # noqa: E402


def _payload(rows):
    return {"mode": "quick", "failures": 0,
            "rows": [{"name": n, "us_per_call": "0.0", "derived": d}
                     for n, d in rows]}


def test_parse_row_units_and_lists():
    vals = bench_gate.parse_row(
        "energy=1.80pJ err=0.5% speedup=3.21x conv_skipped_tiles=0.040/0.020 "
        "flexible=IF+LIF+RMP na=n/a instr=49276")
    assert vals["energy"] == 1.80 and vals["err"] == 0.5
    assert vals["speedup"] == 3.21 and vals["instr"] == 49276
    assert vals["conv_skipped_tiles"] == [0.040, 0.020]
    assert "flexible" not in vals and "na" not in vals


def test_skip_fraction_drop_fails_gain_notes():
    base = _payload([("g", "tile=0.500 block8=0.300 events=0.850")])
    ok = _payload([("g", "tile=0.480 block8=0.400 events=0.850")])
    fails, notes = bench_gate.compare(ok, base)
    assert not fails
    assert any("block8 improved" in n for n in notes)
    bad = _payload([("g", "tile=0.300 block8=0.300 events=0.850")])
    fails, _ = bench_gate.compare(bad, base)
    assert len(fails) == 1 and "tile" in fails[0]


def test_instr_drift_two_sided_wallclock_ignored():
    base = _payload([("w", "instr=10000 dense_us=100.0 measured_s=0.5")])
    ok = _payload([("w", "instr=10100 dense_us=900.0 measured_s=0.9")])
    fails, _ = bench_gate.compare(ok, base)
    assert not fails                       # 1% instr, wall-clock/stats free
    for drift in ("10300", "9700"):
        bad = _payload([("w", f"instr={drift} dense_us=100.0 "
                             "measured_s=0.5")])
        fails, _ = bench_gate.compare(bad, base)
        assert len(fails) == 1 and "instr" in fails[0]


def test_missing_row_and_failed_row_fail():
    base = _payload([("a", "tile=0.5"), ("b", "instr=5")])
    cur = _payload([("a", "tile=0.5"), ("c_FAILED", "RuntimeError('x')"),
                    ("new_row", "tile=0.9")])
    fails, notes = bench_gate.compare(cur, base)
    assert any("missing from current run" in f for f in fails)
    assert any("crashed" in f for f in fails)
    assert any("new row" in n for n in notes)


def test_fig11_calibrated_keys_are_gated():
    """The fig11 row spellings (measured_EDP / measured_reduction /
    reduction_vs_dense) must hit the calibrated two-sided gate, not fall
    through to report-only."""
    base = _payload([("f11", "measured_EDP=7.301e-20Js "
                             "measured_reduction=99.7% "
                             "reduction_vs_dense=99.7%")])
    ok = _payload([("f11", "measured_EDP=7.30e-20Js "
                           "measured_reduction=99.5% "
                           "reduction_vs_dense=99.7%")])
    fails, _ = bench_gate.compare(ok, base)
    assert not fails
    bad = _payload([("f11", "measured_EDP=9.0e-20Js "
                            "measured_reduction=80.0% "
                            "reduction_vs_dense=99.7%")])
    fails, _ = bench_gate.compare(bad, base)
    assert {f.split()[1].split("=")[0] for f in fails} == {
        "measured_EDP", "measured_reduction"}


def test_slash_list_length_change_fails():
    """Losing an element of a slash-list (a conv layer stopped reporting)
    is a coverage regression, not a pass-by-truncation."""
    base = _payload([("c", "conv_skipped_tiles=0.040/0.020")])
    cur = _payload([("c", "conv_skipped_tiles=0.040")])
    fails, _ = bench_gate.compare(cur, base)
    assert len(fails) == 1 and "value count changed" in fails[0]


def test_missing_key_fails():
    base = _payload([("a", "tile=0.5 events=0.9")])
    cur = _payload([("a", "tile=0.5")])
    fails, _ = bench_gate.compare(cur, base)
    assert len(fails) == 1 and "'events'" in fails[0]


def test_write_baseline_refuses_crashed_payload(tmp_path):
    """A run with crashed rows must never become the baseline — compare()
    skips *_FAILED baseline rows, so adopting one would silently drop
    coverage."""
    import json
    cur = tmp_path / "cur.json"
    base = tmp_path / "base.json"
    cur.write_text(json.dumps(_payload([("g_FAILED", "RuntimeError('x')")])))
    rc = bench_gate.main([str(cur), str(base), "--write-baseline"])
    assert rc == 1 and not base.exists()
    cur.write_text(json.dumps(_payload([("g", "tile=0.5")])))
    rc = bench_gate.main([str(cur), str(base), "--write-baseline"])
    assert rc == 0 and base.exists()


def test_serve_skip_fraction_is_gated():
    """The serving benchmark's pooled row-skip fraction (skipped_rows) is a
    one-sided gated key like the other skip fractions; its throughput
    numbers stay report-only."""
    base = _payload([("serve_snn_s85",
                      "frames_per_s=500.0 words_per_s=50.0 "
                      "skipped_rows=0.850 instr=67054 offered=0.85 reqs=4")])
    ok = _payload([("serve_snn_s85",
                    "frames_per_s=100.0 words_per_s=10.0 "
                    "skipped_rows=0.900 instr=67054 offered=0.85 reqs=4")])
    fails, _ = bench_gate.compare(ok, base)
    assert not fails                      # slower wall-clock never fails
    bad = _payload([("serve_snn_s85",
                     "frames_per_s=500.0 words_per_s=50.0 "
                     "skipped_rows=0.700 instr=67054 offered=0.85 reqs=4")])
    fails, _ = bench_gate.compare(bad, base)
    assert len(fails) == 1 and "skipped_rows" in fails[0]
