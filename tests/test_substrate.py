"""Substrate tests: optimizers, checkpointing (+restart), data pipeline,
train loop fault tolerance, serving engine."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs.base import (ParallelConfig, RunConfig, ShapeConfig,
                                get_config, reduced_config)
from repro.data import ShardedLoader, lm_batch_fn, make_sentiment_vocab, sentiment_batch
from repro.models import lm
from repro.optim import (adafactor, apply_updates, clip_by_global_norm,
                         make_optimizer)
from repro.serve import Request, ServeEngine
from repro.train import LoopConfig, init_train_state, make_train_step, train_loop


# ---------------------------------------------------------------------------
# optimizers
# ---------------------------------------------------------------------------

def _quad_params():
    return {"w": jnp.array([3.0, -2.0]), "b": jnp.array([[1.0, 1.0], [1.0, 1.0]])}


@pytest.mark.parametrize("name", ["sgd", "adam", "adamw", "adafactor"])
def test_optimizers_minimize_quadratic(name):
    params = _quad_params()
    opt = make_optimizer(name, lr=0.1, weight_decay=0.0)
    state = opt.init(params)

    def loss(p):
        return jnp.sum(p["w"] ** 2) + jnp.sum(p["b"] ** 2)

    l0 = float(loss(params))
    for _ in range(60):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 0.1 * l0


def test_adafactor_memory_is_factored():
    p = {"w": jnp.zeros((64, 32))}
    st = adafactor(1e-3).init(p)
    leaves = jax.tree_util.tree_leaves(st["v"])
    assert sum(x.size for x in leaves) == 64 + 32            # not 64*32


def test_grad_clip():
    g = {"a": jnp.full((10,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) > 100
    _, n2 = clip_by_global_norm(clipped, 1e9)
    assert float(n2) == pytest.approx(1.0, rel=1e-3)


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.float32(1.5)}}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree_util.tree_map(lambda x: x + s, tree), blocking=True)
    assert mgr.all_steps() == [2, 3]                         # keep=2 gc'd step 1
    step, restored = mgr.restore(like=tree)
    assert step == 3
    np.testing.assert_array_equal(np.asarray(restored["a"]),
                                  np.asarray(tree["a"]) + 3)


def test_checkpoint_atomicity_tmp_never_visible(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros((256, 256))}
    mgr.save(7, tree)                                        # async
    mgr.wait()
    assert (tmp_path / "step_7").exists()
    assert not list(tmp_path.glob("*.tmp"))


def test_checkpoint_elastic_restore_resharded(tmp_path):
    """Restore onto a different sharding than saved (elastic restart)."""
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
    mgr.save(1, tree, blocking=True)
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1,), ("data",))
    from jax.sharding import NamedSharding, PartitionSpec as P
    sh = {"w": NamedSharding(mesh, P("data", None))}
    step, restored = mgr.restore(like=tree, shardings=sh)
    assert restored["w"].sharding == sh["w"]
    np.testing.assert_array_equal(np.asarray(restored["w"]), np.asarray(tree["w"]))


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------

def test_loader_deterministic_and_sharded():
    fn = lm_batch_fn(vocab=97, global_batch=8, seq=16, seed=3)
    a = fn(5, 0, 2)
    b = fn(5, 0, 2)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # pure in step
    c = fn(5, 1, 2)
    assert not np.array_equal(a["tokens"], c["tokens"])      # shards differ
    assert a["tokens"].shape == (4, 16)                      # local = global/shards


def test_loader_prefetch_and_resume():
    fn = lm_batch_fn(vocab=17, global_batch=2, seq=8, seed=0)
    l1 = ShardedLoader(fn, start_step=0)
    batches1 = [next(l1) for _ in range(4)]
    l1.close()
    l2 = ShardedLoader(fn, start_step=2)                     # restart mid-stream
    s, b = next(l2)
    l2.close()
    assert s == 2
    np.testing.assert_array_equal(b["tokens"], batches1[2][1]["tokens"])


def test_sentiment_task_needs_sequence():
    """Negators flip following words: per-word linear readout can't saturate."""
    ds = make_sentiment_vocab()
    x, y = sentiment_batch(ds, 512, 12, seed=1)
    assert x.shape == (512, 12, 100)
    assert 0.3 < y.mean() < 0.7                              # balanced-ish


# ---------------------------------------------------------------------------
# train loop fault tolerance
# ---------------------------------------------------------------------------

def _tiny_run():
    cfg = reduced_config(get_config("llama3.2-1b"))
    shape = ShapeConfig("t", 32, 4, "train")
    return RunConfig(model=cfg, shape=shape,
                     parallel=ParallelConfig(remat="none", fsdp=False,
                                             seq_parallel=False),
                     optimizer="adamw", learning_rate=1e-3, warmup_steps=2)


def test_train_loop_checkpoint_restart(tmp_path):
    run = _tiny_run()
    state, opt = init_train_state(jax.random.PRNGKey(0), run, total_steps=8)
    step_fn = jax.jit(make_train_step(run, opt))
    fn = lm_batch_fn(run.model.vocab_size, 4, 32, seed=0)

    def mk_loader(start=0):
        return ShardedLoader(lambda s, i, n: {k: jnp.asarray(v) for k, v in
                                              fn(s, i, n).items()},
                             start_step=start)

    cfg = LoopConfig(total_steps=4, ckpt_every=2, ckpt_dir=str(tmp_path),
                     log_every=1)
    r1 = train_loop(step_fn, state, mk_loader(), cfg)
    assert int(r1.state.step) == 4

    # "crash" and restart: fresh state, must resume from the checkpoint
    state2, _ = init_train_state(jax.random.PRNGKey(0), run, total_steps=8)
    cfg2 = LoopConfig(total_steps=6, ckpt_every=2, ckpt_dir=str(tmp_path),
                      log_every=1)
    r2 = train_loop(step_fn, state2, mk_loader(), cfg2)
    assert r2.resumed_from == 4
    assert int(r2.state.step) == 6


def test_train_loss_decreases():
    run = _tiny_run()
    state, opt = init_train_state(jax.random.PRNGKey(0), run, total_steps=30)
    step_fn = jax.jit(make_train_step(run, opt))
    fn = lm_batch_fn(run.model.vocab_size, 4, 32, seed=0)
    losses = []
    for s in range(20):
        batch = {k: jnp.asarray(v) for k, v in fn(s, 0, 1).items()}
        state, m = step_fn(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1


def test_microbatch_accumulation_matches_full_batch():
    run = _tiny_run()
    state, opt = init_train_state(jax.random.PRNGKey(0), run, total_steps=8)
    fn = lm_batch_fn(run.model.vocab_size, 4, 32, seed=0)
    batch = {k: jnp.asarray(v) for k, v in fn(0, 0, 1).items()}
    s_full, m_full = jax.jit(make_train_step(run, opt))(state, batch)
    run_mb = run.replace(parallel=ParallelConfig(remat="none", fsdp=False,
                                                 seq_parallel=False,
                                                 microbatches=2))
    s_mb, m_mb = jax.jit(make_train_step(run_mb, opt))(state, batch)
    assert float(m_full["loss"]) == pytest.approx(float(m_mb["loss"]), rel=2e-2)
    w1 = jax.tree_util.tree_leaves(s_full.params)[0]
    w2 = jax.tree_util.tree_leaves(s_mb.params)[0]
    np.testing.assert_allclose(np.asarray(w1, np.float32),
                               np.asarray(w2, np.float32), atol=5e-2)


# ---------------------------------------------------------------------------
# serving engine
# ---------------------------------------------------------------------------

def test_serve_engine_continuous_batching():
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.default_rng(0)
    for rid in range(5):                                     # > slots: queueing
        eng.submit(Request(rid=rid, prompt=rng.integers(0, 64, 6),
                           max_new_tokens=4))
    done = eng.run_until_drained()
    assert len(done) == 5
    for r in done:
        assert len(r.out_tokens) == 4
        assert all(0 <= t < cfg.vocab_size for t in r.out_tokens)


def test_serve_engine_matches_manual_decode():
    """Engine output == manual prefill+decode for a single request."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    prompt = np.asarray([5, 9, 2, 7], np.int64)
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=3))
    done = eng.run_until_drained()
    par = ParallelConfig(remat="none")
    logits, cache = lm.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                               cfg, 32, par)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(2):
        logits, cache = lm.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache, cfg, par)
        toks.append(int(jnp.argmax(logits[0])))
    assert done[0].out_tokens == toks


def test_serve_engine_max_new_tokens_one():
    """Regression: max_new_tokens=1 must emit exactly one token (the prefill
    token), not run a spurious decode tick — the request finalizes at admit."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=32)
    rng = np.random.default_rng(1)
    for rid, n in enumerate([1, 1, 3, 0]):       # finalize-at-admit + normal mix
        eng.submit(Request(rid=rid, prompt=rng.integers(0, 64, 5),
                           max_new_tokens=n))
    done = eng.run_until_drained()
    assert sorted(len(r.out_tokens) for r in done) == [0, 1, 1, 3]
    assert all(len(r.out_tokens) == r.max_new_tokens for r in done)


def test_serve_engine_eos_at_prefill():
    """Regression: a request whose prefill token already is eos must stop
    there instead of decoding past eos."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    prompt = np.asarray([5, 9, 2, 7], np.int64)
    par = ParallelConfig(remat="none")
    logits, _ = lm.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                           cfg, 32, par)
    eos = int(jnp.argmax(logits[0]))             # force eos at prefill
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=32)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=8, eos_id=eos))
    done = eng.run_until_drained()
    assert done[0].out_tokens == [eos]


def test_serve_engine_single_slot_lane_scatter():
    """Regression: with batch_slots=1 the prefill cache-lane scatter must
    resolve the batch axis structurally (every size-1 axis 'matches' a
    shape-based guess); the single-slot engine must equal manual decode."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    prompt = np.asarray([3, 1, 4, 1], np.int64)
    eng = ServeEngine(params, cfg, batch_slots=1, max_len=32)
    for rid in range(2):                         # sequential through one slot
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=3))
    done = eng.run_until_drained()
    par = ParallelConfig(remat="none")
    logits, cache = lm.prefill(params, {"tokens": jnp.asarray(prompt[None])},
                               cfg, 32, par)
    toks = [int(jnp.argmax(logits[0]))]
    for _ in range(2):
        logits, cache = lm.decode_step(
            params, jnp.asarray([[toks[-1]]], jnp.int32), cache, cfg, par)
        toks.append(int(jnp.argmax(logits[0])))
    assert len(done) == 2
    for r in done:
        assert r.out_tokens == toks


def test_serve_engine_one_dispatch_per_tick():
    """Regression (dispatch storm): a tick must issue exactly one device
    decode and one host->device token-buffer upload, independent of how
    many slots are active — the old per-slot ``.at[i, 0].set`` pattern
    dispatched one scatter per active slot per tick."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=3, max_len=64)
    counts = {"decode": 0, "upload": 0}
    decode, token_batch = eng._decode, eng._token_batch

    def counting_decode(*a):
        counts["decode"] += 1
        return decode(*a)

    def counting_token_batch():
        counts["upload"] += 1
        return token_batch()

    eng._decode = counting_decode
    eng._token_batch = counting_token_batch
    rng = np.random.default_rng(2)
    for rid in range(3):                         # all slots active
        eng.submit(Request(rid=rid, prompt=rng.integers(0, 64, 5),
                           max_new_tokens=6))
    for tick in range(1, 4):                     # slots stay active: 3 full
        assert eng.step() == 3                   # 3-slot decode ticks
        assert counts["decode"] == tick
        assert counts["upload"] == tick
    eng.run_until_drained()
    assert all(len(r.out_tokens) == 6 for r in eng.finished)
    # the token buffer itself is host memory: slot updates are free stores
    assert isinstance(eng.last_tokens, np.ndarray)


def test_serve_engine_undrained_raises():
    """Regression: hitting max_ticks with work still queued/active must not
    return a silently-partial finished list."""
    from repro.serve import EngineUndrained
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServeEngine(params, cfg, batch_slots=1, max_len=64)
    rng = np.random.default_rng(3)
    for rid in range(3):
        eng.submit(Request(rid=rid, prompt=rng.integers(0, 64, 4),
                           max_new_tokens=8))
    with pytest.raises(EngineUndrained) as ei:
        eng.run_until_drained(max_ticks=3)
    assert ei.value.pending >= 1
    assert len(ei.value.finished) < 3
    # the engine is resumable: a fresh drain finishes the remaining work
    done = eng.run_until_drained()
    assert sorted(r.rid for r in done) == [0, 1, 2]
    assert all(len(r.out_tokens) == 8 for r in done)


def test_serve_engine_prefill_length_bucketing():
    """Regression (unbounded jit cache): 20 distinct prompt lengths must
    compile at most 6 prefill variants (power-of-two buckets, pad + true-
    length mask), and bucketed prefill must stay exact — engine output
    equals manual unpadded prefill + decode."""
    cfg = reduced_config(get_config("llama3.2-1b"))
    params = lm.init_params(jax.random.PRNGKey(1), cfg, dtype=jnp.float32)
    assert all(cfg.is_attention_layer(i) for i in range(cfg.n_layers))
    eng = ServeEngine(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.default_rng(4)
    prompts = {plen: rng.integers(0, 64, plen) for plen in range(1, 21)}
    for rid, (plen, prompt) in enumerate(prompts.items()):
        eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=3))
    done = eng.run_until_drained()
    assert len(done) == 20
    assert len(eng._prefill_cache) <= 6
    par = ParallelConfig(remat="none")
    for rid, plen in [(0, 1), (6, 7), (19, 20)]:   # spot-check exactness
        r = next(d for d in done if d.rid == rid)
        logits, cache = lm.prefill(
            params, {"tokens": jnp.asarray(prompts[plen][None], jnp.int32)},
            cfg, 64, par)
        toks = [int(jnp.argmax(logits[0]))]
        for _ in range(2):
            logits, cache = lm.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache, cfg, par)
            toks.append(int(jnp.argmax(logits[0])))
        assert r.out_tokens == toks, (rid, plen)
