"""Network-fusion benchmark: per-layer kernel dispatch vs the fused network
kernel on the IMDB sentiment stack.

Two quantities per configuration:
  * wall-clock of the full T_total presentation (Pallas interpret on CPU
    containers — RELATIVE numbers; the TPU target is the real measurement);
  * estimated HBM bytes for V and inter-layer spikes, from the kernels'
    traffic models:
      per-layer dispatch: every layer round-trips its input+output rasters
        (T*B*N int8 each way) and writes V once per layer;
      fused net:          input raster in, final V out; inter-layer spikes
        and V never touch HBM (emit_rasters=False serving mode; accounting
        mode adds the raster stores back).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.kernels.fused_snn_net.ops import fused_snn_net
from repro.kernels.fused_snn_step.ops import fused_snn_layer

# IMDB deployment shapes: encoder(100) -> 128 -> 128 -> 1, 12 words x 10 steps
LAYERS = [(100, 128), (128, 128), (128, 1)]
T_TOTAL, B = 120, 8
THRESH, LEAK = 60, 2


def _per_layer(spikes, ws):
    cur = spikes
    for i, w in enumerate(ws[:-1]):
        cur, v = fused_snn_layer(cur, w, threshold=THRESH, leak=LEAK,
                                 neuron="rmp", interpret=True)
    # readout accumulate (wide)
    acc = jnp.einsum("tbn,no->bo", cur.astype(jnp.int32),
                     ws[-1].astype(jnp.int32))
    return acc


def _hbm_bytes(emit_rasters: bool, fused: bool) -> int:
    """int8 spike rasters + int32 V crossing HBM per inference batch."""
    bytes_ = T_TOTAL * B * LAYERS[0][0]                  # input raster (int8)
    for i, (n_in, n_out) in enumerate(LAYERS):
        is_readout = i == len(LAYERS) - 1
        if fused:
            if emit_rasters and not is_readout:
                bytes_ += T_TOTAL * B * n_out            # raster store
        else:
            # per-layer: output raster store + next layer's load, V write
            if not is_readout:
                bytes_ += 2 * T_TOTAL * B * n_out
            bytes_ += 4 * B * n_out                      # V leaves the kernel
    bytes_ += 4 * B * LAYERS[-1][1]                      # final V out
    return bytes_


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    spikes = jnp.asarray((rng.random((T_TOTAL, B, LAYERS[0][0])) < 0.1)
                         .astype(np.int8))
    ws = [jnp.asarray(rng.integers(-31, 32, shp).astype(np.int8))
          for shp in LAYERS]
    ths, lks = (THRESH, THRESH), (LEAK, LEAK)

    us_layer = time_call(lambda: _per_layer(spikes, ws))
    rows.append(emit("fusion_per_layer_dispatch", us_layer,
                     f"hbm_bytes={_hbm_bytes(True, fused=False)}"))
    us_acct = time_call(lambda: fused_snn_net(
        spikes, ws, thresholds=ths, leaks=lks, neuron="rmp",
        interpret=True, emit_rasters=True)[1][-1])
    rows.append(emit("fusion_fused_net_accounting", us_acct,
                     f"hbm_bytes={_hbm_bytes(True, fused=True)} "
                     f"speedup={us_layer/us_acct:.2f}x"))
    us_serve = time_call(lambda: fused_snn_net(
        spikes, ws, thresholds=ths, leaks=lks, neuron="rmp",
        interpret=True, emit_rasters=False)[1][-1])
    b_layer, b_serve = _hbm_bytes(True, False), _hbm_bytes(False, True)
    rows.append(emit("fusion_fused_net_serving", us_serve,
                     f"hbm_bytes={b_serve} "
                     f"hbm_reduction={(1 - b_serve/b_layer)*100:.1f}% "
                     f"speedup={us_layer/us_serve:.2f}x"))
    # numerical parity of the two dispatch strategies (same final readout V)
    v_layer = np.asarray(_per_layer(spikes, ws))
    v_fused = np.asarray(fused_snn_net(spikes, ws, thresholds=ths, leaks=lks,
                                       neuron="rmp", interpret=True,
                                       emit_rasters=False)[1][-1])
    rows.append(emit("fusion_parity", 0.0,
                     f"identical={bool(np.array_equal(v_layer, v_fused))}"))
    return rows


if __name__ == "__main__":
    run()
