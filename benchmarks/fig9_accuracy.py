"""Fig. 9b: SNN (29.3K params) vs 2-layer LSTM (247.8K params) on the
sentiment task. Validates the paper's relative claim: SNN within ~1% of the
LSTM at 8.5x fewer parameters. Synthetic structure-matched data when real
IMDB is absent (DESIGN.md §8.2)."""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_call
from repro.configs.impulse_snn import IMDB
from repro.core import snn
from repro.data import make_sentiment_vocab, sentiment_batch
from repro.models import lstm_baseline as lstm
from repro.optim import adamw, apply_updates

STEPS = 400
BATCH = 128
WORDS = 12
# DIET-SNN threshold init 0.5 (thresholds are trainable; lower init gives
# finer rate coding over 10 timesteps)
IMDB_T = dataclasses.replace(IMDB, spiking=dataclasses.replace(IMDB.spiking, threshold=0.5))


def _train(loss_fn, params, lr=5e-3, steps=STEPS, seed=0):
    opt = adamw(lambda s: lr, weight_decay=0.0)
    opt_state = opt.init(params)
    ds = make_sentiment_vocab(seed)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, _), g = jax.value_and_grad(loss_fn, has_aux=True)(params, x, y)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    for s in range(steps):
        xb, yb = sentiment_batch(ds, BATCH, WORDS, seed=s)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb),
                                    jnp.asarray(yb))
    xb, yb = sentiment_batch(ds, 1024, WORDS, seed=99_991)
    return params, jnp.asarray(xb), jnp.asarray(yb)


def run() -> list[str]:
    rows = []
    # --- SNN ---
    p0 = snn.init_fc_snn(jax.random.PRNGKey(0), IMDB_T)
    n_snn = snn.param_count(p0)
    p, x, y = _train(lambda p, x, y: snn.sentiment_loss(p, x, y, IMDB_T), p0)
    us = time_call(lambda: snn.sentiment_apply(p, x[:64], IMDB_T)[0])
    logits, _ = snn.sentiment_apply(p, x, IMDB_T)
    acc_snn = float(jnp.mean((logits > 0) == (y > 0.5)))
    # deployed integer program via the network pipeline
    from repro.core import pipeline
    program = pipeline.compile_network(IMDB_T, p, domain="int")
    logits_i = pipeline.run_network(
        program, pipeline.present_words(x, IMDB_T.timesteps),
        "int_ref").logits[:, 0]
    acc_int = float(jnp.mean((logits_i > 0) == (y > 0.5)))
    rows.append(emit("fig9b_snn", us,
                     f"params={n_snn} acc={acc_snn:.4f} acc_int={acc_int:.4f} "
                     f"paper_params=29.3K paper_acc=0.8815"))
    # --- LSTM baseline ---
    l0 = lstm.init_lstm(jax.random.PRNGKey(1))
    n_lstm = lstm.param_count(l0)
    lp, x, y = _train(lambda p, x, y: lstm.lstm_loss(p, x, y), l0, steps=STEPS)
    us = time_call(lambda: lstm.lstm_apply(lp, x[:64]))
    acc_lstm = float(jnp.mean((lstm.lstm_apply(lp, x) > 0) == (y > 0.5)))
    rows.append(emit("fig9b_lstm", us,
                     f"params={n_lstm} acc={acc_lstm:.4f} "
                     f"ratio={n_lstm/n_snn:.1f}x paper_ratio=8.5x "
                     f"gap={abs(acc_lstm-acc_snn)*100:.2f}pp (paper ~1pp)"))
    return rows


if __name__ == "__main__":
    run()
