"""Fig. 6: energy per neuron update for IF / LIF / RMP via the in-memory
instruction sequences, plus wall time of the bit-accurate sequence."""
from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_call
from repro.core import energy, isa, macro

PAPER = {"if": 1.81, "lif": 2.67, "rmp": 1.68}


def run() -> list[str]:
    rows = []
    rng = np.random.default_rng(0)
    wq = rng.integers(-31, 32, (isa.MACRO_IN, isa.MACRO_OUT)).astype(np.int8)
    for neuron in ("if", "lif", "rmp"):
        bm = macro.BitMacro.from_weights(wq, threshold=50, leak=2)
        us = time_call(lambda bm=bm, n=neuron: bm.neuron_update(0, n),
                       repeats=3, warmup=1)
        pj = energy.neuron_update_energy_pj(neuron)
        rows.append(emit(
            f"fig6_{neuron}_update", us,
            f"energy={pj:.2f}pJ paper={PAPER[neuron]}pJ "
            f"err={abs(pj-PAPER[neuron])/PAPER[neuron]*100:.1f}%"))
    return rows


if __name__ == "__main__":
    run()
