"""Fig. 11: (a) per-layer spike sparsity per timestep of the trained SNN;
(b) EDP per-neuron per-timestep vs input sparsity — the event-driven claim:
~97.4% EDP reduction at 85% sparsity."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.configs.impulse_snn import IMDB
from repro.core import energy, pipeline, snn
from repro.data import make_sentiment_vocab, sentiment_batch
from repro.optim import adamw, apply_updates


def run() -> list[str]:
    rows = []
    # quick-train the SNN so sparsity stats are meaningful
    ds = make_sentiment_vocab(0)
    params = snn.init_fc_snn(jax.random.PRNGKey(0), IMDB)
    opt = adamw(lambda s: 2e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, _), g = jax.value_and_grad(snn.sentiment_loss, has_aux=True)(
            params, x, y, IMDB)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    for s in range(80):
        xb, yb = sentiment_batch(ds, 64, 12, seed=s)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb),
                                    jnp.asarray(yb))

    xb, _ = sentiment_batch(ds, 256, 12, seed=77_777)
    # deployed integer program via the network pipeline (int_ref backend)
    program = pipeline.compile_network(IMDB, params, domain="int")
    xs_small = pipeline.present_words(jnp.asarray(xb[:32]), IMDB.timesteps)
    us = time_call(lambda: pipeline.run_network(program, xs_small,
                                                "int_ref").logits)
    xs = pipeline.present_words(jnp.asarray(xb), IMDB.timesteps)
    res = pipeline.run_network(program, xs, "int_ref")
    rasters = res.rasters
    counts = pipeline.count_network_instructions(program, rasters)
    spars = [1.0 - float(np.asarray(r).mean()) for r in rasters]
    overall = float(np.mean(spars))
    rows.append(emit(
        "fig11a_layer_sparsity", us,
        f"enc={spars[0]:.3f} fc1={spars[1]:.3f} fc2={spars[2]:.3f} "
        f"overall={overall:.3f} paper~0.85"))

    # (b) EDP vs sparsity curve from the calibrated model
    for s in (0.0, 0.25, 0.5, 0.75, 0.85, 0.95):
        edp = energy.edp_per_neuron_per_timestep(s)
        red = energy.edp_reduction(s)
        rows.append(emit(f"fig11b_sparsity_{int(s*100):02d}", 0.0,
                         f"EDP={edp:.3e}Js reduction={red*100:.1f}%"))
    rows.append(emit("fig11b_claim", 0.0,
                     f"reduction@85%={energy.edp_reduction(0.85)*100:.2f}% "
                     f"paper=97.4%"))
    # energy of the measured workload at its MEASURED sparsity
    e = energy.snn_energy_j(counts)
    rows.append(emit("fig11_workload_energy", 0.0,
                     f"instr={counts.total} energy={e*1e9:.2f}nJ for 256 inferences"))
    return rows


if __name__ == "__main__":
    run()
