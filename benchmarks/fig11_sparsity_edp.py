"""Fig. 11: (a) per-layer spike sparsity per timestep of the trained SNN;
(b) EDP per-neuron per-timestep vs input sparsity — the event-driven claim:
~97.4% EDP reduction at 85% sparsity. The analytic curve
(`energy.edp_per_neuron_per_timestep`) is paired with a *measured* curve:
synthetic encoder rasters at each swept sparsity run through the trained
integer program, instruction cycles counted from the resulting rasters
(`pipeline.sparsity_report`), EDP normalized per macro-timestep
(`energy.measured_edp_per_neuron_timestep`) — so the claim is checked
against executed event counts, not just the closed form."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from benchmarks.sparsity_gating import synthetic_raster
from repro.configs.impulse_snn import IMDB
from repro.core import energy, pipeline, snn
from repro.data import make_sentiment_vocab, sentiment_batch
from repro.optim import adamw, apply_updates


def run() -> list[str]:
    rows = []
    # quick-train the SNN so sparsity stats are meaningful
    ds = make_sentiment_vocab(0)
    params = snn.init_fc_snn(jax.random.PRNGKey(0), IMDB)
    opt = adamw(lambda s: 2e-3, weight_decay=0.0)
    opt_state = opt.init(params)

    @jax.jit
    def step(params, opt_state, x, y):
        (loss, _), g = jax.value_and_grad(snn.sentiment_loss, has_aux=True)(
            params, x, y, IMDB)
        upd, opt_state = opt.update(g, opt_state, params)
        return apply_updates(params, upd), opt_state, loss

    for s in range(80):
        xb, yb = sentiment_batch(ds, 64, 12, seed=s)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(xb),
                                    jnp.asarray(yb))

    xb, _ = sentiment_batch(ds, 256, 12, seed=77_777)
    # deployed integer program via the network pipeline (int_ref backend)
    program = pipeline.compile_network(IMDB, params, domain="int")
    xs_small = pipeline.present_words(jnp.asarray(xb[:32]), IMDB.timesteps)
    us = time_call(lambda: pipeline.run_network(program, xs_small,
                                                "int_ref").logits)
    xs = pipeline.present_words(jnp.asarray(xb), IMDB.timesteps)
    res = pipeline.run_network(program, xs, "int_ref")
    rasters = res.rasters
    counts = pipeline.count_network_instructions(program, rasters)
    spars = [1.0 - float(np.asarray(r).mean()) for r in rasters]
    overall = float(np.mean(spars))
    rows.append(emit(
        "fig11a_layer_sparsity", us,
        f"enc={spars[0]:.3f} fc1={spars[1]:.3f} fc2={spars[2]:.3f} "
        f"overall={overall:.3f} paper~0.85"))

    # (b) EDP vs sparsity: analytic curve next to the measured one.
    # Measured: a synthetic encoder raster at each swept sparsity executes
    # the trained fc stack; counts come from the resulting rasters.
    rng = np.random.default_rng(11)
    T_syn, B_syn = 48, 8
    for s in (0.0, 0.25, 0.5, 0.75, 0.85, 0.95):
        edp = energy.edp_per_neuron_per_timestep(s)
        red = energy.edp_reduction(s)
        enc = jnp.asarray(synthetic_raster(rng, T_syn, B_syn,
                                           program.layers[0].n_out, s))
        full_rasters, _, _ = pipeline.run_stack_from_raster(program, enc)
        rep = pipeline.sparsity_report(program, full_rasters)
        medp = energy.measured_edp_per_neuron_timestep(
            rep.instruction_counts(), rep.macro_timesteps)
        rows.append(emit(
            f"fig11b_sparsity_{int(s*100):02d}", 0.0,
            f"EDP={edp:.3e}Js reduction={red*100:.1f}% "
            f"measured_EDP={medp:.3e}Js "
            f"measured_s={rep.overall_sparsity:.3f}"))
    rows.append(emit("fig11b_claim", 0.0,
                     f"reduction@85%={energy.edp_reduction(0.85)*100:.2f}% "
                     f"paper=97.4%"))
    # the trained workload at its MEASURED sparsity: energy plus the
    # raster-derived EDP row, next to the analytic value at that sparsity
    rep = pipeline.sparsity_report(program, rasters)
    counts_rep = pipeline.count_network_instructions(program, report=rep)
    if counts_rep != counts:                      # two counting paths agree
        raise RuntimeError(f"counting paths diverged: report {counts_rep} "
                           f"vs rasters {counts}")
    medp = energy.measured_edp_per_neuron_timestep(counts_rep,
                                                   rep.macro_timesteps)
    dense = energy.edp_per_neuron_per_timestep(0.0)
    rows.append(emit(
        "fig11_measured_edp", 0.0,
        f"measured_EDP={medp:.3e}Js analytic@s={energy.edp_per_neuron_per_timestep(rep.overall_sparsity):.3e}Js "
        f"s_measured={rep.overall_sparsity:.3f} "
        f"reduction_vs_dense={(1 - medp/dense)*100:.1f}%"))
    # row-granular skip accounting: executed + skipped == dense, so the
    # measured EDP reduction is the Fig. 11b claim computed from what the
    # workload actually skipped (silent rows), not tile-gate statistics
    red = energy.measured_edp_reduction(counts_rep,
                                        rep.skipped_instruction_counts())
    rows.append(emit(
        "fig11_rowskip_reduction", 0.0,
        f"measured_reduction={red*100:.1f}% "
        f"analytic@s={energy.edp_reduction(rep.overall_sparsity)*100:.1f}% "
        f"s={rep.overall_sparsity:.3f}"))
    e = energy.snn_energy_j(counts)
    rows.append(emit("fig11_workload_energy", 0.0,
                     f"instr={counts.total} energy={e*1e9:.2f}nJ for 256 inferences"))
    return rows


if __name__ == "__main__":
    run()
