"""Static-analyzer verdicts for the committed configs, as benchmark rows.

Runs the range pass + kernel-contract pass + trace cost model
(repro.analysis) over the two paper configs and emits one row per
(config, backend): the proven ``max_safe_frames`` horizon, the per-call
VMEM residency, and the traced ``macs``/``hbm_bytes`` of the real batch
dispatch land in the bench artifact next to the timing rows, so the perf
trajectory and the safety envelope travel together. The cost tokens are
exact functions of the compiled jaxpr — `tools/bench_gate.py` gates them
at zero tolerance. A config the analyzer rejects emits a
``*_FAILED``-style verdict row (and `run` raises, which benchmarks/run.py
records as a failure)."""
from __future__ import annotations

import jax

from benchmarks.common import emit


def run(quick: bool = False) -> list[str]:
    del quick  # analysis is static — the full check IS the quick check
    from repro.analysis import PALLAS_BACKENDS, check_kernel_contracts, \
        check_program, check_trace
    from repro.configs.impulse_snn import IMDB, MNIST
    from repro.core import pipeline, snn

    key = jax.random.PRNGKey(0)
    rows = []
    for cfg, init in ((IMDB, snn.init_fc_snn), (MNIST, snn.init_lenet_snn)):
        program = pipeline.compile_network(cfg, init(key, cfg),
                                           domain="int", validate=False)
        ranges = check_program(program)
        safe = ranges.max_safe_frames
        rows.append(emit(
            f"analysis_{cfg.arch_id}_range", 0,
            f"layers={len(ranges.layers)} clamp={program.clamp_mode} "
            f"max_safe_frames={safe}"))
        for backend in PALLAS_BACKENDS:
            rep = check_kernel_contracts(program, backend)
            cost = check_trace(program, backend, surfaces=("batch",)).cost
            rows.append(emit(
                f"analysis_{cfg.arch_id}_{backend}", 0,
                f"checks={len(rep.checks)} vmem_bytes={rep.vmem_bytes} "
                f"macs={cost.macs} hbm_bytes={cost.hbm_bytes}"))
    return rows
