# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper figure/table plus the roofline
report derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run
"""
from __future__ import annotations

import sys
import time


def main() -> None:
    from benchmarks import (fig6_neuron_energy, fig9_accuracy, fig9_efficiency,
                            fig11_sparsity_edp, pipeline_fusion, roofline,
                            table1_comparison)
    print("name,us_per_call,derived")
    t0 = time.time()
    mods = [("fig6", fig6_neuron_energy), ("fig9_eff", fig9_efficiency),
            ("fig9_acc", fig9_accuracy), ("fig11", fig11_sparsity_edp),
            ("fusion", pipeline_fusion), ("table1", table1_comparison),
            ("roofline", roofline)]
    failures = 0
    for name, mod in mods:
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}_FAILED,0,{e!r}")
    print(f"# total {time.time()-t0:.0f}s, failures={failures}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
