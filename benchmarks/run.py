# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness: one module per paper figure/table plus the roofline
report derived from the dry-run artifacts.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--json PATH]

``--quick`` runs the fast smoke subset (analytic tables + a reduced
sparsity-gating sweep) — the per-PR CI perf-trajectory probe. ``--json``
additionally writes the emitted rows as a JSON artifact (default
BENCH_quick.json / BENCH_full.json when the flag is given bare).
"""
from __future__ import annotations

import argparse
import inspect
import json
import sys
import time


def _run_mod(mod, quick: bool):
    if quick and "quick" in inspect.signature(mod.run).parameters:
        return mod.run(quick=True)
    return mod.run()


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast smoke subset (CI perf trajectory)")
    ap.add_argument("--json", nargs="?", const="", default=None,
                    help="write rows to a JSON artifact (optional path)")
    args = ap.parse_args(argv)

    from benchmarks import (analysis_check, fig6_neuron_energy, fig9_accuracy,
                            fig9_efficiency, fig11_sparsity_edp,
                            pipeline_fusion, roofline, serve_snn,
                            sparsity_gating, table1_comparison)
    print("name,us_per_call,derived")
    t0 = time.time()
    if args.quick:
        mods = [("fig6", fig6_neuron_energy), ("table1", table1_comparison),
                ("fig9_eff", fig9_efficiency), ("gating", sparsity_gating),
                ("serve_snn", serve_snn), ("analysis", analysis_check)]
    else:
        mods = [("fig6", fig6_neuron_energy), ("fig9_eff", fig9_efficiency),
                ("fig9_acc", fig9_accuracy), ("fig11", fig11_sparsity_edp),
                ("gating", sparsity_gating), ("serve_snn", serve_snn),
                ("fusion", pipeline_fusion), ("table1", table1_comparison),
                ("roofline", roofline), ("analysis", analysis_check)]
    failures, rows = 0, []
    for name, mod in mods:
        try:
            rows += _run_mod(mod, args.quick) or []
        except Exception as e:  # noqa: BLE001
            failures += 1
            row = f"{name}_FAILED,0,{e!r}"
            rows.append(row)
            print(row)
    elapsed = time.time() - t0
    print(f"# total {elapsed:.0f}s, failures={failures}")
    if args.json is not None:
        path = args.json or ("BENCH_quick.json" if args.quick
                             else "BENCH_full.json")
        payload = {"mode": "quick" if args.quick else "full",
                   "elapsed_s": round(elapsed, 1), "failures": failures,
                   "rows": [dict(zip(("name", "us_per_call", "derived"),
                                     r.split(",", 2))) for r in rows]}
        with open(path, "w") as f:
            json.dump(payload, f, indent=1)
        print(f"# wrote {path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
