"""Fig. 9a + Table I supply columns: power and energy-efficiency per
instruction across operating points; wall time of the fused Pallas kernel for
the equivalent work (TPU-target path, interpret mode on CPU); and an executed
conv workload (LeNet-style int program) whose instruction counts come from
the im2col-lowered execution pipeline, not the analytic pass."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import energy
from repro.core.isa import InstrCount
from repro.kernels.fused_snn_step.ops import fused_snn_layer

PAPER_POINTS = {  # vdd -> (freq MHz, power mW, TOPS/W)
    "A(0.7V)": (66.67, 0.072, 0.91),
    "D(0.85V)": (200.0, 0.201, 0.99),
    "G(1.2V)": (500.0, 0.88, 0.57),
}


def _conv_workload_row() -> str:
    """A LeNet5-mod-structured int conv program executed end to end on the
    word-level backend: per-inference energy from executed event counts
    (conv layers counted per (timestep, example, output position) frame)."""
    from repro.configs.base import SpikingConfig
    from repro.configs.impulse_snn import SNNModelConfig
    from repro.core import pipeline, snn
    cfg = SNNModelConfig(
        arch_id="lenet-bench", conv_spec=((8, 3, 1), (12, 3, 2)),
        in_shape=(12, 12, 1), layer_sizes=(6 * 6 * 12, 64, 10),
        spiking=SpikingConfig(neuron="rmp", timesteps=4, threshold=1.0,
                              leak=0.0625, w_bits=6, v_bits=11),
        timesteps=4, task="multiclass")
    params = snn.init_lenet_snn(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.standard_normal((4, *cfg.in_shape)).astype(np.float32)) * 2
    program = pipeline.compile_network(cfg, params, domain="int")
    xs = pipeline.present_static(x, cfg.timesteps)
    cell = []                    # reuse the last timed run for accounting

    def _run():
        cell.append(pipeline.run_network(program, xs, "int_ref"))
        return cell[-1].v_out

    us = time_call(_run, repeats=2, warmup=1)
    res = cell[-1]
    counts = pipeline.count_network_instructions(program, res.rasters)
    rep = pipeline.sparsity_report(program, res.rasters)
    e_inf = energy.energy_per_inference_j(counts, x.shape[0])
    return emit(
        "fig9_conv_workload", us,
        f"instr={counts.total} E/inference={e_inf*1e9:.2f}nJ "
        f"measured_s={rep.overall_sparsity:.3f} "
        f"conv_frames={rep.frames_by_layer[0]}")


def run(quick: bool = False) -> list[str]:
    rows = []
    for pt in energy.OPERATING_POINTS:
        freq_mhz, p_mw, topsw = PAPER_POINTS[pt.name]
        rows.append(emit(
            f"fig9_point_{pt.name}", 1e6 / pt.freq_hz,
            f"freq={pt.freq_hz/1e6:.0f}MHz power={pt.power_w*1e3:.3f}mW "
            f"AccW2V={energy.tops_per_watt(pt):.2f}TOPS/W paper={topsw}"))
    # per-instruction efficiency at point D (Fig. 9a inset)
    d = energy.POINT_D
    for instr, topsw in energy.TOPS_W_D.items():
        e = energy.instr_energy_j(instr, d)
        rows.append(emit(f"fig9_instr_{instr}", 1e6 / d.freq_hz,
                         f"TOPS/W={topsw} E/op={e*1e12:.3f}pJ"))
    rows.append(_conv_workload_row())
    if quick:           # analytic tables + executed conv workload only
        return rows
    # the TPU-path equivalent: one fused timestep of a 128x128 layer
    rng = np.random.default_rng(0)
    spikes = jnp.asarray((rng.random((10, 8, 128)) < 0.15).astype(np.int8))
    wq = jnp.asarray(rng.integers(-31, 32, (128, 128)).astype(np.int8))
    us = time_call(lambda: fused_snn_layer(spikes, wq, threshold=60,
                                           neuron="rmp", interpret=True))
    events = int(np.asarray(spikes).sum())
    cnt = InstrCount(acc_w2v=2 * events, spike_check=2 * 8 * 10, acc_v2v=2 * 8 * 10)
    rows.append(emit("fig9_fused_kernel_10steps", us,
                     f"macro_energy={energy.sequence_energy_j(cnt)*1e9:.2f}nJ "
                     f"events={events}"))
    # the network-level fused kernel on the same work plus a second layer
    from repro.kernels.fused_snn_net.ops import fused_snn_net
    w2 = jnp.asarray(rng.integers(-31, 32, (128, 128)).astype(np.int8))
    us = time_call(lambda: fused_snn_net(
        spikes, [wq, w2], thresholds=(60,), leaks=(0,), neuron="rmp",
        interpret=True, emit_rasters=False)[1][-1])
    rows.append(emit("fig9_fused_net_10steps", us,
                     "whole-stack VMEM-resident V (see pipeline_fusion)"))
    return rows


if __name__ == "__main__":
    run()
