"""Fig. 9a + Table I supply columns: power and energy-efficiency per
instruction across operating points; wall time of the fused Pallas kernel for
the equivalent work (TPU-target path, interpret mode on CPU)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.core import energy
from repro.core.isa import InstrCount
from repro.kernels.fused_snn_step.ops import fused_snn_layer

PAPER_POINTS = {  # vdd -> (freq MHz, power mW, TOPS/W)
    "A(0.7V)": (66.67, 0.072, 0.91),
    "D(0.85V)": (200.0, 0.201, 0.99),
    "G(1.2V)": (500.0, 0.88, 0.57),
}


def run() -> list[str]:
    rows = []
    for pt in energy.OPERATING_POINTS:
        freq_mhz, p_mw, topsw = PAPER_POINTS[pt.name]
        rows.append(emit(
            f"fig9_point_{pt.name}", 1e6 / pt.freq_hz,
            f"freq={pt.freq_hz/1e6:.0f}MHz power={pt.power_w*1e3:.3f}mW "
            f"AccW2V={energy.tops_per_watt(pt):.2f}TOPS/W paper={topsw}"))
    # per-instruction efficiency at point D (Fig. 9a inset)
    d = energy.POINT_D
    for instr, topsw in energy.TOPS_W_D.items():
        e = energy.instr_energy_j(instr, d)
        rows.append(emit(f"fig9_instr_{instr}", 1e6 / d.freq_hz,
                         f"TOPS/W={topsw} E/op={e*1e12:.3f}pJ"))
    # the TPU-path equivalent: one fused timestep of a 128x128 layer
    rng = np.random.default_rng(0)
    spikes = jnp.asarray((rng.random((10, 8, 128)) < 0.15).astype(np.int8))
    wq = jnp.asarray(rng.integers(-31, 32, (128, 128)).astype(np.int8))
    us = time_call(lambda: fused_snn_layer(spikes, wq, threshold=60,
                                           neuron="rmp", interpret=True))
    events = int(np.asarray(spikes).sum())
    cnt = InstrCount(acc_w2v=2 * events, spike_check=2 * 8 * 10, acc_v2v=2 * 8 * 10)
    rows.append(emit("fig9_fused_kernel_10steps", us,
                     f"macro_energy={energy.sequence_energy_j(cnt)*1e9:.2f}nJ "
                     f"events={events}"))
    # the network-level fused kernel on the same work plus a second layer
    from repro.kernels.fused_snn_net.ops import fused_snn_net
    w2 = jnp.asarray(rng.integers(-31, 32, (128, 128)).astype(np.int8))
    us = time_call(lambda: fused_snn_net(
        spikes, [wq, w2], thresholds=(60,), leaks=(0,), neuron="rmp",
        interpret=True, emit_rasters=False)[1][-1])
    rows.append(emit("fig9_fused_net_10steps", us,
                     "whole-stack VMEM-resident V (see pipeline_fusion)"))
    return rows


if __name__ == "__main__":
    run()
