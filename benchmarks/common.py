"""Shared benchmark helpers: timing + the CSV row contract."""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_call(fn: Callable, *args, repeats: int = 5, warmup: int = 2) -> float:
    """Median wall time per call in microseconds (jit warmup excluded)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> str:
    row = f"{name},{us_per_call:.1f},{derived}"
    print(row)
    return row
